"""Jit'd wrappers for the flash-decode kernels (padding + dispatch) and
the KV-VQ decode-attention plan backends.

``flash_decode``/``flash_decode_paged`` serve fp caches. The KV-VQ
entry points (``flash_decode_kvq``/``flash_decode_kvq_paged``) consume
vector-quantized caches natively — uint8 codebook indices + per-(token,
head) scales + params-resident codebooks — and register two backends
with core/plan.py so the cost-ranked planner covers the new kernel:

  "kvq_dequant_jnp"  : reconstruct the fp cache through core.vq.kv_decode
                       then run the masked-softmax oracle (always
                       eligible for kind="kvq_attn"; the parity anchor).
  "kvq_flash_pallas" : the fused kernel — query/K-codebook dot table
                       computed once per step, indices streamed and
                       gathered in-kernel (impl="pallas" only).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.kernels.flash_decode.kernel import (flash_decode_kvq_pallas,
                                               flash_decode_pallas)
from repro.kernels.flash_decode.ref import (flash_decode_kvq_ref,
                                            flash_decode_ref)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret", "use_pallas"))
def flash_decode(
    q: jax.Array,        # (B, H, hd) or (B, 1, H, hd)
    k: jax.Array,        # (B, S, Hk, hd)
    v: jax.Array,
    lengths: jax.Array,  # (B,)
    *,
    block_s: int = 512,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    if not use_pallas:
        o = flash_decode_ref(q, k, v, lengths)
    else:
        B, S = k.shape[0], k.shape[1]
        bs = min(block_s, S)
        pad = (-S) % bs
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        o = flash_decode_pallas(q, k, v, lengths.astype(jnp.int32),
                                block_s=bs, interpret=interpret)
    return o[:, None] if squeeze else o


@functools.partial(jax.jit, static_argnames=("block_s", "interpret", "use_pallas"))
def flash_decode_paged(
    q: jax.Array,            # (B, H, hd) or (B, 1, H, hd)
    k_arena: jax.Array,      # (NB, bs, Hk, hd) shared block arena
    v_arena: jax.Array,
    block_table: jax.Array,  # (B, W) physical block ids (NB == sentinel)
    lengths: jax.Array,      # (B,)
    *,
    block_s: int = 512,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    """Flash decode over a paged KV cache (serve/paging.py): gather the
    per-request contiguous view through the block table, then run the
    unchanged kernel. ``W * bs`` equals the contiguous cache's time
    length by construction, so outputs are token-identical to
    ``flash_decode`` over the contiguous cache. Sentinel block ids clamp
    to in-bounds garbage masked by ``lengths`` (``mode="clip"`` — the
    default fill mode would inject NaN that survives masking)."""
    B, W = block_table.shape
    bs = k_arena.shape[1]
    k = jnp.take(k_arena, block_table, axis=0, mode="clip").reshape(
        (B, W * bs) + k_arena.shape[2:])
    v = jnp.take(v_arena, block_table, axis=0, mode="clip").reshape(
        (B, W * bs) + v_arena.shape[2:])
    return flash_decode(q, k, v, lengths, block_s=block_s,
                        interpret=interpret, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# KV-VQ decode attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("block_s", "interpret", "use_pallas"))
def flash_decode_kvq(
    q: jax.Array,        # (B, H, hd) or (B, 1, H, hd)
    k_idx: jax.Array,    # (B, S, Hk, R*G) uint8 codebook indices
    v_idx: jax.Array,    # (B, S, Hk, R*G) uint8
    k_s: jax.Array,      # (B, S, Hk) per-(token, head) scales
    v_s: jax.Array,      # (B, S, Hk)
    lengths: jax.Array,  # (B,)
    cb_k: jax.Array,     # (Hk, R, E, vd) K codebooks
    cb_v: jax.Array,     # (Hk, R, E, vd) V codebooks
    *,
    block_s: int = 512,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    """Decode attention directly over a vector-quantized KV cache.

    The EVA trick in reverse: the query is dotted against the K codebook
    ONCE per step (a (B, Hk, g, R*G, E) table — cost independent of S),
    the kernel gathers per-token scores from the uint8 indices, and V
    contributions are reconstructed from the V codebook after softmax
    weighting. ``use_pallas=False`` runs the dequantize oracle
    (``flash_decode_kvq_ref``) instead.

    Returns: attention output shaped like ``q`` (in q.dtype).
    """
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    if not use_pallas:
        o = flash_decode_kvq_ref(q, k_idx, v_idx, k_s, v_s, lengths,
                                 cb_k, cb_v)
        return o[:, None] if squeeze else o
    B, H, hd = q.shape
    Hk, R, E, vd = cb_k.shape
    G = hd // vd
    g = H // Hk
    S = k_idx.shape[1]
    qg = q.reshape(B, Hk, g, G, vd).astype(jnp.float32)
    qd = jnp.einsum("bkgcd,kred->bkgrce", qg, cb_k.astype(jnp.float32))
    qd = (qd / math.sqrt(hd)).reshape(B, Hk, g, R * G, E)
    bs = min(block_s, S)
    pad = (-S) % bs
    if pad:
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        pad3 = ((0, 0), (0, pad), (0, 0))
        k_idx = jnp.pad(k_idx, pad4)
        v_idx = jnp.pad(v_idx, pad4)
        k_s = jnp.pad(k_s, pad3)
        v_s = jnp.pad(v_s, pad3)
    o = flash_decode_kvq_pallas(
        qd, k_idx, v_idx, k_s.astype(jnp.float32), v_s.astype(jnp.float32),
        cb_v.astype(jnp.float32), lengths.astype(jnp.int32),
        out_dtype=q.dtype, block_s=bs, interpret=interpret)
    return o[:, None] if squeeze else o


@functools.partial(jax.jit,
                   static_argnames=("block_s", "interpret", "use_pallas"))
def flash_decode_kvq_paged(
    q: jax.Array,             # (B, H, hd) or (B, 1, H, hd)
    k_arena: jax.Array,       # (NB, bs, Hk, R*G) uint8 index arena
    v_arena: jax.Array,
    ks_arena: jax.Array,      # (NB, bs, Hk) scale arenas
    vs_arena: jax.Array,
    block_table: jax.Array,   # (B, W) physical block ids (NB == sentinel)
    lengths: jax.Array,       # (B,)
    cb_k: jax.Array,
    cb_v: jax.Array,
    *,
    block_s: int = 512,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    """KV-VQ flash decode over a paged index arena: gather the per-slot
    contiguous view (uint8 gathers — a fraction of the fp cache's
    traffic), then run ``flash_decode_kvq`` unchanged. Sentinel ids
    clamp to in-bounds garbage masked by ``lengths``."""
    B, W = block_table.shape
    bs = k_arena.shape[1]

    def gather(a):
        return jnp.take(a, block_table, axis=0, mode="clip").reshape(
            (B, W * bs) + a.shape[2:])

    return flash_decode_kvq(
        q, gather(k_arena), gather(v_arena), gather(ks_arena),
        gather(vs_arena), lengths, cb_k, cb_v,
        block_s=block_s, interpret=interpret, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# Plan backends (cost-ranked selection over kind="kvq_attn" sites)
# ---------------------------------------------------------------------------


def _kvq_idx_bytes(spec: plan_mod.LinearSpec) -> int:
    """Per-step compressed cache traffic: two uint8 index planes of
    (B, S, Hk, idx_width) plus two bf16 scale planes."""
    return (2 * spec.M * spec.K * spec.C * spec.V
            + 4 * spec.M * spec.K * spec.C)


def _match_kvq_jnp(spec: plan_mod.LinearSpec,
                   policy: plan_mod.PlanPolicy) -> bool:
    return spec.kind == "kvq_attn"


def _plan_kvq_jnp(spec: plan_mod.LinearSpec,
                  policy: plan_mod.PlanPolicy) -> plan_mod.MatmulPlan:
    def run(operands, _leaf):
        return flash_decode_kvq(*operands, use_pallas=False)

    # dequantize-then-attend: QK+PV macs over the reconstructed cache,
    # plus an HBM round trip for the two fp32 reconstructed planes
    cost = plan_mod.PlanCost(
        macs=2 * spec.M * spec.K * spec.N,
        lookup_adds=2 * spec.M * spec.K * spec.C * spec.V,
        weight_bytes=_kvq_idx_bytes(spec),
        intermediate_bytes=8 * spec.M * spec.K * spec.C * spec.d,
        launches=3,
    )
    return plan_mod.MatmulPlan("kvq_dequant_jnp", spec, policy, (),
                               cost, run)


def _match_kvq_pallas(spec: plan_mod.LinearSpec,
                      policy: plan_mod.PlanPolicy) -> bool:
    return spec.kind == "kvq_attn" and policy.impl == "pallas"


def _plan_kvq_pallas(spec: plan_mod.LinearSpec,
                     policy: plan_mod.PlanPolicy) -> plan_mod.MatmulPlan:
    interpret = policy.interpret

    def run(operands, _leaf):
        return flash_decode_kvq(*operands, interpret=interpret)

    # fused: the S-independent query/K-codebook table (N * E macs per
    # batch row) + per-token index gathers; intermediates are just the
    # qd table, not an S-length fp cache
    H = spec.N // spec.d
    cost = plan_mod.PlanCost(
        macs=spec.M * spec.N * spec.k,
        lookup_adds=spec.M * spec.K * (H + spec.C) * spec.V,
        weight_bytes=_kvq_idx_bytes(spec),
        intermediate_bytes=4 * spec.M * H * spec.V * spec.k,
        launches=1,
    )
    return plan_mod.MatmulPlan("kvq_flash_pallas", spec, policy, (),
                               cost, run)


plan_mod.register_backend("kvq_dequant_jnp", _match_kvq_jnp, _plan_kvq_jnp)
plan_mod.register_backend("kvq_flash_pallas", _match_kvq_pallas,
                          _plan_kvq_pallas)
