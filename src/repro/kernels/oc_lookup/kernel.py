"""Pallas TPU kernel for EVA Step 2: conflict-free output-codebook lookup
with add-only reduction (the paper's Epilogue Unit, Fig. 6).

  y[m, j] = scale[j] * sum_c sum_v O[c, m, v, I[c, v, j]]

TPU mapping of the paper's bank argument: the OC tile (C, M, bv, 2^n) is
VMEM-resident with the 2^n(=256) table axis on lanes; each sublane row `v`
owns its own table — the analogue of "one bank per OC row". The gather per
output tile is `take_along_axis` along the table axis and the reduction is
a pure add tree (no multipliers except the final per-channel scale, exactly
the paper's EU).

Grid: (num_n_tiles, num_v_tiles) with V innermost so the (M, bn) output
block stays resident in VMEM across the V accumulation (output-stationary,
matching Fig. 4's stationary output tile).

uint8 index-streaming contract: index tiles arrive in their storage
dtype (uint8 for n <= 8, int32 only for n > 8) and are upcast to int32
per tile INSIDE the kernel, so HBM->VMEM index traffic stays at the
paper's q bits/weight. Callers must not pre-widen I. For a grouped
projection family (shared codebook set, core/vq.py) N is the family's
summed width — the same OC tile serves every member's columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _oc_lookup_kernel(o_ref, i_ref, s_ref, y_ref, *, n_v_tiles: int):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    o = o_ref[...]                          # (C, M, bv, k) fp32
    idx = i_ref[...].astype(jnp.int32)      # (C, bv, bn) per-tile upcast
    g = jnp.take_along_axis(o, idx[:, None, :, :], axis=3)  # (C, M, bv, bn)
    y_ref[...] += g.sum(axis=(0, 2))        # add-only reduction

    @pl.when(v == n_v_tiles - 1)
    def _scale():
        y_ref[...] *= s_ref[...][None, :].astype(jnp.float32)


def oc_lookup_pallas(
    O: jax.Array,        # (C, M, V, k) fp32
    I: jax.Array,        # (C, V, N) uint8 (n<=8) or int32 (n>8)
    scale: jax.Array,    # (N,) fp32
    *,
    block_v: int = 32,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Returns y (M, N) fp32. V % block_v == 0 and N % block_n == 0
    (wrapper pads)."""
    C, M, V, k = O.shape
    C2, V2, N = I.shape
    assert (C, V) == (C2, V2), ((C, V), (C2, V2))
    assert V % block_v == 0 and N % block_n == 0, (V, block_v, N, block_n)
    n_v_tiles = V // block_v
    grid = (N // block_n, n_v_tiles)

    kernel = functools.partial(_oc_lookup_kernel, n_v_tiles=n_v_tiles)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, M, block_v, k), lambda n, v: (0, 0, v, 0)),
            pl.BlockSpec((C, block_v, block_n), lambda n, v: (0, v, n)),
            pl.BlockSpec((block_n,), lambda n, v: (n,)),
        ],
        out_specs=pl.BlockSpec((M, block_n), lambda n, v: (0, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(O, I, scale)
