"""Fig. 8: design-space exploration over the number of Epilogue Units.

Paper's finding: at 64 GB/s, 4 EUs (4x32 = 128 indices/cycle = DRAM rate)
saturate — latency flattens beyond 4 EUs while energy keeps rising.
"""
from __future__ import annotations

from benchmarks.accel_model import eva_cost, fc_layers
from repro.configs import get_config

EU_SWEEP = (1, 2, 4, 8, 16)


def run(report):
    cfg = get_config("llama2_7b")
    layers = fc_layers(cfg)
    rows = []
    for eu in EU_SWEEP:
        lat = sum(eva_cost(1, K, N, C=2, num_eu=eu).latency_s
                  for (K, N) in layers)
        en = sum(eva_cost(1, K, N, C=2, num_eu=eu).energy
                 for (K, N) in layers)
        rows.append((eu, lat, en))
        report(f"fig8/eu{eu}", lat * 1e6, f"energy_uJ={en*1e6:.1f}")
    # saturation check: 4 -> 8 EUs gains < 10%
    l4 = dict((e, l) for e, l, _ in rows)[4]
    l8 = dict((e, l) for e, l, _ in rows)[8]
    report("fig8/saturation_4to8", 0.0, f"gain={l4/l8:.3f}(paper: ~1.0)")
    return rows
