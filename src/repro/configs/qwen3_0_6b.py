"""Qwen3-0.6B — qk_norm, GQA [hf:Qwen/Qwen3-8B family; hf].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    vq_C=2,
)

SMOKE = ModelConfig(
    name="qwen3-0.6b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    qk_norm=True,
    rope_theta=1000000.0,
    vq_C=2,
)
