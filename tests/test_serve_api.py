"""serve/api.py: typed request surface validation, prefill buckets, and
the in-jit batched sampling/stopping math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import api
from repro.serve.api import (GenerationRequest, RequestOutput, SamplingParams,
                             StreamEvent, bucket_for, prefill_buckets,
                             sample_and_stop, sample_tokens)


class TestTypes:
    def test_sampling_params_validation(self):
        SamplingParams(greedy=False, temperature=0.5, top_k=10, top_p=0.9)
        with pytest.raises(ValueError, match="temperature"):
            SamplingParams(greedy=False, temperature=0.0)
        with pytest.raises(ValueError, match="top_k"):
            SamplingParams(top_k=-1)
        with pytest.raises(ValueError, match="top_p"):
            SamplingParams(top_p=0.0)
        with pytest.raises(ValueError, match="top_p"):
            SamplingParams(top_p=1.5)
        # greedy ignores the sampling knobs but still validates types
        SamplingParams(greedy=True, temperature=1.0)

    def test_generation_request_validation(self):
        r = GenerationRequest(prompt=[3, 4, 5], max_new_tokens=2,
                              eos_ids=(7,), stop_token_ids=(9, 7))
        assert r.prompt.dtype == np.int32 and r.prompt_len == 3
        assert r.stop_set == frozenset({7, 9})
        with pytest.raises(ValueError, match="at least one token"):
            GenerationRequest(prompt=np.zeros((0,), np.int32))
        with pytest.raises(ValueError, match="max_new_tokens"):
            GenerationRequest(prompt=[1], max_new_tokens=0)

    def test_stream_event_done(self):
        assert not StreamEvent(uid=1, index=0, token=5).done
        assert StreamEvent(uid=1, index=3, token=5,
                           finish_reason="stop").done

    def test_request_output(self):
        out = RequestOutput(uid=1, tokens=(1, 2, 3), finish_reason="stop",
                            decode_s=2.0)
        assert out.num_tokens == 3
        assert out.decode_tokens_per_s == pytest.approx(1.0)
        assert RequestOutput(uid=1, tokens=(5,), finish_reason="length"
                             ).decode_tokens_per_s == 0.0
        with pytest.raises(ValueError, match="finish_reason"):
            RequestOutput(uid=1, tokens=(), finish_reason="oom")


class TestBuckets:
    def test_power_of_two_ladder(self):
        assert prefill_buckets(256) == (8, 16, 32, 64, 128, 256)
        assert prefill_buckets(32) == (8, 16, 32)
        # non-power-of-two max_len is always its own (largest) bucket
        assert prefill_buckets(48) == (8, 16, 32, 48)
        assert prefill_buckets(6) == (6,)

    def test_bucket_for(self):
        b = prefill_buckets(32)
        assert bucket_for(1, b) == 8
        assert bucket_for(8, b) == 8
        assert bucket_for(9, b) == 16
        assert bucket_for(32, b) == 32
        with pytest.raises(ValueError, match="exceeds"):
            bucket_for(33, b)


def _state(B):
    return dict(
        keys=jnp.asarray(np.stack([np.asarray(jax.random.PRNGKey(i))
                                   for i in range(B)])),
        temperature=jnp.ones((B,), jnp.float32),
        top_k=jnp.zeros((B,), jnp.int32),
        top_p=jnp.ones((B,), jnp.float32),
        greedy=jnp.zeros((B,), bool),
    )


class TestSampling:
    def test_greedy_rows_exact_argmax(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.normal(size=(4, 33)).astype(np.float32))
        st = _state(4)
        st["greedy"] = jnp.asarray([True, False, True, False])
        tok, _ = sample_tokens(logits, **st)
        ref = np.argmax(np.asarray(logits), axis=-1)
        tok = np.asarray(tok)
        assert tok[0] == ref[0] and tok[2] == ref[2]

    def test_top_k_one_is_argmax(self):
        rng = np.random.default_rng(1)
        logits = jnp.asarray(rng.normal(size=(3, 20)).astype(np.float32))
        st = _state(3)
        st["top_k"] = jnp.full((3,), 1, jnp.int32)
        tok, _ = sample_tokens(logits, **st)
        np.testing.assert_array_equal(np.asarray(tok),
                                      np.argmax(np.asarray(logits), -1))

    def test_tiny_top_p_is_argmax(self):
        rng = np.random.default_rng(2)
        logits = jnp.asarray(rng.normal(size=(3, 20)).astype(np.float32))
        st = _state(3)
        st["top_p"] = jnp.full((3,), 1e-6, jnp.float32)
        tok, _ = sample_tokens(logits, **st)
        np.testing.assert_array_equal(np.asarray(tok),
                                      np.argmax(np.asarray(logits), -1))

    def test_top_k_mask_honored_over_draws(self):
        """With top_k=3, every draw lands in the 3 highest logits — the
        per-row mask really restricts the support."""
        rng = np.random.default_rng(3)
        logits = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
        top3 = np.argsort(np.asarray(logits), -1)[:, -3:]
        st = _state(2)
        st["top_k"] = jnp.full((2,), 3, jnp.int32)
        st["temperature"] = jnp.full((2,), 2.0, jnp.float32)  # flatten
        fn = jax.jit(sample_tokens)
        seen = set()
        for _ in range(64):
            tok, new_keys = fn(logits, **st)
            st["keys"] = new_keys
            tok = np.asarray(tok)
            for b in range(2):
                assert tok[b] in top3[b], (tok[b], top3[b])
                seen.add((b, int(tok[b])))
        assert len(seen) > 2  # it does sample, not argmax

    def test_per_slot_streams_independent_and_deterministic(self):
        rng = np.random.default_rng(4)
        logits = jnp.asarray(rng.normal(size=(3, 32)).astype(np.float32))
        st = _state(3)
        t1, k1 = sample_tokens(logits, **st)
        t2, _ = sample_tokens(logits, **st)
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
        # keys advance -> next draw differs from a frozen-key redraw
        assert not np.array_equal(np.asarray(k1), np.asarray(st["keys"]))

    def test_mixed_params_are_data_single_trace(self):
        """All knobs are arrays: one jit trace covers every combination."""
        traces = {"n": 0}

        def f(logits, **st):
            traces["n"] += 1
            return sample_tokens(logits, **st)

        jf = jax.jit(f)
        rng = np.random.default_rng(5)
        logits = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
        for tk, tp, g in [(0, 1.0, True), (5, 0.9, False), (1, 0.5, False)]:
            st = _state(2)
            st["top_k"] = jnp.full((2,), tk, jnp.int32)
            st["top_p"] = jnp.full((2,), tp, jnp.float32)
            st["greedy"] = jnp.full((2,), g)
            jf(logits, **st)
        assert traces["n"] == 1


class TestSampleAndStop:
    def test_stop_budget_and_masking(self):
        B, V = 4, 8
        # logits force tok = 5 on every row
        logits = jnp.tile(jax.nn.one_hot(5, V)[None] * 50.0, (B, 1))
        st = _state(B)
        st["greedy"] = jnp.ones((B,), bool)
        stop_ids = jnp.full((B, api.MAX_STOP_IDS), -1, jnp.int32)
        stop_ids = stop_ids.at[1, 0].set(5)          # row 1 stops on 5
        remaining = jnp.asarray([4, 4, 1, 4], jnp.int32)  # row 2 out of budget
        active = jnp.asarray([True, True, True, False])   # row 3 inactive
        tok, done, bad, _ = sample_and_stop(
            logits, stop_ids=stop_ids, remaining=remaining, active=active,
            **st)
        tok, done, bad = np.asarray(tok), np.asarray(done), np.asarray(bad)
        np.testing.assert_array_equal(tok, [5, 5, 5, 0])  # inactive masked
        np.testing.assert_array_equal(done, [False, True, True, False])
        # finite logits: no lane is flagged by the numerics check
        np.testing.assert_array_equal(bad, [False] * 4)

    def test_nonfinite_logits_flag_bad_not_done(self):
        """The in-jit numerics quarantine mask: a NaN/Inf row flags bad
        (active lanes only) and is masked OUT of done — one readback, one
        disposition per lane. Healthy lanes are untouched."""
        B, V = 4, 8
        logits = jnp.tile(jax.nn.one_hot(5, V)[None] * 50.0, (B, 1))
        logits = logits.at[1, 3].set(jnp.nan)    # active + poisoned
        logits = logits.at[2, 0].set(jnp.inf)    # inactive + poisoned
        st = _state(B)
        st["greedy"] = jnp.ones((B,), bool)
        stop_ids = jnp.full((B, api.MAX_STOP_IDS), -1, jnp.int32)
        stop_ids = stop_ids.at[1, 0].set(5)      # would stop — but it's bad
        remaining = jnp.asarray([4, 1, 4, 4], jnp.int32)
        active = jnp.asarray([True, True, False, True])
        tok, done, bad, _ = sample_and_stop(
            logits, stop_ids=stop_ids, remaining=remaining, active=active,
            **st)
        done, bad = np.asarray(done), np.asarray(bad)
        np.testing.assert_array_equal(bad, [False, True, False, False])
        # the bad lane never reports done (stop hit AND budget exhausted
        # there) — the engine quarantines it off the bad mask instead
        np.testing.assert_array_equal(done, [False, False, False, False])
        # bystander lanes' tokens are unaffected by the poisoned row
        assert int(np.asarray(tok)[0]) == 5 and int(np.asarray(tok)[3]) == 5


class TestSamplingEdges:
    """Epilogue edge cases the speculative verify loop leans on: the
    temperature floor's greedy degeneracy and exact key-stream
    reproducibility when state is rebuilt from scratch."""

    def test_temperature_to_zero_degenerates_to_greedy(self):
        # SamplingParams rejects temperature=0.0 at the API boundary, but
        # the in-jit math clamps at 1e-6 — a near-zero temperature must
        # sharpen the categorical into the argmax, matching greedy lanes
        rng = np.random.default_rng(7)
        logits = jnp.asarray(rng.normal(size=(4, 24)).astype(np.float32))
        st = _state(4)
        st["temperature"] = jnp.full((4,), 1e-6, jnp.float32)
        tok, _ = sample_tokens(logits, **st)
        st2 = _state(4)
        st2["greedy"] = jnp.ones((4,), bool)
        ref, _ = sample_tokens(logits, **st2)
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(ref))

    def test_seeded_stream_reproducible_across_restarts(self):
        """Rebuilding the key state from the same seeds (a process
        restart) replays the identical top-k/top-p token stream."""
        rng = np.random.default_rng(8)
        logit_seq = [jnp.asarray(rng.normal(size=(3, 24)).astype(np.float32))
                     for _ in range(6)]

        def run():
            st = _state(3)
            st["top_k"] = jnp.full((3,), 5, jnp.int32)
            st["top_p"] = jnp.full((3,), 0.9, jnp.float32)
            st["temperature"] = jnp.full((3,), 1.1, jnp.float32)
            out = []
            for logits in logit_seq:
                tok, st["keys"] = sample_tokens(logits, **st)
                out.append(np.asarray(tok).tolist())
            return out

        assert run() == run()

    def test_token_logprobs_are_log_softmax_at_token(self):
        rng = np.random.default_rng(9)
        logits = jnp.asarray(rng.normal(size=(3, 24)).astype(np.float32))
        tok = jnp.asarray([0, 5, 23], jnp.int32)
        lp = np.asarray(api.token_logprobs(logits, tok))
        ref = np.asarray(jax.nn.log_softmax(logits, axis=-1))
        np.testing.assert_allclose(
            lp, ref[np.arange(3), np.asarray(tok)], rtol=1e-6)
        assert (lp <= 0.0).all()
