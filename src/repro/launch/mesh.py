"""Production mesh construction.

Single pod:  (data=16, model=16)           = 256 chips (one v5e pod slice)
Multi pod:   (pod=2, data=16, model=16)    = 512 chips

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: Optional[int] = None) -> Mesh:
    """Mesh over whatever devices exist locally (tests)."""
    n = len(jax.devices())
    data = data if data is not None else n // model
    return jax.make_mesh((data, model), ("data", "model"))
