"""KV-VQ cache subsystem (core/vq.py + serve/kvcache.py + paging +
kernels/flash_decode): encode/decode round-trip geometry, kernel parity
against the dequantize oracle (contiguous AND paged), planner backend
registration/ranking, per-family logit-drift bounds vs the fp cache,
paged-vs-contiguous byte identity of the uint8 index arenas, and
engine-level token identity at 4-bit on a mixed workload."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import plan as plan_mod
from repro.core.plan import PlanPolicy
from repro.core.quantize import attach_kv_codebooks, kv_codebook_tree
from repro.core.vq import (KVQuantConfig, kv_decode, kv_encode,
                           kv_grid_codebooks)
from repro.kernels.flash_decode import (flash_decode_kvq,
                                        flash_decode_kvq_paged,
                                        flash_decode_kvq_ref)
from repro.models import build_model
from repro.models.common import RunConfig
from repro.serve import (BlockPool, Engine, EngineConfig, GenerationRequest,
                         SamplingParams, make_paging_config)
from repro.serve import paging
from repro.serve.kvcache import encode_prefill_cache, pad_prefill_cache

KEY = jax.random.PRNGKey(0)
CAP = 32


# ------------------------------------------------------------- encode/decode


class TestEncodeDecode:
    @pytest.mark.parametrize("kv_bits,residual", [(4, 1), (4, 2), (2, 1)])
    def test_geometry_and_roundtrip_error(self, kv_bits, residual):
        """Index width follows R*G = R*dim/vec_d; grid reconstruction
        error is bounded by half a lattice cell per stage (activations
        are scale-normalized into [-1, 1] before assignment)."""
        kvq = KVQuantConfig(kv_bits=kv_bits, residual=residual)
        Hk, hd = 2, 8
        assert kvq.vec_d * kv_bits == 8 * residual
        assert kvq.idx_width(hd) == residual * (hd // kvq.vec_d)
        cb = kv_grid_codebooks(Hk, hd, kvq)
        assert cb.shape == (Hk, residual, 256, kvq.vec_d)
        x = jax.random.normal(KEY, (3, 7, Hk, hd), jnp.float32)
        idx, scale = kv_encode(x, cb, kvq.variant)
        assert idx.shape == (3, 7, Hk, kvq.idx_width(hd))
        assert idx.dtype == jnp.uint8 and scale.shape == (3, 7, Hk)
        xhat = kv_decode(idx, scale, cb)
        err = jnp.max(jnp.abs(xhat - x) / jnp.maximum(
            jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8))
        levels = int(round(256 ** (1.0 / kvq.vec_d)))
        # finest stage cell half-width, relative to the scale channel
        # (+eps: greedy residual assignment lands exactly on the bound)
        bound = (1.0 / (levels - 1)) * levels ** (1 - residual)
        assert float(err) <= bound * (1 + 1e-5) + 1e-6

    def test_config_validation(self):
        with pytest.raises(ValueError, match="kv_bits"):
            KVQuantConfig(kv_bits=3)
        with pytest.raises(ValueError, match="entries"):
            KVQuantConfig(entries=512)
        with pytest.raises(ValueError):
            KVQuantConfig(variant="nope")


# ------------------------------------------------------------- kernel level


def _kvq_operands(kvq, *, B=2, S=24, Hk=2, g=2, hd=8):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hk * g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, hd), jnp.float32)
    cb_k = kv_grid_codebooks(Hk, hd, kvq)
    cb_v = kv_grid_codebooks(Hk, hd, kvq)
    k_idx, k_s = kv_encode(k, cb_k, kvq.variant)
    v_idx, v_s = kv_encode(v, cb_v, kvq.variant)
    lengths = jnp.array([S, S - 7], jnp.int32)
    return q, k_idx, v_idx, k_s, v_s, lengths, cb_k, cb_v


class TestKernel:
    @pytest.mark.parametrize("kv_bits,residual", [(4, 1), (4, 2), (2, 1)])
    def test_pallas_matches_dequant_oracle(self, kv_bits, residual):
        """The fused kernel (query/K-codebook table + in-kernel index
        gathers + post-softmax V reconstruction) reproduces
        dequantize-then-flash-decode."""
        kvq = KVQuantConfig(kv_bits=kv_bits, residual=residual)
        ops = _kvq_operands(kvq)
        ref = flash_decode_kvq_ref(*ops)
        out = flash_decode_kvq(*ops, block_s=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_partial_tail_block_masked(self):
        """lengths beyond the last full S-block: the online-softmax mask
        must zero pad positions, not just pad rows of the final block."""
        kvq = KVQuantConfig(kv_bits=4)
        q, k_idx, v_idx, k_s, v_s, _, cb_k, cb_v = _kvq_operands(kvq, S=24)
        lengths = jnp.array([1, 17], jnp.int32)
        ref = flash_decode_kvq_ref(q, k_idx, v_idx, k_s, v_s, lengths,
                                   cb_k, cb_v)
        out = flash_decode_kvq(q, k_idx, v_idx, k_s, v_s, lengths,
                               cb_k, cb_v, block_s=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_paged_matches_contiguous(self):
        """Scatter the index/scale planes into block arenas; the paged
        entry (uint8 gathers through the table) is bit-equivalent to the
        contiguous call, sentinel ids included."""
        kvq = KVQuantConfig(kv_bits=4)
        B, S, bs = 2, 24, 8
        q, k_idx, v_idx, k_s, v_s, lengths, cb_k, cb_v = _kvq_operands(
            kvq, B=B, S=S)
        W = S // bs
        NB = B * W  # sentinel == NB
        table = jnp.arange(B * W, dtype=jnp.int32).reshape(B, W)
        table = table.at[1, -1].set(NB)  # short row: last block unmapped

        def scatter(x):
            arena = jnp.zeros((NB + 1, bs) + x.shape[2:], x.dtype)
            return arena.at[:NB].set(
                x.reshape((B * W, bs) + x.shape[2:]))[:NB]

        lengths = jnp.array([S, bs], jnp.int32)
        out = flash_decode_kvq_paged(
            q, scatter(k_idx), scatter(v_idx), scatter(k_s), scatter(v_s),
            table, lengths, cb_k, cb_v, block_s=16, interpret=True)
        ref = flash_decode_kvq(q, k_idx, v_idx, k_s, v_s, lengths,
                               cb_k, cb_v, block_s=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


class TestPlanBackends:
    def _spec(self):
        kvq = KVQuantConfig(kv_bits=4)
        return plan_mod.kvq_attention_spec(
            B=2, S=CAP, H=4, Hk=2, hd=8, idx_width=kvq.idx_width(8),
            entries=kvq.entries, x_dtype=jnp.float32, out_dtype=jnp.float32)

    def test_backend_selection_by_policy(self):
        """kind="kvq_attn" resolves to the dequantize oracle under jnp
        and to the fused kernel under impl="pallas" — cost ranking
        prefers the single-launch table+gather formulation."""
        spec = self._spec()
        assert plan_mod.plan(spec, PlanPolicy()).backend == "kvq_dequant_jnp"
        pl = plan_mod.plan(spec, PlanPolicy(impl="pallas", interpret=True))
        assert pl.backend == "kvq_flash_pallas"

    def test_execute_matches_direct_call(self):
        kvq = KVQuantConfig(kv_bits=4)
        ops = _kvq_operands(kvq, S=CAP)
        ref = flash_decode_kvq_ref(*ops)
        for policy in (PlanPolicy(), PlanPolicy(impl="pallas",
                                                interpret=True)):
            pl = plan_mod.plan(self._spec(), policy)
            out = pl.execute(ops, None)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- model level


KVQ_ARCHS = ["llama2_7b", "mixtral_8x22b", "deepseek_v2_lite_16b"]


def _fp32_cfg(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    if cfg.num_experts:
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.num_experts) / cfg.top_k)
    return cfg


def _family_setup(arch, kvq):
    cfg = _fp32_cfg(arch)
    model = build_model(cfg)
    params = attach_kv_codebooks(model.init(KEY), cfg, kvq)
    return cfg, model, params, kv_codebook_tree(params)


def _prefill_pair(cfg, model, params, cbs, kvq, S):
    """One fp prefill -> (fp cache, KV-VQ-encoded cache), both padded to
    decode capacity."""
    window = cfg.sliding_window or cfg.local_window
    tokens = jax.random.randint(KEY, (1, S + 8), 0, cfg.vocab_size)
    rc_p = RunConfig(mode="prefill", remat=False, attn_chunk=8)
    _, fresh = model.prefill(params, {"tokens": tokens[:, :S]}, rc_p)
    enc = encode_prefill_cache(fresh, cbs, kvq)
    return (tokens, window, fresh, enc,
            pad_prefill_cache(fresh, CAP, window=window),
            pad_prefill_cache(enc, CAP, window=window))


@pytest.mark.parametrize("arch", KVQ_ARCHS)
def test_kvvq_decode_drift_vs_fp(arch):
    """Accuracy drift per family (dense/SWA/MLA): greedy decode over the
    4-bit VQ cache stays within a pinned max-logit deviation of the fp
    cache on a fixed prompt (observed ~0.8 on the smoke models; the
    bound is 3x slack, catching quantizer/kernel regressions, not noise).
    """
    kvq = KVQuantConfig(kv_bits=4)
    cfg, model, params, cbs = _family_setup(arch, kvq)
    S, N = 12, 3
    tokens, _, _, _, cont_fp, cont_q = _prefill_pair(
        cfg, model, params, cbs, kvq, S)
    rc_fp = RunConfig(mode="decode", remat=False)
    rc_q = RunConfig(mode="decode", remat=False, kv_vq=kvq)
    drift = 0.0
    for t in range(S, S + N):
        pos = jnp.full((1, 1), t, jnp.int32)
        lf, cont_fp = model.decode(params, tokens[:, t:t + 1], pos,
                                   cont_fp, rc_fp)
        lq, cont_q = model.decode(params, tokens[:, t:t + 1], pos,
                                  cont_q, rc_q)
        assert bool(jnp.all(jnp.isfinite(lq)))
        drift = max(drift, float(jnp.max(jnp.abs(lq - lf))))
    assert drift < 2.5, f"{arch}: 4-bit logit drift {drift} exceeds bound"


@pytest.mark.parametrize("arch", KVQ_ARCHS)
def test_kvvq_paged_decode_matches_contiguous(arch):
    """Paged KV-VQ decode (uint8 arenas + block tables) reproduces the
    contiguous VQ cache's logits for every family."""
    kvq = KVQuantConfig(kv_bits=4)
    cfg, model, params, cbs = _family_setup(arch, kvq)
    S, N = 12, 3
    tokens, window, _, enc, _, cont_q = _prefill_pair(
        cfg, model, params, cbs, kvq, S)
    meta = make_paging_config(model, 1, CAP, window=window, block_size=4,
                              kvq=kvq)
    paged = paging.init_paged_cache(model, 1, CAP, meta, kvq=kvq)
    pool = BlockPool(meta.num_blocks)
    row = np.asarray(pool.alloc(meta.blocks_per_slot), np.int32)
    paged = paging.write_prefill_into_blocks(
        paged, enc, 0, row, jnp.asarray(S, jnp.int32), meta, window=window)
    paged = paging.set_block_tables(paged, row[None])
    rc_q = RunConfig(mode="decode", remat=False, kv_vq=kvq)
    for t in range(S, S + N):
        pos = jnp.full((1, 1), t, jnp.int32)
        lc, cont_q = model.decode(params, tokens[:, t:t + 1], pos,
                                  cont_q, rc_q)
        lp, paged = model.decode(params, tokens[:, t:t + 1], pos,
                                 paged, rc_q)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lc),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["llama2_7b", "deepseek_v2_lite_16b"])
def test_kvvq_index_arena_byte_identity(arch):
    """The paged uint8 index arenas, gathered through the block table,
    are byte-identical to the contiguous index cache — same codes, same
    scales, only the memory layout differs. (The quantizer runs before
    the layout split, so any divergence is a scatter/paging bug.)"""
    kvq = KVQuantConfig(kv_bits=4)
    cfg, model, params, cbs = _family_setup(arch, kvq)
    S = 12
    _, window, _, enc, _, cont_q = _prefill_pair(
        cfg, model, params, cbs, kvq, S)
    meta = make_paging_config(model, 1, CAP, window=window, block_size=4,
                              kvq=kvq)
    paged = paging.init_paged_cache(model, 1, CAP, meta, kvq=kvq)
    pool = BlockPool(meta.num_blocks)
    row = np.asarray(pool.alloc(meta.blocks_per_slot), np.int32)
    paged = paging.write_prefill_into_blocks(
        paged, enc, 0, row, jnp.asarray(S, jnp.int32), meta, window=window)

    checked = []

    def walk(pnode, cnode, path):
        if not isinstance(pnode, dict):
            return
        if "block_table" not in pnode:
            for k in pnode:
                walk(pnode[k], cnode[k], path + (k,))
            return
        for k, arena in pnode.items():
            if k in ("block_table", "len"):
                continue
            a = np.asarray(arena)          # (L, NB, bs, ...)
            cont = np.asarray(cnode[k])    # (L, 1, S_cap, ...)
            view = a[:, row].reshape((a.shape[0], CAP) + a.shape[3:])
            assert np.array_equal(view, cont[:, 0]), (path, k)
            checked.append((path, k, str(a.dtype)))

    walk(paged, cont_q, ())
    kinds = {dt for _, _, dt in checked}
    assert "uint8" in kinds and "bfloat16" in kinds  # indices AND scales


# -------------------------------------------------------------- engine level


@pytest.fixture(scope="module")
def setup():
    cfg = _fp32_cfg("llama2_7b")
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params, RunConfig(mode="decode", remat=False,
                                         attn_chunk=16)


def _mixed_requests(cfg, lengths, max_new=6):
    rng = np.random.default_rng(7)
    reqs = []
    for i, L in enumerate(lengths):
        prompt = rng.integers(0, cfg.vocab_size, int(L)).astype(np.int32)
        sp = SamplingParams()
        if i % 3 == 1:
            sp = SamplingParams(greedy=False, temperature=0.8, top_k=20,
                                seed=100 + i)
        reqs.append(GenerationRequest(prompt=prompt, max_new_tokens=max_new,
                                      sampling=sp))
    return reqs


def _drain(eng, uids, limit=400):
    for _ in range(limit):
        eng.step()
        if all(eng.output(u) is not None and eng.output(u).finish_reason
               for u in uids):
            return [list(eng.output(u).tokens) for u in uids]
    raise AssertionError("engine did not drain")


def test_engine_kvvq_token_identity_mixed_workload(setup):
    """4-bit engine end-to-end on a mixed greedy/sampled workload:
    paged and contiguous arenas produce identical token streams (the
    acceptance gate — quantization happens before the layout split)."""
    cfg, model, params, rc = setup
    lengths = [5, 9, 3, 12]
    outs = {}
    for paged in (False, True):
        eng = Engine(model, params, rc,
                     EngineConfig(num_slots=2, max_len=CAP, kv_bits=4,
                                  paged=paged))
        uids = [eng.submit(r) for r in _mixed_requests(cfg, lengths)]
        outs[paged] = _drain(eng, uids)
    assert outs[False] == outs[True]
    assert all(len(t) > 0 for t in outs[False])


def test_engine_kvvq_2bit_runs(setup):
    """2-bit cache (vec_d=4 grid): engine completes and emits tokens —
    accuracy is not pinned at 2 bits, liveness and layout are."""
    cfg, model, params, rc = setup
    eng = Engine(model, params, rc,
                 EngineConfig(num_slots=2, max_len=CAP, kv_bits=2))
    uids = [eng.submit(r) for r in _mixed_requests(cfg, [4, 7], max_new=3)]
    toks = _drain(eng, uids)
    assert all(len(t) == 3 for t in toks)


def test_engine_kv_bits_validation(setup):
    cfg, model, params, rc = setup
    with pytest.raises(ValueError, match="kv_bits"):
        Engine(model, params, rc,
               EngineConfig(num_slots=1, max_len=CAP, kv_bits=3))


def test_engine_mla_int8_rejected():
    """int8 per-channel KV is a GQA layout; MLA latents only support
    fp16/fp32 or KV-VQ — the engine refuses the combination loudly."""
    cfg = _fp32_cfg("deepseek_v2_lite_16b")
    model = build_model(cfg)
    params = model.init(KEY)
    rc = RunConfig(mode="decode", remat=False, attn_chunk=16)
    with pytest.raises(ValueError):
        Engine(model, params, rc,
               EngineConfig(num_slots=1, max_len=CAP, kv_bits=8))
