from repro.roofline.analysis import (
    RooflineReport, analyze_compiled, model_flops,
    PEAK_FLOPS, HBM_BW, LINK_BW,
)
from repro.roofline.hlo import analyze, parse_hlo, HloCosts
