"""Tbl. VIII: throughput / efficiency comparison at the accelerator level.

Effective throughput = delivered GEMV ops / time for batch-1 decode of the
LLaMA-2-7B FC stack. Paper: SA 15.75 GOPs (1.00x), ANT 0.97x, FIGNA 0.94x,
FIGLUT 2.82x, EVA 31.64x.
"""
from __future__ import annotations

from benchmarks.accel_model import fc_layers, model_decode_cost
from repro.configs import get_config

PAPER = {"SA": 1.00, "ANT": 0.97, "FIGNA": 0.94, "FIGLUT": 2.82, "EVA": 31.64}


def run(report):
    cfg = get_config("llama2_7b")
    ops = 2 * sum(K * N for K, N in fc_layers(cfg)) * cfg.num_layers
    rows = []
    base = None
    for arch in ["SA", "ANT", "FIGNA", "FIGLUT", "EVA"]:
        c = model_decode_cost(arch, cfg, batch=1, bits=2)
        gops = ops / c.latency_s / 1e9
        base = base or gops
        rows.append((arch, gops, gops / base))
        report(f"tbl8/{arch}", c.latency_s * 1e6,
               f"GOPs={gops:.2f};ratio={gops/base:.2f};paper={PAPER[arch]:.2f}")
    return rows
