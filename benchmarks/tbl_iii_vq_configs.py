"""Tbl. III: EVA latency across VQ configurations (d, n, C) on LLaMA-2-7B.

Paper's finding: latency ~ q = n*C/d when 2^n < N; PE:EU balance flips to
PE-bound at n=12 (2^n = N) and collapses at n=16.
"""
from __future__ import annotations

from benchmarks.accel_model import eva_cost, fc_layers
from repro.configs import get_config

# (label, d, n, C, paper_norm_latency)
CONFIGS = [
    ("AQLM 2x8", 8, 8, 2, 1.00),
    ("AQLM 3x8", 8, 8, 3, 1.49),
    ("AQLM 2x12", 8, 12, 2, 2.96),
    ("AQLM 4x8", 8, 8, 4, 1.98),
    ("AQLM 1x16", 8, 16, 1, 22.86),
    ("GPTVQ-4D", 4, 8, 1, 4.17),
]


def run(report):
    cfg = get_config("llama2_7b")
    layers = fc_layers(cfg)

    def latency(d, n, C, N_override=None):
        total = 0.0
        for (K, N) in layers:
            N_eff = N_override or N
            total += eva_cost(1, K, N_eff, d=d, n=n, C=C).latency_s
        return total

    base = latency(8, 8, 2)
    rows = []
    for label, d, n, C, paper in CONFIGS:
        N_over = 256 if label == "GPTVQ-4D" else None
        lat = latency(d, n, C, N_over)
        # GPTVQ-4D shares a codebook per 256 output channels: the OC GEMM
        # repeats per group -> scale by N/256 groups
        if label == "GPTVQ-4D":
            groups = sum(N for _, N in layers) / (256 * len(layers))
            lat = sum(
                eva_cost(1, K, 256, d=4, n=8, C=1).latency_s * (N / 256)
                for (K, N) in layers
            )
        norm = lat / base
        rows.append((label, norm, paper))
        report(f"tbl3/{label.replace(' ', '_')}", lat * 1e6,
               f"norm={norm:.2f};paper={paper:.2f}")
    return rows
