"""Request-level serving package: engine, typed API, paged KV memory,
scheduling, metrics and resilience (see docs/ARCHITECTURE.md section 2 for the
request lifecycle)."""
from repro.serve.api import (GenerationRequest, RequestEvicted, RequestOutput,
                             SamplingParams, StreamEvent)
from repro.serve.engine import Engine, EngineConfig
from repro.serve.kvcache import pad_prefill_cache, cache_bytes
from repro.serve.metrics import EngineMetrics
from repro.serve.paging import (BlockPool, PagingConfig, blocks_for_len,
                                gather_block_view, init_contiguous_cache,
                                init_paged_cache, make_paging_config,
                                paged_cache_specs)
from repro.serve.resilience import (CircuitBreaker, EngineSnapshot, FaultPlan,
                                    FaultSpec, InjectedFault,
                                    serve_with_restarts)
from repro.serve.scheduler import QueueFull, Scheduler, TrackedRequest
