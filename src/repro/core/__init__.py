"""Core quantization + execution-planning package: weight/KV vector
quantization (vq), fused ops, and the cost-ranked matmul planner (plan).
"""
from repro.core.vq import VQWeight, fit_vq, dequantize, synthetic_vq, vq_specs
from repro.core.ops import (
    eva_matmul, dequant_matmul, fp_matmul, int8_matmul, vq_matmul,
    compute_output_codebook, compute_collapse_ratio,
)
from repro.core.plan import (
    LinearSpec, MatmulPlan, PlanPolicy, Planner, default_planner,
    register_backend, registered_backends,
)
# repro.core.quantize imports repro.models (circular via this __init__);
# import it directly: `from repro.core.quantize import quantize_params`.
