"""Llama-3-8B — GQA, 128k vocab [arXiv:2407.21783].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    vq_C=2,
)

SMOKE = ModelConfig(
    name="llama3-8b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=448,
    vocab_size=512,
    rope_theta=500000.0,
    vq_C=2,
)
