"""Tiny-shape bench smoke for CI: real timed executions in seconds, not
minutes, emitting the SAME calibration-ready row structure as the full
`measured` module so `benchmarks/schema.py` can gate the JSON contract
on every push (plan= + backend= + cost fields per row; interpret rows
flagged; ranked rows reporting ranking= and first_match=).

The numbers themselves are throwaway (tiny shapes, shared CI runners) —
only the row SHAPE is load-bearing here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ops as core_ops
from repro.core import plan as plan_mod
from repro.core.vq import synthetic_vq
from benchmarks.measured import _plan, _time, plan_fields


def run(report):
    key = jax.random.PRNGKey(0)
    K, N = 128, 96
    vq = synthetic_vq(key, K, N, d=8, n=8, C=2)

    # jnp regimes: direct at M=1, recon at M>=d — same auto policy CI
    # users hit, tiny shapes
    for M in (1, 16):
        x = jax.random.normal(key, (M, K), jnp.float32)
        t_eva = _time(jax.jit(core_ops.eva_matmul), x, vq, iters=3, warmup=1)
        t_deq = _time(jax.jit(core_ops.dequant_matmul), x, vq, iters=3,
                      warmup=1)
        report(f"smoke/eva_m{M}_{K}x{N}", t_eva * 1e6,
               f"dequant_us={t_deq*1e6:.0f};{plan_fields(_plan(x, vq))}")
        report(f"smoke/dequant_m{M}_{K}x{N}", t_deq * 1e6,
               plan_fields(_plan(x, vq, vq_mode="dequant")))

    # ranked Pallas path (interpret): fused vs split candidates priced by
    # the Planner; the row records the decision + what first-match would
    # have picked
    x1 = jax.random.normal(key, (1, K), jnp.float32)
    pl = plan_mod.plan_vq(x1, vq, plan_mod.PlanPolicy(
        vq_mode="eva", impl="pallas", interpret=True))
    t_pal = _time(pl.execute, x1, vq, iters=2, warmup=1)
    report(f"smoke/pallas_ranked_interpret_{K}x{N}", t_pal * 1e6,
           f"interpret-mode;{plan_fields(pl)}")
