"""Unit/property tests for the attention and recurrence primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.common import blocked_attention, decode_attention
from repro.models.rglru import causal_conv1d, rg_lru
from repro.models.xlstm import mlstm_chunkwise, mlstm_sequential

KEY = jax.random.PRNGKey(0)


def _naive_attention(q, k, v, causal, window=0):
    B, Sq, H, hd = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    qf = np.asarray(q, np.float64)
    kf = np.asarray(k, np.float64)
    vf = np.asarray(v, np.float64)
    out = np.zeros((B, Sq, H, vf.shape[-1]))
    for h in range(H):
        hk = h // g
        s = np.einsum("bqd,bkd->bqk", qf[:, :, h], kf[:, :, hk]) / np.sqrt(hd)
        qpos = np.arange(Sq)[:, None]
        kpos = np.arange(Skv)[None, :]
        mask = np.ones((Sq, Skv), bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = np.where(mask[None], s, -1e30)
        p = np.exp(s - s.max(-1, keepdims=True))
        p = p / p.sum(-1, keepdims=True)
        out[:, :, h] = np.einsum("bqk,bkd->bqd", p, vf[:, :, hk])
    return out


class TestBlockedAttention:
    @settings(max_examples=10, deadline=None)
    @given(
        Sq=st.integers(1, 24), H=st.sampled_from([2, 4]),
        Hk=st.sampled_from([1, 2]), chunk=st.sampled_from([4, 8, 64]),
        causal=st.booleans(), window=st.sampled_from([0, 5]),
        seed=st.integers(0, 1000),
    )
    def test_matches_naive(self, Sq, H, Hk, chunk, causal, window, seed):
        if window and not causal:
            causal = True  # window implies causal in our models
        key = jax.random.PRNGKey(seed)
        hd = 8
        q = jax.random.normal(key, (2, Sq, H, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (2, Sq, Hk, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (2, Sq, Hk, hd))
        got = blocked_attention(q, k, v, causal=causal, window=window,
                                chunk=chunk)
        ref = _naive_attention(q, k, v, causal, window)
        np.testing.assert_allclose(np.asarray(got), ref, rtol=2e-4, atol=2e-4)

    def test_skip_oob_chunks_equivalent(self):
        """The triangular-schedule optimization changes FLOPs, not values."""
        q = jax.random.normal(KEY, (2, 32, 4, 8))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 32, 2, 8))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 32, 2, 8))
        for window in (0, 8):
            base = blocked_attention(q, k, v, causal=True, window=window,
                                     chunk=8, skip_oob_chunks=False)
            opt = blocked_attention(q, k, v, causal=True, window=window,
                                    chunk=8, skip_oob_chunks=True)
            np.testing.assert_allclose(np.asarray(base), np.asarray(opt),
                                       rtol=1e-5, atol=1e-5)

    def test_skip_oob_reduces_flops(self):
        from repro.roofline.hlo import analyze

        q = jax.ShapeDtypeStruct((1, 64, 4, 8), jnp.float32)
        kv = jax.ShapeDtypeStruct((1, 64, 2, 8), jnp.float32)

        def run(skip):
            fn = lambda q_, k_, v_: blocked_attention(
                q_, k_, v_, causal=True, chunk=8, skip_oob_chunks=skip)
            # trip-count-aware FLOPs (cost_analysis visits scan bodies once)
            return analyze(jax.jit(fn).lower(q, kv, kv).compile().as_text()).flops

        # triangular schedule: ~(n+1)/2n of the full sweep (n=8 chunks)
        assert run(True) < 0.7 * run(False)

    def test_decode_attention_ring_vs_full(self):
        """Ring decode == full-cache decode restricted to the window."""
        B, S, Hk, hd, H, W = 1, 16, 1, 8, 2, 8
        full_k = jax.random.normal(KEY, (B, S, Hk, hd))
        full_v = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hk, hd))
        q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, 1, H, hd))
        # ring holds positions S-W..S-1 at slots p % W
        pos = np.array([S - W + ((s - (S - W)) % W) for s in range(W)])
        ring_k, ring_v = full_k[:, pos], full_v[:, pos]
        got = decode_attention(q, ring_k, ring_v,
                               jnp.full((B,), S, jnp.int32), window=W, ring=True)
        ref = _naive_attention(q, full_k[:, S - W:], full_v[:, S - W:],
                               causal=False)
        np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-4, atol=1e-4)


class TestMLSTM:
    @settings(max_examples=8, deadline=None)
    @given(S=st.integers(3, 40), chunk=st.sampled_from([4, 8, 16]),
           seed=st.integers(0, 100))
    def test_chunkwise_equals_sequential(self, S, chunk, seed):
        key = jax.random.PRNGKey(seed)
        B, H, hd = 2, 2, 4
        ks = jax.random.split(key, 6)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, H, hd))
        v = jax.random.normal(ks[2], (B, S, H, hd))
        li = jax.random.normal(ks[3], (B, S, H)) * 2
        lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) * 2)
        st0 = {"C": jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1,
               "n": jnp.abs(jax.random.normal(ks[0], (B, H, hd))),
               "m": jnp.zeros((B, H))}
        o_seq, s_seq = mlstm_sequential(q, k, v, li, lf, st0)
        o_chk, s_chk = mlstm_chunkwise(q, k, v, li, lf, st0, chunk=chunk)
        np.testing.assert_allclose(np.asarray(o_seq), np.asarray(o_chk),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_seq["C"]), np.asarray(s_chk["C"]),
                                   rtol=1e-4, atol=1e-4)


class TestRGLRU:
    def test_associative_scan_equals_loop(self):
        B, S, dr = 2, 17, 8
        ks = jax.random.split(KEY, 5)
        y = jax.random.normal(ks[0], (B, S, dr))
        r = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, dr)))
        i = jax.nn.sigmoid(jax.random.normal(ks[2], (B, S, dr)))
        lam = jax.random.normal(ks[3], (dr,))
        h0 = jax.random.normal(ks[4], (B, dr))
        hs, h_last = rg_lru(y, r, i, lam, h0)
        # python-loop oracle
        import math
        a = np.exp(-8.0 * np.log1p(np.exp(np.asarray(lam)))[None, None]
                   * np.asarray(r))
        gated = np.sqrt(np.maximum(1 - a * a, 1e-12)) * (np.asarray(i) * np.asarray(y))
        h = np.asarray(h0)
        for t in range(S):
            h = a[:, t] * h + gated[:, t]
            np.testing.assert_allclose(np.asarray(hs[:, t]), h, rtol=2e-4,
                                       atol=2e-4)
        np.testing.assert_allclose(np.asarray(h_last), h, rtol=2e-4, atol=2e-4)

    def test_causal_conv_decode_matches_prefill(self):
        B, S, dr, W = 1, 10, 4, 4
        y = jax.random.normal(KEY, (B, S, dr))
        cw = jax.random.normal(jax.random.fold_in(KEY, 1), (W, dr)) * 0.5
        cb = jnp.zeros((dr,))
        full, _ = causal_conv1d(y, cw, cb)
        buf = jnp.zeros((B, W - 1, dr))
        outs = []
        for t in range(S):
            o, buf = causal_conv1d(y[:, t:t + 1], cw, cb, buf)
            outs.append(o)
        step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                                   rtol=1e-5, atol=1e-5)
