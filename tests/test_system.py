"""End-to-end behaviour tests for the full system: train a small model to
convergence on the synthetic task, serve it quantized, and check the
framework-level invariants tie together."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import serve
from repro.launch.train import train
from repro.models import build_model
from repro.models.common import RunConfig


def test_train_learns_synthetic_task(tmp_path):
    """The affine next-token task is learnable: loss falls well below the
    uniform baseline ln(V)."""
    out = train("qwen3-0.6b", smoke=True, steps=40, seq_len=32,
                global_batch=8, lr=3e-3, ckpt_dir=str(tmp_path),
                ckpt_every=20, log_every=0)
    losses = [out["losses"][s] for s in sorted(out["losses"])]
    v = get_smoke_config("qwen3-0.6b").vocab_size
    assert losses[0] > 0.8 * np.log(v)
    assert losses[-1] < 0.6 * losses[0], (losses[0], losses[-1])


def test_serve_end_to_end_vq():
    out = serve("llama2-7b", smoke=True, requests=4, max_new=5,
                num_slots=2, vq_mode="eva", quantize=True)
    assert len(out["results"]) == 4
    assert all(len(v) == 5 for v in out["results"].values())


@pytest.mark.slow  # ~106 s: the slowest tier-1 offender; the fast serve
# smoke above keeps end-to-end engine coverage in every run
def test_quantize_then_serve_trained_model(tmp_path):
    """The full paper pipeline: train dense -> VQ-quantize -> EVA decode.
    The quantized model's decode stays close to the dense model on a
    trained (structured) network."""
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"), dtype="float32")
    model = build_model(cfg)
    # train briefly so the weights have structure
    from repro.data import DataConfig, global_batch_at
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    params = model.init(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=3e-3)
    opt = adamw_init(params, ocfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=24, global_batch=8)
    rc = RunConfig(mode="train", remat=False, attn_chunk=8)
    for step in range(15):
        batch = {k: jnp.asarray(v) for k, v in global_batch_at(dcfg, step).items()}
        grads = jax.grad(lambda p: model.loss(p, batch, rc))(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)

    qparams = model.quantize(params, method="fit", key=jax.random.PRNGKey(1))
    batch = {k: jnp.asarray(v) for k, v in global_batch_at(dcfg, 99).items()}
    dense_loss = float(model.loss(params, batch, rc))
    vq_loss = float(model.loss(
        qparams, batch, rc.replace_policy(vq_mode="eva")))
    # C=2 (2-bit) quantization degrades, but the model must stay usable
    # (paper Tbl. V: VQ keeps 2-bit models functional where RTN collapses)
    assert np.isfinite(vq_loss)
    assert vq_loss < np.log(cfg.vocab_size) * 1.2
    assert dense_loss <= vq_loss
