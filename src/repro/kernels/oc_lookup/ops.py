"""Jit'd wrapper for the OC-lookup kernel (padding + dtype handling)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.oc_lookup.kernel import oc_lookup_pallas
from repro.kernels.oc_lookup.ref import oc_lookup_ref


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_n", "interpret", "use_pallas")
)
def oc_lookup(
    O: jax.Array,
    I: jax.Array,
    scale: jax.Array,
    *,
    block_v: int = 32,
    block_n: int = 512,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    C, M, V, k = O.shape
    N = I.shape[-1]
    # indices stream in their storage dtype (uint8 for n<=8); the kernel
    # upcasts per tile — see the uint8 streaming contract in kernel.py
    scale = scale.astype(jnp.float32)
    if not use_pallas:
        return oc_lookup_ref(O, I, scale)

    bv = min(block_v, V)
    bn = min(block_n, N)
    pad_v = (-V) % bv
    pad_n = (-N) % bn
    if pad_v:
        # padded rows gather index 0 from zeroed O rows -> contribute 0
        O = jnp.pad(O, ((0, 0), (0, 0), (0, pad_v), (0, 0)))
        I = jnp.pad(I, ((0, 0), (0, pad_v), (0, 0)))
    if pad_n:
        I = jnp.pad(I, ((0, 0), (0, 0), (0, pad_n)))
        scale = jnp.pad(scale, (0, pad_n))
    y = oc_lookup_pallas(O, I, scale, block_v=bv, block_n=bn, interpret=interpret)
    if pad_n:
        y = y[:, :N]
    return y
