"""Gradient compression for cross-pod all-reduce: int8 symmetric
quantization with error feedback (EF-SGD style).

At multi-pod scale the inter-pod (DCN/ICI "pod" axis) reduce dominates
collective time. We quantize each gradient leaf to int8 with a per-leaf
fp32 scale, psum the int8 payload in int32, dequantize, and keep the
quantization residual in an error-feedback buffer added back next step —
preserving convergence (the compression error is compensated, not lost).

Used inside shard_map over the 'pod' axis (runtime/sharding.py wires it);
the intra-pod reduce stays full-precision.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_psum(grads: Any, ef: Any, axis_name: str) -> Tuple[Any, Any]:
    """Quantized mean-reduce over `axis_name` with error feedback.

    grads/ef: pytrees (fp32 leaves). Returns (reduced_grads, new_ef).
    Must be called inside shard_map/pmap with `axis_name` bound.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(g32)
        deq_local = q.astype(jnp.float32) * scale
        new_e = g32 - deq_local                       # residual kept locally
        # Semantically each device contributes an int8 payload + fp32 scale;
        # XLA has no mixed-scale int8 all-reduce, so the HLO carries the
        # dequantized values — the quantization/EF *numerics* are exact and
        # the roofline accounts wire bytes at the int8 ratio (DESIGN.md §5).
        red = jax.lax.psum(deq_local, axis_name) / n
        return red.astype(g.dtype), new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        tdef.unflatten([o[0] for o in outs]),
        tdef.unflatten([o[1] for o in outs]),
    )


def init_error_feedback(grads_spec: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), grads_spec
    )


def compression_ratio(grads: Any) -> float:
    """Bytes on the wire vs fp32: int8 payload + one fp32 scale per leaf."""
    total = sum(x.size for x in jax.tree_util.tree_leaves(grads))
    nleaves = len(jax.tree_util.tree_leaves(grads))
    return (total * 1 + nleaves * 4) / (total * 4)
