"""Model-level quantization pass: eligibility, structure, compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.quantize import (
    compressed_model_bytes, count_vq_layers, quantize_params,
)
from repro.core.vq import VQWeight
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _params(arch="llama2_7b"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


class TestEligibility:
    def test_fc_layers_quantized_embeddings_not(self):
        cfg, model, params = _params()
        q = quantize_params(params, cfg, method="synthetic", key=KEY)
        assert count_vq_layers(q) > 0
        # embedding and lm_head stay dense
        assert "emb" in q["embedding"]
        assert "w" in q["lm_head"]
        # same-input projection families grouped into single wide leaves
        wqkv = q["layers"]["attn"]["wqkv"]["vq"]
        assert isinstance(wqkv, VQWeight)
        assert wqkv.splits == (cfg.q_dim, cfg.kv_dim, cfg.kv_dim)
        assert wqkv.N == cfg.q_dim + 2 * cfg.kv_dim
        gu = q["layers"]["mlp"]["gu"]["vq"]
        assert isinstance(gu, VQWeight)
        assert gu.splits == (cfg.d_ff, cfg.d_ff)
        assert isinstance(q["layers"]["mlp"]["down"]["vq"], VQWeight)
        assert q["layers"]["mlp"]["down"]["vq"].splits == ()
        # norms untouched
        assert "g" in q["final_norm"]

    def test_moe_experts_quantized_router_not(self):
        cfg, model, params = _params("mixtral_8x22b")
        q = quantize_params(params, cfg, method="synthetic", key=KEY)
        moe = q["layers"]["moe"]
        # expert gate+up grouped into one wide leaf per expert
        assert isinstance(moe["experts"]["gu"]["vq"], VQWeight)
        assert len(moe["experts"]["gu"]["vq"].splits) == 2
        assert "wr" in moe["router"]  # router stays dense
        # stacked leading dims preserved: (L, E, C, V, N)
        assert moe["experts"]["gu"]["vq"].idx.ndim == 5

    def test_gates_and_recurrence_not_quantized(self):
        cfg, model, params = _params("xlstm_125m")
        q = quantize_params(params, cfg, method="synthetic", key=KEY)
        g0 = q["groups"]["b0_mlstm"]
        assert "w" in g0["w_if"]          # per-head gates stay dense
        # mLSTM wq/wk/wv (same input h) grouped into one wide leaf
        assert "wq" not in g0
        wqkv = g0["wqkv"]["vq"]
        assert isinstance(wqkv, VQWeight)
        di = 2 * cfg.d_model
        assert wqkv.splits == (di, di, di)
        g1 = q["groups"]["b1_slstm"]
        assert "rz" in g1                  # recurrent weights untouched
        assert "wqkv" not in g1            # sLSTM wz/wi/wf/wo never grouped

    def test_mla_q_kva_grouped(self):
        cfg, model, params = _params("deepseek_v2_lite_16b")
        q = quantize_params(params, cfg, method="synthetic", key=KEY)
        for block in (q["layers"]["attn"], q["pre_layers"]["attn"]):
            assert "wq" not in block and "wkv_a" not in block
            vq = block["wq_kva"]["vq"]
            assert isinstance(vq, VQWeight)
            assert vq.splits == (
                cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim),
                cfg.kv_lora_rank + cfg.qk_rope_dim,
            )
            # wkv_b / wo stay independent leaves
            assert isinstance(block["wkv_b"]["vq"], VQWeight)
            assert block["wkv_b"]["vq"].splits == ()


class TestStructure:
    def test_idempotent(self):
        cfg, model, params = _params()
        q1 = quantize_params(params, cfg, method="synthetic", key=KEY)
        q2 = quantize_params(q1, cfg, method="synthetic", key=KEY)
        assert count_vq_layers(q1) == count_vq_layers(q2)

    def test_specs_mode_matches_synthetic_structure(self):
        cfg, model, params = _params()
        spec_tree = quantize_params(jax.eval_shape(lambda: params), cfg,
                                    method="specs")
        syn_tree = quantize_params(params, cfg, method="synthetic", key=KEY)
        s_leaves = jax.tree_util.tree_leaves(spec_tree)
        y_leaves = jax.tree_util.tree_leaves(syn_tree)
        assert len(s_leaves) == len(y_leaves)
        for s, y in zip(s_leaves, y_leaves):
            assert s.shape == y.shape and s.dtype == y.dtype

    def test_compression_ratio(self):
        cfg, model, params = _params()
        q = quantize_params(params, cfg, method="synthetic", key=KEY)
        vq_bytes, dense_bytes = compressed_model_bytes(q)
        # q = C*n/d = 2 bits/weight vs bf16 -> ~1/8 (+ codebook overhead,
        # large on smoke-size layers)
        assert vq_bytes < dense_bytes * 0.5
        assert vq_bytes > dense_bytes * 0.1

    def test_fit_matches_dequant_quality(self):
        """fit on real weights reconstructs better than synthetic junk."""
        from repro.core.vq import dequantize
        cfg, model, params = _params()
        cfg2 = dataclasses.replace(cfg, vq_n=6)
        qf = quantize_params(params, cfg2, method="fit", key=KEY)
        vq = qf["layers"]["mlp"]["gu"]["vq"]      # grouped [W_gate|W_up]
        W = np.concatenate(
            [np.asarray(params["layers"]["mlp"]["gate"]["w"]),
             np.asarray(params["layers"]["mlp"]["up"]["w"])], axis=-1,
        )  # (L, K, 2*d_ff)
        assert vq.splits == (cfg.d_ff, cfg.d_ff)
        errs = []
        for l in range(W.shape[0]):
            wl = W[l]
            vql = VQWeight(idx=vq.idx[l], codebooks=vq.codebooks[l],
                           scale=vq.scale[l], K=vq.K, N=vq.N, d=vq.d, n=vq.n)
            w_hat = np.asarray(dequantize(vql))
            errs.append(np.linalg.norm(wl - w_hat) / np.linalg.norm(wl))
        assert max(errs) < 0.9  # random-gaussian bound; structured << this
