"""Baseline Pallas kernel: conventional VQ decode (paper Fig. 1(b)).

Reconstructs dequantized weight tiles in VMEM from (I, B) — the full
'1-to-many' centroid gather EVA eliminates — then multiplies. Per output
tile the kernel moves d x more gathered bytes than the OC lookup and
spends M*K*N MACs instead of M*K*2^n; it exists to expose that contrast
in the benchmarks (and as the memory-traffic-faithful baseline).

Grid: (num_n_tiles, num_v_tiles), V innermost, output-stationary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dequant_gemv_kernel(x_ref, cb_ref, i_ref, s_ref, y_ref, *, n_v_tiles: int):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    C = cb_ref.shape[0]
    M, bv, d = x_ref.shape
    bn = i_ref.shape[2]

    idx = i_ref[...].astype(jnp.int32)          # (C, bv, bn) per-tile upcast
    # centroid gather: w[v, j, :] = sum_c cb[c, idx[c,v,j], :]
    w = jnp.zeros((bv, bn, d), jnp.float32)
    for c in range(C):
        w = w + jnp.take(cb_ref[c].astype(jnp.float32), idx[c], axis=0)
    w = w.transpose(0, 2, 1).reshape(bv * d, bn)  # (bv*d, bn)
    x = x_ref[...].astype(jnp.float32).reshape(M, bv * d)
    y_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(v == n_v_tiles - 1)
    def _scale():
        y_ref[...] *= s_ref[...][None, :].astype(jnp.float32)


def dequant_gemv_pallas(
    x: jax.Array,          # (M, V, d)
    codebooks: jax.Array,  # (C, k, d)  NOTE: centroid-major layout
    I: jax.Array,          # (C, V, N) uint8 (n<=8) or int32 (n>8)
    scale: jax.Array,      # (N,)
    *,
    block_v: int = 32,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, V, d = x.shape
    C, k, d2 = codebooks.shape
    N = I.shape[-1]
    assert d == d2 and I.shape[:2] == (C, V)
    assert V % block_v == 0 and N % block_n == 0
    n_v_tiles = V // block_v
    grid = (N // block_n, n_v_tiles)

    kernel = functools.partial(_dequant_gemv_kernel, n_v_tiles=n_v_tiles)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, block_v, d), lambda n, v: (0, v, 0)),
            pl.BlockSpec((C, k, d), lambda n, v: (0, 0, 0)),
            pl.BlockSpec((C, block_v, block_n), lambda n, v: (0, v, n)),
            pl.BlockSpec((block_n,), lambda n, v: (n,)),
        ],
        out_specs=pl.BlockSpec((M, block_n), lambda n, v: (0, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, codebooks, I, scale)
