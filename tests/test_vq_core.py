"""Core VQ properties: quantizer behaviour and the EVA reformulation's
exactness (paper: 'preserving arithmetic precision after VQ')."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ops
from repro.core.vq import (
    VQWeight, dequantize, fit_vq, kmeans, reconstruction_error, synthetic_vq,
    vq_specs,
)


class TestKMeans:
    def test_assignment_is_nearest(self):
        key = jax.random.PRNGKey(0)
        pts = jax.random.normal(key, (256, 4))
        cents, assign = kmeans(key, pts, 16, iters=10)
        d2 = np.sum((np.asarray(pts)[:, None] - np.asarray(cents)[None]) ** 2, -1)
        np.testing.assert_array_equal(np.asarray(assign), d2.argmin(1))

    def test_no_dead_centroids_on_clusterable_data(self):
        key = jax.random.PRNGKey(1)
        centers = jax.random.normal(key, (8, 4)) * 10
        pts = centers[jax.random.randint(key, (512,), 0, 8)]
        pts += 0.01 * jax.random.normal(key, (512, 4))
        cents, assign = kmeans(key, pts, 8, iters=25)
        assert len(np.unique(np.asarray(assign))) == 8
        assert np.all(np.isfinite(np.asarray(cents)))


class TestFitVQ:
    def test_residual_error_decreases_with_C(self):
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (128, 96)) * 0.1
        errs = []
        for C in (1, 2, 3):
            vq = fit_vq(key, W, d=8, n=6, C=C, kmeans_iters=8, refine_rounds=0)
            errs.append(float(reconstruction_error(W, vq)))
        assert errs[0] > errs[1] > errs[2], errs

    def test_structured_weights_compress_well(self):
        # weights drawn from a small set of prototype vectors -> near-exact
        key = jax.random.PRNGKey(2)
        protos = jax.random.normal(key, (16, 8))
        idx = jax.random.randint(key, (64 // 8 * 48,), 0, 16)
        W = protos[idx].reshape(8, 48, 8).transpose(0, 2, 1).reshape(64, 48)
        vq = fit_vq(key, W, d=8, n=4, C=1, kmeans_iters=25, refine_rounds=0)
        # per-column scaling keeps this from being exactly 16 prototypes,
        # but structured weights compress far better than gaussian (~0.73)
        assert float(reconstruction_error(W, vq)) < 0.12

    def test_shapes_and_dtypes(self):
        key = jax.random.PRNGKey(0)
        vq = fit_vq(key, jnp.ones((64, 32)), d=8, n=8, C=2, kmeans_iters=2)
        assert vq.idx.shape == (2, 8, 32) and vq.idx.dtype == jnp.uint8
        assert vq.codebooks.shape == (2, 8, 256)
        assert vq.scale.shape == (32,)
        assert vq.bits_per_weight == 2.0

    def test_compressed_bytes_ratio(self):
        vq = synthetic_vq(jax.random.PRNGKey(0), 4096, 4096, d=8, n=8, C=2)
        dense_bf16 = 4096 * 4096 * 2
        ratio = vq.compressed_bytes() / dense_bf16
        # 2 bits/weight vs 16 -> ~1/8 plus codebook/scale overhead
        assert 0.12 < ratio < 0.14, ratio


class TestEquivalence:
    """EVA matmul == dequantized matmul (the paper's core exactness claim)."""

    @settings(max_examples=12, deadline=None)
    @given(
        V=st.integers(2, 12),
        N=st.integers(3, 50),
        M=st.integers(1, 5),
        d=st.sampled_from([4, 8]),
        n=st.sampled_from([2, 4, 8]),
        C=st.integers(1, 4),
        seed=st.integers(0, 2 ** 16),
    )
    def test_eva_equals_dequant(self, V, N, M, d, n, C, seed):
        key = jax.random.PRNGKey(seed)
        K = V * d
        vq = synthetic_vq(key, K, N, d=d, n=n, C=C)
        x = jax.random.normal(jax.random.fold_in(key, 1), (M, K))
        y_eva = ops.eva_matmul(x, vq, block_v=5)
        y_deq = ops.dequant_matmul(x, vq)
        np.testing.assert_allclose(np.asarray(y_eva), np.asarray(y_deq),
                                   rtol=2e-4, atol=2e-5)

    def test_eva_with_fitted_weights(self):
        key = jax.random.PRNGKey(3)
        W = jax.random.normal(key, (64, 48)) * 0.3
        vq = fit_vq(key, W, d=8, n=5, C=2, kmeans_iters=6, refine_rounds=1)
        x = jax.random.normal(key, (3, 64))
        np.testing.assert_allclose(
            np.asarray(ops.eva_matmul(x, vq)),
            np.asarray(ops.dequant_matmul(x, vq)),
            rtol=1e-4, atol=1e-5,
        )

    def test_output_codebook_shape(self):
        vq = synthetic_vq(jax.random.PRNGKey(0), 64, 32, d=8, n=4, C=3)
        O = ops.compute_output_codebook(jnp.ones((5, 64)), vq)
        assert O.shape == (3, 5, 8, 16)


class TestComputeCollapse:
    """Paper §III-B advantage 3: VQ-GEMM needs N/2^n x fewer MACs."""

    def test_ratio(self):
        assert ops.compute_collapse_ratio(4096, 8) == 16.0

    def test_mac_counts(self):
        M, K, N, d, n, C = 1, 4096, 4096, 8, 8, 2
        gemv = ops.gemv_macs(M, K, N)
        vqg = ops.vq_gemm_macs(M, K, n, C, d)
        # per codebook: K*2^n; two codebooks -> ratio N/(C*2^n)
        assert gemv / vqg == N / (C * 2 ** n)

    def test_hlo_flops_collapse(self):
        """The compiled OC GEMM really is independent of N."""
        key = jax.random.PRNGKey(0)
        x = jnp.ones((1, 512))
        small = synthetic_vq(key, 512, 256, d=8, n=8, C=1)
        big = synthetic_vq(key, 512, 4096, d=8, n=8, C=1)
        f_small = jax.jit(ops.compute_output_codebook).lower(x, small).compile()
        f_big = jax.jit(ops.compute_output_codebook).lower(x, big).compile()

        def flops(f):
            ca = f.cost_analysis()
            if isinstance(ca, (list, tuple)):  # older jax returns [dict]
                ca = ca[0]
            return ca["flops"]

        assert flops(f_small) == flops(f_big)


class TestInt8:
    def test_int8_matmul_close(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (4, 128))
        w = jax.random.normal(key, (128, 64)) * 0.1
        y = ops.int8_matmul(x, w)
        ref = np.asarray(x) @ np.asarray(w)
        rel = np.abs(np.asarray(y) - ref).max() / np.abs(ref).max()
        assert rel < 0.03

    def test_quantize_int8_range(self):
        q, s = ops.quantize_int8(jnp.linspace(-3, 3, 128)[None])
        assert q.dtype == jnp.int8
        assert int(jnp.max(jnp.abs(q))) == 127


class TestSpecs:
    def test_vq_specs_match_synthetic(self):
        spec = vq_specs(128, 64, d=8, n=8, C=2)
        real = synthetic_vq(jax.random.PRNGKey(0), 128, 64, d=8, n=8, C=2)
        for s, r in zip(jax.tree_util.tree_leaves(spec),
                        jax.tree_util.tree_leaves(real)):
            assert s.shape == r.shape and s.dtype == r.dtype
