"""Fig. 12/13 + Tbl. IX: end-to-end latency on real-dataset length
distributions (prefill INT8 + decode VQ, per-phase accounting).

Paper's findings: Dolly is decode-heavy (>80% of time in decode for all
architectures) -> EVA e2e speedup 8.2x-24.49x; on prefill-heavy Arxiv the
gain shrinks to 1.13x-2.28x; on decode-heavy GSM8K 5.01x-18.92x.
"""
from __future__ import annotations

from benchmarks.accel_model import model_decode_cost, model_prefill_cost
from repro.configs import get_config

# Tbl. IX mean lengths
DATASETS = {
    "dolly": ("llama2_7b", 22.25, 246.87),
    "arxiv": ("mixtral_8x22b", 8575.45, 227.08),
    "gsm8k": ("mixtral_8x22b", 66.03, 126.79),
}
BASELINES = ["SA", "ANT", "FIGNA", "FIGLUT"]


def _e2e(arch, cfg, in_len, out_len, bits=2):
    pre = model_prefill_cost(arch, cfg, tokens=int(in_len), bits=bits)
    dec = model_decode_cost(arch, cfg, batch=1, bits=bits)
    total = pre.latency_s + dec.latency_s * out_len
    return pre.latency_s, dec.latency_s * out_len, total


def run(report):
    rows = []
    for ds, (model, in_len, out_len) in DATASETS.items():
        cfg = get_config(model)
        _, _, eva_total = _e2e("EVA", cfg, in_len, out_len)
        pre_e, dec_e, _ = _e2e("EVA", cfg, in_len, out_len)
        report(f"fig12/{ds}/EVA", eva_total * 1e6,
               f"decode_share={dec_e/eva_total:.2f}")
        sps = []
        for b in BASELINES:
            pre, dec, total = _e2e(b, cfg, in_len, out_len)
            sp = total / eva_total
            sps.append(sp)
            rows.append((ds, b, sp, dec / total))
            report(f"fig12/{ds}/{b}", total * 1e6,
                   f"e2e_speedup={sp:.2f};decode_share={dec/total:.2f}")
        expected = {"dolly": "8.2-24.5", "arxiv": "1.13-2.28",
                    "gsm8k": "5.01-18.92"}[ds]
        report(f"fig12/{ds}/speedup_range", 0.0,
               f"got={min(sps):.2f}-{max(sps):.2f};paper={expected}")
    return rows
