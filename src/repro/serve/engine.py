"""Event-driven continuous-batching serving engine.

The EVA deployment shape (paper §V-C / Fig. 7(c)): prefill runs per-request
(INT8 GEMM path), decode runs as one batched step over all active slots so
every streamed weight-index tile is reused across requests. Slots free up
as requests finish and queued requests are admitted with a fresh prefill —
classic continuous batching, expressed with jit-stable shapes (fixed slot
count, fixed cache capacity).

Request-level surface (serve/api.py types):

  uid = engine.submit(GenerationRequest(...))   # admission-checked
  events = engine.step()                        # one tick -> StreamEvents
  for ev in engine.stream(uid): ...             # per-request iterator
  engine.generate(prompts, n)                   # greedy batch convenience
  engine.metrics()                              # counters snapshot

Sampling and stopping run INSIDE the jitted decode step with jit-stable
shapes: per-slot PRNG keys, temperature/top-k/top-p, stop-token sets and
budgets are all device arrays of fixed (num_slots, ...) shape, so a
mixed-sampling workload traces the decode step exactly ONCE and the host
loop only reads back a ``(next_tok, done_mask)`` pair.

Prefill is length-BUCKETED for attention families: prompts right-pad
(edge mode — the pad value is causally masked) to power-of-two buckets,
the true length rides along as a traced scalar, and the jitted prefill
step retraces at most once per bucket instead of once per prompt length.
Families whose prefill is not padding-invariant (recurrent state
integrates pad tokens: xlstm/rglru; MoE capacity-drop routing depends on
the token count: moe) run exact-length prefill instead.

All caches are batched on axis 1 (axis 0 is the scanned layer/group axis),
so slot insertion is a tree-wide dynamic_update_slice at index b.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import time
from collections import deque
from typing import Any, Deque, Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt_manager
from repro.core import plan as plan_mod
from repro.models.api import Model
from repro.models.common import RunConfig
from repro.runtime.fault_tolerance import StepWatchdog
from repro.serve import api
from repro.serve import paging
from repro.serve import speculative
from repro.serve.api import (GenerationRequest, RequestEvicted, RequestOutput,
                             SamplingParams, StreamEvent)
from repro.serve.kvcache import (cache_bytes, encode_prefill_cache,
                                 pad_prefill_cache,
                                 quantize_prefill_cache_int8)
from repro.serve.metrics import EngineMetrics
from repro.serve.resilience import (CircuitBreaker, EngineSnapshot, FaultPlan,
                                    InjectedFault)
from repro.serve.scheduler import QueueFull, Scheduler, TrackedRequest

log = logging.getLogger(__name__)

# families whose prefill output is invariant to causal right-padding
# (pure-attention stacks); recurrent state (xlstm/rglru) integrates pad
# tokens and MoE capacity-based routing depends on the total token count,
# so those families prefill at exact prompt length
_BUCKETABLE_FAMILIES = ("dense", "whisper", "vision")


def _insert_slot(batched: Any, single: Any, b: int) -> Any:
    """Write a single-request cache (batch size 1 at axis 1) into slot b of
    the batched cache tree."""

    def one(dst, src):
        idx = [0] * dst.ndim
        idx[1] = b
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), tuple(idx))

    return jax.tree_util.tree_map(one, batched, single)


@dataclasses.dataclass
class EngineConfig:
    num_slots: int = 4
    max_len: int = 256
    max_queue: int = 256               # submit() rejects past this bound
    prefill_bucketing: bool = True     # pad prompts to power-of-two buckets
    min_prefill_bucket: int = 8
    # finished RequestOutputs (+ their undrained event buffers) retained
    # for output()/stream(); oldest evicted past this bound so a
    # long-running submit()/step() server stays memory-bounded
    max_retained: int = 1024
    # ---- resilience (serve/resilience.py) ----
    # queued requests older than this time out at the admission sweep
    # (finish_reason="timeout") — per-request deadline_s is checked too
    queue_ttl_s: Optional[float] = None
    # stream() raises RuntimeError after this long without yielding an
    # event (replaces the old 1,000,000-iteration guard with wall clock)
    stream_stall_s: float = 60.0
    # >= breaker_k CONSECUTIVE poisoned decode steps trip the engine
    # unhealthy: pending requests reject cleanly, submits refuse
    breaker_k: int = 3
    # decode steps slower than threshold x rolling median are stragglers
    # (runtime/fault_tolerance.StepWatchdog -> metrics straggler_steps)
    straggler_threshold: float = 3.0
    # scripted fault schedule for tests/chaos drills; None in production
    fault_plan: Optional[FaultPlan] = None
    # ---- paged KV memory (serve/paging.py) ----
    # paged=True swaps the per-slot contiguous cache for shared block
    # arenas + per-slot block tables: admission allocates blocks for the
    # prompt, decode grows one block at a time, finish recycles — so
    # memory tracks ACTUAL sequence lengths and an out-of-blocks decode
    # step preempts the youngest request back to the queue instead of
    # failing
    paged: bool = False
    block_size: int = 16               # tokens per block (gcd-snapped)
    # pool size; None -> num_slots * blocks_per_slot (contiguous parity)
    num_blocks: Optional[int] = None
    # chunked prefill: prompts longer than this admit as several engine
    # ticks (one bucketed chunk each) interleaved with decode; None
    # disables. Only effective for paged + bucketed attention families
    # with window == 0 and no MLA (the continuation path's support set)
    prefill_chunk: Optional[int] = None
    # ---- compressed KV (core/vq.py, serve/kvcache.py) ----
    # bits per stored KV channel: 16 = fp, 8 = int8 k_s/v_s layout,
    # 4/2 = KV-VQ (uint8 codebook indices; codebooks attach to params).
    # Prefill caches are encoded EXPLICITLY before slot insertion;
    # chunked prefill is gated off below 16 (the continuation path
    # cannot append into quantized leaves)
    kv_bits: int = 16
    # ---- speculative decoding (serve/speculative.py) ----
    # K > 0 turns every batched decode step into a K-draft verify
    # window: up to K+1 tokens emit per slot per step, streams stay
    # token-identical to K=0 (the acceptance rule replays the exact
    # sampling epilogue). Requires window == 0, an attention family
    # (dense/moe is how caches append; MoE capacity routing depends on
    # the token count, so only dense keeps bit-identity) and no MLA.
    # Per-request opt-out: GenerationRequest.speculate=False
    speculate_k: int = 0


class Engine:
    def __init__(self, model: Model, params: Any, rc: RunConfig,
                 ecfg: EngineConfig, extras: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.rc = rc
        self.ecfg = ecfg
        self.extras = extras or {}
        self.sched = Scheduler(ecfg.num_slots, max_queue=ecfg.max_queue)
        cfg = model.cfg
        self.window = cfg.sliding_window or cfg.local_window
        self.metrics_counters = EngineMetrics(num_slots=ecfg.num_slots)

        # ---- compressed KV layout (EngineConfig.kv_bits) ----
        if ecfg.kv_bits not in (16, 8, 4, 2):
            raise ValueError(
                f"kv_bits={ecfg.kv_bits} unsupported; expected 16/8/4/2")
        self.kvq = None
        self.kv_int8 = False
        if ecfg.kv_bits != 16 and cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"kv_bits={ecfg.kv_bits} requires an attention-cache "
                f"family (dense/moe), got {cfg.family!r}")
        if ecfg.kv_bits == 8:
            if getattr(cfg, "use_mla", False):
                raise ValueError(
                    "kv_bits=8 has no MLA latent layout; use 16 or the "
                    "KV-VQ 4/2-bit modes")
            self.kv_int8 = True
        elif ecfg.kv_bits in (4, 2):
            from repro.core.quantize import (attach_kv_codebooks,
                                             kv_codebook_tree)
            from repro.core.vq import KVQuantConfig

            self.kvq = KVQuantConfig(kv_bits=ecfg.kv_bits)
            try:  # keep calibrated codebooks when the caller attached them
                self._kv_cb = kv_codebook_tree(params)
            except ValueError:
                params = attach_kv_codebooks(params, cfg, self.kvq)
                self.params = params
                self._kv_cb = kv_codebook_tree(params)
            rc = rc.replace(kv_vq=self.kvq)
            self.rc = rc
        # only pass the quantized-cache kwargs when active: duck-typed
        # model stubs (and pre-kvq signatures) need not accept them
        self._cache_kw = {}
        if self.kv_int8:
            self._cache_kw["kv_int8"] = True
        if self.kvq is not None:
            self._cache_kw["kvq"] = self.kvq

        if ecfg.paged:
            self.paging: Optional[paging.PagingConfig] = \
                paging.make_paging_config(
                    model, ecfg.num_slots, ecfg.max_len, window=self.window,
                    block_size=ecfg.block_size, num_blocks=ecfg.num_blocks,
                    **self._cache_kw)
            self.caches = paging.init_paged_cache(
                model, ecfg.num_slots, ecfg.max_len, self.paging,
                **self._cache_kw)
            self.pool: Optional[paging.BlockPool] = \
                paging.BlockPool(self.paging.num_blocks)
            # host-side source of truth: per-slot block rows + owned ids;
            # the device mirror (set_block_tables) lags until _sync_tables
            self.tables = np.full(
                (ecfg.num_slots, self.paging.blocks_per_slot),
                self.paging.sentinel, np.int32)
            self._owned: List[List[int]] = [[] for _ in range(ecfg.num_slots)]
            self._tables_dirty = True
            self._update_kv_gauges()
        else:
            self.paging = None
            self.pool = None
            self.tables = None
            self._owned = []
            self._tables_dirty = False
            self.caches = paging.init_contiguous_cache(
                model, ecfg.num_slots, ecfg.max_len, **self._cache_kw)
            # contiguous allocation is worst-case and constant
            self.metrics_counters.kv_bytes_in_use = cache_bytes(self.caches)
            self.metrics_counters.peak_kv_bytes_in_use = \
                self.metrics_counters.kv_bytes_in_use

        B = ecfg.num_slots
        # per-slot decode state: every per-request sampling/stopping knob
        # is DATA of fixed shape, so the jitted decode step traces once
        self.positions = np.zeros((B,), np.int32)
        self.last_token = np.zeros((B,), np.int32)
        self.rng_keys = np.zeros((B, 2), np.uint32)
        self.temperature = np.ones((B,), np.float32)
        self.top_k = np.zeros((B,), np.int32)
        self.top_p = np.ones((B,), np.float32)
        self.greedy = np.ones((B,), bool)
        self.stop_ids = np.full((B, api.MAX_STOP_IDS), -1, np.int32)
        self.remaining = np.zeros((B,), np.int32)
        self.active = np.zeros((B,), bool)

        # ---- speculative decoding state (EngineConfig.speculate_k) ----
        self.spec_k = int(ecfg.speculate_k)
        if self.spec_k < 0:
            raise ValueError(f"speculate_k must be >= 0, got {self.spec_k}")
        if self.spec_k:
            if self.window != 0:
                raise ValueError(
                    "speculate_k > 0 requires a full (non-windowed) cache: "
                    "ring caches decode one token at a time")
            if cfg.family != "dense":
                raise ValueError(
                    f"speculate_k > 0 requires family='dense' (MoE capacity "
                    f"routing depends on the token count, breaking "
                    f"token-identity), got {cfg.family!r}")
            if getattr(cfg, "use_mla", False):
                raise ValueError(
                    "speculate_k > 0 is not supported with MLA decode")
            # per-slot successor table (the self-drafting n-gram model)
            # and opt-in mask — device args of the one traced decode
            # step, and part of the snapshot slot state
            self.succ = np.full((B, cfg.vocab_size), -1, np.int32)
            self.spec_on = np.ones((B,), bool)
            self._SLOT_STATE = Engine._SLOT_STATE + ("succ", "spec_on")

        # request-level bookkeeping; _retired drives FIFO eviction of
        # finished outputs/buffers past ecfg.max_retained
        self._outputs: Dict[int, RequestOutput] = {}
        self._buffers: Dict[int, Deque[StreamEvent]] = {}
        self._pending: List[StreamEvent] = []
        self._retired: Deque[int] = deque()

        # trace-counting harness: these tick only when jax (re)traces the
        # python body — tests pin decode==1 and prefill<=len(buckets)
        self.trace_counts = {"decode": 0, "prefill": 0, "prefill_chunk": 0}

        # resilience state: engine tick counter (FaultPlan schedule / the
        # snapshot resume point), numerics circuit breaker and the decode
        # step watchdog
        self._tick = 0
        self.fault_plan = ecfg.fault_plan
        self.breaker = CircuitBreaker(ecfg.breaker_k)
        self.watchdog = StepWatchdog(window=50,
                                     threshold=ecfg.straggler_threshold)

        self._bucketed = (ecfg.prefill_bucketing
                          and cfg.family in _BUCKETABLE_FAMILIES)
        self._buckets = (api.prefill_buckets(ecfg.max_len,
                                             ecfg.min_prefill_bucket)
                         if self._bucketed else ())
        # chunked prefill runs model.forward over a slot_view — supported
        # for the bucketable attention families with a full (non-ring)
        # cache and no MLA latent path (models/common.py gates the same)
        self._chunked = bool(
            ecfg.paged and ecfg.prefill_chunk and self._bucketed
            and self.window == 0 and not getattr(cfg, "use_mla", False)
            and ecfg.kv_bits == 16)  # continuations can't append quantized

        # Pre-plan at the exact execution shapes. Decode always runs at
        # M = num_slots tokens in flight; bucketed prefill runs at exactly
        # the bucket lengths — both warm the Planner cache before the
        # first trace (the traced steps then only hit it). Unbucketed
        # families keep the capacity-bound estimate for introspection.
        self.plans: Dict[str, Any] = {
            "decode": plan_mod.preplan_params(
                params, rc.policy, mode="decode", m=ecfg.num_slots,
                act_dtype=cfg.act_dtype),
        }
        if self._bucketed:
            per_bucket = plan_mod.preplan_prefill_buckets(
                params, rc.policy, buckets=self._buckets,
                act_dtype=cfg.act_dtype)
            for m, plans in per_bucket.items():
                self.plans[f"prefill@{m}"] = plans
        else:
            self.plans["prefill@cap"] = plan_mod.preplan_params(
                params, rc.policy, mode="prefill", m=ecfg.max_len,
                act_dtype=cfg.act_dtype)
        for phase, plans in self.plans.items():
            uniq: Dict[str, int] = {}
            rankings: Dict[str, int] = {}
            for _path, pl in plans:
                uniq[pl.describe()] = uniq.get(pl.describe(), 0) + 1
                rk = pl.describe_ranking()
                if rk:  # >1 eligible backend: show the predicted-time order
                    rankings[rk] = rankings.get(rk, 0) + 1
            for desc, count in sorted(uniq.items()):
                log.info("%s plan [%d leaves] %s", phase, count, desc)
            for rk, count in sorted(rankings.items()):
                log.info("%s ranking [%d leaves] %s", phase, count, rk)

        self._decode_fn = self._make_decode_fn()
        self._prefill_fn = jax.jit(
            functools.partial(self._prefill_impl,
                              rc=self.rc.replace(mode="prefill")),
        )
        if ecfg.paged:
            self._paged_prefill_fn = jax.jit(
                functools.partial(self._paged_prefill_impl,
                                  rc=self.rc.replace(mode="prefill")))
            self._chunk_fn = jax.jit(
                functools.partial(self._prefill_chunk_impl,
                                  rc=self.rc.replace(mode="prefill")))
        # prefill extras (whisper frames / vision embeds), batched once
        self._extra_batch = {
            k: (v[None] if getattr(v, "ndim", 0) == 2 else v[:1])
            for k, v in self.extras.items()
        }

    # ------------------------------------------------------------ admission
    def _admission_error(self, request: GenerationRequest) -> Optional[str]:
        """Why this request can never be served on this engine (None when
        servable). Windowed caches wrap by design, so only the prompt must
        fit. Contiguous full caches also need room for every decode write
        (positions prompt_len .. prompt_len + max_new_tokens - 2) — past
        capacity the write would be dropped and the stream corrupted.
        PAGED full caches admit length-aware instead: memory is bounded
        by actual block consumption (free blocks at admission + growth /
        preemption during decode), so ``max_new_tokens`` is treated as a
        cap, not a reservation — the decode budget simply clamps to the
        remaining capacity at activation (finish_reason="length")."""
        if request.prompt_len > self.ecfg.max_len:
            return (f"prompt length {request.prompt_len} exceeds max_len "
                    f"{self.ecfg.max_len}")
        need = request.prompt_len + request.max_new_tokens - 1
        if self.window == 0 and need > self.ecfg.max_len:
            if self.paging is None:
                return (f"prompt_len + max_new_tokens - 1 = {need} exceeds "
                        f"the cache capacity max_len={self.ecfg.max_len}")
            need = self.ecfg.max_len  # paged: budget clamps at activation
        if self.paging is not None:
            peak = self.paging.blocks_for(need)
            if peak > self.paging.num_blocks:
                return (f"request needs {peak} KV blocks at peak, the pool "
                        f"only has {self.paging.num_blocks} "
                        f"(EngineConfig.num_blocks)")
        return None

    def submit(self, request: GenerationRequest) -> int:
        """Admission-checked submit. Unservable requests (over-long
        prompt, decode budget past cache capacity) and a full queue
        reject IMMEDIATELY with a clean terminal
        ``RequestOutput(finish_reason="rejected")`` — no prefill compute
        is spent and no deep shape error or silent cache clamp happens
        later."""
        if not isinstance(request, GenerationRequest):
            raise TypeError(
                f"submit() takes a GenerationRequest, got "
                f"{type(request).__name__}; use Engine.generate() for the "
                "prompt-list convenience path")
        if len(request.stop_set) > api.MAX_STOP_IDS:
            raise ValueError(
                f"request has {len(request.stop_set)} stop ids; the engine "
                f"supports at most {api.MAX_STOP_IDS} (api.MAX_STOP_IDS)")
        self.metrics_counters.submitted += 1
        if not self.healthy:
            return self._reject(
                request,
                f"engine unhealthy: circuit breaker tripped after "
                f"{self.breaker.consecutive} consecutive poisoned steps")
        why = self._admission_error(request)
        if why is not None:
            return self._reject(request, why)
        try:
            uid = self.sched.submit(request)
        except QueueFull as e:
            return self._reject(request, str(e))
        self._buffers[uid] = deque()
        return uid

    def _reject(self, request: GenerationRequest, why: str) -> int:
        uid = self.sched.next_uid()
        log.info("request %d rejected: %s", uid, why)
        self.metrics_counters.rejected += 1
        out = RequestOutput(uid=uid, tokens=(), finish_reason="rejected")
        self._outputs[uid] = out
        # the terminal event is delivered (and buffered) by the next step()
        self._buffers[uid] = deque()
        self._pending.append(StreamEvent(uid=uid, index=-1, token=None,
                                         finish_reason="rejected"))
        self._retain(uid)
        return uid

    def _retain(self, uid: int) -> None:
        """FIFO-bound the finished outputs + undrained event buffers: a
        long-running submit()/step() server that never reads them must
        not grow memory linearly in total requests served."""
        self._retired.append(uid)
        while len(self._retired) > self.ecfg.max_retained:
            old = self._retired.popleft()
            self._outputs.pop(old, None)
            self._buffers.pop(old, None)

    # ------------------------------------------------------------- prefill
    def _encode_cache(self, cache: Any) -> Any:
        """Bridge an fp prefill cache into the engine's compressed KV
        layout (kv_bits < 16) — the EXPLICIT quantization step before
        slot insertion / block writes; ``_insert_slot``'s astype would
        truncate rather than quantize. No-op at kv_bits=16. Runs inside
        the jitted prefill step."""
        if self.kvq is not None:
            return encode_prefill_cache(cache, self._kv_cb, self.kvq)
        if self.kv_int8:
            return quantize_prefill_cache_int8(cache)
        return cache

    def _prefill_impl(self, params, tokens, true_len, key, temperature,
                      top_k, top_p, greedy, poison, extras, *, rc):
        """Jitted per-request prefill: forward at the (bucket-)padded
        length, sample the first token from the logits at the TRUE last
        position, and convert the cache to decode capacity — all on
        device, one trace per bucket.

        ``poison`` is the fault-injection scalar (0.0 in production —
        adding it is a no-op): a scripted NaN/Inf rides into the logits
        here so the numerics quarantine is testable. ``bad`` (any
        non-finite in the sampled row) reads back with the token —
        no extra device sync."""
        self.trace_counts["prefill"] += 1
        batch = {"tokens": tokens}
        batch.update(extras)
        logits, cache = self.model.prefill(params, batch, rc)
        last = jax.lax.dynamic_slice_in_dim(
            logits[0], true_len - 1, 1, axis=0)[0]
        last = last[: self.model.cfg.vocab_size][None] + poison  # (1, V)
        bad = ~jnp.all(jnp.isfinite(last.astype(jnp.float32)))
        tok, new_key = api.sample_tokens(
            last, key[None], temperature[None], top_k[None], top_p[None],
            greedy[None])
        lp = api.token_logprobs(last, tok)[0]
        cache = self._encode_cache(cache)
        cache = pad_prefill_cache(cache, self.ecfg.max_len,
                                  window=self.window, true_len=true_len)
        return tok[0], bad, lp, new_key[0], cache

    def _paged_prefill_impl(self, params, caches, tokens, true_len, slot,
                            bt_row, key, temperature, top_k, top_p, greedy,
                            poison, extras, *, rc):
        """Jitted paged prefill (first/only chunk): same forward + sample
        as ``_prefill_impl``, but the fresh cache commits by scattering
        through ``slot``'s block-table row into the shared arenas
        (paging.write_prefill_into_blocks) instead of a contiguous slot
        insert. ``slot``/``bt_row``/``true_len`` are traced — one trace
        per bucket, shared by every slot."""
        self.trace_counts["prefill"] += 1
        batch = {"tokens": tokens}
        batch.update(extras)
        logits, fresh = self.model.prefill(params, batch, rc)
        last = jax.lax.dynamic_slice_in_dim(
            logits[0], true_len - 1, 1, axis=0)[0]
        last = last[: self.model.cfg.vocab_size][None] + poison
        bad = ~jnp.all(jnp.isfinite(last.astype(jnp.float32)))
        tok, new_key = api.sample_tokens(
            last, key[None], temperature[None], top_k[None], top_p[None],
            greedy[None])
        lp = api.token_logprobs(last, tok)[0]
        caches = paging.write_prefill_into_blocks(
            caches, self._encode_cache(fresh), slot, bt_row, true_len,
            self.paging, window=self.window)
        return tok[0], bad, lp, new_key[0], caches

    def _prefill_chunk_impl(self, params, caches, tokens, hist, true_len,
                            slot, bt_row, key, temperature, top_k, top_p,
                            greedy, poison, extras, *, rc):
        """Jitted chunked-prefill CONTINUATION (``hist`` committed
        positions already in the slot's blocks): run model.forward in
        prefill mode over a single-slot view of the paged cache at
        absolute positions ``hist + [0, S)``; attention_fwd's paged
        continuation branch scatters the chunk's KV and attends over the
        gathered history. The sampled token only matters on the FINAL
        chunk (the engine discards it otherwise)."""
        self.trace_counts["prefill_chunk"] += 1
        S = tokens.shape[1]
        view = paging.slot_view(caches, slot, bt_row, hist, true_len)
        batch = {"tokens": tokens,
                 "positions": hist + jnp.arange(S, dtype=jnp.int32)[None]}
        batch.update(extras)
        logits, new_view = self.model.forward(params, batch, rc, caches=view)
        last = jax.lax.dynamic_slice_in_dim(
            logits[0], true_len - 1, 1, axis=0)[0]
        last = last[: self.model.cfg.vocab_size][None] + poison
        bad = ~jnp.all(jnp.isfinite(last.astype(jnp.float32)))
        tok, new_key = api.sample_tokens(
            last, key[None], temperature[None], top_k[None], top_p[None],
            greedy[None])
        lp = api.token_logprobs(last, tok)[0]
        caches = paging.merge_slot(caches, new_view, slot)
        return tok[0], bad, lp, new_key[0], caches

    def _prefill_target(self, tr: TrackedRequest) -> int:
        """Positions to prefill before ``slot`` can (re)join decode: the
        prompt, plus the already-generated tokens minus one for a
        preempted request (the last generated token becomes the resume
        decode input, not cache history)."""
        if tr.preempted and tr.generated:
            return tr.prompt_len + len(tr.generated) - 1
        return tr.prompt_len

    def _prefill_tokens(self, tr: TrackedRequest) -> np.ndarray:
        seq = np.asarray(tr.request.prompt, np.int32)
        if tr.preempted and len(tr.generated) > 1:
            seq = np.concatenate(
                [seq, np.asarray(tr.generated[:-1], np.int32)])
        return seq

    def _prefill_one(self, slot: int, tr: TrackedRequest
                     ) -> "tuple[Optional[int], bool, bool]":
        """Advance the request in ``slot`` by one prefill step — the
        whole prompt in one call, or (chunked prefill) the next
        ``prefill_chunk``-sized piece. Returns ``(token, bad, final)``:

        * ``final=False`` — a non-final chunk committed; the slot stays
          occupied-but-inactive and the next tick continues.
        * ``bad=True`` — the sampled logits row failed the finite check:
          the slot is NOT activated and the caller quarantines.
        * ``token`` — the first sampled token on the final step, or None
          for non-final chunks and for preempted-request resumes (their
          re-sampled token is discarded; decode state restores from the
          eviction record instead, keeping the stream token-identical)."""
        if self.fault_plan is not None:
            spec = self.fault_plan.poll("prefill", self._tick, tr.uid)
            if spec is not None:
                raise InjectedFault("prefill", self._tick, tr.uid)
        poison = 0.0
        if self.fault_plan is not None:
            spec = self.fault_plan.poll("poison", self._tick, tr.uid)
            if spec is not None:
                poison = float("nan") if spec.mode == "nan" else float("inf")
        req = tr.request
        sp = req.sampling
        target = self._prefill_target(tr)
        chunked = self._chunked and target > int(self.ecfg.prefill_chunk)
        pos0 = tr.prefill_pos
        c = min(int(self.ecfg.prefill_chunk), target - pos0) if chunked \
            else target
        final = pos0 + c >= target
        chunk = self._prefill_tokens(tr)[pos0: pos0 + c]
        if self._bucketed:
            bucket = api.bucket_for(c, self._buckets)
            if bucket > c:
                # edge-pad: the value is causally masked for real rows,
                # and repeating the last token keeps stub models (that
                # read tokens[:, -1]) meaningful in tests
                chunk = np.pad(chunk, (0, bucket - c), mode="edge")
        key = jax.random.PRNGKey(sp.seed)
        sample_args = (
            jnp.asarray(key),
            jnp.asarray(sp.temperature, jnp.float32),
            jnp.asarray(sp.top_k, jnp.int32),
            jnp.asarray(sp.top_p, jnp.float32),
            jnp.asarray(sp.greedy),
            jnp.asarray(poison, jnp.float32), self._extra_batch,
        )
        toks_dev = jnp.asarray(chunk[None], jnp.int32)
        true_c = jnp.asarray(c, jnp.int32)
        if self.paging is None:
            tok, bad, lp, new_key, cache = self._prefill_fn(
                self.params, toks_dev, true_c, *sample_args)
        elif pos0 == 0:
            tok, bad, lp, new_key, new_caches = self._paged_prefill_fn(
                self.params, self.caches, toks_dev, true_c,
                jnp.asarray(slot, jnp.int32), jnp.asarray(self.tables[slot]),
                *sample_args)
        else:
            tok, bad, lp, new_key, new_caches = self._chunk_fn(
                self.params, self.caches, toks_dev,
                jnp.asarray(pos0, jnp.int32), true_c,
                jnp.asarray(slot, jnp.int32), jnp.asarray(self.tables[slot]),
                *sample_args)
            self.metrics_counters.prefill_chunks += 1
        tok, bad = int(tok), bool(bad)
        if bad:
            # quarantine: never activate the slot, never stream the
            # garbage token — the caller finishes with "error" (which
            # also recycles any blocks committed by earlier chunks)
            return tok, True, final
        if self.paging is None:
            self.caches = _insert_slot(self.caches, cache, slot)
        else:
            self.caches = new_caches
        tr.prefill_pos = pos0 + c
        if not final:
            return None, False, False

        # per-slot decode state for this request
        stop = sorted(req.stop_set)
        self.positions[slot] = target
        self.temperature[slot] = sp.temperature
        self.top_k[slot] = sp.top_k
        self.top_p[slot] = sp.top_p
        self.greedy[slot] = sp.greedy
        self.stop_ids[slot, :] = -1
        self.stop_ids[slot, : len(stop)] = stop
        self.active[slot] = True
        self._tables_dirty = self.paging is not None
        # paged full caches admit length-aware (_admission_error): the
        # decode budget clamps to the capacity left past the prompt
        budget = req.max_new_tokens
        if self.paging is not None and self.window == 0:
            budget = min(budget, self.ecfg.max_len - target + 1)
        if tr.preempted and tr.generated:
            # preemption resume: the re-sampled token is a duplicate of
            # history — restore the decode state saved at eviction so
            # the continuation is token-identical to an uninterrupted run
            self.last_token[slot] = tr.generated[-1]
            self.rng_keys[slot] = np.asarray(tr.resume_key)
            self.remaining[slot] = tr.resume_remaining
            tr.preempted = False
            self._prime_spec(slot, tr)
            return None, False, True
        tr.generated.append(tok)
        if sp.logprobs:
            tr.logprobs.append(float(lp))
        self.last_token[slot] = tok
        self.rng_keys[slot] = np.asarray(new_key)
        self.remaining[slot] = budget - 1
        self._prime_spec(slot, tr)
        return tok, False, True

    def _prime_spec(self, slot: int, tr: TrackedRequest) -> None:
        """(Re)prime the slot's speculative state at activation: the
        opt-in flag and the successor table, seeded from the full token
        history (prompt ++ generated — including the token prefill just
        sampled, so the last-prompt-token transition is known)."""
        if not self.spec_k:
            return
        self.spec_on[slot] = bool(tr.request.speculate)
        speculative.prime_successors(
            self.succ, slot,
            np.concatenate([np.asarray(tr.request.prompt, np.int32),
                            np.asarray(tr.generated, np.int32)]))

    # ------------------------------------------------------- paged KV blocks
    def _update_kv_gauges(self) -> None:
        m = self.metrics_counters
        used = self.pool.used_count
        m.blocks_in_use = used
        m.blocks_free = self.pool.free_count
        m.kv_bytes_in_use = used * self.paging.bytes_per_block
        m.peak_blocks_in_use = max(m.peak_blocks_in_use, used)
        m.peak_kv_bytes_in_use = max(m.peak_kv_bytes_in_use,
                                     m.kv_bytes_in_use)

    def _alloc_blocks(self, slot: int, n: int) -> bool:
        """Grow ``slot`` by ``n`` pool blocks (all-or-nothing)."""
        if n <= 0:
            return True
        blks = self.pool.alloc(n)
        if blks is None:
            return False
        start = len(self._owned[slot])
        self._owned[slot].extend(blks)
        self.tables[slot, start: start + len(blks)] = blks
        self._tables_dirty = True
        self._update_kv_gauges()
        return True

    def _free_blocks(self, slot: int) -> None:
        """Recycle every block ``slot`` owns and sentinel its table row."""
        if self._owned[slot]:
            self.pool.free(self._owned[slot])
            self._owned[slot] = []
        self.tables[slot, :] = self.paging.sentinel
        self._tables_dirty = True
        self._update_kv_gauges()

    def _sync_tables(self) -> None:
        """Push the host block tables to the device cache mirror before a
        batched decode step. Non-ACTIVE rows (free slots AND mid-prefill
        slots, which own blocks but must not receive interleaved decode
        writes) are masked to the sentinel, so the one traced decode step
        serves any live/dead/mid-prefill mix."""
        if self.paging is None or not self._tables_dirty:
            return
        masked = np.where(self.active[:, None], self.tables,
                          self.paging.sentinel).astype(np.int32)
        self.caches = paging.set_block_tables(self.caches, masked)
        self._tables_dirty = False

    def _preempt_victim(self) -> Optional[int]:
        """The youngest (highest-uid) active slot whose resume prefill
        still fits ``max_len`` — preempting it frees blocks NOW and the
        request remains servable later. None when nothing qualifies."""
        best = None
        for b in np.nonzero(self.active)[0]:
            tr = self.sched.slots[int(b)]
            resume = tr.prompt_len + max(0, len(tr.generated) - 1)
            if resume > self.ecfg.max_len:
                continue
            if best is None or tr.uid > self.sched.slots[best].uid:
                best = int(b)
        return best

    def _preempt(self, slot: int) -> None:
        """Evict ``slot`` mid-decode: save its decode state on the
        tracked request, recycle its blocks, and push it back to the
        QUEUE HEAD. It resumes by re-prefilling prompt ++ generated[:-1]
        and restoring the saved PRNG key/budget — token-identical to an
        uninterrupted run, just later."""
        tr = self.sched.slots[slot]
        tr.resume_key = np.array(self.rng_keys[slot], copy=True)
        tr.resume_remaining = int(self.remaining[slot])
        tr.preempted = True
        tr.prefill_pos = 0
        self.active[slot] = False
        self.sched.slots[slot] = None
        self.sched.queue.appendleft(tr)
        self._free_blocks(slot)
        self.metrics_counters.preemptions += 1
        log.info("request %d preempted out of slot %d (out of KV blocks); "
                 "re-queued at head with %d tokens generated",
                 tr.uid, slot, len(tr.generated))

    def _grow_decode_blocks(self) -> None:
        """Before a batched decode step, make sure every active slot owns
        blocks for the position(s) it is about to write — the next token
        plus, when the slot speculates, its K draft positions (draft KV
        past the slot's capacity drops harmlessly, so the lookahead caps
        at max_len / the table width). An exhausted pool preempts the
        youngest active request (possibly the one that needs the block)
        until the write fits."""
        for b in np.nonzero(self.active)[0]:
            b = int(b)
            k_ahead = self.spec_k if (self.spec_k and self.spec_on[b]) else 0
            while self.active[b]:
                want = min(int(self.positions[b]) + 1 + k_ahead,
                           self.ecfg.max_len)
                need = min(self.paging.blocks_for(want),
                           self.paging.blocks_per_slot)
                short = need - len(self._owned[b])
                if short <= 0 or self._alloc_blocks(b, short):
                    break
                victim = self._preempt_victim()
                if victim is None:  # pragma: no cover - defensive
                    raise RuntimeError(
                        "out of KV blocks with no preemptible request; "
                        "raise EngineConfig.num_blocks")
                self._preempt(victim)

    # -------------------------------------------------------------- decode
    def _decode_impl(self, params, caches, tokens, positions, keys,
                     temperature, top_k, top_p, greedy, stop_ids, remaining,
                     active, poison, *, rc):
        """Jitted batched decode step: model decode + in-jit per-slot
        sampling and stopping (serve/api.sample_and_stop). Every
        per-request knob is a fixed-shape device array -> ONE trace.

        ``poison`` (B,) is the fault-injection lane: all-zero in
        production (adding it is a no-op, and it is DATA — injecting a
        fault never retraces). ``bad`` flags lanes whose logits failed
        the all-finite check; it rides the existing readback, costing no
        extra device sync."""
        self.trace_counts["decode"] += 1
        logits, new_caches = self.model.decode(
            params, tokens[:, None], positions[:, None], caches, rc)
        logits = logits[:, 0, : self.model.cfg.vocab_size] + poison[:, None]
        tok, done, bad, new_keys = api.sample_and_stop(
            logits, keys=keys, temperature=temperature, top_k=top_k,
            top_p=top_p, greedy=greedy, stop_ids=stop_ids,
            remaining=remaining, active=active)
        lp = api.token_logprobs(logits, tok)
        return tok, done, bad, lp, new_keys, new_caches

    def _spec_decode_impl(self, params, caches, tokens, positions, succ,
                          keys, temperature, top_k, top_p, greedy, stop_ids,
                          remaining, active, spec_on, poison, *, rc):
        """Jitted SPECULATIVE batched decode step (speculate_k > 0):
        serve/speculative.spec_decode_step — K drafts from the per-slot
        successor tables verified in one model call, the acceptance rule
        replaying the exact sample_and_stop math per logit row. Same
        one-trace discipline as ``_decode_impl``: per-slot knobs, drafts
        and acceptance counts are all data."""
        self.trace_counts["decode"] += 1
        return speculative.spec_decode_step(
            self.model, params, caches, tokens, positions, succ, keys,
            temperature, top_k, top_p, greedy, stop_ids, remaining, active,
            spec_on, poison, rc=rc, k=self.spec_k)

    def _make_decode_fn(self):
        """Jit the batched decode step — the speculative multi-token body
        or the classic single-token one, chosen ONCE at construction (and
        at backend-quarantine re-jit); the step shape never flips
        mid-serve."""
        impl = self._spec_decode_impl if self.spec_k else self._decode_impl
        return jax.jit(
            functools.partial(impl, rc=self.rc.replace(mode="decode")))

    def _prefill_step_events(self, slot: int,
                             events: List[StreamEvent]) -> bool:
        """Run one prefill step for ``slot`` (a whole prompt, one chunk,
        or a preemption-resume re-prefill) and translate the outcome into
        events + metrics. Returns True when the step poisoned.

        Counter discipline (keeps the EngineMetrics invariants exact):
        ``prefills`` ticks when a step emits a first token or poisons;
        non-final chunks tick ``prefill_chunks`` only, and a good
        preemption resume ticks neither (its token was already counted
        before eviction — ``preemptions`` observes the event)."""
        m = self.metrics_counters
        tr = self.sched.slots[slot]
        now = time.perf_counter()
        pos0 = tr.prefill_pos
        tok, bad, final = self._prefill_one(slot, tr)
        dt = time.perf_counter() - now
        tr.prefill_s += dt
        m.prefill_s += dt
        m.prefill_prompt_tokens += tr.prefill_pos - pos0
        if bad:
            # numerics quarantine straight out of prefill: the garbage
            # token is suppressed, the request errors (and any blocks
            # committed by earlier chunks recycle via _finish_slot)
            m.prefills += 1
            m.poisoned_slot_steps += 1
            events.append(StreamEvent(tr.uid, 0, None, "error"))
            self._finish_slot(slot, "error")
            return True
        if not final:
            return False
        tr.decode_t0 = time.perf_counter()
        if tok is None:
            # preemption resume rejoins decode silently: its next token
            # continues the stream exactly where eviction cut it
            return False
        m.prefills += 1
        m.tokens_generated += 1
        # stop-set token straight out of prefill / effective budget of
        # one (max_new_tokens == 1, or a paged length-aware admission
        # whose clamped budget leaves no decode room): retire before the
        # request joins a decode batch at all
        reason = None
        if tok in tr.stop_set:
            reason = "stop"
        elif int(self.remaining[slot]) <= 0:
            reason = "length"
        lp = tr.logprobs[-1] if tr.request.sampling.logprobs else None
        events.append(StreamEvent(tr.uid, 0, tok, reason, logprob=lp))
        if reason is not None:
            self._finish_slot(slot, reason)
        return False

    # ---------------------------------------------------------------- step
    def _timeout_sweep(self) -> List[StreamEvent]:
        """Enforce per-request ``deadline_s`` and the engine queue TTL
        between steps: expired QUEUED requests time out before wasting a
        prefill; expired ACTIVE requests free their slot before another
        batched decode step is spent on them."""
        m = self.metrics_counters
        events: List[StreamEvent] = []
        now = time.perf_counter()
        ttl = self.ecfg.queue_ttl_s

        def dead_in_queue(tr: TrackedRequest) -> bool:
            return tr.expired(now) or (
                ttl is not None and now - tr.submit_t > ttl)

        for tr in self.sched.prune_queue(dead_in_queue):
            m.count_finish("timeout")
            # a preempted request waiting to resume may already hold
            # streamed tokens — the terminal output must carry them
            self._outputs[tr.uid] = RequestOutput(
                uid=tr.uid, tokens=tuple(tr.generated),
                logprobs=tuple(tr.logprobs),
                finish_reason="timeout", queue_wait_s=now - tr.submit_t)
            events.append(StreamEvent(tr.uid, -1, None, "timeout"))
            self._retain(tr.uid)
        for b in list(self.sched.active_slots()):
            tr = self.sched.slots[b]
            if tr.expired(now):
                events.append(
                    StreamEvent(tr.uid, len(tr.generated), None, "timeout"))
                self._finish_slot(b, "timeout")
        return events

    def step(self) -> List[StreamEvent]:
        """One engine tick: deadline/TTL sweep, admit+prefill queued
        requests, one batched decode step over active slots, retire
        finished requests. Returns the tick's StreamEvents (prefill
        tokens, decode tokens, pending rejections/timeouts).

        A request retires in the SAME step its stopping condition is met
        (stop-set token emitted / budget exhausted) — including straight
        out of prefill — so it never occupies a slot for an extra batched
        decode step. Free slots are masked out of the decode inputs
        (token 0 at position 0) instead of replaying stale state.

        Failure semantics: a lane whose logits fail the in-jit finite
        check is QUARANTINED — its garbage token is never streamed, the
        request finishes ``finish_reason="error"``, and the rest of the
        batch streams on untouched (poison is additive per-lane data, so
        bystander lanes are bit-identical to a fault-free run). ``k``
        consecutive poisoned steps trip the circuit breaker: pending
        requests are rejected and new submits refuse. A scripted
        ``backend`` fault quarantines the planned backend and re-plans
        (core/plan.py re-ranks; the next-cheapest candidate takes over).
        Exceptions out of ``step()`` (scripted prefill/decode/sample
        faults, real crashes) leave this tick's events undelivered —
        ``snapshot()``/``restore()`` (serve/resilience.py
        ``serve_with_restarts``) is the recovery path that resumes
        token-identically without double-delivering."""
        m = self.metrics_counters
        tick = self._tick
        fp = self.fault_plan
        events: List[StreamEvent] = list(self._pending)
        self._pending.clear()

        events.extend(self._timeout_sweep())

        if fp is not None:
            backend_spec = fp.poll("backend", tick)
            if backend_spec is not None:
                self._fail_backend(backend_spec.backend)

        any_poisoned = False
        did_work = False

        # advance mid-prefill (chunked) slots one chunk each before
        # admitting more work: occupied-but-inactive marks mid-prefill
        for slot in self.sched.active_slots():
            if self.active[slot]:
                continue
            did_work = True
            any_poisoned |= self._prefill_step_events(slot, events)

        # paged admission reserves pool blocks for each candidate's full
        # prefill target; Scheduler.admit stops at the first refusal
        planned_free = self.pool.free_count if self.paging is not None else 0

        def can_admit(tr: TrackedRequest) -> bool:
            nonlocal planned_free
            if self.paging is None:
                return True
            need = self.paging.blocks_for(self._prefill_target(tr))
            if need > planned_free:
                return False
            planned_free -= need
            return True

        for slot in self.sched.admit(can_admit):
            tr = self.sched.slots[slot]
            did_work = True
            now = time.perf_counter()
            tr.queue_wait_s = now - tr.submit_t
            m.admitted += 1
            m.queue_wait_s += tr.queue_wait_s
            if self.paging is not None:
                need = self.paging.blocks_for(self._prefill_target(tr))
                ok = self._alloc_blocks(slot, need)
                assert ok, "can_admit reserved blocks the pool cannot supply"
            any_poisoned |= self._prefill_step_events(slot, events)

        # every active slot must own blocks for the position this decode
        # step writes; an exhausted pool preempts the youngest request
        if self.paging is not None and np.any(self.active):
            self._grow_decode_blocks()

        active_idx = np.nonzero(self.active)[0]
        if active_idx.size:
            did_work = True
            self._sync_tables()
            if fp is not None and fp.poll("decode", tick) is not None:
                raise InjectedFault("decode", tick)
            poison = np.zeros((self.ecfg.num_slots,), np.float32)
            if fp is not None:
                for b in active_idx:
                    spec = fp.poll("poison", tick, self.sched.slots[b].uid)
                    if spec is not None:
                        poison[b] = (np.nan if spec.mode == "nan"
                                     else np.inf)
            t0 = time.perf_counter()
            self.watchdog.start_step()
            dev_args = [
                self.params, self.caches,
                jnp.asarray(np.where(self.active, self.last_token, 0)),
                jnp.asarray(np.where(self.active, self.positions, 0)),
            ]
            if self.spec_k:
                dev_args.append(jnp.asarray(self.succ))
            dev_args += [
                jnp.asarray(self.rng_keys),
                jnp.asarray(self.temperature),
                jnp.asarray(self.top_k),
                jnp.asarray(self.top_p),
                jnp.asarray(self.greedy),
                jnp.asarray(self.stop_ids),
                jnp.asarray(self.remaining),
                jnp.asarray(self.active),
            ]
            if self.spec_k:
                dev_args.append(jnp.asarray(self.spec_on))
            dev_args.append(jnp.asarray(poison))
            if self.spec_k:
                (toks, lps, e_cnt, acc, done, bad, new_keys, new_succ,
                 self.caches) = self._decode_fn(*dev_args)
                toks = np.asarray(toks)                 # (B, K+1)
                lps = np.asarray(lps)
                e_cnt = np.asarray(e_cnt).astype(np.int32)
                acc = np.asarray(acc)
                self.succ = np.array(new_succ)
            else:
                tok, done, bad, lp, new_keys, self.caches = self._decode_fn(
                    *dev_args)
                toks = np.asarray(tok)[:, None]         # (B, 1)
                lps = np.asarray(lp)[:, None]
                acc = None
            done = np.asarray(done)
            bad = np.asarray(bad)
            if not self.spec_k:
                e_cnt = (self.active & ~bad).astype(np.int32)
            rep = self.watchdog.end_step()
            if rep.is_straggler:
                m.straggler_steps += 1
            if fp is not None and fp.poll("sample", tick) is not None:
                # the classic torn-state crash: the device step already
                # ran, host bookkeeping has not — only a snapshot
                # restore recovers consistently
                raise InjectedFault("sample", tick)
            # np.array (copy) — np.asarray of a device array is read-only,
            # and the next prefill writes per-slot keys in place
            self.rng_keys = np.array(new_keys)
            n_bad = int(np.count_nonzero(bad))
            n_emit = int(e_cnt.sum())
            m.decode_steps += 1
            m.decode_slot_steps += int(active_idx.size)
            m.decode_s += time.perf_counter() - t0
            m.tokens_generated += n_emit
            m.extra_decode_tokens += n_emit - (int(active_idx.size) - n_bad)
            m.poisoned_slot_steps += n_bad
            if self.spec_k:
                spec_lanes = self.active & ~bad & self.spec_on
                n_spec = int(np.count_nonzero(spec_lanes))
                n_acc = int(acc[spec_lanes].sum())
                m.drafted_tokens += self.spec_k * n_spec
                m.accepted_draft_tokens += n_acc
                m.rejected_draft_tokens += self.spec_k * n_spec - n_acc
            any_poisoned = any_poisoned or n_bad > 0

            # only healthy lanes advance and emit; a poisoned lane's
            # token never reaches its stream. e_cnt is the per-lane
            # emission count (always 1 for non-speculative steps, up to
            # K+1 for accepted draft windows) — already zero for
            # inactive/bad lanes
            self.positions += e_cnt
            self.remaining -= e_cnt
            last_idx = np.maximum(e_cnt - 1, 0)
            new_last = toks[np.arange(toks.shape[0]), last_idx]
            self.last_token = np.where(e_cnt > 0, new_last, self.last_token)
            for b in active_idx:
                tr = self.sched.slots[b]
                if bad[b]:
                    events.append(StreamEvent(tr.uid, len(tr.generated),
                                              None, "error"))
                    self._finish_slot(int(b), "error")
                    continue
                n = int(e_cnt[b])
                if n == 0:  # pragma: no cover - defensive
                    continue
                want_lp = tr.request.sampling.logprobs
                reason = None
                if done[b]:
                    last_t = int(toks[b, n - 1])
                    reason = "stop" if last_t in tr.stop_set else "length"
                base = len(tr.generated)
                for j in range(n):
                    t = int(toks[b, j])
                    tr.generated.append(t)
                    lpj = None
                    if want_lp:
                        lpj = float(lps[b, j])
                        tr.logprobs.append(lpj)
                    events.append(StreamEvent(
                        tr.uid, base + j, t,
                        reason if j == n - 1 else None, logprob=lpj))
                if reason is not None:
                    self._finish_slot(int(b), reason)

        if did_work:
            was_tripped = self.breaker.tripped
            if self.breaker.record(any_poisoned) and not was_tripped:
                events.extend(self._reject_pending_unhealthy())

        for ev in events:
            buf = self._buffers.get(ev.uid)
            if buf is not None:
                buf.append(ev)
        self._tick += 1
        return events

    def _reject_pending_unhealthy(self) -> List[StreamEvent]:
        """Circuit breaker just tripped: reject every queued request
        cleanly instead of leaving it waiting on an engine that will
        never serve it (in-flight slots keep draining)."""
        m = self.metrics_counters
        events: List[StreamEvent] = []
        for tr in self.sched.drain_queue():
            m.rejected += 1
            log.error("request %d rejected: engine unhealthy (circuit "
                      "breaker tripped)", tr.uid)
            self._outputs[tr.uid] = RequestOutput(
                uid=tr.uid, tokens=(), finish_reason="rejected")
            events.append(StreamEvent(tr.uid, -1, None, "rejected"))
            self._retain(tr.uid)
        return events

    def _fail_backend(self, name: Optional[str]) -> None:
        """A scripted backend fault fired: quarantine the named backend
        (default: the decode plan's chosen one) in the default planner
        and re-jit the stepped functions — the retrace re-enters
        core/plan.py's cost ranking, which now skips the quarantined
        backend and bakes the next-cheapest candidate in."""
        if name is None:
            name = self.plans["decode"][0][1].backend
        planner = plan_mod.default_planner()
        planner.record_backend_failure(name)
        self.metrics_counters.backend_fallbacks += 1
        log.warning("backend %r failed and was quarantined; re-planning "
                    "decode/prefill on the remaining candidates", name)
        self._decode_fn = self._make_decode_fn()
        self._prefill_fn = jax.jit(
            functools.partial(self._prefill_impl,
                              rc=self.rc.replace(mode="prefill")))
        if self.ecfg.paged:
            self._paged_prefill_fn = jax.jit(
                functools.partial(self._paged_prefill_impl,
                                  rc=self.rc.replace(mode="prefill")))
            self._chunk_fn = jax.jit(
                functools.partial(self._prefill_chunk_impl,
                                  rc=self.rc.replace(mode="prefill")))
        self.plans["decode"] = plan_mod.preplan_params(
            self.params, self.rc.policy, mode="decode",
            m=self.ecfg.num_slots, act_dtype=self.model.cfg.act_dtype)

    def _finish_slot(self, slot: int, reason: str) -> TrackedRequest:
        tr = self.sched.finish(slot)
        self.active[slot] = False
        if self.paging is not None:
            self._free_blocks(slot)
        # a request that crossed a snapshot restore mid-flight finishes
        # with an annotated reason: the tokens are token-identical, the
        # client can still SEE that delivery crossed a failover
        if tr.restored and reason in ("stop", "length"):
            reason = f"{reason}-after-restore"
        self.metrics_counters.count_finish(reason)
        decode_s = (time.perf_counter() - tr.decode_t0
                    if len(tr.generated) > 1 else 0.0)
        self._outputs[tr.uid] = RequestOutput(
            uid=tr.uid, tokens=tuple(tr.generated),
            logprobs=tuple(tr.logprobs), finish_reason=reason,
            queue_wait_s=tr.queue_wait_s, prefill_s=tr.prefill_s,
            decode_s=decode_s)
        self._retain(tr.uid)
        return tr

    # ------------------------------------------------------------ streaming
    @property
    def idle(self) -> bool:
        return self.sched.idle and not self._pending

    @property
    def healthy(self) -> bool:
        """False once the numerics circuit breaker tripped: submits are
        refused and pending requests were rejected (the in-flight slots
        still drain)."""
        return not self.breaker.tripped

    def output(self, uid: int) -> Optional[RequestOutput]:
        """The terminal RequestOutput once ``uid`` finished (else None)."""
        return self._outputs.get(uid)

    def evicted(self, uid: int) -> bool:
        """True when ``uid`` WAS a real request whose retained output +
        event buffer have been FIFO-evicted past ``max_retained`` —
        distinct from a uid that was never issued (uids are dense and
        1-based, so the scheduler counter bounds the issued set)."""
        if not 1 <= uid <= self.sched.last_uid:
            return False
        if uid in self._outputs or uid in self._buffers:
            return False
        if any(tr.uid == uid for tr in self.sched.queue):
            return False
        if any(tr is not None and tr.uid == uid for tr in self.sched.slots):
            return False
        return True

    def stream(self, uid: int) -> Iterator[StreamEvent]:
        """Iterate ``uid``'s StreamEvents, driving ``step()`` as needed;
        ends after yielding the terminal event. Events for OTHER requests
        produced along the way stay buffered for their own streams.

        Raises ``RequestEvicted`` (a KeyError subclass) when the uid was
        served but its buffer was FIFO-evicted past ``max_retained``,
        plain ``KeyError`` when the uid was never issued or was already
        drained — callers can tell "read it sooner / raise max_retained"
        apart from "that uid never existed". A wall-clock stall guard
        (``EngineConfig.stream_stall_s``) bounds how long the stream
        drives an engine that makes no progress for this uid."""
        buf = self._buffers.get(uid)
        if buf is None:
            if self.evicted(uid):
                raise RequestEvicted(
                    f"request {uid} was served but its events were evicted "
                    f"past max_retained={self.ecfg.max_retained}; stream "
                    "promptly or raise EngineConfig.max_retained")
            if 1 <= uid <= self.sched.last_uid:
                raise KeyError(
                    f"request {uid} already streamed to completion")
            raise KeyError(f"unknown request uid {uid}")
        t_last = time.perf_counter()
        while True:
            while buf:
                ev = buf.popleft()
                t_last = time.perf_counter()
                yield ev
                if ev.done:
                    self._buffers.pop(uid, None)
                    return
            if self.idle:  # pragma: no cover - defensive
                raise RuntimeError(
                    f"engine idle but request {uid} never finished")
            self.step()
            if not buf and (time.perf_counter() - t_last
                            > self.ecfg.stream_stall_s):
                raise RuntimeError(
                    f"stream({uid}) stalled: no event for "
                    f"{self.ecfg.stream_stall_s:.1f}s "
                    f"(EngineConfig.stream_stall_s)")

    # ----------------------------------------------------- snapshot/restore
    _SLOT_STATE = ("positions", "last_token", "rng_keys", "temperature",
                   "top_k", "top_p", "greedy", "stop_ids", "remaining",
                   "active")

    def snapshot(self) -> EngineSnapshot:
        """Serialize the full engine state to host memory.

        Everything a resumed engine needs to continue TOKEN-IDENTICALLY
        mid-stream is captured: per-slot KV caches, PRNG keys, sampling/
        stopping state (path-flattened through checkpoint/manager.py's
        format, so the array state can also be persisted with
        CheckpointManager — serve/resilience.save_snapshot), the
        scheduler queue + tracked requests, finished outputs, undrained
        event buffers, metrics counters and the breaker. Nothing aliases
        live engine state — stepping after ``snapshot()`` cannot corrupt
        the snapshot."""
        m = self.metrics_counters
        m.snapshots += 1
        slot_state = {name: getattr(self, name) for name in self._SLOT_STATE}
        flat = ckpt_manager.flatten_with_paths(
            {"caches": self.caches, "slots": slot_state})
        arrays = {path: (np.array(leaf) if leaf is not None else None)
                  for path, leaf in flat}
        return EngineSnapshot(
            tick=self._tick,
            arrays=arrays,
            uid_counter=self.sched.last_uid,
            queue=[tr.clone() for tr in self.sched.queue],
            slots=[tr.clone() if tr is not None else None
                   for tr in self.sched.slots],
            outputs=dict(self._outputs),        # RequestOutput is frozen
            buffers={uid: list(b) for uid, b in self._buffers.items()},
            pending=list(self._pending),        # StreamEvent is frozen
            retired=list(self._retired),
            metrics=m.state(),
            breaker=self.breaker.state(),
            num_slots=self.ecfg.num_slots,
            max_len=self.ecfg.max_len,
            paged=self.paging is not None,
            block_size=self.paging.block_size if self.paging else 0,
            num_blocks=self.paging.num_blocks if self.paging else 0,
            **(paging.paged_state(self.tables, self.pool, self._owned)
               if self.paging is not None else {}),
        )

    def restore(self, snap: EngineSnapshot) -> None:
        """Adopt a snapshot: the engine resumes exactly at the
        snapshot's tick, mid-stream requests continue token-identically
        (their PRNG keys, KV caches and sampling state all came along).
        Requests in-flight across the restore are marked ``restored`` —
        they finish with a ``...-after-restore`` annotated reason."""
        if (snap.num_slots != self.ecfg.num_slots
                or snap.max_len != self.ecfg.max_len):
            raise ValueError(
                f"snapshot geometry (slots={snap.num_slots}, "
                f"max_len={snap.max_len}) does not match engine "
                f"(slots={self.ecfg.num_slots}, max_len={self.ecfg.max_len})")
        snap_paged = getattr(snap, "paged", False)
        if snap_paged != (self.paging is not None):
            raise ValueError(
                f"snapshot paged={snap_paged} does not match engine "
                f"paged={self.paging is not None}")
        if self.paging is not None and (
                snap.block_size != self.paging.block_size
                or snap.num_blocks != self.paging.num_blocks):
            raise ValueError(
                f"snapshot paging geometry (block_size={snap.block_size}, "
                f"num_blocks={snap.num_blocks}) does not match engine "
                f"(block_size={self.paging.block_size}, "
                f"num_blocks={self.paging.num_blocks})")
        tree = ckpt_manager.unflatten_from_paths(dict(snap.arrays))

        # adopt the cache leaves under THIS engine's pytree structure:
        # the path format collapses list-vs-tuple, so unflatten against
        # the live treedef (leaf order matches — both flatteners sort
        # dict keys and keep sequence order)
        t_leaves, treedef = jax.tree_util.tree_flatten(self.caches)
        r_leaves = jax.tree_util.tree_leaves(tree["caches"])
        if len(t_leaves) != len(r_leaves):
            raise ValueError(
                f"snapshot cache has {len(r_leaves)} leaves, engine cache "
                f"has {len(t_leaves)} — incompatible model/cache geometry")
        self.caches = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(r).astype(t.dtype)
                      for t, r in zip(t_leaves, r_leaves)])

        slot_state = tree["slots"]
        for name in self._SLOT_STATE:
            tmpl = getattr(self, name)
            setattr(self, name,
                    np.array(slot_state[name]).astype(tmpl.dtype))

        if self.paging is not None:
            self.tables = np.array(snap.block_tables, np.int32)
            self.pool.restore(snap.pool_free)
            self._owned = [list(o) for o in snap.owned]
            self._tables_dirty = True
            self._update_kv_gauges()

        self.sched.restore_state(snap.uid_counter, snap.queue, snap.slots)
        for tr in self.sched.slots:
            if tr is not None:
                tr.restored = True
        self._outputs = dict(snap.outputs)
        self._buffers = {uid: deque(b) for uid, b in snap.buffers.items()}
        self._pending = list(snap.pending)
        self._retired = deque(snap.retired)
        self.metrics_counters.restore(dict(snap.metrics))
        self.metrics_counters.restores += 1
        self.breaker.restore(snap.breaker)
        self._tick = snap.tick

    # ------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        """Snapshot of the engine counters (serve/metrics.py)."""
        return self.metrics_counters.snapshot()

    # ---------------------------------------------------------- high level
    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int,
                 sampling: Optional[SamplingParams] = None
                 ) -> Dict[int, List[int]]:
        """Convenience wrapper over submit/step: serve a batch of prompts
        to completion and return {uid: tokens} in submission order. The
        default sampling is greedy — token-for-token identical to the
        pre-redesign blocking engine. Rejected prompts raise (the typed
        submit() surface is the place to handle rejection gracefully)."""
        sampling = sampling or api.GREEDY
        reqs = [GenerationRequest(prompt=p, max_new_tokens=max_new_tokens,
                                  sampling=sampling) for p in prompts]
        # validate the whole batch BEFORE enqueueing anything: a partial
        # raise must not leave accepted prompts queued for a later call
        bad = {i: self._admission_error(r) for i, r in enumerate(reqs)}
        bad = {i: why for i, why in bad.items() if why is not None}
        if bad:
            raise ValueError(
                f"generate(): unservable prompt(s) {bad}; use submit() to "
                "handle rejection as data")
        guard = 0
        uids = []
        for r in reqs:
            # respect the bounded queue: drain instead of rejecting
            while len(self.sched.queue) >= self.sched.max_queue:
                self.step()
                guard += 1
                if guard > 100000:  # pragma: no cover
                    raise RuntimeError("engine did not converge")
            uids.append(self.submit(r))
        while not self.idle:
            self.step()
            guard += 1
            if guard > 100000:  # pragma: no cover
                raise RuntimeError("engine did not converge")
        results: Dict[int, List[int]] = {}
        for uid, req in zip(uids, reqs):
            out = self._outputs[uid]
            # the stopping condition is enforced in-jit; over-generation
            # would be an engine bug — assert the invariant rather than
            # silently truncating it away
            assert len(out.tokens) <= req.max_new_tokens, (
                f"request {uid} generated {len(out.tokens)} tokens, over "
                f"its max_new_tokens={req.max_new_tokens} budget")
            results[uid] = list(out.tokens)
            self._buffers.pop(uid, None)
        return results
