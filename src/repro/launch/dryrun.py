import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory / cost / roofline data.

  single-pod mesh: (data=16, model=16)        = 256 chips
  multi-pod mesh:  (pod=2, data=16, model=16) = 512 chips

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape decode_32k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]

Each cell writes <out>/<arch>__<shape>__<mesh>.json incrementally, so the
sweep is resumable. Shape->step mapping: train_4k -> train_step,
prefill_32k -> prefill_step (INT8 path), decode_*/long_* -> serve decode
step (EVA VQ path). long_500k runs only for sub-quadratic archs
(DESIGN.md §4).
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as steps_mod
from repro.models.api import Model, SHAPES, build_model
from repro.core.plan import PlanPolicy
from repro.models.common import RunConfig
from repro.roofline.analysis import analyze_compiled, model_flops
from repro.core.vq import VQWeight


def fc_param_counts(model: Model) -> Dict[str, float]:
    """Analytic FC-parameter counts (total and decode-active) from specs."""
    specs = model.param_specs()
    cfg = model.cfg
    total = 0.0
    active = 0.0

    def walk(node, path):
        nonlocal total, active
        if isinstance(node, dict):
            if "w" in node and hasattr(node["w"], "ndim") and node["w"].ndim >= 2:
                sz = float(np.prod(node["w"].shape))
                total += sz
                if "experts" in path and cfg.num_experts:
                    active += sz * cfg.top_k / cfg.num_experts
                else:
                    active += sz
                return
            for k, v in node.items():
                walk(v, path + (k,))

    walk(specs, ())
    return {"total_fc": total, "active_fc": active}


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             *, vq_mode: str = "eva", tag: str = "",
             rc_overrides: Optional[Dict[str, Any]] = None,
             serve_step: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    model = build_model(cfg)
    mesh_name = "pod2" if mesh_kind == "multi" else "pod1"
    suffix = f"__{tag}" if tag else ""
    out_path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}{suffix}.json")
    if os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    result: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_name,
                              "tag": tag, "status": "pending"}
    if not model.supports_shape(shape):
        result["status"] = "skipped"
        result["reason"] = ("long_500k requires sub-quadratic attention; "
                            "skipped per DESIGN.md §4")
        _write(out_path, result)
        return result

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        ov = dict(rc_overrides or {})
        q_lm_head = ov.pop("quantize_lm_head", False)
        kind, specs = model.input_specs(
            shape, kv_int8=ov.get("kv_cache_int8", False),
            kv_int4=ov.get("kv_cache_int4", False))

        # execution knobs live inside PlanPolicy; any policy-level
        # override in rc_overrides is split out of the flat RunConfig kw
        pol_kw = {f: ov.pop(f) for f in
                  ("vq_mode", "impl", "epilogue", "block_v", "int8_prefill",
                   "interpret") if f in ov}
        if kind == "train":
            rc = RunConfig(mode="train", remat=True, attn_chunk=2048,
                           plan_policy=PlanPolicy(**pol_kw), **ov)
            lowered = steps_mod.lower_train_step(model, mesh, specs, rc)
        elif kind == "prefill":
            pol_kw.setdefault("int8_prefill", True)
            rc = RunConfig(mode="prefill", remat=False, attn_chunk=2048,
                           plan_policy=PlanPolicy(**pol_kw), **ov)
            lowered = steps_mod.lower_prefill_step(model, mesh, specs, rc,
                                                   quantized=True)
        else:
            pol_kw.setdefault("vq_mode", vq_mode)
            rc = RunConfig(mode="decode", remat=False,
                           plan_policy=PlanPolicy(**pol_kw), **ov)
            if serve_step:
                # the FULL serving decode step (in-jit sampling/stopping,
                # host reads back only (next_tok, done)) — what the
                # request-level engine actually lowers in production
                result["serve_step"] = True
                lowered = steps_mod.lower_serve_decode_step(
                    model, mesh, specs, rc, quantized=True, vq_mode=vq_mode,
                    quantize_lm_head=q_lm_head)
            else:
                lowered = steps_mod.lower_decode_step(
                    model, mesh, specs, rc, quantized=True, vq_mode=vq_mode,
                    quantize_lm_head=q_lm_head)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        seq, gb, _ = SHAPES[shape]
        counts = fc_param_counts(model)
        mf = model_flops(cfg, kind, seq, gb, counts["total_fc"],
                         counts["active_fc"])
        cache_bytes_dev = 0.0
        if kind == "decode":
            cache_bytes_dev = sum(
                float(np.prod(x.shape)) * x.dtype.itemsize
                for x in jax.tree_util.tree_leaves(specs["caches"])
            ) / chips
        report = analyze_compiled(
            compiled, arch=arch, shape=shape, mesh_name=mesh_name,
            chips=chips, model_flops=mf, step_kind=kind,
            cache_bytes_per_device=cache_bytes_dev,
        )
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # jax-0.4.37 API drift
            ca = ca[0] if ca else {}
        result.update({
            "status": "ok",
            "chips": chips,
            "step_kind": kind,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "peak_bytes_estimate": mem.argument_size_in_bytes
                + mem.output_size_in_bytes + mem.temp_size_in_bytes,
            },
            "cost_analysis_flops_single_visit": float(ca.get("flops", -1.0)),
            "roofline": report.to_dict(),
            "fc_params": counts,
        })
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    result["wall_s"] = round(time.time() - t0, 2)
    _write(out_path, result)
    return result


def _write(path: str, obj: Dict[str, Any]):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--vq-mode", default="eva", choices=["eva", "dequant"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--serve-step", action="store_true",
                    help="lower decode cells as the full serving step "
                         "(in-jit sampling/stopping; serve/api.py)")
    args = ap.parse_args()
    if args.serve_step and not args.tag:
        args.tag = "servestep"  # keep plain-decode cells resumable

    archs = [a for a in ARCH_IDS if a != "llama2_7b"] if args.all or not args.arch \
        else [args.arch.replace("-", "_").replace(".", "_")]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    any_fail = False
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                r = run_cell(arch, shape, mk, args.out,
                             vq_mode=args.vq_mode, tag=args.tag,
                             serve_step=args.serve_step)
                line = (f"{arch:24s} {shape:12s} {r['mesh']:5s} "
                        f"{r['status']:8s}")
                if r["status"] == "ok":
                    rl = r["roofline"]
                    line += (f" wall={r['wall_s']:7.1f}s "
                             f"t_comp={rl['t_compute']*1e3:8.3f}ms "
                             f"t_mem={rl['t_memory']*1e3:8.3f}ms "
                             f"t_coll={rl['t_collective']*1e3:8.3f}ms "
                             f"bound={rl['bottleneck']}")
                elif r["status"] == "error":
                    line += f" {r['error'][:120]}"
                    any_fail = True
                print(line, flush=True)
    sys.exit(1 if any_fail else 0)


if __name__ == "__main__":
    main()
