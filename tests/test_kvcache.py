"""serve/kvcache.py edge cases: ring conversion at S == window, rings
larger than capacity (window > capacity), int8 KV-scale leaves, and the
dynamic true_len (bucketed prefill) path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.kvcache import _to_ring_dynamic, pad_prefill_cache


def _attn_cache(S, B=1, Hk=2, hd=4, int8=False, seed=0):
    rng = np.random.default_rng(seed)
    cache = {
        "k": jnp.asarray(rng.normal(size=(B, S, Hk, hd)).astype(np.float32)),
        "v": jnp.asarray(rng.normal(size=(B, S, Hk, hd)).astype(np.float32)),
        "len": jnp.full((B,), S, jnp.int32),
    }
    if int8:
        cache["k"] = (cache["k"] * 10).astype(jnp.int8)
        cache["v"] = (cache["v"] * 10).astype(jnp.int8)
        cache["k_s"] = jnp.asarray(
            rng.normal(size=(B, S, Hk)).astype(np.float32)).astype(jnp.bfloat16)
        cache["v_s"] = jnp.asarray(
            rng.normal(size=(B, S, Hk)).astype(np.float32)).astype(jnp.bfloat16)
    return cache


class TestRingConversion:
    def test_ring_at_exactly_window_is_identity(self):
        """S == window: every position keeps its slot (slot = pos %
        window = pos) — conversion must be a no-op on the values."""
        cache = _attn_cache(S=8)
        out = pad_prefill_cache(cache, 16, window=8)
        np.testing.assert_array_equal(np.asarray(out["k"]),
                                      np.asarray(cache["k"]))
        np.testing.assert_array_equal(np.asarray(out["v"]),
                                      np.asarray(cache["v"]))
        assert out["k"].shape[1] == 8

    def test_ring_order_matches_decode_slot_rule(self):
        """S > window: slot s holds the newest position p with
        p % window == s (the rule decode's write uses)."""
        S, W = 13, 8
        cache = _attn_cache(S=S)
        out = pad_prefill_cache(cache, 16, window=W)
        k_in = np.asarray(cache["k"])
        k_out = np.asarray(out["k"])
        for s in range(W):
            newest = max(p for p in range(S) if p % W == s)
            np.testing.assert_array_equal(k_out[:, s], k_in[:, newest])

    def test_window_larger_than_capacity_sizes_to_capacity(self):
        """window > capacity: init_cache allocates min(capacity, window)
        time slots and decode wraps by that size — the converted ring
        must match it, not the raw window (previously produced an
        oversized ring the slot insert could not accept)."""
        S, W, cap = 12, 16, 8
        cache = _attn_cache(S=S)
        out = pad_prefill_cache(cache, cap, window=W)
        assert out["k"].shape[1] == cap
        assert out["v"].shape[1] == cap
        k_in = np.asarray(cache["k"])
        k_out = np.asarray(out["k"])
        # slot rule at the DECODE ring size (cap), not the window
        for s in range(cap):
            newest = max(p for p in range(S) if p % cap == s)
            np.testing.assert_array_equal(k_out[:, s], k_in[:, newest])

    def test_short_prefill_pads_to_ring_size(self):
        cache = _attn_cache(S=5)
        out = pad_prefill_cache(cache, 32, window=8)
        assert out["k"].shape[1] == 8
        np.testing.assert_array_equal(np.asarray(out["k"])[:, :5],
                                      np.asarray(cache["k"]))
        np.testing.assert_array_equal(np.asarray(out["k"])[:, 5:], 0)


class TestInt8ScaleLeaves:
    def test_scales_follow_values_through_ring(self):
        """k_s/v_s (time axis ndim-2) must reorder exactly like k/v."""
        S, W = 11, 8
        cache = _attn_cache(S=S, int8=True)
        out = pad_prefill_cache(cache, 16, window=W)
        assert out["k_s"].shape[1] == W and out["v_s"].shape[1] == W
        ks_in = np.asarray(cache["k_s"].astype(jnp.float32))
        ks_out = np.asarray(out["k_s"].astype(jnp.float32))
        for s in range(W):
            newest = max(p for p in range(S) if p % W == s)
            np.testing.assert_array_equal(ks_out[:, s], ks_in[:, newest])

    def test_scales_pad_like_values_without_window(self):
        cache = _attn_cache(S=6, int8=True)
        out = pad_prefill_cache(cache, 12)
        assert out["k_s"].shape[1] == 12 and out["v"].shape[1] == 12
        np.testing.assert_array_equal(
            np.asarray(out["v_s"].astype(jnp.float32))[:, :6],
            np.asarray(cache["v_s"].astype(jnp.float32)))
        np.testing.assert_array_equal(
            np.asarray(out["k_s"].astype(jnp.float32))[:, 6:], 0)

    def test_capacity_overflow_still_loud(self):
        """The deep ValueError remains as a backstop for non-engine
        callers (the engine rejects oversized prompts at submit)."""
        cache = _attn_cache(S=20)
        with pytest.raises(ValueError, match="exceeds capacity"):
            pad_prefill_cache(cache, 12)


class TestDynamicTrueLen:
    """Bucketed prefill: the cache's static time length is the padded
    bucket; true_len rides along as a traced scalar."""

    def _sliced_ref(self, cache, L, cap, window):
        sliced = {k: (v[:, :L] if k in ("k", "v", "k_s", "v_s") else
                      jnp.full_like(v, L))
                  for k, v in cache.items()}
        return pad_prefill_cache(sliced, cap, window=window)

    @pytest.mark.parametrize("L,window", [(5, 8), (11, 8), (8, 8), (3, 0),
                                          (11, 0)])
    def test_matches_static_conversion_of_true_prefix(self, L, window):
        bucket, cap = 16, 16
        cache = _attn_cache(S=bucket, int8=(window == 8))
        got = jax.jit(
            lambda c, tl: pad_prefill_cache(c, cap, window=window,
                                            true_len=tl)
        )(cache, jnp.asarray(L, jnp.int32))
        ref = self._sliced_ref(cache, L, cap, window)
        assert got["k"].shape == ref["k"].shape
        np.testing.assert_array_equal(np.asarray(got["len"]), L)
        ring = min(cap, window) if window else cap
        # every slot that is VALID at len == L must match the static
        # conversion (invalid slots hold masked garbage by design)
        valid = [s for s in range(ring)
                 if (L > s if L <= ring or not window else True)]
        for key in ("k", "v") + (("k_s", "v_s") if window == 8 else ()):
            g = np.asarray(got[key].astype(jnp.float32))
            r = np.asarray(ref[key].astype(jnp.float32))
            for s in valid:
                np.testing.assert_array_equal(g[:, s], r[:, s], err_msg=key)

    def test_mla_latent_len_overridden(self):
        rng = np.random.default_rng(0)
        cache = {
            "latent": jnp.asarray(rng.normal(size=(1, 12, 4)).astype(np.float32)),
            "k_rope": jnp.asarray(rng.normal(size=(1, 12, 2)).astype(np.float32)),
            "len": jnp.full((1,), 12, jnp.int32),
        }
        out = pad_prefill_cache(cache, 16, true_len=jnp.asarray(7, jnp.int32))
        assert out["latent"].shape[1] == 16
        np.testing.assert_array_equal(np.asarray(out["len"]), 7)
        np.testing.assert_array_equal(np.asarray(out["latent"])[:, :12],
                                      np.asarray(cache["latent"]))

    def test_recurrent_state_passes_through(self):
        state = {"h": jnp.ones((1, 4)), "conv": jnp.zeros((1, 3, 4))}
        out = pad_prefill_cache({"rec": state}, 16,
                                true_len=jnp.asarray(5, jnp.int32))
        np.testing.assert_array_equal(np.asarray(out["rec"]["h"]), 1)
        assert out["rec"]["conv"].shape == (1, 3, 4)


class TestToRingDynamicEdges:
    """Regression pins for the _to_ring_dynamic zero-fill fix: slots
    holding no real position used to carry clip-duplicated garbage that
    broke paged/contiguous bit-comparisons (serve/paging.py relies on
    byte-equal rings) and aliased position 0 at true_len == 0."""

    def _x(self, S=16, F=3):
        return jnp.asarray(
            np.arange(S * F, dtype=np.float32).reshape(1, S, F))

    def test_true_len_zero_is_all_zeros(self):
        out = _to_ring_dynamic(self._x(), 1, 8, jnp.asarray(0, jnp.int32))
        assert out.shape[1] == 8
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_true_len_equals_ring_is_identity_prefix(self):
        x = self._x()
        out = _to_ring_dynamic(x, 1, 8, jnp.asarray(8, jnp.int32))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(x[:, :8]))

    def test_partial_fill_zeroes_tail(self):
        x = self._x()
        out = _to_ring_dynamic(x, 1, 8, jnp.asarray(5, jnp.int32))
        np.testing.assert_array_equal(np.asarray(out[:, :5]),
                                      np.asarray(x[:, :5]))
        np.testing.assert_array_equal(np.asarray(out[:, 5:]), 0.0)

    def test_wrapped_matches_decode_slot_rule(self):
        x, ring, L = self._x(), 8, 13
        out = _to_ring_dynamic(x, 1, ring, jnp.asarray(L, jnp.int32))
        for s in range(ring):
            newest = max(p for p in range(L) if p % ring == s)
            np.testing.assert_array_equal(np.asarray(out[:, s]),
                                          np.asarray(x[:, newest]))
