"""Pallas TPU kernel for the prefill path: int8 x int8 -> int32 GEMM with
per-token / per-channel dequant scales.

This is the paper's "INT8 prefill" mode of the reconfigurable PE array
(§IV-B) expressed TPU-natively: the MXU is int8-capable, so no PE
decomposition trick is needed — int8 dot_general with int32 accumulation
IS the reconfigured mode.

Grid: (num_m_tiles, num_n_tiles, num_k_tiles), K innermost; int32
accumulator held in VMEM scratch, scales applied at the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_gemm_kernel(x_ref, w_ref, xs_ref, ws_ref, y_ref, acc_scr, *, n_k_tiles: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(kk == n_k_tiles - 1)
    def _dequant():
        y_ref[...] = (
            acc_scr[...].astype(jnp.float32)
            * xs_ref[...].astype(jnp.float32)
            * ws_ref[...].astype(jnp.float32)
        )


def int8_gemm_pallas(
    xq: jax.Array,   # (M, K) int8
    wq: jax.Array,   # (K, N) int8
    xs: jax.Array,   # (M, 1) fp32
    ws: jax.Array,   # (1, N) fp32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, K = xq.shape
    K2, N = wq.shape
    assert K == K2
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    n_k_tiles = K // block_k
    grid = (M // block_m, N // block_n, n_k_tiles)

    kernel = functools.partial(_int8_gemm_kernel, n_k_tiles=n_k_tiles)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, n, kk: (m, kk)),
            pl.BlockSpec((block_k, block_n), lambda m, n, kk: (kk, n)),
            pl.BlockSpec((block_m, 1), lambda m, n, kk: (m, 0)),
            pl.BlockSpec((1, block_n), lambda m, n, kk: (0, n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, kk: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(xq, wq, xs, ws)
