"""Roofline tooling: HLO parser correctness on programs with known costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import analyze, parse_hlo
from repro.roofline.analysis import (
    HBM_BW, LINK_BW, PEAK_FLOPS, RooflineReport, model_flops,
)


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


class TestHloParser:
    def test_plain_dot_flops(self):
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        c = _compile(lambda x, y: x @ y, a, b)
        got = analyze(c.as_text()).flops
        assert got == 2 * 64 * 128 * 32

    def test_scan_trip_count_multiplies(self):
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, ws)[0]

        x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
        got = analyze(_compile(f, x, ws).as_text()).flops
        assert got == 7 * 2 * 16 * 32 * 32

    def test_grad_of_scan_counts_backward(self):
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, ws)[0].sum()

        x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
        got = analyze(_compile(jax.grad(f, argnums=1), x, ws).as_text()).flops
        base = 5 * 2 * 16 * 32 * 32
        assert got == pytest.approx(3 * base)

    def test_batched_dot(self):
        a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
        got = analyze(_compile(lambda x, y: jnp.einsum("bij,bjk->bik", x, y),
                               a, b).as_text()).flops
        assert got == 2 * 4 * 8 * 16 * 8

    def test_nested_while(self):
        def f(x, ws):
            def outer(c, w):
                def inner(ci, _):
                    return jnp.tanh(ci @ w), None
                return jax.lax.scan(inner, c, None, length=3)[0], None
            return jax.lax.scan(outer, x, ws)[0]

        x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        ws = jax.ShapeDtypeStruct((4, 16, 16), jnp.float32)
        got = analyze(_compile(f, x, ws).as_text()).flops
        assert got == 4 * 3 * 2 * 8 * 16 * 16

    def test_parse_hlo_finds_entry(self):
        c = _compile(lambda x: x + 1, jax.ShapeDtypeStruct((4,), jnp.float32))
        comps, entry = parse_hlo(c.as_text())
        assert entry is not None and entry in comps


class TestCollectiveParsing:
    def test_psum_bytes_multi_device(self):
        """Compile an 8-way psum in a subprocess-free way: use the parser
        on a handcrafted HLO snippet (device count is 1 in-process)."""
        hlo = """
ENTRY %main.1 (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %all-reduce.1 = f32[1024]{0} all-reduce(%p0), replica_groups=[1,8]<=[8], to_apply=%add.1
}
%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
"""
        got = analyze(hlo)
        assert got.collective_counts == {"all-reduce": 1}
        # ring model: 2 * bytes * (g-1)/g
        assert got.collective_bytes == int(2 * 1024 * 4 * 7 / 8)

    def test_collective_in_while_multiplied(self):
        hlo = """
ENTRY %main.1 (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %tuple.1 = (s32[], f32[64]{0}) tuple(%c0, %p0)
  %while.1 = (s32[], f32[64]{0}) while(%tuple.1), condition=%cond.1, body=%body.1
  ROOT %gte.9 = f32[64]{0} get-tuple-element(%while.1), index=1
}
%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]{0}) parameter(0)
  %g = f32[64]{0} get-tuple-element(%p), index=1
  %ar = f32[64]{0} all-gather(%g), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %t = (s32[], f32[64]{0}) tuple(%i, %ar)
}
%cond.1 (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
"""
        got = analyze(hlo)
        assert got.collective_counts == {"all-gather": 12}
        assert got.collective_bytes == 12 * int(64 * 4 * 3 / 4)


class TestReport:
    def test_bottleneck_selection(self):
        r = RooflineReport(
            arch="a", shape="s", mesh="m", chips=256,
            flops_per_device=PEAK_FLOPS,      # 1 s compute
            hbm_bytes_per_device=HBM_BW / 2,  # 0.5 s memory
            collective_bytes_per_device=LINK_BW / 4,
            collective_breakdown={}, argument_bytes=0, output_bytes=0,
            temp_bytes=0, model_flops=PEAK_FLOPS * 256 / 2,
        ).finalize()
        assert r.bottleneck == "compute"
        assert r.t_compute == pytest.approx(1.0)
        assert r.useful_ratio == pytest.approx(0.5)
        assert r.bound_time == pytest.approx(1.0)

    def test_model_flops(self):
        # train: 6 * N * tokens ; decode: 2 * N_active * batch
        assert model_flops(None, "train", 4096, 256, 1e9) \
            == 6 * 1e9 * 4096 * 256
        assert model_flops(None, "decode", 32768, 128, 1e9, 0.25e9) \
            == 2 * 0.25e9 * 128
