"""Jit'd wrapper for the flash-decode kernel (padding + dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import flash_decode_pallas
from repro.kernels.flash_decode.ref import flash_decode_ref


@functools.partial(jax.jit, static_argnames=("block_s", "interpret", "use_pallas"))
def flash_decode(
    q: jax.Array,        # (B, H, hd) or (B, 1, H, hd)
    k: jax.Array,        # (B, S, Hk, hd)
    v: jax.Array,
    lengths: jax.Array,  # (B,)
    *,
    block_s: int = 512,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    if not use_pallas:
        o = flash_decode_ref(q, k, v, lengths)
    else:
        B, S = k.shape[0], k.shape[1]
        bs = min(block_s, S)
        pad = (-S) % bs
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        o = flash_decode_pallas(q, k, v, lengths.astype(jnp.int32),
                                block_s=bs, interpret=interpret)
    return o[:, None] if squeeze else o


@functools.partial(jax.jit, static_argnames=("block_s", "interpret", "use_pallas"))
def flash_decode_paged(
    q: jax.Array,            # (B, H, hd) or (B, 1, H, hd)
    k_arena: jax.Array,      # (NB, bs, Hk, hd) shared block arena
    v_arena: jax.Array,
    block_table: jax.Array,  # (B, W) physical block ids (NB == sentinel)
    lengths: jax.Array,      # (B,)
    *,
    block_s: int = 512,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    """Flash decode over a paged KV cache (serve/paging.py): gather the
    per-request contiguous view through the block table, then run the
    unchanged kernel. ``W * bs`` equals the contiguous cache's time
    length by construction, so outputs are token-identical to
    ``flash_decode`` over the contiguous cache. Sentinel block ids clamp
    to in-bounds garbage masked by ``lengths`` (``mode="clip"`` — the
    default fill mode would inject NaN that survives masking)."""
    B, W = block_table.shape
    bs = k_arena.shape[1]
    k = jnp.take(k_arena, block_table, axis=0, mode="clip").reshape(
        (B, W * bs) + k_arena.shape[2:])
    v = jnp.take(v_arena, block_table, axis=0, mode="clip").reshape(
        (B, W * bs) + v_arena.shape[2:])
    return flash_decode(q, k, v, lengths, block_s=block_s,
                        interpret=interpret, use_pallas=use_pallas)
