"""Measured (wall-clock, jitted, CPU) benchmarks of the actual JAX ops —
complements the analytic accelerator model with real executions:

  * eva_matmul vs dequant_matmul vs dense matmul at paper decode shapes
    (M=1, LLaMA-2-7B layer sizes): the compute-collapse (N/2^n) shows up
    as a real CPU speedup because the FLOPs genuinely shrink.
  * Pallas kernels in interpret mode at reduced shapes (correct-path
    timing only; interpret mode is not representative of TPU perf).

Every row is CALIBRATION-READY: alongside the chosen ``plan=`` it emits
the plan's cost-model terms (``backend=``/``macs=``/``lookup_adds=``/
``weight_bytes=`` + ``intermediate_bytes=``/``launches=`` where they
differ from the defaults) so `core/calibrate.py` can fit per-backend
time constants from the committed BENCH_measured.json. Interpret-mode
rows carry ``interpret=1`` and are excluded from fitting. Rows where the
Planner ranked multiple eligible backends also report the predicted-time
``ranking=`` and the ``first_match=`` backend the pre-ranking dispatch
would have picked.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as core_ops
from repro.core import plan as plan_mod
from repro.core.vq import split_grouped, synthetic_vq


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def _plan(x, vq, **policy_kw) -> plan_mod.MatmulPlan:
    """The plan the policy resolves to for this call — the single source
    of backend/epilogue naming AND cost fields in these rows (no
    re-implemented selection logic here)."""
    policy_kw.setdefault("vq_mode", "eva")
    policy = plan_mod.PlanPolicy(**policy_kw)
    return plan_mod.plan_vq(x, vq, policy)


def plan_fields(pl: plan_mod.MatmulPlan) -> str:
    """Calibration-ready derived fields for one planned execution."""
    c = pl.cost
    parts = [f"plan={pl.describe()}", f"backend={pl.backend}",
             f"macs={c.macs}", f"lookup_adds={c.lookup_adds}",
             f"weight_bytes={c.weight_bytes}"]
    if c.intermediate_bytes:
        parts.append(f"intermediate_bytes={c.intermediate_bytes}")
    if c.launches != 1:
        parts.append(f"launches={c.launches}")
    if pl.policy.interpret:
        parts.append("interpret=1")
    if pl.predicted_us is not None:
        parts.append(f"pred_us={pl.predicted_us:.1f}")
        parts.append(f"cost_model={pl.provenance}")
    rk = pl.describe_ranking()
    if rk:
        parts.append(f"ranking={rk}")
        parts.append(
            f"first_match={plan_mod.first_match_backend(pl.spec, pl.policy)}")
    return ";".join(parts)


def run(report):
    key = jax.random.PRNGKey(0)
    shapes = [(4096, 4096), (4096, 11008), (11008, 4096)]
    rows = []
    for K, N in shapes:
        x = jax.random.normal(key, (1, K), jnp.float32)
        w = jax.random.normal(key, (K, N), jnp.float32) * 0.02
        vq = synthetic_vq(key, K, N, d=8, n=8, C=2)

        t_dense = _time(jax.jit(core_ops.fp_matmul), x, w)
        t_deq = _time(jax.jit(core_ops.dequant_matmul), x, vq)
        t_eva = _time(jax.jit(core_ops.eva_matmul), x, vq)
        rows.append((K, N, t_dense, t_deq, t_eva))
        report(f"measured/eva_{K}x{N}", t_eva * 1e6,
               f"dense_us={t_dense*1e6:.0f};dequant_us={t_deq*1e6:.0f};"
               f"speedup_vs_dequant={t_deq/t_eva:.2f};"
               f"{plan_fields(_plan(x, vq))}")
        # baseline rows in their own right: calibration samples for the
        # fp and dequant_jnp backends (the timings are already in hand)
        pl_fp = plan_mod.plan_node({"w": w}, x, mode="decode",
                                   policy=plan_mod.PlanPolicy())
        report(f"measured/dense_{K}x{N}", t_dense * 1e6, plan_fields(pl_fp))
        pl_dq = _plan(x, vq, vq_mode="dequant")
        report(f"measured/dequant_{K}x{N}", t_deq * 1e6, plan_fields(pl_dq))

    # batched decode (continuous batching regime): the AUTO epilogue must
    # stay >= 1x vs dequant across the M sweep. At M>=8 the direct
    # gather's (C, M, V, N) intermediate falls out of cache and used to
    # regress below the dequant baseline; select_epilogue switches to the
    # v-blocked scan there (direct_us is reported for crossover evidence).
    K, N = 4096, 4096
    vq = synthetic_vq(key, K, N, d=8, n=8, C=2)
    for M in (1, 8, 32):
        x = jax.random.normal(key, (M, K), jnp.float32)
        t_eva = _time(jax.jit(core_ops.eva_matmul), x, vq)      # auto
        t_dir = _time(jax.jit(
            lambda a, b: core_ops.eva_matmul(a, b, epilogue="direct")), x, vq)
        t_deq = _time(jax.jit(core_ops.dequant_matmul), x, vq)
        report(f"measured/batch{M}_{K}x{N}", t_eva * 1e6,
               f"dequant_us={t_deq*1e6:.0f};speedup={t_deq/t_eva:.2f};"
               f"direct_us={t_dir*1e6:.0f};"
               f"{plan_fields(_plan(x, vq))}")
        if M > 1:  # forced-direct rows calibrate eva_direct beyond M=1
            report(f"measured/direct{M}_{K}x{N}", t_dir * 1e6,
                   plan_fields(_plan(x, vq, epilogue="direct")))

    # grouped QKV decode: ONE wide VQ-GEMM + OC lookup over [Wq|Wk|Wv]
    # (shared codebook set, core/vq.py grouped layout) vs three separate
    # eva_matmul calls — both sides inside one jit, as the jitted engine
    # decode step executes them. The structural saving is the paper's
    # compute-collapse advantage amortized 3x (grouped collapse ratio
    # (Nq+Nk+Nv)/2^n vs N_i/2^n per member); on this CPU oracle the jnp
    # gather epilogue — free lookup hardware on the paper's accelerator —
    # bounds the end-to-end win, so TPU gains are strictly larger. The
    # advantage grows as per-member N shrinks toward 2^n, so we measure
    # both an unsharded GQA layer and a TP8-sharded one (each rank holds
    # N_i/8 columns). Grouped/separate windows are INTERLEAVED and each
    # side reports its min-of-reps (least-interfered window) — shared-
    # runner load drift otherwise swamps the effect. The epilogue is the
    # AUTO selection on both sides (per-regime: direct at M=1, recon at
    # M>=8) — grouped families inherit it through the same eva_matmul
    # default. Families: attention QKV (unsharded GQA + TP8 shard),
    # xlstm mLSTM qkv (square di x di members) and MLA q+kv_a.
    for K, splits, tag in (
            ((4096), (4096, 1024, 1024), "qkv_llama3_8b"),
            ((8192), (1024, 128, 128), "qkv_qwen2_72b_tp8"),
            ((1536), (1536, 1536, 1536), "xlstm_mlstm_qkv"),   # di = 2*768
            ((2048), (3072, 576), "mla_q_kva_dsv2lite"),       # H*(dn+dr), r+dr
    ):
        g = synthetic_vq(key, K, sum(splits), d=8, n=8, C=2, splits=splits)
        members = split_grouped(g)  # same weights, executed apart
        for M in (1, 8):
            x = jax.random.normal(key, (M, K), jnp.float32)
            f_grp = jax.jit(lambda xx, vq: core_ops.split_grouped_outputs(
                core_ops.eva_matmul(xx, vq), vq))
            f_sep = jax.jit(lambda xx, *ms: tuple(
                core_ops.eva_matmul(xx, m) for m in ms))
            for _ in range(2):  # compile + warm
                jax.block_until_ready(f_grp(x, g))
                jax.block_until_ready(f_sep(x, *members))
            # size each timing window to ~200ms so scheduler interference
            # can't flip a single rep; min-of-reps = least-interfered run
            est = _time(f_grp, x, g, iters=1, warmup=0)
            iters = max(2, int(0.2 / max(est, 1e-4)))
            t_g, t_s = [], []
            for _ in range(7):
                t_g.append(_time(f_grp, x, g, iters=iters, warmup=0))
                t_s.append(_time(f_sep, x, *members, iters=iters, warmup=0))
            collapse = core_ops.grouped_compute_collapse_ratio(g.splits, g.n)
            report(f"measured/grouped_{tag}_m{M}", min(t_g) * 1e6,
                   f"separate_us={min(t_s)*1e6:.0f};"
                   f"speedup_vs_separate={min(t_s)/min(t_g):.2f};"
                   f"grouped_collapse_ratio={collapse:.0f};"
                   f"{plan_fields(_plan(x, g))}")

    # pallas kernels, interpret mode (validation-path timing): time the
    # PLANNED execution so the reported plan's tiles are exactly the
    # configuration that was measured. These rows carry interpret=1 and
    # never feed calibration; the fused-vs-split decision they report is
    # the Planner's predicted-time RANKING (vs the first_match= backend
    # the old dispatch order would have picked).
    fused_policy = plan_mod.PlanPolicy(vq_mode="eva", impl="pallas",
                                       interpret=True)
    vq_s = synthetic_vq(key, 256, 512, d=8, n=8, C=2)
    x_s = jax.random.normal(key, (1, 256), jnp.float32)
    pl_s = plan_mod.plan_vq(x_s, vq_s, fused_policy)
    t_fused = _time(pl_s.execute, x_s, vq_s, iters=3)
    report("measured/pallas_ranked_interpret_256x512", t_fused * 1e6,
           "interpret-mode (CPU emulation, not TPU-representative);"
           f"{plan_fields(pl_s)}")

    # the two-kernel split candidate at the same shape (vq_gemm writes
    # the OC buffer to HBM, oc_lookup gathers from it — no fusion)
    from repro.kernels.oc_lookup.ops import _plan_eva_split

    split_pl = _plan_eva_split(pl_s.spec, fused_policy)
    t_split = _time(split_pl.execute, x_s, vq_s, iters=3)
    report("measured/pallas_split_interpret_256x512", t_split * 1e6,
           "interpret-mode; vq_gemm->HBM OC buffer->oc_lookup;"
           f"{plan_fields(split_pl)}")

    # grouped family through the fused Pallas kernel (interpret): one call,
    # one OC scratch fill, the N sweep covers all three members
    g_s = synthetic_vq(key, 256, 384, d=8, n=8, C=2, splits=(256, 64, 64))
    pl_g = plan_mod.plan_vq(x_s, g_s, fused_policy)
    t_gfused = _time(pl_g.execute, x_s, g_s, iters=3)
    report("measured/pallas_fused_grouped_interpret_256x384", t_gfused * 1e6,
           "interpret-mode; uint8 index tiles, grouped qkv sweep;"
           f"{plan_fields(pl_g)}")
    return rows
