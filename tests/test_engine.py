"""Serving-engine tests: continuous batching correctness, the typed
submit/step/stream surface, in-jit sampling/stopping, bucketed prefill
trace counts and metrics consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.common import RunConfig
from repro.serve import (Engine, EngineConfig, GenerationRequest,
                         SamplingParams, Scheduler)
from repro.serve.kvcache import pad_prefill_cache
from repro.serve.scheduler import QueueFull

KEY = jax.random.PRNGKey(0)


def _greedy_reference(model, params, prompt, max_new, rc, cap):
    """Sequential single-request greedy decode (the pre-redesign engine's
    exact-length prefill + host argmax)."""
    cfg = model.cfg
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None], jnp.int32)},
        rc.replace(mode="prefill"),
    )
    window = cfg.sliding_window or cfg.local_window
    caches = pad_prefill_cache(caches, cap, window=window)
    out = [int(np.argmax(np.asarray(logits[0, -1, :cfg.vocab_size])))]
    pos = len(prompt)
    while len(out) < max_new:
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, caches = model.decode(
            params, tok, jnp.asarray([[pos]], jnp.int32), caches,
            rc.replace(mode="decode"),
        )
        out.append(int(np.argmax(np.asarray(logits[0, 0, :cfg.vocab_size]))))
        pos += 1
    return out


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    rc = RunConfig(mode="decode", remat=False, attn_chunk=16)
    return cfg, model, params, rc


def test_continuous_batching_matches_sequential(setup):
    """generate() over the submit/step surface reproduces the
    pre-redesign greedy outputs token-for-token — bucketed prefill and
    in-jit argmax included."""
    cfg, model, params, rc = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 7, 4, 6)]
    max_new = 6
    ecfg = EngineConfig(num_slots=2, max_len=32)  # slots < requests: queueing
    eng = Engine(model, params, rc, ecfg)
    got = eng.generate(prompts, max_new)
    for uid, prompt in zip(got, prompts):
        ref = _greedy_reference(model, params, prompt, max_new, rc, 32)
        assert got[uid] == ref, (uid, got[uid], ref)


def test_scheduler_slot_lifecycle():
    s = Scheduler(num_slots=2)
    req = lambda n: GenerationRequest(prompt=np.ones(n, np.int32),
                                      max_new_tokens=4)
    u1 = s.submit(req(3))
    u2 = s.submit(req(4))
    u3 = s.submit(req(5))
    admitted = s.admit()
    assert len(admitted) == 2 and len(s.queue) == 1
    r = s.finish(admitted[0])
    assert r.uid == u1
    assert s.admit() == [admitted[0]]  # freed slot reused for u3
    assert not s.idle
    s.finish(0), s.finish(1)
    assert s.idle
    assert u2 != u3


def test_scheduler_admits_earliest_deadline_first():
    """EDF admission: the queued request with the nearest absolute
    deadline wins the free slot; no-deadline requests rank behind all
    deadlined ones, FIFO among themselves."""
    s = Scheduler(num_slots=1)
    req = lambda dl: GenerationRequest(prompt=np.ones(3, np.int32),
                                       max_new_tokens=2, deadline_s=dl)
    ua = s.submit(req(None))
    ub = s.submit(req(60.0))
    uc = s.submit(req(5.0))
    assert s.slots[s.admit()[0]].uid == uc  # tightest deadline first
    s.finish(0)
    assert s.slots[s.admit()[0]].uid == ub
    s.finish(0)
    assert s.slots[s.admit()[0]].uid == ua


def test_scheduler_admit_predicate_stops_without_bypass():
    """A can_admit refusal (the paged engine's block budget) stops the
    admission sweep instead of skipping to a smaller request behind the
    refused one — no head-of-line bypass, so large requests can't
    starve."""
    s = Scheduler(num_slots=2)
    big = GenerationRequest(prompt=np.ones(20, np.int32), max_new_tokens=2)
    small = GenerationRequest(prompt=np.ones(3, np.int32), max_new_tokens=2)
    s.submit(big), s.submit(small)
    admitted = s.admit(lambda tr: len(tr.request.prompt) < 10)
    assert admitted == [] and len(s.queue) == 2
    assert s.admit() and s.slots[0].request is big  # budget freed: FIFO


def test_scheduler_queue_bound():
    """The waiting queue is bounded: submit raises QueueFull at max_queue
    instead of growing the deque without limit."""
    s = Scheduler(num_slots=1, max_queue=2)
    req = GenerationRequest(prompt=np.ones(3, np.int32))
    s.submit(req), s.submit(req)
    with pytest.raises(QueueFull):
        s.submit(req)
    s.admit()  # one moves to a slot; queue has room again
    s.submit(req)


class _CountingModel:
    """Deterministic stub: next-token = (last_token + 1) % vocab. Lets the
    slot-retirement tests place a stop token mid-stream exactly and count
    batched decode steps. (The engine edge-pads bucketed prompts, so
    prefill's tokens[:, -1] stays the real last token.)"""

    def __init__(self, cfg):
        self.cfg = cfg

    def init_cache(self, slots, max_len):
        return {"state": jnp.zeros((1, slots, 1), jnp.float32)}

    def prefill(self, params, batch, rc):
        nxt = (batch["tokens"][:, -1] + 1) % self.cfg.vocab_size
        logits = jax.nn.one_hot(nxt, self.cfg.vocab_size)[:, None, :]
        return logits, {"state": jnp.zeros((1, 1, 1), jnp.float32)}

    def decode(self, params, tokens, positions, caches, rc):
        nxt = (tokens[:, 0] + 1) % self.cfg.vocab_size
        logits = jax.nn.one_hot(nxt, self.cfg.vocab_size)[:, None, :]
        return logits, caches


def _counting_engine(num_slots=2, max_len=64):
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), vocab_size=32)
    model = _CountingModel(cfg)
    eng = Engine(model, {}, RunConfig(mode="decode", remat=False),
                 EngineConfig(num_slots=num_slots, max_len=max_len))
    # count batched decode steps
    inner = eng._decode_fn
    calls = {"n": 0}

    def counted(*a, **kw):
        calls["n"] += 1
        return inner(*a, **kw)

    eng._decode_fn = counted
    return eng, calls


def _req(prompt_tok, max_new, eos=(), sampling=None):
    return GenerationRequest(prompt=np.array([prompt_tok], np.int32),
                             max_new_tokens=max_new, eos_ids=eos,
                             sampling=sampling or SamplingParams())


def _drain(eng):
    events = []
    while not eng.idle:
        events.extend(eng.step())
    return events


def test_slot_retires_in_same_step_as_eos():
    """Regression (slot-retirement bug): a request whose eos arrives
    mid-stream must free its slot in the step the token is generated —
    previously it occupied the slot for one extra batched decode step
    (with positions bumped for it anyway). eos is now PER-REQUEST
    (eos_ids), evaluated in-jit."""
    eng, calls = _counting_engine(num_slots=1)
    # prompt ends at 5 -> prefill emits 6; decode emits 7, 8, 9(eos)
    eng.submit(_req(5, 10, eos=(9,)))
    _drain(eng)
    out = eng.output(1)
    assert list(out.tokens) == [6, 7, 8, 9]
    assert out.finish_reason == "stop"
    # exactly 3 decode steps (7, 8, 9) — the old check-before-consume loop
    # needed a 4th step just to notice the eos
    assert calls["n"] == 3


def test_eos_slot_frees_for_queued_request_immediately():
    """With one slot and two requests, the freed slot admits the queued
    request on the tick right after eos — no dead step in between."""
    eng, calls = _counting_engine(num_slots=1)
    u1 = eng.submit(_req(6, 4, eos=(9,)))
    u2 = eng.submit(_req(20, 4, eos=(9,)))
    _drain(eng)
    # first: prefill 7, decode 8, 9(eos); second: prefill 21, decode 22..24
    assert list(eng.output(u1).tokens) == [7, 8, 9]
    assert list(eng.output(u2).tokens) == [21, 22, 23, 24]
    assert calls["n"] == 2 + 3  # no wasted step between the requests

    # a fresh engine serving only the second request needs the same 3
    # decode steps — the queued request paid zero extra latency
    eng2, calls2 = _counting_engine(num_slots=1)
    eng2.submit(_req(20, 4, eos=(9,)))
    _drain(eng2)
    assert calls2["n"] == 3


def test_eos_in_prefill_token_never_decodes():
    """A request whose very first (prefill-sampled) token is in its stop
    set — or whose budget is a single token — retires without any decode
    step."""
    eng, calls = _counting_engine()
    eng.submit(_req(8, 10, eos=(9,)))
    _drain(eng)
    assert list(eng.output(1).tokens) == [9]
    assert eng.output(1).finish_reason == "stop"
    assert calls["n"] == 0

    eng2, calls2 = _counting_engine()
    eng2.submit(_req(3, 1))
    _drain(eng2)
    assert list(eng2.output(1).tokens) == [4]
    assert eng2.output(1).finish_reason == "length"
    assert calls2["n"] == 0


def test_free_slots_fed_masked_tokens():
    """Free slots must not replay their stale last_token through decode:
    the engine masks them to token 0 / position 0."""
    eng, _ = _counting_engine(num_slots=2)
    seen = []
    inner = eng._decode_fn

    def spy(params, caches, tokens, positions, *rest):
        seen.append((np.asarray(tokens).ravel().copy(),
                     np.asarray(positions).ravel().copy()))
        return inner(params, caches, tokens, positions, *rest)

    eng._decode_fn = spy
    # slot 0 hits eos (9) in the second decode step; slot 1 keeps going
    eng.submit(_req(6, 6, eos=(9,)))
    eng.submit(_req(20, 6, eos=(9,)))
    _drain(eng)
    assert len(seen) == 5  # slot 1: 22, 23, 24, 25, 26
    # while slot 0 is live its lane carries the real last_token
    assert seen[0][0][0] == 7 and seen[1][0][0] == 8
    # after slot 0 retires, its lane must carry the masked 0 at position
    # 0 — never its stale eos token / bumped position
    for tok, pos in seen[2:]:
        assert tok[0] == 0 and pos[0] == 0, (tok, pos)


def test_concurrent_requests_finish_independently():
    """Two concurrent requests with different eos and temperature finish
    in their own correct step — stop sets and sampling params are
    per-slot device state, not engine globals."""
    eng, calls = _counting_engine(num_slots=2)
    # near-greedy sampled request: one-hot logits at temperature 0.01
    # concentrate all mass on the counting token
    sharp = SamplingParams(greedy=False, temperature=0.01, seed=3)
    ua = eng.submit(_req(5, 10, eos=(9,)))              # 6,7,8,9 -> stop @ 3
    ub = eng.submit(_req(20, 10, eos=(25,), sampling=sharp))  # 21..25 @ 4
    events = _drain(eng)
    a, b = eng.output(ua), eng.output(ub)
    assert list(a.tokens) == [6, 7, 8, 9] and a.finish_reason == "stop"
    assert list(b.tokens) == [21, 22, 23, 24, 25] and b.finish_reason == "stop"
    # b needed one more decode step than a; total steps = max chain
    assert calls["n"] == 4
    # terminal events carry each request's own final index: a at 3, b at 4
    term = {e.uid: e for e in events if e.done}
    assert term[ua].index == 3 and term[ub].index == 4


def test_decode_traces_once_mixed_sampling(setup):
    """The jitted decode step traces exactly ONCE across a mixed-sampling
    workload: greedy, temperature+top_k and top_p requests differ only in
    per-slot device data."""
    cfg, model, params, rc = setup
    eng = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=32))
    rng = np.random.default_rng(2)
    p = lambda n: rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    eng.submit(GenerationRequest(prompt=p(5), max_new_tokens=4))
    eng.submit(GenerationRequest(
        prompt=p(6), max_new_tokens=4,
        sampling=SamplingParams(greedy=False, temperature=0.7, top_k=8,
                                seed=1)))
    eng.submit(GenerationRequest(
        prompt=p(7), max_new_tokens=3, eos_ids=(2,),
        sampling=SamplingParams(greedy=False, top_p=0.9, seed=2)))
    _drain(eng)
    assert eng.trace_counts["decode"] == 1


def test_prefill_traces_once_per_bucket(setup):
    """Bucketed prefill: prompts pad to power-of-two buckets and the
    jitted prefill step retraces at most once per bucket (not once per
    prompt length). Counted via the engine's trace-counting harness."""
    cfg, model, params, rc = setup
    eng = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=32))
    rng = np.random.default_rng(3)
    # lengths 3/5/6 -> bucket 8; 9/12 -> bucket 16: exactly two traces
    for n in (3, 5, 6, 9, 12):
        eng.submit(GenerationRequest(
            prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
            max_new_tokens=2))
    _drain(eng)
    assert eng.trace_counts["prefill"] == 2
    # pre-planned per-bucket entries replaced the single prefill@cap
    # estimate: every bucket plan is at the exact padded execution M
    assert {"prefill@8", "prefill@16", "prefill@32"} <= set(eng.plans)
    assert "prefill@cap" not in eng.plans
    for m in (8, 16, 32):
        assert all(pl.spec.M == m for _p, pl in eng.plans[f"prefill@{m}"])


def test_metrics_consistent_with_stream_events(setup):
    """Engine.metrics() totals agree with the emitted StreamEvents."""
    cfg, model, params, rc = setup
    eng = Engine(model, params, rc,
                 EngineConfig(num_slots=2, max_len=32, max_queue=2))
    rng = np.random.default_rng(4)
    p = lambda n: rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    eng.submit(GenerationRequest(prompt=p(5), max_new_tokens=3))
    eng.submit(GenerationRequest(
        prompt=p(6), max_new_tokens=4,
        sampling=SamplingParams(greedy=False, temperature=0.9, seed=5)))
    eng.submit(GenerationRequest(prompt=p(40), max_new_tokens=3))  # rejected
    events = _drain(eng)
    m = eng.metrics()
    token_events = [e for e in events if e.token is not None]
    terminal = [e for e in events if e.done]
    assert len(token_events) == m["tokens_generated"]
    assert m["finished"] == m["finished_stop"] + m["finished_length"]
    assert len(terminal) == m["finished"] + m["rejected"]
    assert m["submitted"] == 3 and m["admitted"] == 2 and m["rejected"] == 1
    assert m["tokens_generated"] == m["prefills"] + m["decode_slot_steps"]
    assert 0.0 < m["slot_occupancy"] <= 1.0


def test_submit_rejects_overlong_prompt_cleanly(setup):
    """A prompt longer than max_len used to die as a ValueError deep in
    kvcache._pad_time AFTER wasting prefill compute; it now rejects at
    submit() with a terminal RequestOutput and no compute."""
    cfg, model, params, rc = setup
    eng = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=16))
    uid = eng.submit(GenerationRequest(
        prompt=np.arange(40).astype(np.int32) % cfg.vocab_size,
        max_new_tokens=4))
    out = eng.output(uid)
    assert out is not None and out.finish_reason == "rejected"
    assert out.tokens == ()
    assert eng.trace_counts["prefill"] == 0  # no compute spent
    ev = eng.step()
    assert [e for e in ev if e.uid == uid and e.done and e.token is None]
    # generate() stays loud on rejection (the old crash, but clean+early,
    # and validated BEFORE anything is enqueued)
    with pytest.raises(ValueError, match="unservable"):
        eng.generate([np.arange(40).astype(np.int32) % cfg.vocab_size], 4)


def test_submit_rejects_decode_budget_past_capacity(setup):
    """A full (non-windowed) cache also needs room for the decode writes:
    prompt_len + max_new_tokens - 1 past max_len would silently clamp the
    KV write slot — reject it at submit instead."""
    cfg, model, params, rc = setup
    eng = Engine(model, params, rc, EngineConfig(num_slots=1, max_len=16))
    prompt = np.arange(12).astype(np.int32) % cfg.vocab_size
    uid = eng.submit(GenerationRequest(prompt=prompt, max_new_tokens=8))
    assert eng.output(uid).finish_reason == "rejected"
    # the same prompt with a fitting budget is served: 12 + 5 - 1 = 16
    uid2 = eng.submit(GenerationRequest(prompt=prompt, max_new_tokens=5))
    _drain(eng)
    assert eng.output(uid2).finish_reason == "length"
    assert len(eng.output(uid2).tokens) == 5


def test_generate_partial_rejection_enqueues_nothing(setup):
    """generate() validates the whole batch before submitting: a raise on
    an unservable prompt must not leave the servable ones queued for a
    later call (stale compute + leaked outputs)."""
    cfg, model, params, rc = setup
    eng = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=16))
    good = np.arange(4).astype(np.int32) % cfg.vocab_size
    bad = np.arange(40).astype(np.int32) % cfg.vocab_size
    with pytest.raises(ValueError, match="unservable"):
        eng.generate([good, bad], 4)
    assert eng.idle and len(eng.sched.queue) == 0
    m = eng.metrics()
    assert m["submitted"] == 0 and m["prefills"] == 0


def test_retained_outputs_bounded():
    """A long-running submit()/step() server that never reads outputs
    stays memory-bounded: finished outputs + event buffers evict FIFO
    past max_retained."""
    eng, _ = _counting_engine(num_slots=1)
    eng.ecfg.max_retained = 3
    uids = []
    for i in range(6):
        uids.append(eng.submit(_req(5, 2)))
        _drain(eng)
    assert all(eng.output(u) is None for u in uids[:3])
    assert all(eng.output(u) is not None for u in uids[3:])
    assert len(eng._outputs) == 3 and len(eng._buffers) == 3


def test_stream_iterator_delivers_all_tokens(setup):
    cfg, model, params, rc = setup
    eng = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=32))
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    uid = eng.submit(GenerationRequest(prompt=prompt, max_new_tokens=4))
    evs = list(eng.stream(uid))
    assert [e.index for e in evs] == [0, 1, 2, 3]
    assert evs[-1].done and evs[-1].finish_reason == "length"
    out = eng.output(uid)
    assert tuple(e.token for e in evs) == out.tokens
    # matches greedy generate() on a fresh engine
    eng2 = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=32))
    got = eng2.generate([prompt], 4)
    assert list(out.tokens) == list(got.values())[0]


def test_sampled_request_reproducible_and_different(setup):
    """Equal seed -> identical stream regardless of engine; different
    seed -> (almost surely) different stream. Greedy stays exact."""
    cfg, model, params, rc = setup
    prompt = np.arange(6).astype(np.int32) % cfg.vocab_size

    def run(seed):
        eng = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=32))
        uid = eng.submit(GenerationRequest(
            prompt=prompt, max_new_tokens=6,
            sampling=SamplingParams(greedy=False, temperature=1.5, seed=seed)))
        _drain(eng)
        return eng.output(uid).tokens

    assert run(7) == run(7)
    assert run(7) != run(8)


def test_engine_vq_quantized(setup):
    """The engine runs end-to-end on EVA-quantized weights."""
    cfg, model, params, rc = setup
    qparams = model.quantize(params, method="synthetic", key=KEY)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(3)]
    rc_vq = rc.replace_policy(vq_mode="eva")
    eng = Engine(model, qparams, rc_vq, EngineConfig(num_slots=3, max_len=24))
    got = eng.generate(prompts, 4)
    assert all(len(v) == 4 for v in got.values())
    # eva and dequant paths agree token-for-token
    eng2 = Engine(model, qparams, rc.replace_policy(vq_mode="dequant"),
                  EngineConfig(num_slots=3, max_len=24))
    got2 = eng2.generate(prompts, 4)
    assert list(got.values()) == list(got2.values())


def test_mixed_batch_poison_bystander_token_identity(setup):
    """A NaN/Inf-poisoned slot finishes ``finish_reason="error"`` while
    every bystander lane — greedy AND sampled — streams on BIT-IDENTICAL
    to a fault-free run: poison is additive per-lane data, so injection
    neither retraces the decode step nor perturbs neighbor lanes."""
    from repro.serve.resilience import FaultPlan, FaultSpec

    cfg, model, params, rc = setup
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 6, 7)]
    sampled = SamplingParams(greedy=False, temperature=1.3, seed=9)

    def run(fault_plan):
        eng = Engine(model, params, rc,
                     EngineConfig(num_slots=3, max_len=32,
                                  fault_plan=fault_plan))
        uids = [
            eng.submit(GenerationRequest(prompt=prompts[0],
                                         max_new_tokens=6)),
            eng.submit(GenerationRequest(prompt=prompts[1],
                                         max_new_tokens=6,
                                         sampling=sampled)),
            eng.submit(GenerationRequest(prompt=prompts[2],
                                         max_new_tokens=6)),
        ]
        _drain(eng)
        return eng, uids

    ref, runids = run(None)
    eng, uids = run(FaultPlan.scripted(
        FaultSpec("poison", tick=2, uid=3, mode="inf")))
    bad = eng.output(uids[2])
    assert bad.finish_reason == "error"
    # the poisoned request's pre-fault prefix matches the clean run
    assert bad.tokens == ref.output(runids[2]).tokens[: len(bad.tokens)]
    for i in (0, 1):
        assert eng.output(uids[i]).tokens == ref.output(runids[i]).tokens
        assert eng.output(uids[i]).finish_reason == "length"
    assert eng.trace_counts["decode"] == 1  # injection is data, not a retrace
    m = eng.metrics()
    assert m["errors"] == 1 and m["poisoned_slot_steps"] == 1
    assert m["tokens_generated"] == (
        m["prefills"] + m["decode_slot_steps"] - m["poisoned_slot_steps"])


def test_paged_admission_is_length_aware(setup):
    """Paged + full-cache engines admit by block consumption, not the
    worst-case ``prompt + max_new - 1 <= max_len`` reservation: a request
    whose nominal budget exceeds max_len is admitted, decodes to the
    capacity clamp and finishes with reason "length" — while the same
    request on a contiguous engine is rejected outright."""
    cfg, model, params, rc = setup
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    req = lambda: GenerationRequest(prompt=prompt, max_new_tokens=64)

    contig = Engine(model, params, rc, EngineConfig(num_slots=1, max_len=32))
    uid = contig.submit(req())  # 8 + 64 - 1 = 71 > 32: the old rule fires
    assert contig.output(uid).finish_reason == "rejected"

    paged = Engine(model, params, rc,
                   EngineConfig(num_slots=1, max_len=32, paged=True,
                                num_blocks=8, block_size=8))
    uid = paged.submit(req())
    steps = 0
    while not paged.idle:
        paged.step()
        steps += 1
        assert steps < 200
    out = paged.output(uid)
    assert out.finish_reason == "length"
    # budget clamps to capacity: positions 8..31 leave room for 25 tokens
    assert len(out.tokens) == 32 - len(prompt) + 1
    ref = _greedy_reference(model, params, prompt, len(out.tokens), rc, 32)
    assert list(out.tokens) == ref


def test_paged_admission_still_rejects_oversized_prompt(setup):
    cfg, model, params, rc = setup
    paged = Engine(model, params, rc,
                   EngineConfig(num_slots=1, max_len=32, paged=True,
                                num_blocks=8, block_size=8))
    uid = paged.submit(GenerationRequest(
        prompt=np.zeros(40, np.int32), max_new_tokens=4))
    assert paged.output(uid).finish_reason == "rejected"


def test_logprobs_surface_in_events_and_output(setup):
    """SamplingParams.logprobs attaches the chosen-token logprob to every
    StreamEvent and the terminal RequestOutput; off by default."""
    cfg, model, params, rc = setup
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size
    eng = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=32))
    u_on = eng.submit(GenerationRequest(
        prompt=prompt, max_new_tokens=4,
        sampling=SamplingParams(logprobs=True)))
    u_off = eng.submit(GenerationRequest(prompt=prompt, max_new_tokens=4))
    events = []
    while not eng.idle:
        events.extend(eng.step())
    on = [e for e in events if e.uid == u_on and e.token is not None]
    off = [e for e in events if e.uid == u_off and e.token is not None]
    assert len(on) == 4 and all(e.logprob is not None for e in on)
    # greedy picks the argmax: its logprob is the max, hence > log(1/V)
    assert all(e.logprob > -np.log(cfg.vocab_size) for e in on)
    assert all(e.logprob <= 0.0 for e in on)
    assert all(e.logprob is None for e in off)
    out = eng.output(u_on)
    assert len(out.logprobs) == 4
    np.testing.assert_allclose(out.logprobs, [e.logprob for e in on])
    assert eng.output(u_off).logprobs == ()
