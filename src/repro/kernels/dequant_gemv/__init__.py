from repro.kernels.dequant_gemv.ops import dequant_gemv
from repro.kernels.dequant_gemv.ref import dequant_gemv_ref
