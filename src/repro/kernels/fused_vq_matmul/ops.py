"""Jit'd wrapper for the fused EVA matmul kernel + its plan backend.

Accepts a VQWeight and activations of any leading shape; handles padding,
M-tiling (to bound the VMEM OC scratch), and dtype conversion.

The index matrix is handed to the kernel in its storage dtype (uint8 for
n <= 8) — the kernel upcasts per streamed tile, so HBM index traffic
stays at q bits/weight (see kernel.py's uint8 streaming contract). A
grouped projection family (VQWeight.splits non-empty) is just a wider N
here: one call, one OC scratch fill, every member's output columns swept
against the same VMEM-resident OC.

This module OWNS the fused kernel's tile model (`select_fused_tiles` /
`fused_m_tile`, sized against the shared VMEM budgets in core/ops.py)
and registers the "eva_fused_pallas" backend with core/plan.py: the
planner freezes (m_tile, block_v, block_n) once per (spec, policy) and
execution re-derives nothing.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import ops as core_ops
from repro.core import plan as plan_mod
from repro.core.vq import VQWeight
from repro.kernels.fused_vq_matmul.kernel import fused_vq_matmul_pallas
from repro.kernels.fused_vq_matmul.ref import fused_vq_matmul_ref


def fused_m_tile(C: int, v_padded: int, k: int) -> int:
    """Largest m_tile whose VMEM OC scratch (C, m_tile, v_padded, k) fp32
    stays under FUSED_OC_SCRATCH_BYTES. The single source of truth for
    the fused wrapper's M-tiling (it passes the ACTUAL padded V)."""
    return max(1, core_ops.FUSED_OC_SCRATCH_BYTES // max(C * v_padded * k * 4, 1))


def select_fused_tiles(M: int, V: int, N: int, C: int, k: int = 256
                       ) -> Tuple[int, int, int]:
    """(m_tile, block_v, block_n) for the fused Pallas wrapper.

    m_tile caps the VMEM OC scratch (C * m_tile * V_pad * k fp32) at
    FUSED_OC_SCRATCH_BYTES (via fused_m_tile); block_v/block_n bound the
    gathered epilogue tile (C, m_tile, block_v, block_n) fp32 at
    FUSED_GATHER_TILE_BYTES, shrinking block_v first (the paper's v=32
    tile height is the upper bound), then block_n (512-lane default)."""
    bn = min(512, N)
    bv = min(core_ops.DEFAULT_BLOCK_V, V)
    m_tile = min(fused_m_tile(C, V + ((-V) % bv), k), M)

    def tile_bytes(bv_, bn_):
        return 4 * C * m_tile * bv_ * bn_

    while bv > core_ops._MIN_BLOCK_V and \
            tile_bytes(bv, bn) > core_ops.FUSED_GATHER_TILE_BYTES:
        bv //= 2
    while bn > 128 and tile_bytes(bv, bn) > core_ops.FUSED_GATHER_TILE_BYTES:
        bn //= 2
    return m_tile, bv, min(bn, N)


def _resolve_m_tile(V: int, C: int, k: int, bv: int, bn: int) -> int:
    """M-tile for realized tiles (bv, bn): cap the OC scratch at the
    ACTUAL padded V, then shrink until the gathered tile (C, mt, bv, bn)
    honors the budget (an explicit block_v may pad more than the auto
    sizing assumed)."""
    v_padded = V + ((-V) % bv)
    mt = fused_m_tile(C, v_padded, k)
    while mt > 1 and 4 * C * mt * bv * bn > core_ops.FUSED_GATHER_TILE_BYTES:
        mt = max(1, mt // 2)
    return mt


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_n", "m_tile", "interpret",
                              "use_pallas", "out_dtype")
)
def fused_vq_matmul(
    x: jax.Array,
    vq: VQWeight,
    *,
    block_v="auto",
    block_n="auto",
    m_tile="auto",
    interpret: bool = False,
    use_pallas: bool = True,
    out_dtype=None,
) -> jax.Array:
    """block_v/block_n/m_tile default to "auto": select_fused_tiles sizes
    the v/n tiles AND the m-tiling jointly from the VMEM footprint model
    (OC scratch C*m_tile*V_pad*2^n fp32 capped at FUSED_OC_SCRATCH_BYTES,
    gathered tile capped at FUSED_GATHER_TILE_BYTES). Explicit ints pin
    the tile sizes (plans pass fully-resolved tiles; tests / TPU tuning
    may too)."""
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    K, N, V, d, C = vq.K, vq.N, vq.V, vq.d, vq.C
    k = vq.codebooks.shape[-1]
    M = x.size // K
    X = x.reshape(M, V, d).astype(jnp.float32)
    # stream indices in their storage dtype (uint8 for n<=8) — the kernel
    # upcasts per tile; pre-widening here would 4x the index HBM traffic
    I = vq.idx
    scale = vq.scale.astype(jnp.float32)

    if not use_pallas:
        y = fused_vq_matmul_ref(X, vq.codebooks, I, scale)
        return y.reshape(*lead, N).astype(out_dtype)

    _, auto_bv, auto_bn = select_fused_tiles(M, V, N, C, k)
    bv = auto_bv if block_v == "auto" else min(block_v, V)
    bn = auto_bn if block_n == "auto" else min(block_n, N)
    pad_v = (-V) % bv
    pad_n = (-N) % bn
    if pad_v:
        # padded V rows gather index 0 from zeroed X rows -> contribute 0
        X = jnp.pad(X, ((0, 0), (0, pad_v), (0, 0)))
        I = jnp.pad(I, ((0, 0), (0, pad_v), (0, 0)))
    if pad_n:
        I = jnp.pad(I, ((0, 0), (0, 0), (0, pad_n)))
        scale = jnp.pad(scale, (0, pad_n))

    # M-tiling bounds the OC scratch at C*mt*V_padded*k*4 bytes per call;
    # this Python loop is unrolled under jit (one pallas_call per M-tile).
    mt = _resolve_m_tile(V, C, k, bv, bn) if m_tile == "auto" \
        else max(1, m_tile)
    cb = vq.codebooks.astype(jnp.float32)
    outs = [
        fused_vq_matmul_pallas(
            X[m0:m0 + mt], cb, I, scale,
            block_v=bv, block_n=bn, interpret=interpret,
        )
        for m0 in range(0, M, mt)
    ]
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    if pad_n:
        y = y[:, :N]
    return y.reshape(*lead, N).astype(out_dtype)


# ---------------------------------------------------------------------------
# Plan backend: the fused kernel is THE impl="pallas" execution of an EVA
# matmul — jnp epilogue requests are invalid there (loud, from the
# registration, exactly like the old wrapper-level error).
# ---------------------------------------------------------------------------


def _match_eva_fused(spec: plan_mod.LinearSpec, policy: plan_mod.PlanPolicy
                     ) -> bool:
    return (spec.kind == "vq" and policy.impl == "pallas"
            and policy.vq_mode in ("eva", "none"))


def _plan_eva_fused(spec: plan_mod.LinearSpec, policy: plan_mod.PlanPolicy
                    ) -> plan_mod.MatmulPlan:
    if policy.epilogue != "auto":
        raise ValueError(
            "impl='pallas' always runs the fused tiled kernel; epilogue="
            f"{policy.epilogue!r} does not apply (pass block_v to size its "
            "v-tiles)")
    _, auto_bv, auto_bn = select_fused_tiles(spec.M, spec.V, spec.N, spec.C,
                                             spec.k)
    bv = auto_bv if policy.block_v is None else min(policy.block_v, spec.V)
    bn = auto_bn
    # clamp once: the recorded config IS the static m_tile baked into run
    mt = min(_resolve_m_tile(spec.V, spec.C, spec.k, bv, bn), spec.M)
    out_dt = jnp.dtype(spec.out_dtype)
    interpret = policy.interpret

    def run(x, vq):
        return fused_vq_matmul(x, vq, block_v=bv, block_n=bn, m_tile=mt,
                               interpret=interpret, out_dtype=out_dt)

    cost = plan_mod.PlanCost(
        macs=core_ops.vq_gemm_macs(spec.M, spec.K,
                                   max(spec.k.bit_length() - 1, 0),
                                   spec.C, spec.d),
        lookup_adds=core_ops.epilogue_adds(spec.M, spec.K, spec.N, spec.C,
                                           spec.d),
        weight_bytes=plan_mod.vq_weight_bytes(spec),
    )
    return plan_mod.MatmulPlan(
        "eva_fused_pallas", spec, policy,
        (("mt", mt), ("bv", bv), ("bn", bn)), cost, run)


plan_mod.register_backend("eva_fused_pallas", _match_eva_fused,
                          _plan_eva_fused)
