"""Serving example: continuous batching with EVA-quantized weights.

Submits a stream of variable-length requests to the engine; prefill runs
per request (INT8 path), decode runs as one batched EVA step across all
active slots (the paper's multi-batch weight-tile reuse, Fig. 7(c)).

    PYTHONPATH=src python examples/serve_vq.py --arch mixtral-8x22b
"""
import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.plan import PlanPolicy
from repro.models import build_model
from repro.models.common import RunConfig
from repro.serve import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    # INFO logging shows the engine's pre-planned prefill/decode matmul
    # plans (backend + resolved tiles per layer shape) at startup
    logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.quantize(model.init(key), method="synthetic", key=key)

    rc = RunConfig(mode="decode", plan_policy=PlanPolicy(vq_mode="eva"),
                   remat=False, attn_chunk=32)
    eng = Engine(model, params, rc,
                 EngineConfig(num_slots=args.slots, max_len=64))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 16)))
               .astype(np.int32) for _ in range(args.requests)]
    print(f"serving {len(prompts)} requests on {args.slots} slots "
          f"({cfg.name}, {cfg.vq_C * cfg.vq_n / cfg.vq_d:.0f}-bit VQ)")
    t0 = time.time()
    results = eng.generate(prompts, args.max_new)
    dt = time.time() - t0
    for uid, toks in list(results.items())[:4]:
        print(f"  request {uid}: {toks}")
    total = sum(len(v) for v in results.values())
    print(f"{total} tokens in {dt:.1f}s ({total/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
