from repro.kernels.oc_lookup.ops import oc_lookup
from repro.kernels.oc_lookup.ref import oc_lookup_ref
