"""End-to-end training driver example: fault-tolerant training of a ~100M
model for a few hundred steps with checkpointing, watchdog, and an
injected mid-run failure that the restart loop recovers from.

    PYTHONPATH=src python examples/train_e2e.py --steps 300
(~100M params; use --smoke for a 1-minute run)
"""
import argparse
import dataclasses
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a data failure at this step (FT demo)")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        out = train(
            args.arch,
            smoke=args.smoke or args.steps <= 50,
            steps=args.steps,
            seq_len=args.seq_len if not args.smoke else 32,
            global_batch=args.global_batch,
            lr=3e-3,
            ckpt_dir=ckpt_dir,
            ckpt_every=max(args.steps // 5, 10),
            fail_at=args.fail_at,
            log_every=max(args.steps // 20, 1),
        )
    print(f"\nfinal loss {out['final_loss']:.4f}  "
          f"restarts {out['restarts']}  stragglers {out['stragglers']}")


if __name__ == "__main__":
    main()
