"""Fig. 11: batch scaling on LLaMA-2-7B.

Paper's findings: (a) baselines' latency grows slowly below batch 8 (they
were under-utilized anyway) while EVA-W2 grows ~linearly (it is already
saturated); (b) past batch ~32 the workload turns GEMM-like and EVA's
INT8 mode (EVA-A8W8) overtakes the VQ path.
"""
from __future__ import annotations

from benchmarks.accel_model import model_decode_cost
from repro.configs import get_config

BATCHES = (1, 2, 4, 8, 16, 32, 64)


def run(report):
    cfg = get_config("llama2_7b")
    rows = []
    cross = None
    for b in BATCHES:
        vq = model_decode_cost("EVA", cfg, batch=b, bits=2)
        i8 = model_decode_cost("EVA-A8W8", cfg, batch=b)
        sa = model_decode_cost("SA", cfg, batch=b)
        rows.append((b, vq.latency_s, i8.latency_s, sa.latency_s))
        if cross is None and i8.latency_s < vq.latency_s:
            cross = b
        report(f"fig11/batch{b}", vq.latency_s * 1e6,
               f"int8_us={i8.latency_s*1e6:.1f};sa_us={sa.latency_s*1e6:.1f}")
    report("fig11/crossover_batch", float(cross or -1),
           "paper: VQ loses to INT8 past batch ~32")
    # sub-linear growth of SA at small batch
    sa1 = rows[0][3]
    sa8 = rows[3][3]
    report("fig11/sa_growth_1to8", sa8 / sa1,
           "paper: ~1 (hidden by low utilization)")
    return rows
