"""Distributed tests: sharding rule validity for every arch, plus a real
multi-device SPMD run in a subprocess (8 host devices) covering the
sharded train step, gradient compression over the 'pod' axis, and elastic
resharding.

The subprocess is required because XLA_FLAGS must be set before jax
initializes, and the main test process must keep 1 device (per the
assignment: smoke tests see one device).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _abstract_mesh():
    """Production-shaped AbstractMesh across jax API revisions (0.4.37
    takes ((name, size), ...) pairs; older releases took sizes + names)."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
    except TypeError:
        return AbstractMesh((2, 16, 16), ("pod", "data", "model"))


class TestShardingRules:
    """Specs must be structurally valid and exactly divisible on the
    production mesh for every arch (checked abstractly, no devices)."""

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_param_specs_divisible(self, arch):
        from jax.sharding import PartitionSpec as P
        from repro.runtime.sharding import opt_pspecs, param_pspecs

        mesh = _abstract_mesh()
        model = build_model(get_config(arch))
        for quantized in (False, True):
            specs = model.param_specs(quantized=quantized)
            pspecs = param_pspecs(specs, mesh)
            flat_s, tdef = jax.tree_util.tree_flatten(
                pspecs, is_leaf=lambda x: isinstance(x, P))
            flat_p = tdef.flatten_up_to(specs)
            for spec, leaf in zip(flat_s, flat_p):
                if not isinstance(spec, P) or not hasattr(leaf, "shape"):
                    continue
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    total = int(np.prod([mesh.shape[a] for a in axes]))
                    assert dim % total == 0, (arch, leaf.shape, spec)
            if not quantized:
                ospecs = opt_pspecs(pspecs, specs, mesh)
                assert jax.tree_util.tree_structure(
                    ospecs, is_leaf=lambda x: isinstance(x, P)
                ) == jax.tree_util.tree_structure(
                    pspecs, is_leaf=lambda x: isinstance(x, P))

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_cache_specs_divisible(self, arch):
        from jax.sharding import PartitionSpec as P
        from repro.runtime.sharding import cache_pspecs

        mesh = _abstract_mesh()
        model = build_model(get_config(arch))
        cspecs = model.cache_specs(128, 32768)
        pspecs = cache_pspecs(cspecs, mesh)
        flat_s, tdef = jax.tree_util.tree_flatten(
            pspecs, is_leaf=lambda x: isinstance(x, P))
        flat_c = tdef.flatten_up_to(cspecs)
        for spec, leaf in zip(flat_s, flat_c):
            if not isinstance(spec, P):
                continue
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                total = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % total == 0, (arch, leaf.shape, spec)


_SUBPROC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.configs import get_smoke_config
    from repro.data import DataConfig, DataPipeline, global_batch_at
    from repro.launch.steps import make_train_step, train_shardings
    from repro.models import build_model
    from repro.models.common import RunConfig
    from repro.optim import AdamWConfig, adamw_init
    from repro.optim.compress import compress_psum, init_error_feedback
    from repro.runtime.sharding import to_named
    from repro.runtime.elastic import reshard_state

    out = {}
    assert len(jax.devices()) == 8

    # ---- sharded train step on a (pod=2, data=2, model=2) mesh ----
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    rc = RunConfig(mode="train", remat=True, attn_chunk=8)
    ocfg = AdamWConfig(lr=1e-3)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, ocfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in global_batch_at(dcfg, 0).items()}
    step = make_train_step(model, ocfg, rc)
    in_sh, out_sh = train_shardings(model, mesh, params, opt, batch)
    with mesh:
        jitted = jax.jit(step, in_shardings=to_named(in_sh, mesh),
                         out_shardings=to_named(out_sh, mesh))
        p2, o2, metrics = jitted(params, opt, batch)
        # reference: unsharded single-device step
        p_ref, o_ref, m_ref = jax.jit(step)(params, opt, batch)
    out["sharded_loss"] = float(metrics["loss"])
    out["ref_loss"] = float(m_ref["loss"])
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
               for a, b in zip(jax.tree_util.tree_leaves(p2),
                                jax.tree_util.tree_leaves(p_ref)))
    out["param_diff"] = diff

    # ---- int8 EF gradient compression over the pod axis ----
    cmesh = jax.make_mesh((8,), ("pod",))
    g_global = jax.random.normal(jax.random.PRNGKey(1), (8, 64))

    def reduce_fn(g, e):
        red, new_e = compress_psum({"g": g}, {"g": e}, "pod")
        return red["g"], new_e["g"]

    sm = shard_map(reduce_fn, mesh=cmesh,
                   in_specs=(P("pod", None), P("pod", None)),
                   out_specs=(P("pod", None), P("pod", None)))
    ef = jnp.zeros((8, 64))
    red, ef = sm(g_global, ef)
    true_mean = jnp.mean(g_global, axis=0, keepdims=True)
    err1 = float(jnp.max(jnp.abs(red[0] - true_mean[0])))
    out["compress_err"] = err1
    out["compress_rel"] = err1 / float(jnp.max(jnp.abs(true_mean)))
    # error feedback guarantee: the CUMULATIVE applied update converges to
    # the cumulative true gradient (per-step error is bounded, residual
    # carried) -> relative error of the running mean shrinks ~ 1/k
    applied = red
    K = 8
    for _ in range(K - 1):
        red, ef = sm(g_global, ef)
        applied = applied + red
    cum_err = float(jnp.max(jnp.abs(applied[0] / K - true_mean[0])))
    out["compress_err_ef"] = cum_err
    out["ef_improves"] = cum_err < 0.5 * err1

    # ---- elastic restart: reshard onto a smaller mesh, same math ----
    mesh2 = jax.make_mesh((2, 2), ("data", "model"))
    params_host = jax.tree_util.tree_map(np.asarray, p2)
    opt_host = jax.tree_util.tree_map(
        lambda x: np.asarray(x) if hasattr(x, "shape") else x, o2)
    p3, o3 = reshard_state(params_host, opt_host, model, mesh2)
    batch2 = {k: jnp.asarray(v) for k, v in global_batch_at(dcfg, 1).items()}
    in_sh2, out_sh2 = train_shardings(model, mesh2, p3, o3, batch2)
    with mesh2:
        jit2 = jax.jit(step, in_shardings=to_named(in_sh2, mesh2),
                       out_shardings=to_named(out_sh2, mesh2))
        p4, o4, m4 = jit2(p3, o3, batch2)
    # reference continues on one device
    p_ref2, o_ref2, m_ref2 = jax.jit(step)(p_ref, o_ref, batch2)
    out["elastic_loss"] = float(m4["loss"])
    out["elastic_ref_loss"] = float(m_ref2["loss"])
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
class TestMultiDeviceSPMD:
    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=SRC, TF_CPP_MIN_LOG_LEVEL="2")
        proc = subprocess.run(
            [sys.executable, "-c", _SUBPROC_SCRIPT], env=env,
            capture_output=True, text=True, timeout=560,
        )
        assert proc.returncode == 0, proc.stderr[-3000:]
        line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
        return json.loads(line[len("RESULT"):])

    def test_sharded_step_matches_single_device(self, result):
        assert result["sharded_loss"] == pytest.approx(result["ref_loss"],
                                                       rel=2e-3)
        assert result["param_diff"] < 5e-3

    def test_gradient_compression(self, result):
        assert result["compress_rel"] < 0.05   # int8 quantization error
        assert result["ef_improves"]           # error feedback helps

    def test_elastic_restart(self, result):
        assert result["elastic_loss"] == pytest.approx(
            result["elastic_ref_loss"], rel=2e-3)
