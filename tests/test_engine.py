"""Serving-engine tests: continuous batching correctness and scheduling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.common import RunConfig
from repro.serve import Engine, EngineConfig, Scheduler
from repro.serve.kvcache import pad_prefill_cache

KEY = jax.random.PRNGKey(0)


def _greedy_reference(model, params, prompt, max_new, rc, cap):
    """Sequential single-request greedy decode."""
    cfg = model.cfg
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None], jnp.int32)},
        rc.replace(mode="prefill"),
    )
    window = cfg.sliding_window or cfg.local_window
    caches = pad_prefill_cache(caches, cap, window=window)
    out = [int(np.argmax(np.asarray(logits[0, -1, :cfg.vocab_size])))]
    pos = len(prompt)
    while len(out) < max_new:
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, caches = model.decode(
            params, tok, jnp.asarray([[pos]], jnp.int32), caches,
            rc.replace(mode="decode"),
        )
        out.append(int(np.argmax(np.asarray(logits[0, 0, :cfg.vocab_size]))))
        pos += 1
    return out


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    rc = RunConfig(mode="decode", remat=False, attn_chunk=16)
    return cfg, model, params, rc


def test_continuous_batching_matches_sequential(setup):
    cfg, model, params, rc = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 7, 4, 6)]
    max_new = 6
    ecfg = EngineConfig(num_slots=2, max_len=32)  # slots < requests: queueing
    eng = Engine(model, params, rc, ecfg)
    got = eng.generate(prompts, max_new)
    for uid, prompt in zip(got, prompts):
        ref = _greedy_reference(model, params, prompt, max_new, rc, 32)
        assert got[uid] == ref, (uid, got[uid], ref)


def test_scheduler_slot_lifecycle():
    s = Scheduler(num_slots=2)
    u1 = s.submit(np.ones(3, np.int32), 4)
    u2 = s.submit(np.ones(4, np.int32), 4)
    u3 = s.submit(np.ones(5, np.int32), 4)
    admitted = s.admit()
    assert len(admitted) == 2 and len(s.queue) == 1
    r = s.finish(admitted[0])
    assert r.uid == u1
    assert s.admit() == [admitted[0]]  # freed slot reused for u3
    assert not s.idle
    s.finish(0), s.finish(1)
    assert s.idle


class _CountingModel:
    """Deterministic stub: next-token = (last_token + 1) % vocab. Lets the
    slot-retirement tests place eos mid-stream exactly and count batched
    decode steps."""

    def __init__(self, cfg):
        self.cfg = cfg

    def init_cache(self, slots, max_len):
        return {"state": jnp.zeros((1, slots, 1), jnp.float32)}

    def prefill(self, params, batch, rc):
        nxt = (batch["tokens"][:, -1] + 1) % self.cfg.vocab_size
        logits = jax.nn.one_hot(nxt, self.cfg.vocab_size)[:, None, :]
        return logits, {"state": jnp.zeros((1, 1, 1), jnp.float32)}

    def decode(self, params, tokens, positions, caches, rc):
        nxt = (tokens[:, 0] + 1) % self.cfg.vocab_size
        logits = jax.nn.one_hot(nxt, self.cfg.vocab_size)[:, None, :]
        return logits, caches


def _counting_engine(eos_id, num_slots=2, max_len=64):
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), vocab_size=32)
    model = _CountingModel(cfg)
    eng = Engine(model, {}, RunConfig(mode="decode", remat=False),
                 EngineConfig(num_slots=num_slots, max_len=max_len,
                              eos_id=eos_id))
    # count batched decode steps
    inner = eng._decode_fn
    calls = {"n": 0}

    def counted(*a, **kw):
        calls["n"] += 1
        return inner(*a, **kw)

    eng._decode_fn = counted
    return eng, calls


def test_slot_retires_in_same_step_as_eos():
    """Regression (slot-retirement bug): a request whose eos arrives
    mid-stream must free its slot in the step the token is generated —
    previously it occupied the slot for one extra batched decode step
    (with positions bumped for it anyway)."""
    eng, calls = _counting_engine(eos_id=9, num_slots=1)
    # prompt ends at 5 -> prefill emits 6; decode emits 7, 8, 9(eos)
    out = eng.generate([np.array([5], np.int32)], max_new_tokens=10)
    assert list(out.values()) == [[6, 7, 8, 9]]
    # exactly 3 decode steps (7, 8, 9) — the old check-before-consume loop
    # needed a 4th step just to notice the eos
    assert calls["n"] == 3


def test_eos_slot_frees_for_queued_request_immediately():
    """With one slot and two requests, the freed slot admits the queued
    request on the tick right after eos — no dead step in between."""
    eng, calls = _counting_engine(eos_id=9, num_slots=1)
    out = eng.generate([np.array([6], np.int32), np.array([20], np.int32)],
                       max_new_tokens=4)
    # first: prefill 7, decode 8, 9(eos); second: prefill 21, decode 22..24
    assert list(out.values()) == [[7, 8, 9], [21, 22, 23, 24]]
    assert calls["n"] == 2 + 3  # no wasted step between the requests

    # a fresh engine serving only the second request needs the same 3
    # decode steps — the queued request paid zero extra latency
    eng2, calls2 = _counting_engine(eos_id=9, num_slots=1)
    eng2.generate([np.array([20], np.int32)], max_new_tokens=4)
    assert calls2["n"] == 3


def test_eos_in_prefill_token_never_decodes():
    """A request whose very first (prefill-sampled) token is eos — or
    whose budget is a single token — retires without any decode step."""
    eng, calls = _counting_engine(eos_id=9)
    out = eng.generate([np.array([8], np.int32)], max_new_tokens=10)
    assert list(out.values()) == [[9]]
    assert calls["n"] == 0

    eng2, calls2 = _counting_engine(eos_id=-1)
    out2 = eng2.generate([np.array([3], np.int32)], max_new_tokens=1)
    assert list(out2.values()) == [[4]]
    assert calls2["n"] == 0


def test_free_slots_fed_masked_tokens():
    """Free slots must not replay their stale last_token through decode:
    the engine masks them to token 0 / position 0."""
    eng, _ = _counting_engine(eos_id=9, num_slots=2)
    seen = []
    inner = eng._decode_fn

    def spy(params, tokens, positions, caches):
        seen.append((np.asarray(tokens).ravel().copy(),
                     np.asarray(positions).ravel().copy()))
        return inner(params, tokens, positions, caches)

    eng._decode_fn = spy
    # slot 0 hits eos (9) in the second decode step; slot 1 keeps going
    eng.generate([np.array([6], np.int32), np.array([20], np.int32)],
                 max_new_tokens=6)
    assert len(seen) == 5  # slot 1: 22, 23, 24, 25, 26
    # while slot 0 is live its lane carries the real last_token
    assert seen[0][0][0] == 7 and seen[1][0][0] == 8
    # after slot 0 retires, its lane must carry the masked 0 at position
    # 0 — never its stale eos token / bumped position
    for tok, pos in seen[2:]:
        assert tok[0] == 0 and pos[0] == 0, (tok, pos)


def test_engine_vq_quantized(setup):
    """The engine runs end-to-end on EVA-quantized weights."""
    cfg, model, params, rc = setup
    qparams = model.quantize(params, method="synthetic", key=KEY)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(3)]
    rc_vq = rc.replace_policy(vq_mode="eva")
    eng = Engine(model, qparams, rc_vq, EngineConfig(num_slots=3, max_len=24))
    got = eng.generate(prompts, 4)
    assert all(len(v) == 4 for v in got.values())
    # eva and dequant paths agree token-for-token
    eng2 = Engine(model, qparams, rc.replace_policy(vq_mode="dequant"),
                  EngineConfig(num_slots=3, max_len=24))
    got2 = eng2.generate(prompts, 4)
    assert list(got.values()) == list(got2.values())
