"""Serving-layer fault tolerance (serve/resilience.py + the engine/planner
wiring): deterministic fault injection at every boundary, NaN/Inf slot
quarantine, request deadlines, engine snapshot/restore (incl. the
CheckpointManager wire format), the serve restart controller and backend
quarantine + cost-ranked fallback in core/plan.py."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import plan as plan_mod
from repro.core.plan import (LinearSpec, MatmulPlan, PlanCost, PlanPolicy,
                             Planner, register_backend)
from repro.models import build_model
from repro.models.common import RunConfig
from repro.serve import (Engine, EngineConfig, GenerationRequest,
                         SamplingParams)
from repro.serve.api import RequestEvicted
from repro.serve.resilience import (BOUNDARIES, CircuitBreaker, FaultPlan,
                                    FaultSpec, InjectedFault,
                                    load_snapshot_arrays, save_snapshot,
                                    serve_with_restarts)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Units: FaultPlan / CircuitBreaker
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_spec_validation(self):
        FaultSpec("poison", tick=0, mode="inf", times=2)
        with pytest.raises(ValueError, match="boundary"):
            FaultSpec("gc-pause", tick=0)
        with pytest.raises(ValueError, match="poison mode"):
            FaultSpec("poison", tick=0, mode="zero")
        with pytest.raises(ValueError, match="tick"):
            FaultSpec("decode", tick=-1)
        with pytest.raises(ValueError, match="times"):
            FaultSpec("decode", tick=0, times=0)

    def test_poll_fires_and_consumes(self):
        fp = FaultPlan.scripted(FaultSpec("decode", tick=2, times=2))
        assert fp.poll("decode", 0) is None          # not armed yet
        assert fp.poll("prefill", 3) is None         # wrong boundary
        assert fp.poll("decode", 3) is not None      # tick >= spec.tick
        assert fp.poll("decode", 3) is not None      # times=2: fires again
        assert fp.poll("decode", 4) is None          # budget exhausted
        assert fp.exhausted

    def test_uid_targeting(self):
        fp = FaultPlan.scripted(FaultSpec("poison", tick=0, uid=7))
        assert fp.poll("poison", 0, uid=3) is None   # wrong request
        assert fp.poll("poison", 0, uid=7) is not None
        # an untargeted spec matches any uid; an untargeted poll matches
        # any spec
        fp2 = FaultPlan.scripted(FaultSpec("poison", tick=0))
        assert fp2.poll("poison", 0, uid=42) is not None

    def test_seeded_plan_is_deterministic(self):
        a = FaultPlan.seeded(123, n_faults=4, max_tick=6, uids=(1, 2))
        b = FaultPlan.seeded(123, n_faults=4, max_tick=6, uids=(1, 2))
        assert a.faults == b.faults
        assert all(s.boundary in BOUNDARIES for s in a.faults)
        c = FaultPlan.seeded(124, n_faults=4, max_tick=6, uids=(1, 2))
        assert a.faults != c.faults


class TestCircuitBreaker:
    def test_trips_on_consecutive_only(self):
        br = CircuitBreaker(k=3)
        assert not br.record(True) and not br.record(True)
        assert not br.record(False)                  # clean step resets
        br.record(True), br.record(True)
        assert br.record(True) and br.tripped        # 3 consecutive

    def test_state_roundtrip_and_validation(self):
        with pytest.raises(ValueError, match="k must be"):
            CircuitBreaker(k=0)
        br = CircuitBreaker(k=2)
        br.record(True)
        br2 = CircuitBreaker(k=5)
        br2.restore(br.state())
        assert br2.record(True)                      # continues the streak


# ---------------------------------------------------------------------------
# Engine mechanics on the deterministic counting stub
# ---------------------------------------------------------------------------


class _CountingModel:
    """next-token = (last_token + 1) % vocab (see tests/test_engine.py)."""

    def __init__(self, cfg):
        self.cfg = cfg

    def init_cache(self, slots, max_len):
        return {"state": jnp.zeros((1, slots, 1), jnp.float32)}

    def prefill(self, params, batch, rc):
        nxt = (batch["tokens"][:, -1] + 1) % self.cfg.vocab_size
        return (jax.nn.one_hot(nxt, self.cfg.vocab_size)[:, None, :],
                {"state": jnp.zeros((1, 1, 1), jnp.float32)})

    def decode(self, params, tokens, positions, caches, rc):
        nxt = (tokens[:, 0] + 1) % self.cfg.vocab_size
        return jax.nn.one_hot(nxt, self.cfg.vocab_size)[:, None, :], caches


def _counting_engine(num_slots=2, max_len=64, **ecfg_kw):
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), vocab_size=64)
    return Engine(_CountingModel(cfg), {},
                  RunConfig(mode="decode", remat=False),
                  EngineConfig(num_slots=num_slots, max_len=max_len,
                               **ecfg_kw))


def _req(tok, n, eos=(), **kw):
    return GenerationRequest(prompt=np.array([tok], np.int32),
                             max_new_tokens=n, eos_ids=eos, **kw)


def _drain(eng):
    events = []
    while not eng.idle:
        events.extend(eng.step())
    return events


class TestNumericsQuarantine:
    def test_poisoned_request_errors_bystander_unaffected(self):
        fp = FaultPlan.scripted(FaultSpec("poison", tick=2, uid=1))
        eng = _counting_engine(fault_plan=fp)
        u1 = eng.submit(_req(5, 8))
        u2 = eng.submit(_req(20, 8))
        events = _drain(eng)
        bad, ok = eng.output(u1), eng.output(u2)
        assert bad.finish_reason == "error"
        assert bad.tokens == (6, 7, 8)               # tick0 prefill+decode, tick1
        assert ok.finish_reason == "length"
        assert ok.tokens == (21, 22, 23, 24, 25, 26, 27, 28)
        m = eng.metrics()
        assert m["errors"] == 1 and m["poisoned_slot_steps"] == 1
        assert m["tokens_generated"] == (m["prefills"] + m["decode_slot_steps"]
                                         - m["poisoned_slot_steps"])
        assert m["finished"] == (m["finished_stop"] + m["finished_length"]
                                 + m["errors"] + m["timeouts"])
        # the garbage token is SUPPRESSED: the terminal event carries
        # token=None, and no token-bearing event follows the fault
        term = [e for e in events if e.uid == u1][-1]
        assert term.token is None and term.finish_reason == "error"
        assert sum(e.token is not None
                   for e in events if e.uid == u1) == len(bad.tokens)

    def test_poisoned_prefill_never_activates_slot(self):
        fp = FaultPlan.scripted(FaultSpec("poison", tick=0, uid=1,
                                          mode="inf"))
        eng = _counting_engine()
        u1 = eng.submit(_req(5, 8))
        events = _drain(eng)
        del events
        # no fault plan on this engine: sanity check the scripted one
        eng2 = _counting_engine(fault_plan=fp)
        v1 = eng2.submit(_req(5, 8))
        events = _drain(eng2)
        out = eng2.output(v1)
        assert out.finish_reason == "error" and out.tokens == ()
        assert eng2.metrics()["tokens_generated"] == 0
        term = [e for e in events if e.uid == v1]
        assert len(term) == 1 and term[0].token is None
        assert term[0].finish_reason == "error"
        assert eng.output(u1).tokens == (6, 7, 8, 9, 10, 11, 12, 13)

    def test_breaker_trips_rejects_pending_and_submits(self):
        # one slot + per-admission poison: each tick admits one request,
        # poisons its prefill -> k consecutive poisoned steps
        fp = FaultPlan.scripted(FaultSpec("poison", tick=0, times=3))
        eng = _counting_engine(num_slots=1, fault_plan=fp, breaker_k=3)
        uids = [eng.submit(_req(5, 4)) for _ in range(5)]
        _drain(eng)
        assert [eng.output(u).finish_reason for u in uids] == (
            ["error"] * 3 + ["rejected"] * 2)
        assert not eng.healthy
        # new submits refuse while unhealthy
        u6 = eng.submit(_req(5, 4))
        assert eng.output(u6).finish_reason == "rejected"
        m = eng.metrics()
        assert m["errors"] == 3 and m["rejected"] == 3

    def test_clean_steps_reset_breaker(self):
        fp = FaultPlan.scripted(FaultSpec("poison", tick=0, uid=1),
                                FaultSpec("poison", tick=2, uid=3))
        eng = _counting_engine(num_slots=1, fault_plan=fp, breaker_k=2)
        uids = [eng.submit(_req(5, 2)) for _ in range(4)]
        _drain(eng)
        assert eng.healthy                           # never 2 in a row
        reasons = [eng.output(u).finish_reason for u in uids]
        assert reasons.count("error") == 2


class TestDeadlines:
    def test_queue_ttl_times_out_before_prefill(self):
        eng = _counting_engine(num_slots=1, queue_ttl_s=0.0)
        u1 = eng.submit(_req(5, 4))
        time.sleep(0.005)
        _drain(eng)
        out = eng.output(u1)
        assert out.finish_reason == "timeout" and out.tokens == ()
        assert eng.metrics()["prefills"] == 0        # no compute wasted
        assert eng.metrics()["timeouts"] == 1

    def test_deadline_expires_queued_request(self):
        eng = _counting_engine(num_slots=1)
        ua = eng.submit(_req(5, 6))
        ub = eng.submit(_req(7, 6, deadline_s=0.0))  # stuck behind ua
        time.sleep(0.005)
        _drain(eng)
        assert eng.output(ua).finish_reason == "length"
        assert eng.output(ub).finish_reason == "timeout"

    def test_deadline_frees_active_slot_mid_decode(self):
        eng = _counting_engine(num_slots=1, max_len=256)
        inner = eng._decode_fn

        def slow(*a, **kw):                          # ~5ms per decode step
            time.sleep(0.005)
            return inner(*a, **kw)

        eng._decode_fn = slow
        uid = eng.submit(_req(5, 200, deadline_s=0.05))
        _drain(eng)
        out = eng.output(uid)
        assert out.finish_reason == "timeout"
        assert 0 < len(out.tokens) < 200             # partial stream kept
        assert eng.metrics()["timeouts"] == 1

    def test_stream_delivers_timeout_terminal(self):
        eng = _counting_engine(num_slots=1, max_len=256)
        inner = eng._decode_fn

        def slow(*a, **kw):
            time.sleep(0.005)
            return inner(*a, **kw)

        eng._decode_fn = slow
        ua = eng.submit(_req(5, 200))
        eng.step()  # A takes the slot BEFORE B enters the EDF queue
        ub = eng.submit(_req(9, 4, deadline_s=0.02))
        evs = list(eng.stream(ub))
        assert len(evs) == 1 and evs[0].token is None
        assert evs[0].finish_reason == "timeout"

    def test_stream_stall_guard_is_wall_clock(self):
        """The old guard allowed 1,000,000 silent iterations; the new one
        raises once the stream makes no progress for stream_stall_s."""
        eng = _counting_engine(num_slots=1, stream_stall_s=0.0)
        eng.submit(_req(5, 50))
        ub = eng.submit(_req(9, 4))                  # queued behind slot 0
        with pytest.raises(RuntimeError, match="stalled"):
            next(iter(eng.stream(ub)))


class TestEvictedVsUnknown:
    def test_stream_distinguishes_evicted_from_unknown(self):
        eng = _counting_engine(num_slots=1)
        eng.ecfg.max_retained = 2
        uids = []
        for _ in range(4):
            uids.append(eng.submit(_req(5, 2)))
            _drain(eng)
        assert eng.evicted(uids[0]) and eng.evicted(uids[1])
        assert not eng.evicted(uids[3])
        assert not eng.evicted(999)                  # never issued
        with pytest.raises(RequestEvicted):
            next(iter(eng.stream(uids[0])))
        with pytest.raises(KeyError, match="unknown"):
            next(iter(eng.stream(999)))
        # RequestEvicted IS a KeyError: existing callers keep working
        assert issubclass(RequestEvicted, KeyError)

    def test_drained_stream_is_not_evicted(self):
        eng = _counting_engine(num_slots=1)
        uid = eng.submit(_req(5, 2))
        list(eng.stream(uid))                        # drains the buffer
        assert not eng.evicted(uid)                  # output still retained
        with pytest.raises(KeyError, match="already streamed"):
            next(iter(eng.stream(uid)))


class TestWatchdogWiring:
    def test_straggler_steps_reach_metrics(self):
        # threshold 0: every post-warmup decode step is a "straggler" —
        # pins the watchdog -> metrics wiring without timing flakiness
        eng = _counting_engine(num_slots=1, max_len=64,
                               straggler_threshold=0.0)
        eng.submit(_req(5, 30))
        _drain(eng)
        m = eng.metrics()
        assert m["straggler_steps"] > 0
        assert m["straggler_steps"] == len(eng.watchdog.straggler_steps)


class TestSnapshotRestore:
    def test_midstream_restore_is_token_identical(self):
        eng = _counting_engine()
        u1 = eng.submit(_req(5, 10))
        u2 = eng.submit(_req(20, 10))
        eng.step(), eng.step()
        snap = eng.snapshot()
        _drain(eng)
        ref1, ref2 = eng.output(u1).tokens, eng.output(u2).tokens

        eng2 = _counting_engine()
        eng2.restore(snap)
        _drain(eng2)
        assert eng2.output(u1).tokens == ref1
        assert eng2.output(u2).tokens == ref2
        # in flight across the restore: the annotated finish reason
        assert eng2.output(u1).finish_reason == "length-after-restore"
        assert eng2.metrics()["restores"] == 1

    def test_snapshot_does_not_alias_live_state(self):
        eng = _counting_engine()
        u1 = eng.submit(_req(5, 10))
        eng.step()
        snap = eng.snapshot()
        tick = snap.tick
        frozen = {p: (None if a is None else np.array(a, copy=True))
                  for p, a in snap.arrays.items()}
        _drain(eng)                                  # keep mutating
        assert snap.tick == tick
        for path, leaf in snap.arrays.items():
            if leaf is not None:
                np.testing.assert_array_equal(leaf, frozen[path])
        # restoring the untouched snapshot still resumes correctly
        eng2 = _counting_engine()
        eng2.restore(snap)
        _drain(eng2)
        assert eng2.output(u1).tokens == eng.output(u1).tokens

    def test_snapshot_geometry_mismatch_is_loud(self):
        snap = _counting_engine(num_slots=2).snapshot()
        with pytest.raises(ValueError, match="geometry"):
            _counting_engine(num_slots=3).restore(snap)

    def test_snapshot_roundtrips_through_checkpoint_manager(self, tmp_path):
        """EngineSnapshot array state reuses checkpoint/manager.py's
        path-flattened npz format: save_snapshot persists it atomically,
        load_snapshot_arrays reads back bit-identical leaves."""
        eng = _counting_engine()
        eng.submit(_req(5, 8))
        eng.step(), eng.step()
        snap = eng.snapshot()
        mgr = CheckpointManager(str(tmp_path / "snaps"), keep=2)
        save_snapshot(snap, mgr, step=snap.tick)
        assert mgr.latest_step() == snap.tick
        loaded = load_snapshot_arrays(mgr)
        want = {p: a for p, a in snap.arrays.items() if a is not None}
        assert set(loaded) == set(want)
        for path, arr in want.items():
            np.testing.assert_array_equal(loaded[path], arr)


class TestServeWithRestarts:
    # prefill faults fire at admissions: one slot + a short first request
    # puts the second admission (and the fault) at tick 2, with the
    # snapshot holding the victim still QUEUED. decode/sample faults hit
    # mid-flight slots, so the snapshot holds both requests ACTIVE and
    # their finish reasons carry the -after-restore annotation.
    @pytest.mark.parametrize("boundary,num_slots,budgets", [
        ("prefill", 1, (3, 8)),
        ("decode", 2, (8, 8)),
        ("sample", 2, (8, 8)),
    ])
    def test_crash_boundary_recovers_token_identically(self, boundary,
                                                       num_slots, budgets):
        ref = _counting_engine(num_slots=num_slots)
        refs = [ref.submit(_req(5, budgets[0])), ref.submit(_req(20, budgets[1]))]
        _drain(ref)

        fp = FaultPlan.scripted(FaultSpec(boundary, tick=2))
        eng, outs, stats = serve_with_restarts(
            lambda: _counting_engine(num_slots=num_slots, fault_plan=fp),
            [_req(5, budgets[0]), _req(20, budgets[1])])
        assert stats.restarts == 1 and stats.snapshots >= 2
        assert fp.exhausted                          # one shared plan instance
        for uid, ruid in zip(sorted(outs), refs):
            assert outs[uid].tokens == ref.output(ruid).tokens
            assert outs[uid].finish_reason.startswith("length")
        if boundary in ("decode", "sample"):
            # both requests were mid-flight at the restored snapshot
            assert all(o.finish_reason == "length-after-restore"
                       for o in outs.values())

    def test_gives_up_past_max_restarts(self):
        fp = FaultPlan.scripted(FaultSpec("decode", tick=0, times=10))
        with pytest.raises(RuntimeError, match="exceeded"):
            serve_with_restarts(lambda: _counting_engine(fault_plan=fp),
                                [_req(5, 8)], max_restarts=2)

    def test_no_event_delivered_twice(self):
        """snapshot_every=1 exactly-once: the crashed tick's events were
        never delivered and replay identically after restore — each
        (uid, index) pair appears exactly once across the run."""
        fp = FaultPlan.scripted(FaultSpec("sample", tick=3))
        seen = []

        def factory():
            eng = _counting_engine(fault_plan=fp)
            inner = eng.step

            def step():
                evs = inner()
                seen.extend((e.uid, e.index, e.token) for e in evs)
                return evs

            eng.step = step
            return eng

        _eng, outs, stats = serve_with_restarts(factory, [_req(5, 8)])
        assert stats.restarts == 1
        assert len(seen) == len(set(seen))
        assert outs[1].tokens == (6, 7, 8, 9, 10, 11, 12, 13)


# ---------------------------------------------------------------------------
# Real-model coverage: every fault boundary across the dense family and one
# recurrent family (xlstm exact-length prefill + recurrent cache trees)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense_setup():
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    return cfg, model, model.init(KEY), RunConfig(mode="decode", remat=False,
                                                  attn_chunk=16)


@pytest.fixture(scope="module")
def recurrent_setup():
    cfg = dataclasses.replace(get_smoke_config("xlstm_125m"),
                              dtype="float32")
    model = build_model(cfg)
    return cfg, model, model.init(KEY), RunConfig(mode="decode", remat=False)


@pytest.fixture(params=["dense", "recurrent"])
def family_setup(request, dense_setup, recurrent_setup):
    return dense_setup if request.param == "dense" else recurrent_setup


@pytest.fixture(autouse=True)
def _clean_planner_quarantine():
    yield
    plan_mod.reset_quarantine()


def _family_engine(setup, fault_plan=None, num_slots=2, max_len=24):
    cfg, model, params, rc = setup
    return Engine(model, params, rc,
                  EngineConfig(num_slots=num_slots, max_len=max_len,
                               fault_plan=fault_plan))


def _family_reqs(cfg, n=2, max_new=4, seeds=(0,)):
    rng = np.random.default_rng(17)
    out = []
    for i in range(n):
        prompt = rng.integers(0, cfg.vocab_size, 5 + i).astype(np.int32)
        sampling = (SamplingParams(greedy=False, temperature=1.2,
                                   seed=seeds[i % len(seeds)])
                    if i % 2 else SamplingParams())
        out.append(GenerationRequest(prompt=prompt, max_new_tokens=max_new,
                                     sampling=sampling))
    return out


class TestEveryBoundaryPerFamily:
    def test_poison_quarantine_and_bystander_identity(self, family_setup):
        cfg = family_setup[0]
        reqs = _family_reqs(cfg, n=2, max_new=5, seeds=(3,))
        ref = _family_engine(family_setup)
        r1, r2 = ref.submit(reqs[0]), ref.submit(reqs[1])
        _drain(ref)

        fp = FaultPlan.scripted(FaultSpec("poison", tick=2, uid=1))
        eng = _family_engine(family_setup, fault_plan=fp)
        u1, u2 = eng.submit(reqs[0]), eng.submit(reqs[1])
        _drain(eng)
        assert eng.output(u1).finish_reason == "error"
        # the poisoned request streamed its pre-fault prefix faithfully
        assert eng.output(u1).tokens == ref.output(r1).tokens[
            : len(eng.output(u1).tokens)]
        # the bystander lane (a SAMPLED request — key streams are
        # per-slot) is bit-identical to the fault-free run
        assert eng.output(u2).tokens == ref.output(r2).tokens
        assert eng.output(u2).finish_reason == ref.output(r2).finish_reason
        assert eng.trace_counts["decode"] == 1       # poison is data

    @pytest.mark.parametrize("boundary", ["prefill", "decode", "sample"])
    def test_raise_boundaries_raise_injected_fault(self, family_setup,
                                                   boundary):
        cfg = family_setup[0]
        fp = FaultPlan.scripted(FaultSpec(boundary, tick=0))
        eng = _family_engine(family_setup, fault_plan=fp)
        eng.submit(_family_reqs(cfg, n=1)[0])
        with pytest.raises(InjectedFault) as e:
            _drain(eng)
        assert e.value.boundary == boundary
        assert fp.exhausted
        # recovery from a raise is snapshot/restore territory
        # (serve_with_restarts below), not in-place retry: the crashed
        # engine's state is torn by design

    def test_crash_recovery_is_token_identical(self, family_setup):
        """A scripted sample-boundary crash (device stepped, host did
        not — the torn-state case) recovers through serve_with_restarts
        with the full stream TOKEN-IDENTICAL to a fault-free run, for a
        greedy and a sampled request."""
        cfg = family_setup[0]
        reqs = _family_reqs(cfg, n=2, max_new=5, seeds=(5,))
        ref = _family_engine(family_setup)
        ruids = [ref.submit(r) for r in reqs]
        _drain(ref)

        fp = FaultPlan.scripted(FaultSpec("sample", tick=2))
        _eng, outs, stats = serve_with_restarts(
            lambda: _family_engine(family_setup, fault_plan=fp), reqs)
        assert stats.restarts == 1
        for uid, ruid in zip(sorted(outs), ruids):
            assert outs[uid].tokens == ref.output(ruid).tokens

    def test_backend_fault_recovers_and_counts(self, family_setup):
        cfg = family_setup[0]
        reqs = _family_reqs(cfg, n=1, max_new=4)
        ref = _family_engine(family_setup)
        r1 = ref.submit(reqs[0])
        _drain(ref)

        fp = FaultPlan.scripted(FaultSpec("backend", tick=1))
        eng = _family_engine(family_setup, fault_plan=fp)
        u1 = eng.submit(reqs[0])
        _drain(eng)
        # generation survived the backend failure and stayed exact
        assert eng.output(u1).tokens == ref.output(r1).tokens
        assert eng.metrics()["backend_fallbacks"] == 1
        stats = plan_mod.default_planner().backend_stats()
        assert sum(stats["failures"].values()) >= 1


class TestBackendFallbackVQ:
    def test_vq_engine_backend_fault_switches_token_identically(
            self, dense_setup):
        """A scripted backend fault on an EVA-quantized engine
        quarantines the PLANNED backend and re-plans through
        core/plan.py's ranking; the next-cheapest eligible candidate
        (another EVA formulation, or ultimately the dequant jnp baseline
        — all token-exact) takes over and the stream stays identical."""
        cfg, model, params, rc = dense_setup
        qparams = model.quantize(params, method="synthetic", key=KEY)
        rc_vq = rc.replace_policy(vq_mode="eva")
        rng = np.random.default_rng(23)
        prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
        req = GenerationRequest(prompt=prompt, max_new_tokens=5)

        ref = Engine(model, qparams, rc_vq,
                     EngineConfig(num_slots=1, max_len=24))
        r1 = ref.submit(req)
        _drain(ref)
        eva_chosen = sorted({pl.backend for _p, pl in ref.plans["decode"]
                             if pl.backend.startswith("eva_")})
        assert eva_chosen, "VQ decode plan should use an EVA backend"
        victim = eva_chosen[0]

        fp = FaultPlan.scripted(FaultSpec("backend", tick=1, backend=victim))
        eng = Engine(model, qparams, rc_vq,
                     EngineConfig(num_slots=1, max_len=24, fault_plan=fp))
        u1 = eng.submit(req)
        _drain(eng)
        assert eng.output(u1).tokens == ref.output(r1).tokens
        assert eng.metrics()["backend_fallbacks"] == 1
        # the failed backend is out of every re-planned decode leaf
        replanned = {pl.backend for _p, pl in eng.plans["decode"]}
        assert victim not in replanned
        stats = plan_mod.default_planner().backend_stats()
        assert victim in stats["quarantined"]
        assert stats["failures"][victim] == 1

    def test_all_eva_quarantined_degrades_to_dequant(self, dense_setup):
        """With EVERY eligible EVA backend quarantined the planner
        degrades the policy itself: vq_mode="eva" falls back to the
        dequant jnp baseline (token-exact vs EVA per the engine VQ
        equivalence test) instead of refusing to serve."""
        from repro.core.vq import VQWeight

        cfg, model, params, rc = dense_setup
        qparams = model.quantize(params, method="synthetic", key=KEY)
        vq = next(leaf for leaf in jax.tree_util.tree_leaves(
            qparams, is_leaf=lambda x: isinstance(x, VQWeight))
            if isinstance(leaf, VQWeight))
        spec = LinearSpec.for_vq(vq, M=2, x_dtype="float32",
                                 out_dtype="float32", in_mesh=False)
        policy = PlanPolicy(vq_mode="eva")
        pl = Planner(cooloff_s=60.0)
        matched = {be.name for be in Planner._match_all(spec, policy)}
        assert matched and all(b.startswith("eva_") for b in matched)
        for b in matched:
            pl.record_backend_failure(b)
        degraded = pl.plan(spec, policy)
        assert degraded.backend == "dequant_jnp"


class TestRecurrentRestore:
    def test_restore_preserves_recurrent_cache_structure(self,
                                                         recurrent_setup):
        """xlstm caches are nested tuple/dict trees; restore adopts the
        leaves under the LIVE engine's treedef (the path format collapses
        list-vs-tuple), so a restored engine decodes without retracing
        errors and stays token-identical."""
        cfg, model, params, rc = recurrent_setup
        rng = np.random.default_rng(29)
        prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        mk = lambda: Engine(model, params, rc,
                            EngineConfig(num_slots=2, max_len=24))
        eng = mk()
        uid = eng.submit(GenerationRequest(prompt=prompt, max_new_tokens=8))
        eng.step(), eng.step(), eng.step()
        snap = eng.snapshot()
        _drain(eng)
        ref = eng.output(uid).tokens

        eng2 = mk()
        eng2.restore(snap)
        _drain(eng2)
        assert eng2.output(uid).tokens == ref
        assert jax.tree_util.tree_structure(
            eng2.caches) == jax.tree_util.tree_structure(eng.caches)


# ---------------------------------------------------------------------------
# The acceptance scenario: seeded mixed batch under the restart controller
# ---------------------------------------------------------------------------


class TestAcceptance:
    def test_mixed_batch_error_timeout_stop_after_restore(self, dense_setup):
        """One scripted plan drives a mixed batch: the poisoned request
        finishes "error", the expired one "timeout", the request that
        crosses an engine crash + restore finishes "stop-after-restore" —
        and both bystanders (one greedy, one sampled) stream tokens
        BIT-IDENTICAL to a fault-free run."""
        cfg, model, params, rc = dense_setup
        rng = np.random.default_rng(41)
        p = lambda n: rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        pa, pb, pc, pd, pe = p(5), p(6), p(4), p(7), p(5)
        sampled = SamplingParams(greedy=False, temperature=1.1, seed=13)

        # fault-free reference (no deadline, no eos: B runs to length)
        def submit_all(eng, with_faults):
            ua = eng.submit(GenerationRequest(prompt=pa, max_new_tokens=6))
            ub = eng.submit(GenerationRequest(
                prompt=pb, max_new_tokens=12,
                eos_ids=(b_eos,) if with_faults else ()))
            uc = eng.submit(GenerationRequest(
                prompt=pc, max_new_tokens=6,
                deadline_s=0.0 if with_faults else None))
            ud = eng.submit(GenerationRequest(prompt=pd, max_new_tokens=4))
            ue = eng.submit(GenerationRequest(prompt=pe, max_new_tokens=4,
                                              sampling=sampled))
            return ua, ub, uc, ud, ue

        b_eos = -1  # placeholder; reference ignores it
        ref = Engine(model, params, rc, EngineConfig(num_slots=4, max_len=32))
        ra, rb, rc_, rd, re_ = submit_all(ref, with_faults=False)
        _drain(ref)
        b_ref = ref.output(rb).tokens
        # choose B's stop token: a late token whose FIRST occurrence in
        # the stream is after the crash tick (so B is mid-flight at the
        # crash and stops only after the restore)
        b_idx = next(i for i in range(8, 12)
                     if b_ref[i] not in b_ref[:i])
        b_eos = int(b_ref[b_idx])

        fp = FaultPlan.scripted(
            FaultSpec("poison", tick=1, uid=1),      # A -> error
            FaultSpec("decode", tick=6),             # crash: only B active
        )

        def factory():
            return Engine(model, params, rc,
                          EngineConfig(num_slots=4, max_len=32,
                                       fault_plan=fp))

        # C's deadline_s=0.0 is already past at the first tick's sweep
        eng, outs, stats = serve_with_restarts(
            factory,
            [GenerationRequest(prompt=pa, max_new_tokens=6),
             GenerationRequest(prompt=pb, max_new_tokens=12,
                               eos_ids=(b_eos,)),
             GenerationRequest(prompt=pc, max_new_tokens=6, deadline_s=0.0),
             GenerationRequest(prompt=pd, max_new_tokens=4),
             GenerationRequest(prompt=pe, max_new_tokens=4,
                               sampling=sampled)])
        ua, ub, uc, ud, ue = sorted(outs)
        assert stats.restarts == 1
        # the three affected requests
        assert outs[ua].finish_reason == "error"
        assert outs[uc].finish_reason == "timeout"
        assert outs[ub].finish_reason == "stop-after-restore"
        assert outs[ub].tokens == b_ref[: b_idx + 1]
        # bystanders: bit-identical streams (greedy AND sampled lanes)
        assert outs[ud].tokens == ref.output(rd).tokens
        assert outs[ue].tokens == ref.output(re_).tokens
        assert outs[ud].finish_reason == "length"
        assert outs[ue].finish_reason == "length"
        # A's pre-fault prefix is the fault-free prefix
        assert outs[ua].tokens == ref.output(ra).tokens[
            : len(outs[ua].tokens)]
        m = eng.metrics()
        assert m["errors"] == 1 and m["timeouts"] == 1
        assert m["restores"] == 1 and m["snapshots"] >= 1
        assert m["finished"] == (m["finished_stop"] + m["finished_length"]
                                 + m["errors"] + m["timeouts"])


# ---------------------------------------------------------------------------
# Planner backend quarantine / fallback units (private Planner instances;
# synthetic backends match only a sentinel spec no real model produces)
# ---------------------------------------------------------------------------

_SENTINEL_N = 9973  # prime; no real layer width


def _synthetic_backend(name, fail, us):
    def matcher(s, p):
        return s.kind == "dense" and s.N == _SENTINEL_N

    def planner_fn(s, p):
        def run(x, w):
            if fail:
                raise RuntimeError(f"{name} exploded")
            return x @ w

        return MatmulPlan(name, s, p, (), PlanCost(
            macs=us, lookup_adds=0, weight_bytes=1), run)

    return matcher, planner_fn


@pytest.fixture(scope="module")
def synthetic_backends():
    register_backend("t_cheap_flaky", *_synthetic_backend(
        "t_cheap_flaky", fail=True, us=1))
    register_backend("t_pricey_solid", *_synthetic_backend(
        "t_pricey_solid", fail=False, us=10 ** 12))
    return LinearSpec(M=4, K=8, N=_SENTINEL_N, kind="dense",
                      x_dtype="float32", out_dtype="float32")


class TestPlannerQuarantine:
    def test_execute_fallback_quarantines_and_reranks(self,
                                                      synthetic_backends):
        spec = synthetic_backends
        pl = Planner(cooloff_s=60.0)
        plan = pl.plan(spec, PlanPolicy())
        assert plan.backend == "t_cheap_flaky"       # cheapest candidate
        x = jnp.ones((4, 8), jnp.float32)
        w = jnp.ones((8, _SENTINEL_N), jnp.float32)
        out = plan.execute(x, w)                     # chains to a survivor
        assert out.shape == (4, _SENTINEL_N)
        stats = pl.backend_stats()
        assert stats["failures"]["t_cheap_flaky"] == 1
        assert stats["exec_fallbacks"] >= 1
        assert "t_cheap_flaky" in stats["quarantined"]
        # a fresh plan skips the quarantined backend entirely
        assert pl.plan(spec, PlanPolicy()).backend != "t_cheap_flaky"

    def test_cooloff_releases_quarantine(self, synthetic_backends):
        spec = synthetic_backends
        pl = Planner(cooloff_s=0.05)
        pl.record_backend_failure("t_cheap_flaky")
        assert pl.plan(spec, PlanPolicy()).backend != "t_cheap_flaky"
        time.sleep(0.06)
        # expiry releases the backend AND clears the cache, so the
        # recovered candidate is re-ranked rather than shadowed
        assert pl.plan(spec, PlanPolicy()).backend == "t_cheap_flaky"
        assert pl.backend_stats()["quarantined"] == ()

    def test_all_quarantined_serves_as_last_resort(self, synthetic_backends):
        spec = synthetic_backends
        pl = Planner(cooloff_s=60.0)
        matched = {be.name for be in Planner._match_all(spec, PlanPolicy())}
        assert {"t_cheap_flaky", "t_pricey_solid", "fp"} <= matched
        for b in matched:
            pl.record_backend_failure(b)
        # policy is already the degraded jnp shape -> quarantine is
        # ignored rather than refusing to serve
        plan = pl.plan(spec, PlanPolicy())
        assert plan.backend in matched

    def test_reset_quarantine_clears_everything(self, synthetic_backends):
        spec = synthetic_backends
        pl = Planner(cooloff_s=60.0)
        pl.record_backend_failure("t_cheap_flaky")
        pl.reset_quarantine()
        stats = pl.backend_stats()
        assert stats == {"failures": {}, "quarantined": (),
                         "exec_fallbacks": 0}
        assert pl.plan(spec, PlanPolicy()).backend == "t_cheap_flaky"
