"""Llama-3.2-Vision-11B — 40L GQA decoder with gated cross-attention image
layers every 5th layer [hf:meta-llama/Llama-3.2-11B-Vision]. Vision tower
stubbed (input_specs provides patch embeddings).

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vision",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_period=5,
    rope_theta=500000.0,
    vq_C=2,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-smoke",
    family="vision",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    cross_attn_period=2,
    vq_C=2,
)
