"""Elastic scaling: restart training on a different device count.

Because checkpoints store unsharded host arrays (checkpoint/manager.py)
and the data pipeline is stateless/counter-based (data/pipeline.py),
elastic restart is: rebuild the mesh at the new size, recompute pspecs,
device_put the restored state with the new shardings, and resume at the
saved step — the global batch content and the optimizer math are
invariant to the new dp_size (tests pin this down).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.runtime import sharding as shd


def reshard_state(params: Any, opt_state: Any, model, mesh: Mesh):
    """Place restored (host) state onto `mesh` with the rule-based specs."""
    pspec = shd.param_pspecs(params, mesh)
    mspec = shd.opt_pspecs(pspec, params, mesh, zero1=True)
    params = jax.device_put(params, shd.to_named(pspec, mesh))
    new_opt = opt_state._replace(
        step=jax.device_put(opt_state.step),
        m=jax.device_put(opt_state.m, shd.to_named(mspec, mesh)),
        v=jax.device_put(opt_state.v, shd.to_named(mspec, mesh)),
        master=(jax.device_put(opt_state.master, shd.to_named(mspec, mesh))
                if opt_state.master is not None else None),
    )
    return params, new_opt


def valid_dp_sizes(global_batch: int, num_devices: int, model_parallel: int):
    """Data-parallel sizes an elastic restart may choose from."""
    out = []
    for dp in range(1, num_devices // model_parallel + 1):
        if dp * model_parallel <= num_devices and global_batch % dp == 0:
            out.append(dp)
    return out
