from repro.runtime.sharding import (
    param_pspecs, opt_pspecs, batch_pspecs, cache_pspecs, to_named,
)
from repro.runtime.fault_tolerance import (
    StepWatchdog, StragglerReport, RestartStats, run_with_restarts,
)
from repro.runtime.elastic import reshard_state, valid_dp_sizes
