"""Request scheduler for continuous batching.

Typed ``GenerationRequest``s (serve/api.py) arrive through the engine;
the scheduler wraps each in a ``TrackedRequest`` (runtime record: uid,
generated tokens, timing marks) and admits them into free decode slots
(paper §V-C: EU-stage weight-tile reuse across requests is what makes
multi-batch decode cheap — the engine keeps slots as full as possible so
every streamed WI tile is reused by all active requests).

The queue is BOUNDED: ``max_queue`` caps waiting requests and ``submit``
raises ``QueueFull`` instead of growing the deque without limit — the
engine turns that into a clean ``RequestOutput(finish_reason="rejected")``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, List, Optional

from repro.serve.api import GenerationRequest


class QueueFull(Exception):
    """Raised by ``Scheduler.submit`` when the waiting queue is at
    ``max_queue``; the engine rejects the request instead of queueing."""


@dataclasses.dataclass
class TrackedRequest:
    """Engine-side runtime record of one submitted request."""

    uid: int
    request: GenerationRequest
    generated: List[int] = dataclasses.field(default_factory=list)
    # per-token logprobs of ``generated``; populated only when the
    # request's SamplingParams.logprobs flag is set
    logprobs: List[float] = dataclasses.field(default_factory=list)
    submit_t: float = dataclasses.field(default_factory=time.perf_counter)
    queue_wait_s: float = 0.0
    prefill_s: float = 0.0
    decode_t0: float = 0.0           # set when the request joins decode
    done: bool = False
    restored: bool = False           # was in flight across a snapshot restore
    # ---- paged-engine state (serve/paging.py) ----
    # committed prefill positions; > 0 marks a mid-prefill (chunked) slot
    prefill_pos: int = 0
    # evicted out of a slot by an out-of-blocks decode step; resumes by
    # re-prefilling prompt ++ generated[:-1] with the saved decode state
    preempted: bool = False
    resume_key: Optional[Any] = None         # (2,) uint32 PRNG key
    resume_remaining: int = 0                # decode budget at eviction

    @property
    def prompt_len(self) -> int:
        return self.request.prompt_len

    @property
    def stop_set(self) -> frozenset:
        return self.request.stop_set

    @property
    def deadline_t(self) -> Optional[float]:
        """Absolute perf_counter deadline (None when the request has no
        ``deadline_s``)."""
        if self.request.deadline_s is None:
            return None
        return self.submit_t + self.request.deadline_s

    def expired(self, now: Optional[float] = None) -> bool:
        dl = self.deadline_t
        if dl is None:
            return False
        return (time.perf_counter() if now is None else now) > dl

    def clone(self) -> "TrackedRequest":
        """Snapshot copy: shares the frozen GenerationRequest, copies the
        mutable generated list — a live engine mutating this record can
        never corrupt an EngineSnapshot that holds the clone."""
        return dataclasses.replace(self, generated=list(self.generated),
                                   logprobs=list(self.logprobs))


class Scheduler:
    def __init__(self, num_slots: int, max_queue: int = 256):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.num_slots = num_slots
        self.max_queue = max_queue
        self.queue: Deque[TrackedRequest] = deque()
        self.slots: List[Optional[TrackedRequest]] = [None] * num_slots
        self._uid = 0

    def next_uid(self) -> int:
        """Allocate a uid without enqueueing (rejected submissions get a
        uid too, so their RequestOutput is addressable)."""
        self._uid += 1
        return self._uid

    def submit(self, request: GenerationRequest,
               uid: Optional[int] = None) -> int:
        """Enqueue a typed request; raises ``QueueFull`` at the bound."""
        if len(self.queue) >= self.max_queue:
            raise QueueFull(
                f"scheduler queue is at max_queue={self.max_queue}")
        uid = self.next_uid() if uid is None else uid
        self.queue.append(TrackedRequest(uid, request))
        return uid

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self, can_admit: Optional[Callable[[TrackedRequest], bool]]
              = None) -> List[int]:
        """Move queued requests into free slots; returns slot indices that
        need prefill.

        Ordering is earliest-deadline-first: the queued request with the
        nearest absolute deadline is admitted first; requests without a
        deadline rank behind all deadlined ones, FIFO among themselves
        (preempted requests re-enter at the queue head, so they also
        resume first within their deadline class).

        ``can_admit`` (the paged engine's block-budget predicate) gates
        each candidate; admission STOPS at the first refusal rather than
        skipping to a smaller request behind it — no head-of-line bypass
        means a large request cannot be starved forever."""
        admitted = []
        for i in self.free_slots():
            if not self.queue:
                break
            best = min(
                range(len(self.queue)),
                key=lambda j: (self.queue[j].deadline_t
                               if self.queue[j].deadline_t is not None
                               else float("inf"), j))
            tr = self.queue[best]
            if can_admit is not None and not can_admit(tr):
                break
            del self.queue[best]
            self.slots[i] = tr
            admitted.append(i)
        return admitted

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def prune_queue(self, predicate) -> List[TrackedRequest]:
        """Remove (and return) queued requests matching ``predicate`` —
        the deadline/TTL sweep drops expired requests before they are
        admitted, so an already-dead request never wastes a prefill."""
        kept: Deque[TrackedRequest] = deque()
        removed: List[TrackedRequest] = []
        for tr in self.queue:
            (removed if predicate(tr) else kept).append(tr)
        self.queue = kept
        return removed

    def drain_queue(self) -> List[TrackedRequest]:
        """Empty the waiting queue (circuit-breaker trip: pending
        requests are rejected cleanly instead of waiting on an engine
        that will never serve them)."""
        out = list(self.queue)
        self.queue.clear()
        return out

    @property
    def last_uid(self) -> int:
        """Highest uid issued so far (uids are dense and 1-based, so a
        uid is known iff ``1 <= uid <= last_uid``)."""
        return self._uid

    def restore_state(self, uid_counter: int, queue, slots) -> None:
        """Adopt snapshot state (Engine.restore)."""
        self._uid = uid_counter
        self.queue = deque(tr.clone() for tr in queue)
        if len(slots) != self.num_slots:
            raise ValueError(
                f"snapshot has {len(slots)} slots, engine has "
                f"{self.num_slots}")
        self.slots = [tr.clone() if tr is not None else None for tr in slots]

    def finish(self, slot: int) -> TrackedRequest:
        r = self.slots[slot]
        assert r is not None
        r.done = True
        self.slots[slot] = None
        return r

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active_slots()
