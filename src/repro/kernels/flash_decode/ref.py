"""Pure-jnp oracle for the flash-decode kernel (plain masked softmax)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_decode_ref(q, k, v, lengths) -> jax.Array:
    """q (B,H,hd), k/v (B,S,Hk,hd), lengths (B,) -> (B,H,hd)."""
    B, H, hd = q.shape
    S, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    qg = q.reshape(B, Hk, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
