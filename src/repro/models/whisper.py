"""Whisper-medium family: encoder-decoder transformer backbone.

Per the assignment the conv/mel frontend is a STUB — `input_specs()`
provides precomputed frame embeddings (B, S_src, d_model); the frontend is
a single projection. Encoder: bidirectional self-attn + GeLU MLP with
LayerNorm; decoder: causal self-attn + cross-attn + GeLU MLP. Sinusoidal
absolute positions (whisper uses no RoPE).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig, RunConfig

# fixed 30-s window -> 1500 frames in real whisper; the assignment's
# seq_len applies to the decoder (LM backbone); encoder memory is S_SRC.
S_SRC = 1500


def sinusoid_pos(S: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def sinusoid_at(positions: jax.Array, d: int, dtype) -> jax.Array:
    """Sinusoidal embedding evaluated at arbitrary positions (B, S) — avoids
    materializing a max-length table for long-context decode."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _init_enc_layer(key, cfg: ModelConfig) -> Any:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": cm.make_layernorm(cfg.d_model),
        "attn": cm.make_attention(ks[0], cfg, bias=True),
        "mlp_norm": cm.make_layernorm(cfg.d_model),
        "mlp": cm.make_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> Any:
    ks = jax.random.split(key, 3)
    return {
        "self_norm": cm.make_layernorm(cfg.d_model),
        "self_attn": cm.make_attention(ks[0], cfg, bias=True),
        "cross_norm": cm.make_layernorm(cfg.d_model),
        "cross_attn": cm.make_attention(ks[1], cfg, bias=True),
        "mlp_norm": cm.make_layernorm(cfg.d_model),
        "mlp": cm.make_gelu_mlp(ks[2], cfg.d_model, cfg.d_ff),
    }


def init_params(key, cfg: ModelConfig) -> Any:
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "frontend": {"proj": cm.make_linear(ks[2], cfg.d_model, cfg.d_model, bias=True)},
        "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": cm.make_layernorm(cfg.d_model),
        "embedding": cm.make_embedding(ks[3], cfg.padded_vocab, cfg.d_model),
        "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": cm.make_layernorm(cfg.d_model),
        "lm_head": cm.make_linear(ks[4], cfg.d_model, cfg.padded_vocab),
    }


def encode(params: Any, frames: jax.Array, rc: RunConfig, cfg: ModelConfig) -> jax.Array:
    """frames: (B, S_src, d_model) precomputed embeddings (stub frontend)."""
    B, S, _ = frames.shape
    x = cm.linear(params["frontend"]["proj"], frames.astype(cfg.act_dtype), rc)
    x = x + sinusoid_pos(S, cfg.d_model, cfg.act_dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    # encoder runs in prefill/train style (bidirectional, no cache)
    enc_rc = rc.replace(mode="prefill" if rc.mode == "decode" else rc.mode)

    def step(x, lp):
        h = cm.layernorm(lp["attn_norm"], x, cfg.norm_eps)
        a, _ = cm.attention_fwd(
            lp["attn"], h, enc_rc, cfg, positions=positions, causal=False
        )
        x = x + a
        h = cm.layernorm(lp["mlp_norm"], x, cfg.norm_eps)
        return x + cm.gelu_mlp_fwd(lp["mlp"], h, enc_rc), None

    x, _ = jax.lax.scan(step, x, params["encoder"])
    return cm.layernorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer_fwd(lp, x, rc, cfg, *, positions, memory, cache):
    h = cm.layernorm(lp["self_norm"], x, cfg.norm_eps)
    self_cache = None if cache is None else cache["self"]
    a, new_self = cm.attention_fwd(
        lp["self_attn"], h, rc, cfg, positions=positions, cache=self_cache
    )
    x = x + a
    h = cm.layernorm(lp["cross_norm"], x, cfg.norm_eps)
    if rc.mode == "decode" and cache is not None:
        # cross K/V precomputed at prefill time
        o = cm.decode_attention(
            cm.linear(lp["cross_attn"]["wq"], h, rc).reshape(
                h.shape[0], 1, cfg.num_heads, cfg.head_dim
            ),
            cache["cross_k"], cache["cross_v"], cache["cross_len"],
        )
        c = cm.linear(
            lp["cross_attn"]["wo"],
            o.reshape(h.shape[0], 1, cfg.q_dim), rc,
        )
        new_cache = {
            "self": new_self,
            "cross_k": cache["cross_k"], "cross_v": cache["cross_v"],
            "cross_len": cache["cross_len"],
        }
    else:
        c, _ = cm.attention_fwd(
            lp["cross_attn"], h, rc, cfg,
            positions=positions, kv_source=memory, causal=False,
        )
        if rc.mode == "prefill":
            B = h.shape[0]
            Sm = memory.shape[1]
            ck = cm.linear(lp["cross_attn"]["wk"], memory, rc).reshape(
                B, Sm, cfg.num_kv_heads, cfg.head_dim
            )
            cv = cm.linear(lp["cross_attn"]["wv"], memory, rc).reshape(
                B, Sm, cfg.num_kv_heads, cfg.head_dim
            )
            new_cache = {
                "self": new_self, "cross_k": ck, "cross_v": cv,
                "cross_len": jnp.full((B,), Sm, jnp.int32),
            }
        else:
            new_cache = None
    x = x + c
    h = cm.layernorm(lp["mlp_norm"], x, cfg.norm_eps)
    return x + cm.gelu_mlp_fwd(lp["mlp"], h, rc), new_cache


def forward(
    params: Any,
    tokens: jax.Array,
    rc: RunConfig,
    cfg: ModelConfig,
    *,
    frames: Optional[jax.Array] = None,
    memory: Optional[jax.Array] = None,   # precomputed encoder output
    positions: Optional[jax.Array] = None,
    caches: Optional[Any] = None,
) -> Tuple[jax.Array, Optional[Any]]:
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if memory is None and frames is not None:
        memory = encode(params, frames, rc, cfg)

    x = cm.embed(params["embedding"], tokens, cfg.act_dtype)
    x = x + sinusoid_at(positions, cfg.d_model, cfg.act_dtype)

    body = functools.partial(
        _dec_layer_fwd, rc=rc, cfg=cfg, positions=positions, memory=memory
    )

    def step(carry, xs):
        lp, cache = xs
        if rc.remat and rc.mode == "train":
            fn = jax.checkpoint(
                lambda lp_, x_: body(lp_, x_, cache=None),
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
            y, nc = fn(lp, carry)
        else:
            y, nc = body(lp, carry, cache=cache)
        return y, nc

    if caches is None:
        x, new_caches = jax.lax.scan(
            lambda c, lp: step(c, (lp, None)), x, params["decoder"]
        )
    else:
        x, new_caches = jax.lax.scan(step, x, (params["decoder"], caches))

    if rc.mode == "prefill" and rc.lm_head_last_only:
        x = x[:, -1:]  # §Perf: skip the vocab projection for prompt tokens
    x = cm.layernorm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.lm_head(params["lm_head"], x, rc)
    out = new_caches if caches is not None or rc.mode == "prefill" else None
    return logits, out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Any:
    dtype = dtype or cfg.act_dtype

    def one(_):
        return {
            "self": {
                "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
                "len": jnp.zeros((batch,), jnp.int32),
            },
            "cross_k": jnp.zeros((batch, S_SRC, cfg.num_kv_heads, cfg.head_dim), dtype),
            "cross_v": jnp.zeros((batch, S_SRC, cfg.num_kv_heads, cfg.head_dim), dtype),
            "cross_len": jnp.full((batch,), S_SRC, jnp.int32),
        }

    return jax.vmap(one)(jnp.arange(cfg.num_layers))
