"""Jit'd wrapper: quantize activations/weights and run the int8 GEMM.

Registers the "int8_pallas" backend with core/plan.py (the INT8 prefill
path of a dense weight under PlanPolicy(int8_prefill=True, impl="pallas"));
the planner freezes the (block_m, block_n, block_k) tiles per spec."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.core.ops import quantize_int8
from repro.kernels.int8_gemm.kernel import int8_gemm_pallas
from repro.kernels.int8_gemm.ref import int8_gemm_ref


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "use_pallas", "out_dtype"),
)
def int8_matmul_kernel(
    x: jax.Array,   # (..., K) float
    w: jax.Array,   # (K, N) float
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
    use_pallas: bool = True,
    out_dtype=None,
) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    M = x.size // K
    xq, xs = quantize_int8(x.reshape(M, K), axis=-1)
    wq, ws = quantize_int8(w, axis=0)

    if not use_pallas:
        y = int8_gemm_ref(xq, wq, xs, ws)
        return y.reshape(*lead, N).astype(out_dtype)

    bm = min(block_m, max(8, M))
    bn = min(block_n, N)
    bk = min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        xq = jnp.pad(xq, ((0, pm), (0, pk)))
        xs = jnp.pad(xs, ((0, pm), (0, 0)))
    if pk or pn:
        wq = jnp.pad(wq, ((0, pk), (0, pn)))
        ws = jnp.pad(ws, ((0, 0), (0, pn)))
    y = int8_gemm_pallas(xq, wq, xs, ws, block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    y = y[:M, :N]
    return y.reshape(*lead, N).astype(out_dtype)


# ---------------------------------------------------------------------------
# Plan backend
# ---------------------------------------------------------------------------


def _plan_int8_pallas(spec: plan_mod.LinearSpec,
                      policy: plan_mod.PlanPolicy) -> plan_mod.MatmulPlan:
    # this kernel's tile model: MXU-friendly 256x256x512 defaults clamped
    # to the actual GEMM extents (the wrapper pads the remainders)
    bm = min(256, max(8, spec.M))
    bn = min(256, spec.N)
    bk = min(512, spec.K)
    out_dt = jnp.dtype(spec.out_dtype)
    interpret = policy.interpret

    def run(x, w):
        return int8_matmul_kernel(x, w, block_m=bm, block_n=bn, block_k=bk,
                                  interpret=interpret, out_dtype=out_dt)

    cost = plan_mod.PlanCost(macs=spec.M * spec.K * spec.N, lookup_adds=0,
                             weight_bytes=spec.K * spec.N)
    return plan_mod.MatmulPlan("int8_pallas", spec, policy,
                               (("bm", bm), ("bn", bn), ("bk", bk)), cost, run)


plan_mod.register_backend(
    "int8_pallas",
    lambda s, p: s.kind == "int8" and p.impl == "pallas",
    _plan_int8_pallas,
)
