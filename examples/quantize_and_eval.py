"""Quantization-quality example (the paper's Tbl. V story): train a model,
then compare FP32 / VQ-4bit / VQ-2bit / RTN-INT4 / RTN-INT2 perplexity.

    PYTHONPATH=src python examples/quantize_and_eval.py
"""
from benchmarks.tbl_v_accuracy_proxy import run


def main():
    rows = run(lambda name, us, derived="": print(f"{name:24s} {derived}"))
    print("\nsummary (ppl):")
    for name, ppl in rows:
        print(f"  {name:16s} {ppl:10.3f}")
    print("\npaper's qualitative claim: 4-bit near-lossless for all methods;"
          "\nat 2-bit, scalar RTN collapses while VQ stays usable.")


if __name__ == "__main__":
    main()
