"""Deterministic, sharding-aware synthetic LM data pipeline.

Production posture without an external corpus: token streams are generated
from a counter-based PRNG (stateless — any (host, step) pair regenerates
its shard deterministically, which is what makes checkpoint-restart and
elastic resharding exact), packed into fixed-length sequences, and
prefetched on a background thread.

Key properties the tests pin down:
  * determinism: stream(step) identical across restarts,
  * disjointness: different data-parallel shards never overlap,
  * elasticity: re-sharding to a different dp_size re-partitions the same
    global stream (global batch content is invariant),
  * failure injection: `fail_at` raises at a chosen step (FT tests).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # structured synthetic task: next-token = (token * a + b) % vocab on a
    # noisy copy channel — learnable, so training losses move (tests).
    task: str = "affine"   # affine | uniform
    noise: float = 0.05


def _batch_for_step(cfg: DataConfig, step: int) -> np.ndarray:
    """Global batch of tokens (global_batch, seq_len+1), deterministic."""
    rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=[0, 0, 0, step]))
    B, S = cfg.global_batch, cfg.seq_len + 1
    if cfg.task == "uniform":
        return rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int64)
    # affine-chain task
    a = 31 % cfg.vocab_size or 1
    b = 17 % cfg.vocab_size
    x0 = rng.integers(0, cfg.vocab_size, (B,))
    toks = np.empty((B, S), np.int64)
    toks[:, 0] = x0
    for t in range(1, S):
        toks[:, t] = (toks[:, t - 1] * a + b) % cfg.vocab_size
    flip = rng.random((B, S)) < cfg.noise
    toks[flip] = rng.integers(0, cfg.vocab_size, flip.sum())
    return toks


class DataPipeline:
    """Iterator over host-local shards of the global stream."""

    def __init__(
        self,
        cfg: DataConfig,
        *,
        dp_rank: int = 0,
        dp_size: int = 1,
        start_step: int = 0,
        prefetch: int = 2,
        fail_at: Optional[int] = None,
    ):
        assert cfg.global_batch % dp_size == 0, (cfg.global_batch, dp_size)
        self.cfg = cfg
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.step = start_step
        self.fail_at = fail_at
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> Dict[str, np.ndarray]:
        if self.fail_at is not None and step == self.fail_at:
            raise RuntimeError(f"injected data failure at step {step}")
        g = _batch_for_step(self.cfg, step)
        per = self.cfg.global_batch // self.dp_size
        shard = g[self.dp_rank * per:(self.dp_rank + 1) * per]
        return {
            "tokens": shard[:, :-1].astype(np.int32),
            "labels": shard[:, 1:].astype(np.int32),
        }

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            try:
                item = self._make(step)
            except Exception as e:  # surface injected failures to consumer
                self._q.put(e)
                return
            self._q.put(item)
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        self.step += 1
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    # ---- stateless access (tests / restart logic) ----
    def peek_step(self, step: int) -> Dict[str, np.ndarray]:
        return self._make(step)


def global_batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    g = _batch_for_step(cfg, step)
    return {"tokens": g[:, :-1].astype(np.int32),
            "labels": g[:, 1:].astype(np.int32)}
