"""Self-drafting speculative decoding: multi-token verify inside the
one-trace jitted decode step.

One engine decode step proposes K draft tokens per slot from a
per-slot successor table (device state, like the RNG keys), feeds
``[t0, d1..dK]`` through ONE ``model.decode`` call (the KV layer
appends all K+1 entries), then replays the exact non-speculative
sampling epilogue over the K+1 logit rows and keeps the longest prefix
the acceptance rule proves identical to what the non-speculative
engine would have emitted. Everything here is per-slot vectorized
device math — there is no host-side per-draft loop, and the decode
step still traces exactly once.

Why the streams are provably identical
--------------------------------------
The non-speculative engine is a deterministic map: given the committed
context and the slot's PRNG key, ``api.sample_tokens`` fixes the next
token (argmax for greedy slots; one key split + ``categorical`` over
the masked, temperature-scaled logits for sampled slots). Logit row j
of the verify window is conditioned on ``[context, t0, d1..dj]``, so
row j equals the baseline's step-(j+1) logits IFF every draft before
it matched the baseline emission: ``d_i == s_{i-1}`` for i <= j. The
verify scan samples ``s_j`` from row j advancing the key once per row
— the same key trajectory the baseline would follow — and the emit
mask keeps exactly the rows whose conditioning prefix matched (plus
the first mismatch row, whose sample IS the baseline's correction).
The slot's key is then rolled back to "after e splits" where e is the
number of emitted tokens, so the next step resumes the identical
PRNG stream. Acceptance is by token equality, not distribution
overlap, so this holds for greedy and seeded sampling alike.

Rejected drafts are rolled back WITHOUT retracing: the model wrote
K+1 cache entries and advanced every ``len`` leaf by K+1, and
``truncate_cache_len`` walks the returned cache tree adding ``e -
(K+1)`` — stale entries beyond ``len`` are invisible to the
``pos < len`` attention validity mask and are overwritten in place by
the next step's writes at the same slots.

The drafter is prompt-lookup style self-drafting (no extra model): a
``(B, V) int32`` successor table mapping token -> the token that last
followed it in this slot's own stream, primed from the prompt at
prefill and updated in-jit from emitted transitions. -1 means "never
seen": the draft chain self-terminates and shorter windows simply
verify fewer rows.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import api


def prime_successors(succ: np.ndarray, slot: int, tokens) -> None:
    """Host-side (re)prime of one slot's successor row from its token
    history (prompt + any already-emitted tokens): ``succ[slot, t_i] =
    t_{i+1}``, later transitions winning. Called at prefill activation,
    outside the jitted step."""
    toks = np.asarray(tokens, np.int64).ravel()
    vocab = succ.shape[1]
    succ[slot, :] = -1
    if toks.size < 2:
        return
    src, dst = toks[:-1], toks[1:]
    ok = (src >= 0) & (src < vocab) & (dst >= 0) & (dst < vocab)
    # np fancy-index assignment applies duplicates in order: later wins
    succ[slot, src[ok]] = dst[ok].astype(np.int32)


def propose_drafts(succ: jax.Array, last_token: jax.Array,
                   k: int) -> jax.Array:
    """Chain k successor lookups from each slot's last committed token.
    succ (B, V) int32, last_token (B,) int32 -> drafts (B, k) int32
    with -1 past the end of the known chain."""
    B, vocab = succ.shape
    rows = jnp.arange(B)

    def step(tok, _):
        nxt = succ[rows, jnp.clip(tok, 0, vocab - 1)]
        nxt = jnp.where(tok >= 0, nxt, -1)
        return nxt, nxt

    _, chain = jax.lax.scan(step, last_token, None, length=k)
    return jnp.moveaxis(chain, 0, 1)                     # (B, k)


def update_successors(succ: jax.Array, prevs: jax.Array, nexts: jax.Array,
                      emit: jax.Array) -> jax.Array:
    """Record the emitted transitions ``prevs[:, j] -> nexts[:, j]`` for
    every j with ``emit[:, j]`` — sequentially, so within one window the
    latest transition wins, matching the host priming order."""
    B, S = prevs.shape
    vocab = succ.shape[1]
    rows = jnp.arange(B)

    def body(j, table):
        pv = jnp.clip(prevs[:, j], 0, vocab - 1)
        cur = table[rows, pv]
        new = jnp.where(emit[:, j], nexts[:, j], cur)
        return table.at[rows, pv].set(new)

    return jax.lax.fori_loop(0, S, body, succ)


def truncate_cache_len(caches: Any, delta: jax.Array) -> Any:
    """Roll back every ``len`` leaf of a decode-cache tree by ``delta``
    (B,) — the rejected-draft rollback. ``len`` leaves carry batch on
    the LAST axis ((L, B) after the per-layer vmap stack), so delta
    broadcasts from the right. Trees without ``len`` (stub models) pass
    through untouched; block tables are never modified."""

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                if key == "len" and hasattr(val, "dtype"):
                    d = delta.astype(val.dtype)
                    out[key] = val + d.reshape((1,) * (val.ndim - 1) + (-1,))
                else:
                    out[key] = walk(val)
            return out
        return node

    return walk(caches)


def sample_window(logits: jax.Array, keys: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array, greedy: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Replay the baseline sampling epilogue over each of the S logit
    rows in order, advancing the PRNG keys exactly once per row — the
    identical key trajectory the non-speculative engine walks across S
    consecutive steps.

    logits (B, S, V) -> (tokens (B, S) i32, logprobs (B, S) f32,
    keys_after (B, S, 2): the key state after sampling row j)."""

    def step(ks, row):
        tok, nk = api.sample_tokens(row, ks, temperature, top_k, top_p,
                                    greedy)
        lp = api.token_logprobs(row, tok)
        return nk, (tok, lp, nk)

    _, (toks, lps, ktraj) = jax.lax.scan(
        step, keys, jnp.moveaxis(logits, 1, 0))
    return (jnp.moveaxis(toks, 0, 1), jnp.moveaxis(lps, 0, 1),
            jnp.moveaxis(ktraj, 0, 1))


def accept_window(toks: jax.Array, drafts: jax.Array, finite: jax.Array,
                  stop_ids: jax.Array, remaining: jax.Array,
                  active: jax.Array, spec_on: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                             jax.Array]:
    """The acceptance rule. All conjuncts of the emit mask are monotone
    non-increasing in j, so the mask is a prefix and ``e = sum(emit)``.

    Emission j (the sample from logit row j) is kept iff:
      * every draft before it matched the corresponding emission
        (``drafts[:, i] == toks[:, i]`` for i < j) — row j's
        conditioning equals the baseline context;
      * no earlier emission hit a stop token (baseline would have
        finished the stream);
      * every row up to j is finite (a poisoned/NaN row invalidates
        itself and everything after, exactly like the baseline's
        ``bad`` short-circuit — row 0 non-finite marks the slot bad);
      * j < remaining (never emit past the token budget);
      * j == 0 or the slot opted into speculation.

    Returns (emit (B, S) bool, e (B,) i32, accepted (B,) i32 drafts
    kept, done (B,) bool, bad (B,) bool)."""
    B, S = toks.shape
    K = S - 1
    bad = active & ~finite[:, 0]

    ones = jnp.ones((B, 1), bool)
    if K:
        mismatch = jnp.cumsum(drafts != toks[:, :K], axis=1) > 0   # (B, K)
        prefix = jnp.concatenate([ones, ~mismatch], axis=1)
    else:
        prefix = ones
    hit_stop = jnp.any(toks[..., None] == stop_ids[:, None, :], axis=-1)
    stopped = jnp.cumsum(hit_stop, axis=1) > 0                     # (B, S)
    nostop_before = jnp.concatenate([ones, ~stopped[:, :K]], axis=1)
    finite_prefix = jnp.cumsum(~finite, axis=1) == 0               # (B, S)
    j = jnp.arange(S)[None, :]
    emit = (prefix & nostop_before & finite_prefix
            & (j < remaining[:, None])
            & (spec_on[:, None] | (j == 0))
            & active[:, None] & ~bad[:, None])
    e = jnp.sum(emit, axis=1).astype(jnp.int32)
    if K:
        accepted = jnp.sum(emit[:, :K] & (drafts == toks[:, :K]),
                           axis=1).astype(jnp.int32)
    else:
        accepted = jnp.zeros((B,), jnp.int32)
    last = jnp.clip(e - 1, 0, S - 1)
    stop_last = jnp.take_along_axis(hit_stop, last[:, None], axis=1)[:, 0]
    done = active & ~bad & (e > 0) & (stop_last | (e >= remaining))
    return emit, e, accepted, done, bad


def spec_decode_step(model, params, caches, tokens, positions, succ, keys,
                     temperature, top_k, top_p, greedy, stop_ids, remaining,
                     active, spec_on, poison, *, rc, k: int):
    """One speculative decode step — the jitted body the engine traces
    ONCE (all K+1 positions ride fixed shapes; per-slot variability is
    data, never shape).

    Returns (tokens (B, K+1) emitted-or-zero, logprobs (B, K+1),
    e (B,) emitted counts, accepted (B,) draft hits, done, bad,
    new_keys (B, 2), new_succ, new_caches)."""
    B = tokens.shape[0]
    S = k + 1
    vocab = model.cfg.vocab_size
    t0 = jnp.where(active, tokens, 0)
    drafts = propose_drafts(succ, t0, k)                 # (B, k)
    feed = jnp.concatenate(
        [t0[:, None], jnp.clip(drafts, 0, vocab - 1)], axis=1)
    pos = positions[:, None] + jnp.arange(S, dtype=positions.dtype)[None, :]
    logits, new_caches = model.decode(params, feed, pos, caches, rc)
    logits = logits[:, :, :vocab].astype(jnp.float32) + poison[:, None, None]
    finite = jnp.all(jnp.isfinite(logits), axis=-1)      # (B, S)
    toks, lps, ktraj = sample_window(logits, keys, temperature, top_k,
                                     top_p, greedy)
    emit, e, accepted, done, bad = accept_window(
        toks, drafts, finite, stop_ids, remaining, active, spec_on)
    # key rollback: after this step the slot must sit e splits ahead,
    # exactly where the baseline would be after emitting e tokens
    last = jnp.clip(e - 1, 0, S - 1)
    new_keys = jnp.take_along_axis(
        ktraj, last[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    new_keys = jnp.where((e > 0)[:, None], new_keys, keys)
    new_caches = truncate_cache_len(new_caches, e - S)
    prevs = jnp.concatenate([t0[:, None], toks[:, :k]], axis=1)
    new_succ = update_successors(succ, prevs, toks, emit)
    out_toks = jnp.where(emit, toks, 0)
    return (out_toks, lps, e, accepted, done, bad, new_keys, new_succ,
            new_caches)
