"""Grouped-projection VQ correctness: a same-input family ([Wq|Wk|Wv],
[W_gate|W_up]) quantized as ONE wide VQ weight sharing a codebook set must
match independent per-projection oracles, through every execution path —
jnp EVA, the fused Pallas kernel (uint8 index streaming, interpret mode),
padding, the quantization pass, checkpointing, and model-level decode."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops as core_ops
from repro.core.plan import PlanPolicy
from repro.core.vq import (
    VQWeight, dequantize, fit_vq, split_grouped, synthetic_vq, vq_specs,
)
from repro.kernels.fused_vq_matmul import fused_vq_matmul
from repro.kernels.fused_vq_matmul.kernel import fused_vq_matmul_pallas

KEY = jax.random.PRNGKey(0)

# (K, splits, M, d, n, C) — includes non-multiple V and N vs the kernel
# block sizes used below (block_v=8, block_n=64)
GROUPED_SWEEP = [
    (64, (128, 32, 32), 1, 8, 8, 2),     # paper decode M=1, qkv-like
    (80, (40, 18, 12), 3, 8, 8, 2),      # V=10, N=70: pads V and N
    (128, (96, 96), 2, 8, 4, 1),         # gate+up-like, n=4
    (96, (50, 26, 20), 4, 8, 5, 3),      # odd widths, C=3
]


def _grouped(K, splits, M, d, n, C):
    vq = synthetic_vq(KEY, K, sum(splits), d=d, n=n, C=C, splits=splits)
    x = jax.random.normal(jax.random.fold_in(KEY, K + M), (M, K), jnp.float32)
    return x, vq


class TestGroupedCore:
    def test_fit_vq_grouped_records_splits(self):
        Wq = jax.random.normal(KEY, (64, 48)) * 0.1
        Wk = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 16)) * 0.1
        g = fit_vq(KEY, [Wq, Wk], d=8, n=5, C=2, kmeans_iters=5,
                   refine_rounds=0)
        assert g.splits == (48, 16) and g.N == 64
        # grouped reconstruction approximates the concatenated matrix
        err = float(np.linalg.norm(np.asarray(dequantize(g))
                                   - np.concatenate([Wq, Wk], axis=1)))
        assert np.isfinite(err)

    def test_fit_vq_grouped_rejects_mismatched_K(self):
        with pytest.raises(ValueError, match="equal K"):
            fit_vq(KEY, [jnp.zeros((64, 8)), jnp.zeros((32, 8))], d=8)

    def test_grouped_collapse_ratio(self):
        # one shared VQ-GEMM serves sum(N_i) channels: (4096+2*1024)/2^8
        members = (4096, 1024, 1024)
        assert core_ops.grouped_compute_collapse_ratio(members, 8) == \
            pytest.approx(24.0)
        # grouped ratio is the sum of the members' individual ratios
        assert core_ops.grouped_compute_collapse_ratio(members, 8) == \
            pytest.approx(sum(core_ops.compute_collapse_ratio(m, 8)
                              for m in members))

    def test_split_grouped_members_reconstruct(self):
        _, vq = _grouped(64, (128, 32, 32), 1, 8, 8, 2)
        members = split_grouped(vq)
        assert tuple(m.N for m in members) == vq.splits
        w = np.asarray(dequantize(vq))
        off = 0
        for m in members:
            np.testing.assert_allclose(
                np.asarray(dequantize(m)), w[:, off:off + m.N], rtol=1e-6)
            off += m.N

    @pytest.mark.parametrize("K,splits,M,d,n,C", GROUPED_SWEEP)
    def test_grouped_eva_matches_per_projection_oracles(self, K, splits, M,
                                                        d, n, C):
        """One wide EVA matmul + split == independent dequant_matmul
        oracles on each member (the tentpole's exactness requirement)."""
        x, vq = _grouped(K, splits, M, d, n, C)
        y = core_ops.eva_matmul(x, vq, out_dtype=jnp.float32)
        parts = core_ops.split_grouped_outputs(y, vq)
        assert tuple(p.shape[-1] for p in parts) == splits
        for part, member in zip(parts, split_grouped(vq)):
            ref = core_ops.dequant_matmul(x, member, out_dtype=jnp.float32)
            np.testing.assert_allclose(np.asarray(part), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("K,splits,M,d,n,C", GROUPED_SWEEP)
    def test_grouped_fused_pallas_interpret(self, K, splits, M, d, n, C):
        """The fused Pallas kernel on a grouped weight (single OC scratch,
        widened N sweep, uint8 index tiles) matches the jnp oracle,
        including the non-multiple V/N padding paths."""
        x, vq = _grouped(K, splits, M, d, n, C)
        assert vq.idx.dtype == jnp.uint8  # n<=8 storage dtype
        got = fused_vq_matmul(x, vq, interpret=True, block_v=8, block_n=64,
                              out_dtype=jnp.float32)
        ref = core_ops.eva_matmul(x, vq, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestUint8Streaming:
    def test_pallas_call_consumes_uint8_indices(self):
        """The fused kernel's pallas_call input must be the uint8 index
        matrix itself — no pre-call int32 upcast (which would stream 4x
        the bytes the paper's q-bits/weight bandwidth model assumes)."""
        x, vq = _grouped(64, (128, 32, 32), 1, 8, 8, 2)
        jaxpr = jax.make_jaxpr(
            lambda a, b: fused_vq_matmul(a, b, interpret=True)
        )(x, vq)

        def find_pallas(jxp, out):
            for eqn in jxp.eqns:
                if eqn.primitive.name == "pallas_call":
                    out.append(eqn)
                for sub in eqn.params.values():
                    if hasattr(sub, "jaxpr"):
                        find_pallas(sub.jaxpr, out)
            return out

        calls = find_pallas(jaxpr.jaxpr, [])
        assert calls, "no pallas_call found in fused_vq_matmul jaxpr"
        idx_shape = vq.idx.shape  # (C, V, N); no padding at these shapes
        for eqn in calls:
            dtypes = {v.aval.shape: v.aval.dtype for v in eqn.invars}
            assert dtypes.get(idx_shape) == jnp.uint8, dtypes

    def test_kernel_level_uint8_input(self):
        """fused_vq_matmul_pallas accepts storage-dtype (uint8) index tiles
        directly and upcasts per tile in-kernel."""
        x, vq = _grouped(64, (64, 32, 32), 2, 8, 8, 2)
        X = x.reshape(2, vq.V, vq.d)
        got = fused_vq_matmul_pallas(
            X, vq.codebooks, vq.idx, vq.scale, block_v=4, block_n=64,
            interpret=True,
        )
        ref = core_ops.eva_matmul(x, vq, out_dtype=jnp.float32)
        assert vq.idx.dtype == jnp.uint8
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestGroupedQuantizePass:
    def test_specs_match_synthetic_for_grouped_tree(self):
        from repro.configs import get_smoke_config
        from repro.core.quantize import quantize_params
        from repro.models import build_model

        cfg = get_smoke_config("llama2_7b")
        model = build_model(cfg)
        params = model.init(KEY)
        syn = quantize_params(params, cfg, method="synthetic", key=KEY)
        spec = quantize_params(jax.eval_shape(lambda: params), cfg,
                               method="specs")
        # same treedef (incl. splits aux) and leaf shapes/dtypes
        ts = jax.tree_util.tree_structure(syn)
        tp = jax.tree_util.tree_structure(spec)
        assert ts == tp
        for s, y in zip(jax.tree_util.tree_leaves(spec),
                        jax.tree_util.tree_leaves(syn)):
            assert s.shape == y.shape and s.dtype == y.dtype

    def test_group_projections_off_preserves_legacy_layout(self):
        from repro.configs import get_smoke_config
        from repro.core.quantize import quantize_params
        from repro.models import build_model

        cfg = get_smoke_config("llama2_7b")
        model = build_model(cfg)
        params = model.init(KEY)
        q = quantize_params(params, cfg, method="synthetic", key=KEY,
                            group_projections=False)
        assert "wq" in q["layers"]["attn"] and "wqkv" not in q["layers"]["attn"]
        assert q["layers"]["attn"]["wq"]["vq"].splits == ()

    def test_grouped_bias_concatenated(self):
        from repro.configs import get_smoke_config
        from repro.core.quantize import quantize_params
        from repro.models import build_model

        cfg = get_smoke_config("whisper_medium")  # qkv_bias=True family
        model = build_model(cfg)
        params = model.init(KEY)
        q = quantize_params(params, cfg, method="synthetic", key=KEY)
        enc_attn = q["encoder"]["attn"]
        assert "wqkv" in enc_attn
        vq = enc_attn["wqkv"]["vq"]
        # bias is the member concatenation (stacked layer dims preserved)
        assert enc_attn["wqkv"]["b"].shape[-1] == vq.N
        # cross-attention is never grouped (q consumes a different input)
        assert "wq" in q["decoder"]["cross_attn"]


class TestGroupedCheckpoint:
    def test_splits_survive_roundtrip(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager

        vq = synthetic_vq(KEY, 64, 48, d=8, n=8, C=2, splits=(32, 8, 8))
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, {"params": {"wqkv": {"vq": vq}}}, block=True)
        _, state = mgr.restore()
        back = state["params"]["wqkv"]["vq"]
        assert isinstance(back, VQWeight)
        assert back.splits == (32, 8, 8)
        assert back.idx.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(back.idx),
                                      np.asarray(vq.idx))


class TestGroupedNewFamilies:
    """xlstm mLSTM wq/wk/wv and MLA wq/wkv_a grouped families: the grouped
    block forward must match the same block run on per-projection members
    (split_grouped keeps the identical codebooks/indices, so this is an
    exact per-projection oracle through the real model code)."""

    @staticmethod
    def _ungroup(block, gkey, member_names):
        out = {k: v for k, v in block.items() if k != gkey}
        members = split_grouped(block[gkey]["vq"])
        for name, m in zip(member_names, members):
            out[name] = {"vq": m}
        return out

    def test_xlstm_mlstm_grouped_matches_split_members(self):
        from repro.configs import get_smoke_config
        from repro.core.quantize import quantize_params
        from repro.models import xlstm
        from repro.models.common import RunConfig

        cfg = dataclasses.replace(get_smoke_config("xlstm_125m"),
                                  dtype="float32")
        block = xlstm.make_mlstm_block(KEY, cfg)
        pg = quantize_params({"groups": {"b": block}}, cfg,
                             method="synthetic", key=KEY)["groups"]["b"]
        assert pg["wqkv"]["vq"].splits == (128, 128, 128)
        ps = self._ungroup(pg, "wqkv", ("wq", "wk", "wv"))
        x = jax.random.normal(KEY, (2, 3, cfg.d_model), jnp.float32)
        rc = RunConfig(mode="decode", remat=False,
                       plan_policy=PlanPolicy(vq_mode="eva"))
        yg, _ = xlstm.mlstm_block_fwd(pg, x, rc, cfg)
        ys, _ = xlstm.mlstm_block_fwd(ps, x, rc, cfg)
        np.testing.assert_allclose(np.asarray(yg), np.asarray(ys),
                                   rtol=1e-4, atol=1e-4)

    def test_mla_grouped_matches_split_members(self):
        from repro.configs import get_smoke_config
        from repro.core.quantize import quantize_params
        from repro.models.common import RunConfig, make_mla, mla_fwd

        cfg = dataclasses.replace(get_smoke_config("deepseek_v2_lite_16b"),
                                  dtype="float32")
        block = make_mla(KEY, cfg)
        pg = quantize_params({"layers": {"attn": block}}, cfg,
                             method="synthetic", key=KEY)["layers"]["attn"]
        assert pg["wq_kva"]["vq"].splits == (192, 80)
        ps = self._ungroup(pg, "wq_kva", ("wq", "wkv_a"))
        x = jax.random.normal(KEY, (2, 3, cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(3, dtype=jnp.int32)[None], (2, 3))
        rc = RunConfig(mode="prefill", remat=False, attn_chunk=8,
                       plan_policy=PlanPolicy(vq_mode="eva"))
        yg, _ = mla_fwd(pg, x, rc, cfg, positions=pos)
        ys, _ = mla_fwd(ps, x, rc, cfg, positions=pos)
        np.testing.assert_allclose(np.asarray(yg), np.asarray(ys),
                                   rtol=1e-4, atol=1e-4)


class TestGroupedModelDecode:
    def test_grouped_decode_eva_equals_dequant(self):
        """Model-level parity on grouped params: the single-wide-matmul
        decode path (wqkv + gu) and the dequant oracle agree exactly."""
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.models.common import RunConfig

        cfg = dataclasses.replace(get_smoke_config("llama2_7b"),
                                  dtype="float32")
        model = build_model(cfg)
        params = model.init(KEY)
        q = model.quantize(params, method="synthetic", key=KEY)
        assert "wqkv" in q["layers"]["attn"] and "gu" in q["layers"]["mlp"]
        caches = model.init_cache(2, 16)
        tok = jnp.zeros((2, 1), jnp.int32)
        pos = jnp.zeros((2, 1), jnp.int32)
        l_eva, _ = model.decode(
            q, tok, pos, caches,
            RunConfig(mode="decode", remat=False,
                      plan_policy=PlanPolicy(vq_mode="eva")))
        l_deq, _ = model.decode(
            q, tok, pos, caches,
            RunConfig(mode="decode", remat=False,
                      plan_policy=PlanPolicy(vq_mode="dequant")))
        np.testing.assert_allclose(np.asarray(l_eva), np.asarray(l_deq),
                                   rtol=1e-4, atol=1e-4)

    def test_grouped_decode_pallas_uint8(self):
        """Grouped decode through the fused Pallas kernel (interpret) ==
        the jnp path — the full stack streams uint8 indices."""
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.models.common import RunConfig

        cfg = dataclasses.replace(get_smoke_config("llama2_7b"),
                                  dtype="float32")
        model = build_model(cfg)
        params = model.init(KEY)
        q = model.quantize(params, method="synthetic", key=KEY)
        assert q["layers"]["attn"]["wqkv"]["vq"].idx.dtype == jnp.uint8
        caches = model.init_cache(1, 8)
        tok = jnp.zeros((1, 1), jnp.int32)
        pos = jnp.zeros((1, 1), jnp.int32)
        l_jnp, _ = model.decode(
            q, tok, pos, caches,
            RunConfig(mode="decode", remat=False,
                      plan_policy=PlanPolicy(vq_mode="eva")))
        l_pal, _ = model.decode(
            q, tok, pos, caches,
            RunConfig(mode="decode", remat=False,
                      plan_policy=PlanPolicy(vq_mode="eva", impl="pallas",
                                             interpret=True)))
        np.testing.assert_allclose(np.asarray(l_jnp), np.asarray(l_pal),
                                   rtol=1e-4, atol=1e-4)
