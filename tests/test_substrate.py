"""Substrate tests: optimizer, schedules, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.core.vq import synthetic_vq
from repro.data import DataConfig, DataPipeline, global_batch_at
from repro.optim import (
    AdamWConfig, adamw_init, adamw_update, clip_by_global_norm,
    constant, warmup_cosine, warmup_linear,
)

KEY = jax.random.PRNGKey(0)


class TestAdamW:
    def test_converges_on_quadratic(self):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"x": jnp.zeros(3)}
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
        opt = adamw_init(params, cfg)
        for _ in range(200):
            grads = jax.grad(lambda p: jnp.sum((p["x"] - target) ** 2))(params)
            params, opt, _ = adamw_update(grads, opt, params, cfg)
        np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                                   atol=1e-2)

    def test_master_weights_beat_bf16_updates(self):
        """fp32 master accumulates updates far below bf16 resolution."""
        params = {"x": jnp.ones(8, jnp.bfloat16)}
        cfg = AdamWConfig(lr=1e-5, weight_decay=0.0, grad_clip=0.0,
                          use_master=True)
        opt = adamw_init(params, cfg)
        g = {"x": jnp.ones(8, jnp.float32)}
        for _ in range(100):
            params, opt, _ = adamw_update(g, opt, params, cfg)
        # master moved ~1e-3; bf16 param tracks the master, not stuck at 1.0
        assert float(jnp.max(jnp.abs(opt.master["x"] - 1.0))) > 5e-4
        assert np.all(np.isfinite(np.asarray(params["x"], np.float32)))

    def test_grad_clip(self):
        g = {"x": jnp.full(4, 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(jnp.linalg.norm(clipped["x"])) == pytest.approx(1.0, rel=1e-5)

    def test_schedules(self):
        assert float(warmup_cosine(0, warmup_steps=10, total_steps=100)) == 0.0
        assert float(warmup_cosine(10, warmup_steps=10, total_steps=100)) \
            == pytest.approx(1.0)
        assert float(warmup_cosine(100, warmup_steps=10, total_steps=100)) \
            == pytest.approx(0.1)
        assert float(warmup_linear(100, warmup_steps=10, total_steps=100)) \
            == pytest.approx(0.0)
        assert float(constant(7)) == 1.0


class TestDataPipeline:
    CFG = DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=3)

    def test_deterministic_across_restarts(self):
        a = global_batch_at(self.CFG, 5)
        b = global_batch_at(self.CFG, 5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_shift(self):
        b = global_batch_at(self.CFG, 0)
        g = _batch_raw(self.CFG, 0)
        np.testing.assert_array_equal(b["tokens"], g[:, :-1])
        np.testing.assert_array_equal(b["labels"], g[:, 1:])

    def test_shards_partition_global_batch(self):
        g = global_batch_at(self.CFG, 2)
        shards = []
        for r in range(4):
            p = DataPipeline(self.CFG, dp_rank=r, dp_size=4, start_step=2,
                             prefetch=1)
            shards.append(next(p)["tokens"])
            p.close()
        np.testing.assert_array_equal(np.concatenate(shards, 0), g["tokens"])

    @settings(max_examples=5, deadline=None)
    @given(dp=st.sampled_from([1, 2, 4, 8]), step=st.integers(0, 20))
    def test_elastic_resharding_invariance(self, dp, step):
        """Any dp_size partitions the same global stream."""
        g = global_batch_at(self.CFG, step)["tokens"]
        per = self.CFG.global_batch // dp
        for r in range(dp):
            p = DataPipeline(self.CFG, dp_rank=r, dp_size=dp, start_step=step)
            got = next(p)["tokens"]
            p.close()
            np.testing.assert_array_equal(got, g[r * per:(r + 1) * per])

    def test_failure_injection(self):
        p = DataPipeline(self.CFG, fail_at=2)
        next(p), next(p)
        with pytest.raises(RuntimeError, match="injected data failure"):
            next(p)
        p.close()

    def test_task_is_learnable(self):
        """The affine task has real structure: next token is a deterministic
        function of the previous one ~95% of the time."""
        b = global_batch_at(self.CFG, 0)
        toks, labs = b["tokens"], b["labels"]
        pred = (toks * 31 + 17) % self.CFG.vocab_size
        agree = (pred == labs).mean()
        assert agree > 0.85


def _batch_raw(cfg, step):
    from repro.data.pipeline import _batch_for_step
    return _batch_for_step(cfg, step)


class TestCheckpoint:
    def _state(self):
        params = {
            "layers": {"w": jnp.arange(12.0).reshape(3, 4),
                       "vq": synthetic_vq(KEY, 32, 16, d=8, n=4, C=2)},
            "none_field": None,
        }
        opt = adamw_init({"layers": {"w": params["layers"]["w"]}},
                         AdamWConfig(use_master=True))
        return {"params": params, "opt": opt,
                "extra": {"step": jnp.asarray(7)}}

    def test_roundtrip_bit_exact(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = self._state()
        mgr.save(7, state)
        step, restored = mgr.restore()
        assert step == 7
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # structure match (VQWeight + AdamWState rebuilt)
        assert jax.tree_util.tree_structure(state) \
            == jax.tree_util.tree_structure(restored)

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"params": {"x": jnp.ones(2) * s}})
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
        mgr.save(1, {"params": {"x": jnp.ones(4)}})
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_tmp_dirs_are_not_valid_checkpoints(self, tmp_path):
        """A crash mid-write must never surface a half checkpoint."""
        mgr = CheckpointManager(str(tmp_path), keep=3)
        os.makedirs(tmp_path / "step_0000000005.tmp")
        (tmp_path / "step_0000000005.tmp" / "params.npz").write_bytes(b"junk")
        assert mgr.latest_step() is None
        # a directory without MANIFEST is also invalid
        os.makedirs(tmp_path / "step_0000000006")
        assert mgr.latest_step() is None

    def test_restore_specific_step(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        for s in (1, 2, 3):
            mgr.save(s, {"params": {"x": jnp.ones(2) * s}})
        step, st = mgr.restore(2)
        assert step == 2 and float(st["params"]["x"][0]) == 2.0
