"""Model-level VQ quantization pass: converts a dense checkpoint into the
EVA serving representation by replacing every eligible FC weight
(attention projections, MLP/expert matrices) with a VQWeight
(indices + additive codebooks + per-channel scale).

Embeddings, lm_head, norms, routers, gates, convs and recurrence
parameters stay high-precision — the same split as the paper (attention
computation and non-FC parameters remain FP16).

Three methods:
  fit        — k-means additive VQ on real weights (small/smoke models)
  synthetic  — random valid indices/codebooks (benchmarks, huge dry-runs)
  specs      — ShapeDtypeStruct stand-ins (lowering only, no allocation)
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vq import VQWeight, fit_vq, synthetic_vq, vq_specs

if TYPE_CHECKING:  # only for annotations — avoids a core<->models cycle
    from repro.models.common import ModelConfig

# param-tree path segments under which FC weights live
_BLOCK_SEGMENTS = (
    "layers", "pre_layers", "groups", "trail", "encoder", "decoder", "experts",
)
_MIN_DIM = 64  # don't quantize tiny matrices (per-head gates etc.)


def _eligible(path: Tuple[str, ...], w) -> bool:
    if not any(seg in path for seg in _BLOCK_SEGMENTS):
        return False
    if w.ndim < 2:
        return False
    K, N = w.shape[-2], w.shape[-1]
    return K >= _MIN_DIM and N >= _MIN_DIM


def _quantize_leaf(w, cfg: ModelConfig, method: str, key) -> VQWeight:
    """w: (..., K, N) possibly with stacked leading dims."""
    lead = w.shape[:-2]
    K, N = w.shape[-2], w.shape[-1]
    d, n, C = cfg.vq_d, cfg.vq_n, cfg.vq_C
    if K % d != 0:
        raise ValueError(f"K={K} not divisible by vq_d={d}")
    V = K // d
    k = 2 ** n
    idx_dtype = jnp.uint8 if n <= 8 else jnp.int32

    if method == "specs":
        return VQWeight(
            idx=jax.ShapeDtypeStruct((*lead, C, V, N), idx_dtype),
            codebooks=jax.ShapeDtypeStruct((*lead, C, d, k), jnp.float32),
            scale=jax.ShapeDtypeStruct((*lead, N), jnp.float32),
            K=K, N=N, d=d, n=n,
        )
    if method == "synthetic":
        kk = jax.random.fold_in(key, hash(str(w.shape)) % (2 ** 31))
        base = synthetic_vq(kk, K, N, d=d, n=n, C=C)
        def bcast(a):
            return jnp.broadcast_to(a, (*lead, *a.shape)) if lead else a
        # indices must differ per stacked layer — tile with per-layer perm-ish noise
        if lead:
            nlead = int(np.prod(lead))
            keys = jax.random.split(kk, nlead)
            idx = jax.vmap(
                lambda k_: jax.random.randint(k_, (C, V, N), 0, k).astype(idx_dtype)
            )(keys).reshape(*lead, C, V, N)
            cbs = jax.vmap(
                lambda k_: (jax.random.normal(k_, (C, d, k)) / np.sqrt(K * C))
            )(keys).reshape(*lead, C, d, k)
            return VQWeight(idx=idx, codebooks=cbs,
                            scale=jnp.ones((*lead, N), jnp.float32),
                            K=K, N=N, d=d, n=n)
        return base
    if method == "fit":
        flat = w.reshape(-1, K, N)
        keys = jax.random.split(key, flat.shape[0])

        def fit_one(args):
            kk, wi = args
            return fit_vq(kk, wi, d=d, n=n, C=C, kmeans_iters=10, refine_rounds=0)

        vqs = jax.lax.map(fit_one, (keys, flat))
        def reshape_leaf(a):
            return a.reshape(*lead, *a.shape[1:]) if lead else a[0]
        return VQWeight(
            idx=reshape_leaf(vqs.idx),
            codebooks=reshape_leaf(vqs.codebooks),
            scale=reshape_leaf(vqs.scale),
            K=K, N=N, d=d, n=n,
        )
    raise ValueError(f"unknown method {method}")


_BF16_MIN_SIZE = 65536  # large non-VQ serving leaves (emb/lm_head) -> bf16


def _to_serving_dtype(leaf):
    """Cast large fp32 dense leaves to bf16 for serving (embeddings and
    lm_head stay unquantized per the paper but need not stay fp32)."""
    if not hasattr(leaf, "dtype") or leaf.dtype != jnp.float32:
        return leaf
    if int(np.prod(leaf.shape)) < _BF16_MIN_SIZE:
        return leaf
    if isinstance(leaf, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct(leaf.shape, jnp.bfloat16)
    return leaf.astype(jnp.bfloat16)


def quantize_params(params: Any, cfg: ModelConfig, *, method: str = "fit",
                    key: Optional[jax.Array] = None,
                    serving_bf16: bool = True,
                    quantize_lm_head: bool = False) -> Any:
    """Walk the param tree and replace eligible {"w": ...} linears with
    {"vq": VQWeight} (preserving biases). Remaining large dense leaves
    (embeddings, lm_head) are cast to bf16 when `serving_bf16`.
    `quantize_lm_head` additionally VQ-compresses the output projection —
    beyond the paper (which keeps it FP16); worth ~0.3 GB/device of decode
    traffic on qwen2-72b (EXPERIMENTS.md §Perf cell 1)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    extra = ("lm_head",) if quantize_lm_head else ()

    def eligible(path, w):
        if extra and any(seg in path for seg in extra):
            return w.ndim >= 2 and w.shape[-2] >= _MIN_DIM \
                and w.shape[-1] >= _MIN_DIM
        return _eligible(path, w)

    def walk(node, path):
        if isinstance(node, dict):
            if "w" in node and not isinstance(node["w"], VQWeight) \
                    and eligible(path, node["w"]):
                new = {kk: vv for kk, vv in node.items() if kk != "w"}
                new["vq"] = _quantize_leaf(node["w"], cfg, method, key)
                return new
            return {kk: walk(vv, path + (kk,)) for kk, vv in node.items()}
        if serving_bf16 and not isinstance(node, VQWeight):
            return _to_serving_dtype(node)
        return node

    return walk(params, ())


def count_vq_layers(params: Any) -> int:
    n = 0

    def walk(node):
        nonlocal n
        if isinstance(node, dict):
            if "vq" in node:
                n += 1
            for v in node.values():
                walk(v)

    walk(params)
    return n


def compressed_model_bytes(params: Any) -> Tuple[int, int]:
    """Returns (vq_bytes, dense_bytes_bf16_equivalent) over VQ'd leaves."""
    vq_b, dense_b = 0, 0

    def walk(node):
        nonlocal vq_b, dense_b
        if isinstance(node, dict):
            if "vq" in node:
                v: VQWeight = node["vq"]
                lead = int(np.prod(v.idx.shape[:-3])) if v.idx.ndim > 3 else 1
                vq_b += lead * v.compressed_bytes()
                dense_b += lead * v.K * v.N * 2
            for x in node.values():
                if isinstance(x, dict):
                    walk(x)

    walk(params)
    return vq_b, dense_b
