"""Core matmul formulations for EVA.

Four execution paths, all algebraically computing ``y = x @ W_hat``:

  fp_matmul       : dense high-precision matmul (the FP16/BF16 baseline).
  int8_matmul     : int8 x int8 -> int32 GEMM (the paper's prefill path).
  dequant_matmul  : conventional VQ — reconstruct W_hat from (I, B, scale)
                    then GEMV/GEMM (the paper's Fig. 1(b) baseline with all
                    its memory traffic).
  eva_matmul      : the paper's contribution — VQ-GEMM (O = X·B) followed by
                    the conflict-free output-codebook lookup + add-only
                    reduction epilogue (Fig. 1(c)).

Formulation *selection* lives in `core/plan.py`: a frozen LinearSpec +
PlanPolicy resolve through an LRU-cached Planner to a MatmulPlan carrying
the chosen backend and every resolved number. This module keeps

  * the executable formulations themselves (`eva_epilogue_exec` runs one
    resolved jnp epilogue; the Pallas kernels live under `kernels/`),
  * the epilogue cost models (`select_epilogue` + the auto block sizers)
    that the jnp EVA backend registrations consult, and
  * `eva_matmul` / `vq_matmul` as thin convenience wrappers over
    `Planner.plan(...).execute(...)`. The PR-3 deprecation cycle is
    over: the legacy `flat_gather=` spelling is gone and passing None
    for `block_v` raises (use epilogue="direct" / block_v="auto").

The four jnp epilogue formulations (direct / flat / v-blocked gather /
v-blocked reconstruct-GEMM) are algebraically identical and chosen per
shape from explicit gather-work and cache-footprint cost models, so
"auto" callers stay >= 1x vs the dequant baseline across the whole M
sweep (the PR-1 batched-decode regression).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vq import VQWeight

# Default V-tile for the blocked epilogue. Mirrors the paper's v=32 tile
# height (Tbl. II); on TPU this bounds the gathered intermediate to
# (C, M, 32, N_tile) in VMEM.
DEFAULT_BLOCK_V = 32


# ---------------------------------------------------------------------------
# Epilogue selection
#
# The jnp EVA epilogue has four formulations, all algebraically computing
#   y[m, j] = s[j] * sum_c sum_v O[c, m, v, I[c, v, j]]:
#
#   direct  : 4-D take_along_axis over the full O; XLA fuses gather into
#             the reduction. Gather work is C*M*V*N elements — the win
#             of the M=1 decode regime, where it is far below the
#             reconstruction cost of any weight-materializing path.
#   flat    : single-axis gather with precomputed flat indices; GSPMD
#             partitions 1-D gathers with a replicated operand locally
#             (the SPMD-friendly variant), same work as direct.
#   blocked : lax.scan over V-tiles of height block_v; the live gathered
#             intermediate shrinks from (C, M, V, N) to (C, M, block_v, N)
#             per step — the memory-constrained gather variant (mirrors
#             the paper's v=32 tiling).
#   recon   : v-blocked reconstruct-and-GEMM. Rebuilds W_hat in
#             (block_v*d, N) slabs from the centroid tables (C*V*N*d
#             gathered elements, independent of M) and accumulates
#             x_slab @ w_slab on the MXU/BLAS. Algebraically the dequant
#             formulation, but slab-tiled so the reconstructed weights
#             stay cache-resident instead of materializing (K, N) —
#             measured ~3.5-4x faster than dequant_matmul at M in
#             {8, 32} where it replaces the gather epilogues entirely.
#
# select_epilogue() picks among them from two explicit cost models —
# gather work (C*M*V*N vs the C*V*N*d reconstruction gathers) and the
# cache footprint of the gathered intermediate — called ONLY from the
# jnp EVA backend registrations in core/plan.py, so callers (linear ->
# RunConfig plan_policy epilogue="auto") never hand-tune block_v per
# shape. Measured regime table (K=N=4096, C=2, this CI host, min-of-7):
#
#     M   direct    flat  blocked(best)  recon(best)  dequant
#      1    9 ms   10 ms      43 ms        ~65 ms      259 ms
#      8  193 ms  201 ms     113 ms         63 ms      247 ms
#     32  790 ms  852 ms     417 ms         72 ms      260 ms
#
# i.e. direct wins while gather work < reconstruction work (M < d) and
# recon wins beyond it; the v-blocked gather only leads the gather
# family when the direct intermediate spills the cache budget at M < d
# (large-N mlp shapes). This is what fixed the `measured/batch32`
# regression (EVA < 1x vs dequant with the old always-direct default).
# ---------------------------------------------------------------------------

EPILOGUES = ("direct", "flat", "blocked", "recon")

# Working-set threshold for the un-blocked gather epilogues: the direct
# gather's intermediate is (C, M, V, N) fp32 on top of the O operand
# (C, M, V, 2^n); once that footprint is several multiples of the LLC the
# gather turns DRAM-thrash-bound and the v-blocked scan wins (measured:
# direct still led at a 71 MB footprint (M=4, K=N=4096) but lost ~2x at
# 184 MB (M=4, N=11008); the threshold sits between).
EPILOGUE_CACHE_BYTES = 96 * 1024 * 1024

# Cache target for the live slab of ONE v-block of the blocked-gather
# scan ((C, M, bv, N + 2^n) fp32) — distinct from the spill threshold
# above: a block must be comfortably cache-resident, not merely below
# the thrash point (measured best bv=64 at M=4, N=11008 -> ~24 MB).
EPILOGUE_SLAB_BYTES = 24 * 1024 * 1024

# Cache target for one reconstructed weight slab (block_v*d, N) fp32 of
# the recon epilogue (block_v=128 at N=4096 -> 16 MB, the measured
# sweet spot across M in {8, 32, 64}).
RECON_SLAB_BYTES = 16 * 1024 * 1024

# Floor for auto-sized v-blocks: below this the scan's per-step overhead
# dominates.
_MIN_BLOCK_V = 8

# Shared VMEM budgets for the Pallas kernels' tile models (the fused
# wrapper's OC scratch holds C*m_tile*V_pad*2^n fp32 and must fit
# comfortably under the ~16 MB/core VMEM; the gathered/reconstructed tile
# is each kernel's live slab). The per-kernel tile *functions* live with
# their wrappers in kernels/*/ops.py — only the budgets are shared.
FUSED_OC_SCRATCH_BYTES = 8 * 1024 * 1024
FUSED_GATHER_TILE_BYTES = 2 * 1024 * 1024


def epilogue_gather_bytes(M: int, V: int, N: int, C: int, k: int = 256) -> int:
    """Cache footprint of one un-blocked epilogue pass: the gathered
    intermediate (C, M, V, N) fp32 plus the O operand (C, M, V, k) fp32."""
    return 4 * C * M * V * (N + k)


def _pow2_floor(x: int) -> int:
    return 1 << (int(x).bit_length() - 1)


def auto_block_v(M: int, V: int, N: int, C: int, k: int = 256,
                 *, slab_bytes: Optional[int] = None) -> int:
    """Largest v-block whose live gathered slab (C, M, bv, N+k) fp32 fits
    the slab budget, clamped to [_MIN_BLOCK_V, V] and rounded down to a
    power of two (tiling-friendly; the scan pads the remainder)."""
    budget = slab_bytes or EPILOGUE_SLAB_BYTES
    per_v = 4 * C * M * (N + k)
    bv = max(_MIN_BLOCK_V, budget // max(per_v, 1))
    bv = min(bv, V)
    return max(_MIN_BLOCK_V, _pow2_floor(bv))


def auto_recon_block_v(V: int, N: int, d: int) -> int:
    """v-block for the recon epilogue: size the reconstructed (bv*d, N)
    fp32 slab to RECON_SLAB_BYTES, clamped to [32, V], power of two."""
    bv = max(32, RECON_SLAB_BYTES // max(4 * d * N, 1))
    bv = min(bv, V)
    return max(1, _pow2_floor(bv))


def select_epilogue(
    M: int, V: int, N: int, C: int = 2, k: int = 256, d: int = 8,
    *,
    cache_bytes: Optional[int] = None,
    distributed: bool = False,
) -> Tuple[str, Optional[int]]:
    """Pick the jnp epilogue for an (M, K=V*d) x (K, N) EVA matmul.

    Returns (epilogue, block_v or None), epilogue in EPILOGUES.

      * distributed=True -> ("flat", None): under pjit the 1-D gather
        keeps indices V/N-sharded where the 4-D take_along_axis (and the
        V-block scans) force index all-gathers.
      * M < d (gather work C*M*V*N below the C*V*N*d reconstruction
        gathers) -> gather regime, the paper's memory-bound decode:
        ("direct", None) while the gathered intermediate fits
        EPILOGUE_CACHE_BYTES, else ("blocked", bv) with the live slab
        (C, M, bv, N + 2^n) sized to the budget.
      * M >= d -> ("recon", bv): batched decode is reconstruction-
        bound; the slab-tiled reconstruct-and-GEMM does the minimal
        C*V*N*d gathers once and rides BLAS for the M axis. This is the
        regime where the old always-direct default regressed below the
        dequant baseline (measured/batch32).
    """
    if distributed:
        return "flat", None
    if M >= d:
        return "recon", auto_recon_block_v(V, N, d)
    budget = cache_bytes or EPILOGUE_CACHE_BYTES
    if epilogue_gather_bytes(M, V, N, C, k) <= budget:
        return "direct", None
    bv = auto_block_v(M, V, N, C, k)
    if bv >= V:  # one block == direct, skip the scan machinery
        return "direct", None
    return "blocked", bv


def _in_mesh_context() -> bool:
    """True when tracing under an active mesh context (pjit / shard_map):
    the auto selection then prefers the SPMD-friendly flat epilogue — the
    V-block scans reshape the sharded V axis and the 4-D take_along_axis
    reshards its 3-tuple gather indices, both forcing collectives.

    Uses the same private thread_resources accessor as models/common.py's
    _mesh_divides/_maybe_constrain (no public ambient-mesh API on this
    jax); if a jax upgrade moves it, all three degrade together to the
    single-host behavior and distributed callers should set
    PlanPolicy(epilogue="flat") explicitly."""
    try:
        from jax._src import mesh as mesh_lib

        return not mesh_lib.thread_resources.env.physical_mesh.empty
    except Exception:
        return False


def _eva_policy_args(epilogue, block_v, impl: str
                     ) -> Tuple[str, Optional[int]]:
    """Normalize the eva_matmul keyword surface to the plan API's
    (epilogue, block_v) pair.

    ``block_v="auto"`` means auto-sized (PlanPolicy None); a bare int
    with the default epilogue selects the v-blocked gather scan on jnp
    (and pins the kernel v-tiles on Pallas). Passing None for block_v
    was the pre-plan spelling of the direct epilogue and is REMOVED —
    it raises here so stale callers fail loudly instead of silently
    changing formulation."""
    if block_v is None:
        raise ValueError(
            "passing None for block_v was removed (it was the legacy "
            "spelling of the direct epilogue); pass epilogue='direct', "
            "block_v='auto' or an int")
    # "auto" -> None (auto-sized); ints pass through; anything else is left
    # for PlanPolicy's loud block_v validation
    bv = None if block_v == "auto" else block_v
    if epilogue is None:
        if isinstance(bv, int) and not isinstance(bv, bool) and impl == "jnp":
            # a bare int block_v selects the v-blocked gather scan
            return "blocked", bv
        return "auto", bv
    return epilogue, bv


def fp_matmul(x: jax.Array, w: jax.Array, *, out_dtype=None) -> jax.Array:
    """Dense baseline: y = x @ w with fp32 accumulation."""
    out_dtype = out_dtype or x.dtype
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return y.astype(out_dtype)


def quantize_int8(x: jax.Array, axis: int = -1):
    """Symmetric per-slice int8 quantization: returns (q, scale)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_matmul(x: jax.Array, w: jax.Array, *, out_dtype=None) -> jax.Array:
    """Prefill path: dynamic per-token int8 activations x per-channel int8
    weights -> int32 accumulate -> fp dequant. Mirrors the paper's INT8
    systolic-array prefill mode (the TPU MXU is natively int8-capable)."""
    out_dtype = out_dtype or x.dtype
    xq, xs = quantize_int8(x, axis=-1)             # (..., K), (..., 1)
    wq, ws = quantize_int8(w, axis=0)              # (K, N), (1, N)
    acc = jax.lax.dot_general(
        xq, wq, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return (acc.astype(jnp.float32) * xs * ws).astype(out_dtype)


def dequant_matmul(x: jax.Array, vq: VQWeight, *, out_dtype=None) -> jax.Array:
    """Conventional VQ baseline: on-the-fly reconstruct W_hat, then matmul.

    Expressed so the weight reconstruction materializes (K, N) — exactly the
    memory-traffic pattern EVA eliminates; used as the numerical oracle."""
    from repro.core.vq import dequantize

    out_dtype = out_dtype or x.dtype
    w_hat = dequantize(vq).astype(jnp.float32)
    return fp_matmul(x.astype(jnp.float32), w_hat, out_dtype=out_dtype)


def compute_output_codebook(x: jax.Array, vq: VQWeight) -> jax.Array:
    """Step 1 (VQ-GEMM): O = X·B.

    x: (..., K) -> O: (C, M, V, 2^n) fp32 where M = prod(leading dims).
    This is the GEMM the paper maps onto the 32x8 systolic array; cost is
    M*K*2^n MACs, independent of N.
    """
    K = vq.K
    M = x.size // K
    X = x.reshape(M, vq.V, vq.d).astype(jnp.float32)
    # (M, V, d) x (C, d, k) -> (C, M, V, k)
    return jnp.einsum("mvd,cdk->cmvk", X, vq.codebooks.astype(jnp.float32))


def _recon_epilogue(x: jax.Array, vq: VQWeight, bv: int) -> jax.Array:
    """v-blocked reconstruct-and-GEMM: lax.scan over V tiles, rebuilding
    one (bv*d, N) fp32 slab of W_hat per step (C centroid gathers summed)
    and accumulating x_slab @ w_slab. The slab stays cache-resident —
    unlike dequant_matmul, which materializes the full (K, N) — and the
    C*V*N*d gather work is independent of M, so BLAS carries the batch
    axis. Returns (M, N) fp32 including the per-channel scale."""
    C, V, N, d = vq.C, vq.V, vq.N, vq.d
    M = x.size // vq.K
    X = x.reshape(M, V, d).astype(jnp.float32)
    I = vq.idx.astype(jnp.int32)                              # (C, V, N)
    cb = vq.codebooks.transpose(0, 2, 1).astype(jnp.float32)  # (C, k, d)
    bv = min(bv, V)
    rem = (-V) % bv
    if rem:  # zero-padded X rows null the padded slabs' contribution
        X = jnp.pad(X, ((0, 0), (0, rem), (0, 0)))
        I = jnp.pad(I, ((0, 0), (0, rem), (0, 0)))
    nblk = X.shape[1] // bv
    X_blk = X.reshape(M, nblk, bv, d).transpose(1, 0, 2, 3)   # (nb, M, bv, d)
    I_blk = I.reshape(C, nblk, bv, N).transpose(1, 0, 2, 3)   # (nb, C, bv, N)

    def body(acc, blk):
        x_b, i_b = blk                                        # (M,bv,d), (C,bv,N)
        w = jnp.take(cb[0], i_b[0], axis=0)                   # (bv, N, d)
        for c in range(1, C):  # C is tiny and static — unrolled
            w = w + jnp.take(cb[c], i_b[c], axis=0)
        w = w.transpose(0, 2, 1).reshape(bv * d, N)
        acc = acc + jax.lax.dot_general(
            x_b.reshape(M, bv * d), w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc, None

    acc, _ = jax.lax.scan(body, jnp.zeros((M, N), jnp.float32), (X_blk, I_blk))
    return acc * vq.scale[None, :].astype(jnp.float32)


def eva_epilogue_exec(
    x: jax.Array,
    vq: VQWeight,
    *,
    kind: str,
    block_v: Optional[int] = None,
    out_dtype=None,
) -> jax.Array:
    """Execute ONE resolved jnp EVA formulation — no selection here.

      O = X·B                         (VQ-GEMM, MXU)
      y[m,j] = s[j] * sum_c sum_v O[c,m,v, I[c,v,j]]   (epilogue, add-only)

    ``kind`` is one of EPILOGUES and ``block_v`` the resolved v-block for
    the v-blocked kinds; both come frozen out of a MatmulPlan (the jnp
    EVA backend registrations in core/plan.py resolve them once per
    (spec, policy) via select_epilogue / the auto block sizers)."""
    K = vq.K
    M = x.size // K
    V, N, C = vq.V, vq.N, vq.C
    k = vq.codebooks.shape[-1] if hasattr(vq.codebooks, "shape") else 2 ** vq.n
    out_dtype = out_dtype or x.dtype
    lead_shape = x.shape[:-1]

    if kind == "recon":
        y = _recon_epilogue(x, vq, block_v)
        return y.reshape(*lead_shape, N).astype(out_dtype)

    O = compute_output_codebook(x, vq)  # (C, M, V, k)
    I = vq.idx.astype(jnp.int32)        # (C, V, N)

    if kind == "flat":
        v_iota = jnp.arange(V, dtype=jnp.int32)[None, :, None]
        c_iota = jnp.arange(C, dtype=jnp.int32)[:, None, None]
        flat = ((c_iota * V + v_iota) * k + I).reshape(-1)   # (C*V*N,)
        O2 = O.transpose(1, 0, 2, 3).reshape(M, C * V * k)
        g = jnp.take(O2, flat, axis=1)                       # (M, C*V*N)
        acc = g.reshape(M, C, V, N).sum(axis=(1, 2))
    elif kind == "direct":
        g = jnp.take_along_axis(O, I[:, None].astype(jnp.int32), axis=3)
        acc = g.sum(axis=(0, 2))                             # (M, N)
    elif kind == "blocked":
        bv = block_v
        # pad V to a multiple of bv (index 0 with zeroed O rows)
        rem = (-V) % bv
        if rem:
            O = jnp.pad(O, ((0, 0), (0, 0), (0, rem), (0, 0)))
            I = jnp.pad(I, ((0, 0), (0, rem), (0, 0)))
        nblk = O.shape[2] // bv
        O_blk = O.reshape(C, M, nblk, bv, O.shape[-1]).transpose(2, 0, 1, 3, 4)
        I_blk = I.reshape(C, nblk, bv, N).transpose(1, 0, 2, 3)

        def body(acc, blk):
            o_b, i_b = blk  # (C,M,bv,k), (C,bv,N)
            g = jnp.take_along_axis(o_b, i_b[:, None].astype(jnp.int32), axis=3)
            return acc + g.sum(axis=(0, 2)), None

        acc0 = jnp.zeros((M, N), jnp.float32)
        acc, _ = jax.lax.scan(body, acc0, (O_blk, I_blk))
    else:
        raise ValueError(f"unknown epilogue kind {kind!r}")
    y = acc * vq.scale[None, :].astype(jnp.float32)
    return y.reshape(*lead_shape, N).astype(out_dtype)


def eva_matmul(
    x: jax.Array,
    vq: VQWeight,
    *,
    epilogue: Optional[str] = None,
    block_v="auto",
    out_dtype=None,
    impl: str = "jnp",
    interpret: bool = False,
) -> jax.Array:
    """EVA decode matmul: y = x @ W_hat via output-codebook lookup.

    Thin convenience wrapper over ``Planner.plan(...).execute(...)`` —
    derives a LinearSpec from (x, vq), builds a PlanPolicy from the
    keyword surface and executes the cached plan. See core/plan.py for
    the ranked dispatch layer and `select_epilogue` for the cost models /
    the measured regime table of the jnp epilogues:

      epilogue="auto" / block_v="auto" (the default): choose per shape —
        direct gather in the M < d decode regime, v-blocked gather once
        the gathered intermediate spills the cache budget, the v-blocked
        reconstruct-and-GEMM at M >= d, flat inside a mesh context.
      epilogue="direct" | "flat" | "blocked" | "recon": force a
        formulation; an int ``block_v`` pins the v-block of the
        v-blocked kinds.
      impl="pallas": the Planner ranks the fused tiled kernel against
        the two-kernel vq_gemm+oc_lookup split backend by calibrated
        predicted time (an int ``block_v`` pins the chosen kernel's
        v-tiles; jnp epilogue requests are invalid there).
    """
    from repro.core import plan as plan_mod

    epi, bv = _eva_policy_args(epilogue, block_v, impl)
    policy = plan_mod.PlanPolicy(vq_mode="eva", impl=impl, epilogue=epi,
                                 block_v=bv, interpret=interpret)
    return plan_mod.plan_vq(x, vq, policy, out_dtype=out_dtype).execute(x, vq)


def split_grouped_outputs(y: jax.Array, vq: VQWeight) -> Tuple[jax.Array, ...]:
    """Slice the output of a grouped-family matmul (y = x @ [W1|..|Wg])
    back into per-projection outputs at the recorded split points.

    The wide matmul amortizes one VQ-GEMM / output-codebook computation
    over every member; this split is free (pure slicing)."""
    if not vq.splits:
        return (y,)
    offs = list(np.cumsum(vq.splits[:-1]))
    return tuple(jnp.split(y, offs, axis=-1))


def vq_matmul(
    x: jax.Array,
    vq: VQWeight,
    *,
    mode: str = "eva",
    epilogue: Optional[str] = None,
    block_v="auto",
    out_dtype=None,
    impl: str = "jnp",
    interpret: bool = False,
) -> jax.Array:
    """Unified VQ matmul entry point — a thin wrapper over
    ``Planner.plan(...).execute(...)`` (model layers dispatch through
    core/plan.py directly; this surface remains for scripts/tests).

    mode="eva" takes the epilogue surface of `eva_matmul`; for
    mode="dequant" the jnp baseline has no epilogue (an int ``block_v``
    pins the Pallas dequant kernel's v-tiles — impl="pallas" now actually
    reaches the `dequant_gemv` kernel instead of being silently ignored).
    """
    from repro.core import plan as plan_mod

    if mode == "eva":
        epi, bv = _eva_policy_args(epilogue, block_v, impl)
    elif mode == "dequant":
        epi = "auto"
        bv = block_v if isinstance(block_v, int) \
            and not isinstance(block_v, bool) else None
    else:
        raise ValueError(f"unknown vq matmul mode {mode!r}")
    policy = plan_mod.PlanPolicy(vq_mode=mode, impl=impl, epilogue=epi,
                                 block_v=bv, interpret=interpret)
    return plan_mod.plan_vq(x, vq, policy, out_dtype=out_dtype).execute(x, vq)


# ---------------------------------------------------------------------------
# Analytic op counts (used by tests + the accelerator model)
# ---------------------------------------------------------------------------


def gemv_macs(M: int, K: int, N: int) -> int:
    return M * K * N


def vq_gemm_macs(M: int, K: int, n: int, C: int, d: int) -> int:
    """MACs of the VQ-GEMM stage: (M*K/d) rows x 2^n cols x d depth, per
    codebook."""
    return C * M * (K // d) * (2 ** n) * d


def epilogue_adds(M: int, K: int, N: int, C: int, d: int) -> int:
    """Add-only epilogue work: one add per (m, v, j, c)."""
    return C * M * (K // d) * N


def compute_collapse_ratio(N: int, n: int) -> float:
    """Paper §III-B advantage 3: GEMV MACs / VQ-GEMM MACs = N / 2^n."""
    return N / float(2 ** n)


def grouped_compute_collapse_ratio(splits: Tuple[int, ...], n: int) -> float:
    """Effective collapse ratio of a grouped projection family: the single
    shared VQ-GEMM serves sum(N_i) output channels -> sum(N_i) / 2^n
    (vs N_i / 2^n for each member executed separately)."""
    return compute_collapse_ratio(sum(splits), n)
