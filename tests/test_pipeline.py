"""Pipeline-parallel tests: pipelined forward == sequential scan, and the
pipeline is differentiable (training-grade). Runs in a subprocess with 8
host devices (pipeline axis size 4)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.runtime.pipeline import make_pipelined_forward, split_stages

    out = {}
    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    L, D, B = 8, 16, 8

    def layer_fn(lp, x):
        return jnp.tanh(x @ lp["w"] + lp["b"])

    key = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(key, (L, D, D)) / jnp.sqrt(D),
        "b": jnp.zeros((L, D)),
    }
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, D))

    # sequential reference
    def seq(params, x):
        def body(c, lp):
            return layer_fn(lp, c), None
        return jax.lax.scan(body, x, params)[0]

    y_ref = seq(params, x)

    stage_params = split_stages(params, 4)
    fwd = make_pipelined_forward(layer_fn, mesh, axis="pod", n_micro=4)
    y_pipe = jax.jit(fwd)(stage_params, x)
    out["fwd_err"] = float(jnp.max(jnp.abs(y_pipe - y_ref)))

    # differentiability: grads of a scalar loss match the sequential model
    def loss_pipe(sp, x):
        return jnp.sum(fwd(sp, x) ** 2)

    def loss_seq(p, x):
        return jnp.sum(seq(p, x) ** 2)

    g_pipe = jax.jit(jax.grad(loss_pipe))(stage_params, x)
    g_seq = jax.grad(loss_seq)(params, x)
    g_seq_st = split_stages(g_seq, 4)
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree_util.tree_leaves(g_pipe),
                              jax.tree_util.tree_leaves(g_seq_st))]
    out["grad_err"] = max(diffs)
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    env = dict(os.environ, PYTHONPATH=SRC, TF_CPP_MIN_LOG_LEVEL="2")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    out = json.loads(line[len("RESULT"):])
    assert out["fwd_err"] < 1e-5, out
    assert out["grad_err"] < 1e-4, out
