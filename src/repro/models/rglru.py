"""RecurrentGemma family (arXiv:2402.19427): Griffin-style hybrid of
RG-LRU recurrent blocks and local (sliding-window) attention, pattern
(rec, rec, attn) — 1 attention per 2 recurrent layers.

RG-LRU recurrence (diagonal, parallelized with associative_scan):
    r_t = sigmoid(W_a y_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x y_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * y_t)

Temporal-mixing block: gate branch (linear+gelu) * (linear -> causal
conv1d(width 4) -> RG-LRU) -> out projection. Every layer is followed by a
gated-GeLU MLP. 26 layers = 8 x (rec, rec, attn) + 2 trailing rec.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig, RunConfig

RGLRU_C = 8.0


def make_rec_layer(key, cfg: ModelConfig) -> Any:
    d, dr = cfg.d_model, cfg.d_rnn
    ks = jax.random.split(key, 8)
    # Lambda init so that a ~ U[0.9, 0.999] at r=1 (paper's init range)
    u = jax.random.uniform(ks[0], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RGLRU_C))  # softplus^-1(-log(u)/c)
    return {
        "norm": cm.make_rmsnorm(d),
        "gate_proj": cm.make_linear(ks[1], d, dr),
        "x_proj": cm.make_linear(ks[2], d, dr),
        "cw": jax.random.normal(ks[3], (cfg.conv_width, dr), jnp.float32) * 0.1,
        "cb": jnp.zeros((dr,), jnp.float32),
        "wa": cm.make_linear(ks[4], dr, dr, bias=True),
        "wx": cm.make_linear(ks[5], dr, dr, bias=True),
        "lam": lam,
        "out": cm.make_linear(ks[6], dr, d),
        "mlp_norm": cm.make_rmsnorm(d),
        "mlp": cm.make_mlp(ks[7], d, cfg.d_ff),
    }


def make_attn_layer(key, cfg: ModelConfig) -> Any:
    ks = jax.random.split(key, 2)
    return {
        "norm": cm.make_rmsnorm(cfg.d_model),
        "attn": cm.make_attention(ks[0], cfg),
        "mlp_norm": cm.make_rmsnorm(cfg.d_model),
        "mlp": cm.make_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def causal_conv1d(y: jax.Array, cw: jax.Array, cb: jax.Array,
                  buf: Optional[jax.Array] = None):
    """Depthwise causal conv. y: (B, S, dr); cw: (W, dr). Returns (out,
    new_buf) where buf carries the last W-1 inputs for decoding."""
    B, S, dr = y.shape
    W = cw.shape[0]
    if buf is None:
        ypad = jnp.pad(y, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        ypad = jnp.concatenate([buf.astype(y.dtype), y], axis=1)
    out = jnp.zeros_like(y, dtype=jnp.float32)
    for w in range(W):
        out = out + ypad[:, w:w + S].astype(jnp.float32) * cw[w][None, None, :]
    new_buf = ypad[:, -(W - 1):] if W > 1 else None
    return (out + cb[None, None, :]).astype(y.dtype), new_buf


def rg_lru(y: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array,
           h0: jax.Array):
    """y/r/i: (B, S, dr); h0: (B, dr). Parallel linear recurrence via
    associative_scan. Returns (h_seq (B,S,dr) fp32, h_last)."""
    log_a = -RGLRU_C * jax.nn.softplus(lam)[None, None, :] * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i.astype(jnp.float32) * y.astype(jnp.float32)
    )
    # prepend h0 as the first element with a=0 so scan absorbs it
    a_all = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
    b_all = jnp.concatenate([h0[:, None, :], gated], axis=1)

    def combine(x, y_):
        a1, b1 = x
        a2, b2 = y_
        return a1 * a2, b1 * a2 + b2

    aa, bb = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    hs = bb[:, 1:]
    return hs, hs[:, -1]


def rec_layer_fwd(lp, x, rc: RunConfig, cfg: ModelConfig, cache=None):
    B, S, D = x.shape
    xn = cm.rmsnorm(lp["norm"], x, cfg.norm_eps)
    gate = jax.nn.gelu(cm.linear(lp["gate_proj"], xn, rc))
    y = cm.linear(lp["x_proj"], xn, rc)
    buf = None if cache is None else cache["conv"]
    y, new_buf = causal_conv1d(y, lp["cw"], lp["cb"], buf)
    r = jax.nn.sigmoid(cm.linear(lp["wa"], y, rc, out_dtype=jnp.float32))
    i = jax.nn.sigmoid(cm.linear(lp["wx"], y, rc, out_dtype=jnp.float32))
    h0 = cache["h"] if cache is not None else jnp.zeros((B, cfg.d_rnn), jnp.float32)
    hs, h_last = rg_lru(y, r, i, lp["lam"], h0)
    out = cm.linear(lp["out"], (hs.astype(x.dtype) * gate), rc)
    x = x + out
    h2 = cm.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
    x = x + cm.mlp_fwd(lp["mlp"], h2, rc)
    new_cache = None
    if rc.mode in ("decode", "prefill"):
        new_cache = {"h": h_last, "conv": new_buf.astype(x.dtype)}
    return x, new_cache


def attn_layer_fwd(lp, x, rc: RunConfig, cfg: ModelConfig, *, positions, cache=None):
    h = cm.rmsnorm(lp["norm"], x, cfg.norm_eps)
    a, new_cache = cm.attention_fwd(
        lp["attn"], h, rc, cfg,
        positions=positions, cache=cache, window=cfg.local_window,
    )
    x = x + a
    h = cm.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
    return x + cm.mlp_fwd(lp["mlp"], h, rc), new_cache


# ---------------------------------------------------------------------------
# model: scan over (rec, rec, attn) super-blocks + trailing rec layers
# ---------------------------------------------------------------------------


def _split(cfg: ModelConfig) -> Tuple[int, int]:
    period = len(cfg.rec_pattern)          # 3
    n_groups = cfg.num_layers // period    # 8 for 26 layers
    n_trail = cfg.num_layers - n_groups * period  # 2
    return n_groups, n_trail


def init_params(key, cfg: ModelConfig) -> Any:
    n_groups, n_trail = _split(cfg)
    ks = jax.random.split(key, 5)

    def group_init(k):
        gks = jax.random.split(k, len(cfg.rec_pattern))
        g = {}
        for i, kind in enumerate(cfg.rec_pattern):
            g[f"b{i}_{kind}"] = (
                make_rec_layer(gks[i], cfg) if kind == "rec"
                else make_attn_layer(gks[i], cfg)
            )
        return g

    params = {
        "embedding": cm.make_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
        "groups": jax.vmap(group_init)(jax.random.split(ks[1], n_groups)),
        "final_norm": cm.make_rmsnorm(cfg.d_model),
        "lm_head": cm.make_linear(ks[2], cfg.d_model, cfg.padded_vocab),
    }
    if n_trail:
        params["trail"] = jax.vmap(lambda k: make_rec_layer(k, cfg))(
            jax.random.split(ks[3], n_trail)
        )
    return params


def _group_fwd(gp, x, rc, cfg, positions, cache):
    new_cache = {}
    for i, kind in enumerate(cfg.rec_pattern):
        name = f"b{i}_{kind}"
        c = None if cache is None else cache[name]
        if kind == "rec":
            x, nc = rec_layer_fwd(gp[name], x, rc, cfg, c)
        else:
            x, nc = attn_layer_fwd(gp[name], x, rc, cfg, positions=positions, cache=c)
        new_cache[name] = nc
    return x, (new_cache if rc.mode in ("decode", "prefill") else None)


def forward(params, tokens, rc: RunConfig, cfg: ModelConfig, *,
            positions=None, caches=None):
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = cm.embed(params["embedding"], tokens, cfg.act_dtype)

    body = functools.partial(_group_fwd, rc=rc, cfg=cfg, positions=positions)

    def step(carry, xs):
        gp, cache = xs
        if rc.remat and rc.mode == "train":
            fn = jax.checkpoint(
                lambda g_, x_: body(g_, x_, cache=None),
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
            y, nc = fn(gp, carry)
        else:
            y, nc = body(gp, carry, cache=cache)
        return y, nc

    g_caches = None if caches is None else caches["groups"]
    if g_caches is None:
        x, new_g = jax.lax.scan(lambda c, gp: step(c, (gp, None)), x, params["groups"])
    else:
        x, new_g = jax.lax.scan(step, x, (params["groups"], g_caches))

    new_caches = {"groups": new_g}
    if "trail" in params:
        t_caches = None if caches is None else caches["trail"]

        def tstep(carry, xs):
            lp, cache = xs
            return rec_layer_fwd(lp, carry, rc, cfg, cache)

        if t_caches is None:
            x, new_t = jax.lax.scan(lambda c, lp: tstep(c, (lp, None)), x, params["trail"])
        else:
            x, new_t = jax.lax.scan(tstep, x, (params["trail"], t_caches))
        new_caches["trail"] = new_t

    if rc.mode == "prefill" and rc.lm_head_last_only:
        x = x[:, -1:]  # §Perf: skip the vocab projection for prompt tokens
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.lm_head(params["lm_head"], x, rc)
    out = new_caches if caches is not None or rc.mode == "prefill" else None
    return logits, out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Any:
    dtype = dtype or cfg.act_dtype
    n_groups, n_trail = _split(cfg)
    W = min(max_len, cfg.local_window)

    def rec_state(_):
        return {
            "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), dtype),
        }

    def group_state(_):
        g = {}
        for i, kind in enumerate(cfg.rec_pattern):
            if kind == "rec":
                g[f"b{i}_{kind}"] = rec_state(None)
            else:
                g[f"b{i}_{kind}"] = {
                    "k": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
                    "v": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
                    "len": jnp.zeros((batch,), jnp.int32),
                }
        return g

    caches = {"groups": jax.vmap(group_state)(jnp.arange(n_groups))}
    if n_trail:
        caches["trail"] = jax.vmap(rec_state)(jnp.arange(n_trail))
    return caches
