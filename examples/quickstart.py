"""Quickstart: the EVA pipeline end-to-end on one CPU in ~a minute.

1. build a small llama-family model,
2. train it briefly on the synthetic LM task,
3. VQ-quantize the weights (AQLM-style additive codebooks, d=8 n=8 C=2),
4. decode with the EVA path (output-codebook GEMM + conflict-free lookup)
   and verify it matches the conventional dequantize-then-matmul path.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.plan import PlanPolicy
from repro.core.quantize import compressed_model_bytes, count_vq_layers
from repro.data import DataConfig, global_batch_at
from repro.models import build_model
from repro.models.common import RunConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.serve.kvcache import pad_prefill_cache


def main():
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)

    # ---- 1-2: init + short training run --------------------------------
    params = model.init(key)
    ocfg = AdamWConfig(lr=3e-3)
    opt = adamw_init(params, ocfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    rc = RunConfig(mode="train", remat=False, attn_chunk=16)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, rc))(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in global_batch_at(dcfg, i).items()}
        params, opt, loss = step(params, opt, batch)
        if i % 5 == 0:
            print(f"step {i:3d}  loss {float(loss):.3f}")

    # ---- 3: VQ-quantize (the paper's offline compression) --------------
    qparams = model.quantize(params, method="fit", key=key)
    vq_b, dense_b = compressed_model_bytes(qparams)
    n_weights = dense_b / 2  # dense bytes are bf16
    print(f"\nquantized {count_vq_layers(qparams)} FC layers: "
          f"{dense_b/1e6:.1f} MB bf16 -> {vq_b/1e6:.1f} MB "
          f"({8*vq_b/n_weights:.2f} bits/weight incl. codebook overhead; "
          f"2.0 asymptotic)")

    # ---- 4: EVA decode vs conventional dequant decode ------------------
    prompt = jnp.asarray(global_batch_at(dcfg, 999)["tokens"][:2, :12])
    _, caches = model.prefill(params, {"tokens": prompt},
                              RunConfig(mode="prefill", remat=False,
                                        attn_chunk=16))
    caches = pad_prefill_cache(caches, 32)
    pos = jnp.full((2, 1), prompt.shape[1], jnp.int32)
    tok = prompt[:, -1:]

    # execution policy is one typed object now: RunConfig(plan_policy=...)
    # — each linear fetches a cached MatmulPlan (backend + resolved tiles)
    l_eva, _ = model.decode(qparams, tok, pos, caches,
                            RunConfig(mode="decode",
                                      plan_policy=PlanPolicy(vq_mode="eva")))
    l_deq, _ = model.decode(qparams, tok, pos, caches,
                            RunConfig(mode="decode",
                                      plan_policy=PlanPolicy(vq_mode="dequant")))
    l_pal, _ = model.decode(qparams, tok, pos, caches,
                            RunConfig(mode="decode",
                                      plan_policy=PlanPolicy(
                                          vq_mode="eva", impl="pallas",
                                          interpret=True)))
    print(f"EVA vs dequant max |Δlogit| : {float(np.max(np.abs(l_eva-l_deq))):.2e}")
    print(f"EVA jnp vs Pallas kernel    : {float(np.max(np.abs(l_eva-l_pal))):.2e}")
    print("next tokens (EVA):   ", np.argmax(np.asarray(l_eva[:, 0]), -1))
    print("next tokens (dequant)", np.argmax(np.asarray(l_deq[:, 0]), -1))


if __name__ == "__main__":
    main()
