from repro.kernels.flash_decode.ops import (flash_decode, flash_decode_kvq,
                                            flash_decode_kvq_paged,
                                            flash_decode_paged)
from repro.kernels.flash_decode.ref import (flash_decode_kvq_ref,
                                            flash_decode_ref)
