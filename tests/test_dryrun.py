"""Dry-run regression guard: lower+compile one (arch x shape) cell on both
production meshes inside a 512-device subprocess, and check the recorded
roofline structure. Keeps the multi-pod path from silently regressing."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    from repro.launch.dryrun import run_cell

    out_dir = sys.argv[1]
    results = {}
    for mesh in ("single", "multi"):
        r = run_cell("qwen3_0_6b", "decode_32k", mesh, out_dir)
        results[mesh] = {
            "status": r["status"],
            "chips": r.get("chips"),
            "bottleneck": r.get("roofline", {}).get("bottleneck"),
            "t_memory": r.get("roofline", {}).get("t_memory"),
        }
    print("RESULT" + json.dumps(results))
""")


@pytest.mark.slow
def test_dryrun_cell_compiles_on_both_meshes(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC, TF_CPP_MIN_LOG_LEVEL="2")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, str(tmp_path)], env=env,
        capture_output=True, text=True, timeout=560,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    res = json.loads(line[len("RESULT"):])
    assert res["single"]["status"] == "ok", res
    assert res["multi"]["status"] == "ok", res
    assert res["single"]["chips"] == 256 and res["multi"]["chips"] == 512
    # decode must be memory-bound (EVA's expected physics) on this arch
    assert res["single"]["bottleneck"] == "memory", res
    # multi-pod shards the decode batch further -> lower memory term
    assert res["multi"]["t_memory"] < res["single"]["t_memory"]
    # artifacts written
    files = os.listdir(tmp_path)
    assert any("pod1" in f for f in files) and any("pod2" in f for f in files)
