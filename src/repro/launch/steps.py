"""Step builders shared by the training driver, the serving driver and the
multi-pod dry-run: given (model, mesh) produce the jit-wrapped train /
prefill / decode steps with full in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.plan import PlanPolicy
from repro.models.api import Model
from repro.models.common import RunConfig
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.runtime import sharding as shd


# ---------------------------------------------------------------- training


def make_train_step(model: Model, opt_cfg: AdamWConfig, rc: RunConfig,
                    *, total_steps: int = 100000, warmup: int = 1000,
                    accum_steps: int = 1):
    """Sharded train step; `accum_steps > 1` splits the batch into
    microbatches scanned sequentially with gradient accumulation — the
    per-microbatch backward's gradient psums overlap the next
    microbatch's compute under XLA's latency-hiding scheduler, and the
    activation peak shrinks by the accumulation factor."""

    def train_step(params, opt_state: AdamWState, batch):
        def loss_fn(p, b):
            return model.loss(p, b, rc)

        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape(accum_steps, x.shape[0] // accum_steps,
                                 *x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (loss_acc + l, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, g0), micro)
            loss = loss / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)

        lr_scale = warmup_cosine(opt_state.step, warmup_steps=warmup,
                                 total_steps=total_steps)
        new_params, new_opt, gnorm = adamw_update(
            grads, opt_state, params, opt_cfg, lr_scale
        )
        metrics = {"loss": loss, "gnorm": gnorm,
                   "lr_scale": jnp.asarray(lr_scale, jnp.float32)}
        return new_params, new_opt, metrics

    return train_step


def train_shardings(model: Model, mesh: Mesh, params, opt_state, batch):
    pspec = shd.param_pspecs(params, mesh)
    mspec = shd.opt_pspecs(pspec, params, mesh, zero1=True)
    opt_spec = AdamWState(
        step=P(),
        m=mspec,
        v=mspec,
        master=(mspec if opt_state.master is not None else None),
    )
    bspec = shd.batch_pspecs(batch, mesh)
    metr_spec = {"loss": P(), "gnorm": P(), "lr_scale": P()}
    return (pspec, opt_spec, bspec), (pspec, opt_spec, metr_spec)


def lower_train_step(model: Model, mesh: Mesh, specs: Dict[str, Any],
                     rc: Optional[RunConfig] = None,
                     opt_cfg: Optional[AdamWConfig] = None):
    """Lower (but don't run) the sharded train step from ShapeDtypeStructs."""
    rc = rc or RunConfig(mode="train", remat=True)
    opt_cfg = opt_cfg or AdamWConfig()
    param_specs = model.param_specs()
    opt_specs = jax.eval_shape(
        functools.partial(adamw_init, cfg=opt_cfg), param_specs
    )
    step = make_train_step(model, opt_cfg, rc)
    in_shardings, out_shardings = train_shardings(
        model, mesh, param_specs, opt_specs, specs
    )
    with mesh:
        jitted = jax.jit(
            step,
            in_shardings=shd.to_named(in_shardings, mesh),
            out_shardings=shd.to_named(out_shardings, mesh),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(param_specs, opt_specs, specs)
    return lowered


# ----------------------------------------------------------------- serving


def make_prefill_step(model: Model, rc: RunConfig):
    def prefill_step(params, batch):
        rc_p = rc.replace(mode="prefill")
        logits, caches = model.forward(params, batch, rc_p)
        return logits[:, -1:], caches

    return prefill_step


def lower_prefill_step(model: Model, mesh: Mesh, specs: Dict[str, Any],
                       rc: Optional[RunConfig] = None, *,
                       quantized: bool = True):
    rc = rc or RunConfig(mode="prefill", remat=False,
                         plan_policy=PlanPolicy(int8_prefill=True))
    param_specs = model.param_specs(quantized=quantized)
    step = make_prefill_step(model, rc)
    pspec = shd.param_pspecs(param_specs, mesh)
    bspec = shd.batch_pspecs(specs, mesh)
    with mesh:
        jitted = jax.jit(
            step,
            in_shardings=(shd.to_named(pspec, mesh), shd.to_named(bspec, mesh)),
        )
        lowered = jitted.lower(param_specs, specs)
    return lowered


def make_decode_step(model: Model, rc: RunConfig):
    def decode_step(params, tokens, positions, caches):
        rc_d = rc.replace(mode="decode")
        logits, new_caches = model.decode(params, tokens, positions, caches, rc_d)
        return logits, new_caches

    return decode_step


def make_serve_decode_step(model: Model, rc: RunConfig):
    """The FULL serving decode step as the engine jits it: model decode
    plus the in-jit per-slot sampling/stopping epilogue
    (serve/api.sample_and_stop). Dry-runs lowering this step see the true
    production memory/roofline — logits never leave the device, the host
    reads back only (next_tok, done_mask, bad_mask); the per-lane finite
    check and the fault-injection poison lane ride the same readback."""
    from repro.serve import api as serve_api

    def serve_decode_step(params, caches, tokens, positions, keys,
                          temperature, top_k, top_p, greedy, stop_ids,
                          remaining, active, poison):
        rc_d = rc.replace(mode="decode")
        logits, new_caches = model.decode(
            params, tokens[:, None], positions[:, None], caches, rc_d)
        logits = logits[:, 0, : model.cfg.vocab_size] + poison[:, None]
        tok, done, bad, new_keys = serve_api.sample_and_stop(
            logits, keys=keys, temperature=temperature, top_k=top_k,
            top_p=top_p, greedy=greedy, stop_ids=stop_ids,
            remaining=remaining, active=active)
        return tok, done, bad, new_keys, new_caches

    return serve_decode_step


def serve_cache_specs(model: Model, num_slots: int, max_len: int, *,
                      paged: bool = False, block_size: int = 16,
                      num_blocks: Optional[int] = None) -> Any:
    """Cache ShapeDtypeStructs for lowering the serving decode step —
    contiguous by default, or the paged layout (shared block arenas +
    per-slot block tables, serve/paging.py) so a lowered
    ``lower_serve_decode_step`` carries the block table as state. The
    paged decode step still traces ONCE: table contents are data."""
    if not paged:
        return model.cache_specs(num_slots, max_len)
    from repro.serve import paging

    cfg = model.cfg
    window = cfg.sliding_window or cfg.local_window
    meta = paging.make_paging_config(model, num_slots, max_len,
                                     window=window, block_size=block_size,
                                     num_blocks=num_blocks)
    return paging.paged_cache_specs(model, num_slots, max_len, meta)


def serve_state_specs(batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs of the engine's per-slot sampling/stopping state
    (the extra inputs of ``make_serve_decode_step``)."""
    from repro.serve import api as serve_api

    sds = jax.ShapeDtypeStruct
    return {
        "tokens": sds((batch,), jnp.int32),
        "positions": sds((batch,), jnp.int32),
        "keys": sds((batch, 2), jnp.uint32),
        "temperature": sds((batch,), jnp.float32),
        "top_k": sds((batch,), jnp.int32),
        "top_p": sds((batch,), jnp.float32),
        "greedy": sds((batch,), jnp.bool_),
        "stop_ids": sds((batch, serve_api.MAX_STOP_IDS), jnp.int32),
        "remaining": sds((batch,), jnp.int32),
        "active": sds((batch,), jnp.bool_),
        "poison": sds((batch,), jnp.float32),
    }


def lower_decode_step(model: Model, mesh: Mesh, specs: Dict[str, Any],
                      rc: Optional[RunConfig] = None, *,
                      quantized: bool = True, vq_mode: str = "eva",
                      quantize_lm_head: bool = False):
    """specs: {"tokens", "positions", "caches"} from model.input_specs."""
    rc = rc or RunConfig(mode="decode", remat=False,
                         plan_policy=PlanPolicy(vq_mode=vq_mode))
    rc = rc.replace_policy(vq_mode=vq_mode if quantized else "none")
    param_specs = model.param_specs(quantized=quantized,
                                    quantize_lm_head=quantize_lm_head)
    step = make_decode_step(model, rc)
    pspec = shd.param_pspecs(param_specs, mesh)
    cspec = shd.cache_pspecs(specs["caches"], mesh)
    tspec = shd.batch_pspecs(
        {"tokens": specs["tokens"], "positions": specs["positions"]}, mesh
    )
    with mesh:
        jitted = jax.jit(
            step,
            in_shardings=(
                shd.to_named(pspec, mesh),
                shd.to_named(tspec["tokens"], mesh),
                shd.to_named(tspec["positions"], mesh),
                shd.to_named(cspec, mesh),
            ),
            donate_argnums=(3,),
        )
        lowered = jitted.lower(
            param_specs, specs["tokens"], specs["positions"], specs["caches"]
        )
    return lowered


def lower_serve_decode_step(model: Model, mesh: Mesh, specs: Dict[str, Any],
                            rc: Optional[RunConfig] = None, *,
                            quantized: bool = True, vq_mode: str = "eva",
                            quantize_lm_head: bool = False):
    """Lower the full serving decode step (decode + in-jit sampling and
    stopping). The per-slot state arrays are tiny and replicated; the
    cache/param shardings match ``lower_decode_step``."""
    from jax.sharding import NamedSharding

    rc = rc or RunConfig(mode="decode", remat=False,
                         plan_policy=PlanPolicy(vq_mode=vq_mode))
    rc = rc.replace_policy(vq_mode=vq_mode if quantized else "none")
    param_specs = model.param_specs(quantized=quantized,
                                    quantize_lm_head=quantize_lm_head)
    gb = int(specs["tokens"].shape[0])
    state = serve_state_specs(gb)
    step = make_serve_decode_step(model, rc)
    pspec = shd.param_pspecs(param_specs, mesh)
    cspec = shd.cache_pspecs(specs["caches"], mesh)
    repl = NamedSharding(mesh, P())
    state_order = ("tokens", "positions", "keys", "temperature", "top_k",
                   "top_p", "greedy", "stop_ids", "remaining", "active",
                   "poison")
    with mesh:
        jitted = jax.jit(
            step,
            in_shardings=(
                shd.to_named(pspec, mesh),
                shd.to_named(cspec, mesh),
            ) + tuple(repl for _ in state_order),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(
            param_specs, specs["caches"], *[state[k] for k in state_order]
        )
    return lowered
