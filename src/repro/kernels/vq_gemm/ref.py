"""Pure-jnp oracle for the VQ-GEMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vq_gemm_ref(x_flat: jax.Array, codebooks: jax.Array) -> jax.Array:
    """x_flat (MV, d), codebooks (C, d, k) -> O (C, MV, k) fp32."""
    return jnp.einsum(
        "md,cdk->cmk",
        x_flat.astype(jnp.float32),
        codebooks.astype(jnp.float32),
    )
