"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops as core_ops
from repro.core.vq import synthetic_vq
from repro.kernels.dequant_gemv import dequant_gemv
from repro.kernels.fused_vq_matmul import fused_vq_matmul
from repro.kernels.int8_gemm import int8_matmul_kernel
from repro.kernels.oc_lookup import oc_lookup
from repro.kernels.vq_gemm import vq_gemm

KEY = jax.random.PRNGKey(0)

SHAPE_SWEEP = [
    # (K, N, M, d, n, C)
    (64, 128, 1, 8, 8, 1),       # paper decode: M=1
    (128, 384, 4, 8, 8, 2),      # multi-codebook
    (256, 256, 2, 8, 4, 3),
    (96, 80, 3, 8, 5, 2),        # non-divisible N vs block sizes
    (64, 512, 8, 4, 8, 1),       # d=4 (GPTVQ-4D config)
    (160, 100, 2, 8, 8, 4),      # C=4 (4-bit)
    (88, 130, 3, 8, 8, 2),       # V=11, N=130: pads BOTH v and n tiles
    (104, 52, 2, 8, 8, 1),       # V=13 odd vs block_v, N < block_n
]

DTYPE_SWEEP = [jnp.float32, jnp.bfloat16]


def _mk(K, N, M, d, n, C, dtype, splits=()):
    vq = synthetic_vq(KEY, K, N, d=d, n=n, C=C, splits=splits)
    x = jax.random.normal(jax.random.fold_in(KEY, K * N + M), (M, K), dtype)
    return x, vq


@pytest.mark.parametrize("K,N,M,d,n,C", SHAPE_SWEEP)
@pytest.mark.parametrize("dtype", DTYPE_SWEEP)
def test_vq_gemm_kernel(K, N, M, d, n, C, dtype):
    x, vq = _mk(K, N, M, d, n, C, dtype)
    got = vq_gemm(x, vq.codebooks, interpret=True, block_mv=32)
    ref = vq_gemm(x, vq.codebooks, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("K,N,M,d,n,C", SHAPE_SWEEP)
def test_oc_lookup_kernel(K, N, M, d, n, C):
    x, vq = _mk(K, N, M, d, n, C, jnp.float32)
    O = vq_gemm(x, vq.codebooks, use_pallas=False)
    got = oc_lookup(O, vq.idx, vq.scale, interpret=True, block_v=4, block_n=64)
    ref = oc_lookup(O, vq.idx, vq.scale, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("K,N,M,d,n,C", SHAPE_SWEEP)
@pytest.mark.parametrize("dtype", DTYPE_SWEEP)
def test_fused_vq_matmul_kernel(K, N, M, d, n, C, dtype):
    x, vq = _mk(K, N, M, d, n, C, dtype)
    got = fused_vq_matmul(x, vq, interpret=True, block_v=4, block_n=64,
                          out_dtype=jnp.float32)
    ref = core_ops.eva_matmul(x, vq, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-3 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("K,N,M,d,n,C", SHAPE_SWEEP)
def test_dequant_gemv_kernel(K, N, M, d, n, C):
    x, vq = _mk(K, N, M, d, n, C, jnp.float32)
    got = dequant_gemv(x, vq, interpret=True, block_v=4, block_n=64,
                       out_dtype=jnp.float32)
    ref = core_ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N", [(1, 128, 64), (8, 256, 128), (5, 96, 48)])
@pytest.mark.parametrize("dtype", DTYPE_SWEEP)
def test_int8_gemm_kernel(M, K, N, dtype):
    x = jax.random.normal(KEY, (M, K), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 9), (K, N), jnp.float32) * 0.1
    got = int8_matmul_kernel(x, w, interpret=True, block_m=8, block_n=32,
                             block_k=64, out_dtype=jnp.float32)
    ref = core_ops.int8_matmul(x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("K,N", [(88, 130), (104, 52), (80, 70)])
def test_kernel_wrappers_auto_tiles_on_odd_shapes(K, N):
    """Regression (odd-shape padding): every kernel wrapper with "auto"
    tile selection pads non-divisible V/N instead of tripping the
    kernels' V % block_v == 0 / N % block_n == 0 asserts."""
    x, vq = _mk(K, N, 3, 8, 8, 2, jnp.float32)
    ref = core_ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
    got_f = fused_vq_matmul(x, vq, interpret=True, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    got_d = dequant_gemv(x, vq, interpret=True, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    O = vq_gemm(x, vq.codebooks, use_pallas=False)
    got_o = oc_lookup(O, vq.idx, vq.scale, interpret=True)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bv,bn", [(4, 64), (32, 512), (16, 48)])
def test_oc_and_dequant_kernels_pad_non_divisible_blocks(bv, bn):
    """Explicit block sizes that do NOT divide V/N (V=11 vs bv=4/32,
    N=130 vs bn=64/512/48) must be padded the way fused_vq_matmul pads."""
    x, vq = _mk(88, 130, 2, 8, 8, 2, jnp.float32)
    ref = core_ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
    got_o = oc_lookup(vq_gemm(x, vq.codebooks, use_pallas=False), vq.idx,
                      vq.scale, interpret=True, block_v=bv, block_n=bn)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    got_d = dequant_gemv(x, vq, interpret=True, block_v=bv, block_n=bn,
                         out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_kernel_equals_paper_formulation_end_to_end():
    """fused kernel == X @ dequant(I,B,s) — the full pipeline is exact."""
    x, vq = _mk(128, 96, 2, 8, 8, 2, jnp.float32)
    y_kernel = fused_vq_matmul(x, vq, interpret=True, block_v=8, block_n=32,
                               out_dtype=jnp.float32)
    y_dense = core_ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-5)


def test_eva_matmul_pallas_dispatch():
    x, vq = _mk(64, 48, 2, 8, 4, 2, jnp.float32)
    got = core_ops.eva_matmul(x, vq, impl="pallas", interpret=True)
    ref = core_ops.eva_matmul(x, vq, impl="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_eva_split_matmul_two_kernel_pipeline():
    """The no-fusion formulation — vq_gemm materializes the OC buffer,
    oc_lookup gathers from it — equals the dequant oracle, including a
    grouped family (wider N in the lookup stage only) and odd V/N that
    pad against the kernel tiles."""
    from repro.kernels.oc_lookup.ops import eva_split_matmul

    for K, N, splits, M in ((128, 96, (), 2), (80, 70, (), 3),
                            (96, 96, (50, 26, 20), 1)):
        x, vq = _mk(K, N, M, 8, 8, 2, jnp.float32, splits=splits)
        got = eva_split_matmul(x, vq, interpret=True, out_dtype=jnp.float32)
        ref = core_ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
