"""Named sharding rules for params, optimizer state, caches and batches.

Megatron-style tensor parallelism over the 'model' axis, data parallelism
over ('pod','data'), ZeRO-1 optimizer-state sharding over 'data', expert
parallelism for MoE stacks, and sequence-parallel cache sharding for
long-context decode.

Rules are name-based over the param tree (the tree layout is owned by
models/*). Anything unmatched is replicated — XLA SPMD propagation then
chooses intermediate shardings; non-divisible dims are padded by SPMD
(DESIGN.md §4).

Layout reminders:
  dense weight leaves under layers:         (L, ..., K, N)
  VQ idx (L, ..., C, V, N); codebooks (L, ..., C, d, 2^n); scale (L, ..., N)
  grouped families ("wqkv", "gu"): one wide VQWeight, N = sum(splits);
  column-parallel like their members (splits must ride along in pspec
  VQWeights — treedefs compare aux data)
  caches: attention k/v (L, B, S, Hk, hd); MLA latent (L, B, S, r);
          recurrent states (G, B, ...).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.vq import VQWeight, splits_shard_aligned  # noqa: F401
# (splits_shard_aligned is re-exported: the grouped-family alignment rule
# lives with the grouped layout in core/vq.py and is shared with the
# quantization pass's shard-aware grouping)

# output projections back into the residual stream -> row-parallel
_ROW_KEYS = {"wo", "down", "out"}
# everything else 2-D under a block is column-parallel
_REPLICATE_KEYS = {"router", "wr", "w_if", "wi", "wf", "rz", "lam", "cb"}


def _dp_axes(mesh: Mesh) -> Tuple:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes


def _model_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def _dim(mesh: Mesh, axis: Optional[str]) -> int:
    if axis is None:
        return 1
    return mesh.shape[axis]


def _pad_front(spec_tail: Tuple, ndim: int) -> P:
    return P(*([None] * (ndim - len(spec_tail)) + list(spec_tail)))


def _linear_specs(node: dict, key: str, mesh: Mesh, *, row: bool,
                  shard_expert: bool) -> dict:
    """Specs for one linear param dict ({"w"[,b]} or {"vq"[,b]}).

    jit in_shardings require exact divisibility, so every choice falls
    back (row <-> col <-> replicate) when the preferred axis does not
    divide the 'model' mesh dim (e.g. deepseek's d_ff=10944 -> V=1368)."""
    ma = _model_axis(mesh)
    mdim = _dim(mesh, ma)
    out = {}

    def div(x):
        return ma is not None and x % mdim == 0

    col_ok = True
    if "w" in node:
        w = node["w"]
        nd = w.ndim
        K, N = w.shape[-2], w.shape[-1]
        if shard_expert:
            # (L, E, K, N): shard the expert axis over 'model'
            out["w"] = _pad_front((ma, None, None), nd)
        elif row and div(K):
            out["w"] = _pad_front((ma, None), nd)      # shard K
        elif div(N):
            out["w"] = _pad_front((ma,), nd)           # shard N
            col_ok = True
            row = False
        elif div(K):
            out["w"] = _pad_front((ma, None), nd)
            row = True
        else:
            out["w"] = P(*([None] * nd))
            col_ok = False
    if "vq" in node:
        vq: VQWeight = node["vq"]
        nd_idx = vq.idx.ndim        # (L.., C, V, N)
        nd_cb = vq.codebooks.ndim
        nd_sc = vq.scale.ndim
        V, N = vq.idx.shape[-2], vq.idx.shape[-1]

        # grouped family: column-shard only when every member boundary
        # falls on a shard boundary — otherwise split_grouped_outputs'
        # slices straddle devices and each decode layer pays a reshard.
        # Misaligned families prefer V (contraction) sharding instead.
        def n_split_aligned():
            return div(N) and splits_shard_aligned(vq.splits, N, mdim)

        if shard_expert:
            lead = nd_idx - 3
            out["vq"] = VQWeight(
                idx=_pad_front((ma,) + (None,) * (nd_idx - lead), nd_idx)
                if lead >= 1 else P(*([None] * nd_idx)),
                codebooks=_pad_front((ma,) + (None,) * (nd_cb - lead), nd_cb)
                if lead >= 1 else P(*([None] * nd_cb)),
                scale=_pad_front((ma,) + (None,) * (nd_sc - lead), nd_sc)
                if lead >= 1 else P(*([None] * nd_sc)),
                K=vq.K, N=vq.N, d=vq.d, n=vq.n, splits=vq.splits,
            )
        elif row and div(V):
            # shard V (the K/d axis); lookup partial-sums psum over 'model'
            out["vq"] = VQWeight(
                idx=_pad_front((ma, None), nd_idx),
                codebooks=P(*([None] * nd_cb)),
                scale=P(*([None] * nd_sc)),
                K=vq.K, N=vq.N, d=vq.d, n=vq.n, splits=vq.splits,
            )
        elif n_split_aligned():
            # shard N: indices and scales column-sharded, OC replicated
            out["vq"] = VQWeight(
                idx=_pad_front((ma,), nd_idx),
                codebooks=P(*([None] * nd_cb)),
                scale=_pad_front((ma,), nd_sc),
                K=vq.K, N=vq.N, d=vq.d, n=vq.n, splits=vq.splits,
            )
        elif div(V):
            # misaligned-grouped (or otherwise un-N-shardable) fallback:
            # V-sharded contraction -> the output (and bias add) is not
            # column-sharded, so the bias must not be either
            col_ok = False
            out["vq"] = VQWeight(
                idx=_pad_front((ma, None), nd_idx),
                codebooks=P(*([None] * nd_cb)),
                scale=P(*([None] * nd_sc)),
                K=vq.K, N=vq.N, d=vq.d, n=vq.n, splits=vq.splits,
            )
        else:
            col_ok = False
            out["vq"] = VQWeight(
                idx=P(*([None] * nd_idx)),
                codebooks=P(*([None] * nd_cb)),
                scale=P(*([None] * nd_sc)),
                K=vq.K, N=vq.N, d=vq.d, n=vq.n, splits=vq.splits,
            )
    if "b" in node:
        b = node["b"]
        if row or shard_expert or not col_ok or not div(b.shape[-1]):
            out["b"] = P(*([None] * b.ndim))
        else:
            out["b"] = _pad_front((ma,), b.ndim)
    return out


def param_pspecs(params: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching `params`."""
    ma = _model_axis(mesh)
    mdim = _dim(mesh, ma)

    def walk(node, path):
        if isinstance(node, dict):
            # linear param dict?
            if ("w" in node and not isinstance(node["w"], dict)) or "vq" in node:
                key = path[-1] if path else ""
                if key in _REPLICATE_KEYS or (path and path[-2:] and
                                              path[-1] in _REPLICATE_KEYS):
                    return jax.tree_util.tree_map(
                        lambda x: P(*([None] * x.ndim)), node,
                        is_leaf=lambda x: hasattr(x, "ndim"),
                    )
                shard_expert = "experts" in path
                if shard_expert:
                    # only shard the expert axis when it divides the mesh
                    leaf = node["w"] if "w" in node else node["vq"].idx
                    E = leaf.shape[1] if leaf.ndim >= 4 else 0
                    if E % max(mdim, 1) != 0:
                        shard_expert = False  # fall back to feature sharding
                row = path[-1] in _ROW_KEYS
                return _linear_specs(node, path[-1], mesh,
                                     row=row, shard_expert=shard_expert)
            out = {}
            for k, v in node.items():
                if k == "emb":
                    out[k] = _pad_front((ma, None), v.ndim)  # vocab-sharded
                elif k == "cw":
                    out[k] = _pad_front((ma,), v.ndim)       # depthwise conv on d_rnn
                elif k in _REPLICATE_KEYS and hasattr(v, "ndim"):
                    out[k] = P(*([None] * v.ndim))
                elif isinstance(v, dict):
                    if k in _REPLICATE_KEYS:
                        out[k] = jax.tree_util.tree_map(
                            lambda x: P(*([None] * x.ndim)), v,
                            is_leaf=lambda x: hasattr(x, "ndim"),
                        )
                    else:
                        out[k] = walk(v, path + (k,))
                elif hasattr(v, "ndim"):
                    out[k] = P(*([None] * v.ndim))           # norms, gates, lam
                else:
                    out[k] = v
            return out
        if hasattr(node, "ndim"):
            return P(*([None] * node.ndim))
        return node

    return walk(params, ())


def opt_pspecs(param_specs: Any, params: Any, mesh: Mesh, *, zero1: bool = True) -> Any:
    """Optimizer m/v/master specs: param spec + ZeRO-1 sharding of the
    leading stacked axis over 'data' where it is unsharded."""
    dset = "data" if "data" in mesh.axis_names else None

    ddim = mesh.shape[dset] if dset else 1

    def one(spec, p):
        if not isinstance(spec, P):
            return spec
        if not zero1 or dset is None or p.ndim < 3:
            return spec
        parts = list(spec) + [None] * (p.ndim - len(spec))
        # shard the leading stacked (layer/group) axis over 'data' when it
        # divides evenly (jit in_shardings require exact divisibility)
        if parts[0] is None and p.shape[0] % ddim == 0:
            parts[0] = dset
            return P(*parts)
        return spec

    return jax.tree_util.tree_map(
        one, param_specs, params,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_pspecs(batch: Any, mesh: Mesh) -> Any:
    """Shard the batch (leading) axis of every input over DP axes."""
    dp = _dp_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def one(x):
        if x.ndim == 0:
            return P()
        if dp and x.shape[0] % total == 0:
            return P(dp, *([None] * (x.ndim - 1)))
        if "data" in mesh.axis_names and x.shape[0] % mesh.shape["data"] == 0:
            return P("data", *([None] * (x.ndim - 1)))
        return P(*([None] * x.ndim))

    return jax.tree_util.tree_map(one, batch)


_CACHE_TIME_KEYS = {"k", "v", "k_s", "v_s", "latent", "k_rope",
                    "xk", "xv", "cross_k", "cross_v"}


def cache_pspecs(cache: Any, mesh: Mesh) -> Any:
    """Decode-cache sharding: batch over DP axes when divisible; for
    unshardable batch (long-context B=1) shard the time axis over 'data'
    (sequence-parallel decode); heads/feature over 'model' when divisible."""
    ma = _model_axis(mesh)
    mdim = _dim(mesh, ma)
    dp = _dp_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    ddim = mesh.shape["data"] if "data" in mesh.axis_names else 1

    def leaf_spec(key, x):
        nd = x.ndim
        parts = [None] * nd
        if nd >= 2:
            B = x.shape[1]
            if dp and B % dp_total == 0 and B > 1:
                parts[1] = dp
            elif "data" in mesh.axis_names and B % ddim == 0 and B > 1:
                parts[1] = "data"
        if key in _CACHE_TIME_KEYS and nd >= 3:
            # Flash-decoding layout: shard the TIME axis over 'model' (and,
            # when the batch axis is unshardable, over every axis we have).
            # Attention over an S-sharded cache lowers to local partial
            # scores + tiny softmax-stat psums — no cache resharding.
            S = x.shape[2]
            if parts[1] is None:
                full = tuple(dp) + ((ma,) if ma else ())
                fdim = dp_total * mdim
                if S >= 1024 and full and S % fdim == 0:
                    parts[2] = full
                elif ma and S >= 1024 and S % mdim == 0:
                    parts[2] = ma
            elif ma and S >= 1024 and S % mdim == 0:
                parts[2] = ma
        elif nd >= 3 and ma and x.shape[-1] % mdim == 0 and key not in ("len",):
            parts[-1] = ma          # recurrent states: shard feature dim
        return P(*parts)

    def walk(node, key=""):
        if isinstance(node, dict):
            if "block_table" in node:
                # paged cache node (serve/paging.py): arena axis 1 is the
                # BLOCK POOL (NB), not batch, and the block table indexes
                # it globally — sharding either would scatter a slot's
                # blocks across ranks. Replicate both; only the per-slot
                # ``len`` leaf keeps the batch rule.
                return {k: (leaf_spec(k, v) if k == "len"
                            else P(*([None] * v.ndim)))
                        for k, v in node.items()}
            return {k: walk(v, k) for k, v in node.items()}
        if hasattr(node, "ndim"):
            return leaf_spec(key, node)
        return node

    return walk(cache)


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
