"""Launch entry points: mesh construction, sharded step builders, the
fault-tolerant training driver, the serving driver, and the multi-pod
dry-run harness (python -m repro.launch.dryrun)."""
