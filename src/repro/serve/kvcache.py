"""KV/state-cache utilities for serving.

Families store different cache structures (full KV, SWA/local ring
buffers, MLA latent caches, RG-LRU / xLSTM recurrent states). The engine
needs one operation over all of them: convert the variable-length caches
returned by prefill into fixed-capacity decode caches.

Bucketed prefill (serve/engine.py) runs this conversion INSIDE the jitted
prefill step with a traced ``true_len``: the prompt is right-padded to a
power-of-two bucket, so the prefill cache's static time length is the
bucket, while the number of REAL positions is dynamic. The conversion
stays jit-stable (fixed output shapes, dynamic gathers) and the ``len``
leaves are overwritten with ``true_len``; pad garbage beyond ``true_len``
is never read — decode overwrites slot ``len`` before attention unmasks
it (``valid = pos < len``).

Ring caches convert to the DECODE ring size ``min(capacity, window)`` —
the size ``init_cache`` allocates and decode wraps by (``slot = len %
S_cache``) — not the raw window, which previously produced oversized
rings whenever ``window > capacity``.

Conventions (see models/*.init_cache):
  {"k","v","len"}            attention cache, time axis -3 (ring iff window)
  {"latent","k_rope","len"}  MLA cache, time axis -2 ("latent_s" rides
                             along at -2 for KV-VQ caches)
  {"xk","xv","xlen"} / {"cross_k","cross_v","cross_len"}   static memories
  anything else              recurrent state, already fixed-size

``encode_prefill_cache`` bridges fp prefill caches into the KV-VQ
uint8-index layout (core/vq.py) before slot insertion — prefill always
runs in fp; quantization is an explicit engine-side step.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.vq import kv_encode


def _pad_time(x: jax.Array, axis: int, capacity: int) -> jax.Array:
    S = x.shape[axis]
    if S == capacity:
        return x
    if S > capacity:
        raise ValueError(f"prefill length {S} exceeds capacity {capacity}")
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, capacity - S)
    return jnp.pad(x, pad)


def _to_ring(x: jax.Array, axis: int, ring: int) -> jax.Array:
    """Reorder the last `ring` positions of a full-length cache into ring
    order (slot = position % ring)."""
    S = x.shape[axis]
    if S <= ring:
        return _pad_time(x, axis, ring)
    s = jnp.arange(ring)
    pos = S - ring + ((s - (S - ring)) % ring)
    return jnp.take(x, pos, axis=axis)


def _to_ring_dynamic(x: jax.Array, axis: int, ring: int,
                     true_len: jax.Array) -> jax.Array:
    """``_to_ring`` with a traced number of real positions: the cache's
    static time length is the prefill bucket, only the first ``true_len``
    entries are real.

    Ring slots past ``min(true_len, ring)`` hold no real position and
    are ZEROED. (They used to hold ``jnp.clip``-duplicated garbage —
    masked by decode attention, but nondeterministic junk that broke
    paged/contiguous bit-comparisons and could alias real positions at
    ``true_len == 0``. Edge cases pinned by tests/test_kvcache.py:
    ``true_len == 0`` -> all zeros, ``true_len == ring`` -> exactly the
    first ``ring`` positions in ring order.)"""
    S = x.shape[axis]
    s = jnp.arange(ring)
    wrapped = true_len - ring + ((s - true_len) % ring)
    pos = jnp.where(true_len <= ring, s, wrapped)
    pos = jnp.clip(pos, 0, S - 1)
    out = jnp.take(x, pos, axis=axis)
    valid = s < jnp.minimum(true_len, ring)
    shape = [1] * out.ndim
    shape[axis] = ring
    return jnp.where(valid.reshape(shape), out, jnp.zeros_like(out))


def pad_prefill_cache(cache: Any, capacity: int, *, window: int = 0,
                      true_len: Optional[jax.Array] = None) -> Any:
    """Walk the cache tree and pad/ring-convert every attention cache to
    its decode capacity. Recurrent states and static cross memories pass
    through unchanged.

    ``true_len`` (a traced int32 scalar) enables the bucketed-prefill
    path: the cache's static time length is the padded bucket, the ``len``
    leaves are set to ``true_len`` and ring conversion reorders the last
    ``true_len`` (not bucket-length) positions."""
    eff_cap = min(capacity, window) if window else capacity

    def fix_time(x, axis):
        if window:
            if true_len is None:
                return _to_ring(x, axis, eff_cap)
            return _to_ring_dynamic(x, axis, eff_cap, true_len)
        # non-ring: the pad bucket -> capacity is static either way; with
        # true_len the garbage beyond it rides along unread (decode
        # overwrites slot ``len`` before attention unmasks it)
        return _pad_time(x, axis, eff_cap)

    def fix_len(len_leaf):
        if true_len is None:
            return len_leaf
        return jnp.full_like(len_leaf, true_len)

    def walk(node):
        if isinstance(node, dict):
            if "k" in node and "v" in node and "len" in node:
                out = dict(node)
                out["k"] = fix_time(node["k"], node["k"].ndim - 3)
                out["v"] = fix_time(node["v"], node["v"].ndim - 3)
                for s in ("k_s", "v_s"):  # int8-cache scales: (.., S, Hk)
                    if s in node:
                        out[s] = fix_time(node[s], node[s].ndim - 2)
                out["len"] = fix_len(node["len"])
                return out
            if "latent" in node and "k_rope" in node:
                out = dict(node)
                out["latent"] = _pad_time(node["latent"],
                                          node["latent"].ndim - 2, eff_cap)
                out["k_rope"] = _pad_time(node["k_rope"],
                                          node["k_rope"].ndim - 2, eff_cap)
                if "latent_s" in node:  # KV-VQ scale leaf: (.., S, 1)
                    out["latent_s"] = _pad_time(
                        node["latent_s"], node["latent_s"].ndim - 2, eff_cap)
                if "len" in node:
                    out["len"] = fix_len(node["len"])
                return out
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(cache)


def quantize_prefill_cache_int8(cache: Any, *, int4: bool = False) -> Any:
    """Quantize fp attention nodes of a prefill cache into the int8/int4
    ``k``/``v`` + bf16 ``k_s``/``v_s`` layout (same per-(token, head)
    symmetric-absmax rule as models/common._quantize_kv — decode appends
    must round-trip identically).

    Prefill always runs in fp; the engine calls this explicitly before
    slot insertion (``_insert_slot``'s astype would truncate, not
    quantize). MLA/recurrent nodes pass through unchanged.
    """
    qmax = 7.0 if int4 else 127.0
    qdt = jnp.int4 if int4 else jnp.int8

    def quant(x):
        absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
        scale = jnp.maximum(absmax, 1e-8) / qmax
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -qmax, qmax).astype(qdt)
        return q, scale.astype(jnp.bfloat16)

    def walk(node):
        if isinstance(node, dict):
            if "k" in node and "v" in node and "len" in node \
                    and jnp.issubdtype(node["k"].dtype, jnp.floating):
                kq, ks = quant(node["k"])
                vq, vs = quant(node["v"])
                return {"k": kq, "v": vq, "k_s": ks, "v_s": vs,
                        "len": node["len"]}
            if "latent" in node:
                return node
            if "k" in node and "v" in node:
                return node  # static cross memories stay fp
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(cache)


def encode_prefill_cache(cache: Any, codebooks: Any, kvq) -> Any:
    """Quantize an fp prefill cache into the KV-VQ uint8-index layout.

    Prefill always runs in fp (models/common.py returns fp ``k``/``v``/
    ``latent`` leaves); slot insertion merely ``astype``s, which would
    silently truncate rather than vector-quantize. The engine therefore
    calls this explicitly — inside the jitted prefill step, before
    ``pad_prefill_cache`` / paged block writes.

    Args:
      cache: prefill cache tree ({"body": .., "pre": ..} of stacked
        attention or MLA nodes with leading layer dim L).
      codebooks: matching tree from core.quantize.kv_codebook_tree —
        {"body": {"k": (L,Hk,R,E,vd), "v": ..}, ..} for GQA or
        {"body": {"lat": (L,1,R,E,vd)}, ..} for MLA.
      kvq: the frozen core.vq.KVQuantConfig (supplies the scale variant).

    Returns:
      The cache tree with attention/MLA nodes rewritten to uint8 index
      leaves + bf16 scale leaves (same names init_cache allocates:
      ``k``/``v``/``k_s``/``v_s``, or ``latent``/``latent_s``). Nodes
      already uint8, and nodes without codebooks, pass through.

    Raises:
      KeyError: codebook tree is missing an entry ("k"/"v"/"lat") for a
        cache node it claims to cover.
    """
    enc = lambda x, cb: kv_encode(x, cb, kvq.variant)  # noqa: E731

    def walk(node, cbs):
        if isinstance(node, dict):
            if "k" in node and "v" in node and "len" in node:
                if cbs is None or node["k"].dtype == jnp.uint8:
                    return node
                k_idx, k_s = jax.vmap(enc)(node["k"], cbs["k"])
                v_idx, v_s = jax.vmap(enc)(node["v"], cbs["v"])
                return {"k": k_idx, "v": v_idx,
                        "k_s": k_s.astype(jnp.bfloat16),
                        "v_s": v_s.astype(jnp.bfloat16),
                        "len": node["len"]}
            if "latent" in node and "k_rope" in node:
                if cbs is None or node["latent"].dtype == jnp.uint8:
                    return node
                lat = node["latent"][..., None, :]      # (L,B,S,1,r)
                idx, s = jax.vmap(enc)(lat, cbs["lat"])
                out = dict(node)
                out["latent"] = idx[..., 0, :]          # (L,B,S,R*G)
                out["latent_s"] = s.astype(jnp.bfloat16)  # (L,B,S,1)
                return out
            return {k: walk(v, cbs.get(k) if isinstance(cbs, dict) else None)
                    for k, v in node.items()}
        return node

    return walk(cache, codebooks)


def cache_bytes(cache: Any) -> int:
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(cache)
        if hasattr(x, "size")
    )
