"""Paged KV-cache memory subsystem (block pool + block tables).

The contiguous serving cache pays worst-case memory up front: one
``max_len``-capacity time axis per slot, whether a request uses 64
tokens or 8192. With EVA's 2-bit weights the KV cache — not weights —
bounds concurrent users per chip (PAPER.md §VII), so serving memory has
to scale with *actual* sequence lengths. This module provides the
vLLM-style alternative:

  * ``BlockPool``     — a host-side free list over ``num_blocks``
                        physical blocks. One *block* spans
                        ``block_size`` token positions across EVERY
                        pageable cache leaf of EVERY layer/group: a
                        single physical block id is valid simultaneously
                        in all arenas, so allocation is one integer per
                        ``block_size`` tokens, not per-leaf bookkeeping.
  * block-table leaf  — every pageable cache node swaps its per-slot
                        contiguous time axis ``(B, S, ...)`` for a
                        shared arena ``(num_blocks, block_size, ...)``
                        plus a ``block_table`` leaf ``(B, W)`` of
                        physical block ids (logical block ``j`` of slot
                        ``b`` lives at ``arena[table[b, j]]``).
  * gather/scatter    — ``gather_block_view`` materializes the
                        per-slot contiguous view from the arena;
                        decode/prefill writes scatter through the table
                        with the OOB-sentinel trick below.

Pageable node kinds (same detection convention as kvcache.py):
  {"k","v","len"[,"k_s","v_s"]}   attention (time axis -3; scales -2).
                                  Covers fp, int8/int4 AND KV-VQ caches:
                                  a vector-quantized cache stores uint8
                                  codebook indices in "k"/"v" (trailing
                                  dim R*G instead of head_dim) with the
                                  same "k_s"/"v_s" scale leaves, so the
                                  arena/table machinery is layout-blind.
  {"latent","k_rope","len"[,"latent_s"]}  MLA latent cache (time -2;
                                  "latent_s" is the KV-VQ scale leaf)
Everything else (recurrent h/conv states, xLSTM states, whisper/vision
cross-attention memories) is *pass-through*: fixed-size per-slot state
kept at its contiguous ``(..., B, ...)`` shape.

Jit-stability and the sentinel id
---------------------------------
The sentinel block id is ``num_blocks`` — one past the arena. Scatters
go through ``.at[...].set(..., mode="drop")`` so writes routed to the
sentinel vanish, and gathers through ``jnp.take`` (clamp mode) so reads
of the sentinel return in-bounds garbage that the attention validity
mask (``pos < len``) never exposes. Freed or inactive slots simply get
sentinel rows in the device table: the *same* traced decode step serves
any mix of live/dead/mid-prefill slots with no retrace.

Bit-identity with the contiguous path
-------------------------------------
``block_size`` is constrained to divide ``page_len`` (falling back to
``gcd(block_size, page_len)``), so the gathered view is exactly
``(B, page_len, ...)`` — the same shape, same values at valid positions,
as the contiguous cache. Paged decode therefore reuses the *identical*
attention arithmetic (models/common.py) and produces token-identical
samples; tests/test_paging.py pins this per family.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kvcache import _to_ring_dynamic

# Leaf names making up a pageable attention node and their time axes
# (negative, from the right — leaves carry leading scan/batch axes).
_ATTN_TIME_AXES = {"k": -3, "v": -3, "k_s": -2, "v_s": -2}
_MLA_TIME_AXES = {"latent": -2, "k_rope": -2, "latent_s": -2}


def _is_attn_node(node: dict) -> bool:
    return "k" in node and "v" in node and "len" in node


def _is_mla_node(node: dict) -> bool:
    return "latent" in node and "k_rope" in node


def effective_block_size(block_size: int, page_len: int) -> int:
    """Largest divisor of ``page_len`` that is <= the requested block
    size (via gcd). Divisibility is what makes the gathered block view
    exactly ``page_len`` long — the contiguous shapes — so the paged
    path can reuse the contiguous attention math bit-for-bit."""
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    if page_len % block_size == 0:
        return block_size
    return math.gcd(block_size, page_len)


def blocks_for_len(n: int, *, block_size: int, page_len: int) -> int:
    """Blocks needed to hold ``n`` cached token positions.

    Capped at ``ceil(page_len / block_size)``: a ring/SWA cache wraps at
    ``page_len = min(max_len, window)`` and must never allocate beyond
    the ring (ISSUE 8 satellite — a windowed cache needs at most
    ``ceil(window/block_size)`` blocks)."""
    n = min(max(n, 0), page_len)
    return -(-n // block_size)


@dataclasses.dataclass(frozen=True)
class PagingConfig:
    """Static geometry of a paged cache (derived, not user state)."""

    block_size: int        # effective tokens per block (divides page_len)
    num_blocks: int        # physical blocks in the shared pool
    page_len: int          # per-slot logical capacity (= contiguous S)
    blocks_per_slot: int   # W = page_len // block_size
    bytes_per_block: int   # summed across every arena leaf
    sentinel: int          # = num_blocks; OOB id whose writes drop

    def blocks_for(self, n: int) -> int:
        """Blocks needed for an ``n``-token sequence (ring-capped at
        ``blocks_per_slot`` — see ``blocks_for_len``)."""
        return blocks_for_len(n, block_size=self.block_size,
                              page_len=self.page_len)


class BlockPool:
    """Host-side LIFO free list over physical block ids.

    Deterministic: ``alloc`` after ``free`` of the same ids hands the
    ids back in reverse-free order, so a snapshot/restore of
    ``state()`` reproduces the exact allocation sequence (paged decode
    is then token- AND layout-identical across restores)."""

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        self.num_blocks = num_blocks
        # pop() from the tail -> ids come out 0, 1, 2, ...
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._free_set = set(self._free)

    @property
    def free_count(self) -> int:
        """Blocks currently allocatable."""
        return len(self._free)

    @property
    def used_count(self) -> int:
        """Blocks currently owned by slots."""
        return self.num_blocks - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """All-or-nothing: ``n`` block ids, or None when the pool can't
        satisfy the request (caller preempts / defers admission)."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        return out

    def free(self, blocks: Sequence[int]) -> None:
        """Return block ids to the pool.

        Raises: ValueError on an out-of-range id or a double free (both
        indicate scheduler ownership bugs and must stay loud)."""
        for b in blocks:
            if not (0 <= b < self.num_blocks):
                raise ValueError(f"block id {b} out of range "
                                 f"[0, {self.num_blocks})")
            if b in self._free_set:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)
            self._free_set.add(b)

    def state(self) -> Tuple[int, ...]:
        """The exact free-list order for ``Engine.snapshot()``."""
        return tuple(self._free)

    def restore(self, free: Sequence[int]) -> None:
        """Replace the free list with a ``state()`` snapshot, restoring
        the exact allocation replay order.

        Raises: ValueError on duplicate or out-of-range ids."""
        free = [int(b) for b in free]
        if len(set(free)) != len(free):
            raise ValueError("pool snapshot contains duplicate block ids")
        for b in free:
            if not (0 <= b < self.num_blocks):
                raise ValueError(f"pool snapshot block id {b} out of range")
        self._free = free
        self._free_set = set(free)


def make_paging_config(model, num_slots: int, max_len: int, *,
                       window: int = 0, block_size: int = 16,
                       num_blocks: Optional[int] = None,
                       kv_int8: bool = False,
                       kv_int4: bool = False,
                       kvq=None) -> PagingConfig:
    """Derive the pool geometry for ``model`` at the given slot count.

    ``page_len`` mirrors what init_cache allocates per slot:
    ``min(max_len, window)`` for ring/SWA caches, else ``max_len``.
    ``num_blocks`` defaults to ``num_slots * blocks_per_slot`` — same
    worst-case capacity as the contiguous cache, but now *shared*, so
    short requests free headroom for long ones.

    ``kv_int8``/``kv_int4``/``kvq`` (a core.vq.KVQuantConfig) select the
    compressed cache layouts; ``bytes_per_block`` is computed from the
    resulting leaf specs, so the KV gauges and block budgets
    automatically reflect the compressed (e.g. uint8-index) arenas."""
    page_len = min(max_len, window) if window else max_len
    bs = effective_block_size(block_size, page_len)
    W = page_len // bs
    if num_blocks is None:
        num_blocks = num_slots * W
    if num_blocks < W:
        raise ValueError(
            f"num_blocks={num_blocks} cannot hold even one full slot "
            f"(blocks_per_slot={W})")

    specs = model.cache_specs(num_slots, max_len,
                              kv_int8=kv_int8, kv_int4=kv_int4, kvq=kvq)
    per_block = 0

    def walk(node):
        nonlocal per_block
        if not isinstance(node, dict):
            return
        axes = (_ATTN_TIME_AXES if _is_attn_node(node)
                else _MLA_TIME_AXES if _is_mla_node(node) else None)
        if axes is None:
            for v in node.values():
                walk(v)
            return
        for name, t in axes.items():
            if name not in node:
                continue
            leaf = node[name]
            B, S = leaf.shape[t - 1], leaf.shape[t]
            per_block += (leaf.size // (B * S)) * bs * leaf.dtype.itemsize

    walk(specs)
    return PagingConfig(block_size=bs, num_blocks=num_blocks,
                        page_len=page_len, blocks_per_slot=W,
                        bytes_per_block=per_block, sentinel=num_blocks)


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def init_contiguous_cache(model, num_slots: int, max_len: int,
                          **kwargs) -> Any:
    """The classic per-slot contiguous decode cache. All serving-side
    cache allocation routes through this module (CI grep-lints direct
    ``init_cache(num_slots, max_len)`` calls elsewhere in serve/)."""
    return model.init_cache(num_slots, max_len, **kwargs)


def _arena_shape(shape: Tuple[int, ...], t: int, meta: PagingConfig
                 ) -> Tuple[int, ...]:
    """(..., B, S, ...) at time axis ``t`` -> (..., NB, bs, ...)."""
    t = t % len(shape)
    return shape[:t - 1] + (meta.num_blocks, meta.block_size) + shape[t + 1:]


def init_paged_cache(model, num_slots: int, max_len: int,
                     meta: PagingConfig, *, kv_int8: bool = False,
                     kv_int4: bool = False, kvq=None) -> Any:
    """Build the paged decode cache: pageable nodes get shared arenas +
    a sentinel-filled ``block_table`` leaf, pass-through nodes keep
    their contiguous per-slot shapes (zero-initialized; prefill insert
    overwrites the slot rows before anything reads them). ``kvq``
    selects the vector-quantized uint8-index layout (KV codebooks stay
    in the param tree — arenas are zero-initialized and slot-sliced,
    which would corrupt cache-resident codebooks)."""
    specs = model.cache_specs(num_slots, max_len,
                              kv_int8=kv_int8, kv_int4=kv_int4, kvq=kvq)

    def page_node(node, axes):
        out = {}
        for name, leaf in node.items():
            t = axes.get(name)
            if t is None:  # "len" and any future scalar bookkeeping
                out[name] = jnp.zeros(leaf.shape, leaf.dtype)
                continue
            S = leaf.shape[t]
            if S != meta.page_len:
                raise ValueError(
                    f"cache leaf {name!r} has time length {S}, paging "
                    f"geometry expects page_len={meta.page_len}")
            out[name] = jnp.zeros(_arena_shape(leaf.shape, t, meta),
                                  leaf.dtype)
        lead = node["len"].shape[:-1]
        B = node["len"].shape[-1]
        out["block_table"] = jnp.full(
            lead + (B, meta.blocks_per_slot), meta.sentinel, jnp.int32)
        return out

    def walk(node):
        if isinstance(node, dict):
            if _is_attn_node(node):
                return page_node(node, _ATTN_TIME_AXES)
            if _is_mla_node(node):
                return page_node(node, _MLA_TIME_AXES)
            return {k: walk(v) for k, v in node.items()}
        return jnp.zeros(node.shape, node.dtype)

    return walk(specs)


def paged_cache_specs(model, num_slots: int, max_len: int,
                      meta: PagingConfig, *, kv_int8: bool = False,
                      kv_int4: bool = False, kvq=None) -> Any:
    """Shape/dtype tree of the paged cache without allocating it (the
    lowered serve step — launch/steps.py — carries it as state)."""
    return jax.eval_shape(
        lambda: init_paged_cache(model, num_slots, max_len, meta,
                                 kv_int8=kv_int8, kv_int4=kv_int4, kvq=kvq))


def is_paged(caches: Any) -> bool:
    """True when the cache tree contains at least one block table."""
    found = False

    def walk(node):
        nonlocal found
        if isinstance(node, dict):
            if "block_table" in node:
                found = True
                return
            for v in node.values():
                walk(v)

    walk(caches)
    return found


# ---------------------------------------------------------------------------
# Device-side table + slot plumbing
# ---------------------------------------------------------------------------


def set_block_tables(caches: Any, tables: np.ndarray) -> Any:
    """Replace every ``block_table`` leaf with ``tables`` (B, W)
    broadcast across the leading scan axes. The engine masks inactive
    slots' rows to the sentinel *before* calling this, so interleaved
    decode writes for freed/mid-prefill slots drop harmlessly."""
    dev = jnp.asarray(tables, jnp.int32)

    def walk(node):
        if isinstance(node, dict):
            out = {k: walk(v) for k, v in node.items()}
            if "block_table" in node:
                bt = node["block_table"]
                out["block_table"] = jnp.broadcast_to(
                    dev, bt.shape).astype(jnp.int32)
            return out
        return node

    return walk(caches)


def slot_view(caches: Any, slot, bt_row, hist, chunk_true) -> Any:
    """A single-slot (B=1) view of the paged cache for one chunked-
    prefill step. Pageable nodes share the arenas and get this slot's
    block-table row, ``len`` forced to the *host-tracked* committed
    length ``hist`` (the device leaf is corrupted by interleaved decode
    steps incrementing all lanes — never trust it mid-prefill), and an
    extra ``prefill_len`` leaf carrying the chunk's true length into
    attention_fwd (whose signature can't grow). Pass-through leaves are
    dynamic-sliced at the slot's batch row (axis 1 after the leading
    scan axis — the bucketable families all use that layout).

    Only valid for the chunk-continuation families (dense / whisper /
    vision, window == 0); the engine gates accordingly."""
    bt_row = jnp.asarray(bt_row, jnp.int32)

    def page_node(node):
        out = {}
        for name, leaf in node.items():
            if name == "block_table":
                out[name] = jnp.broadcast_to(
                    bt_row[None], leaf.shape[:-2] + (1,) + leaf.shape[-1:]
                ).astype(jnp.int32)
            elif name == "len":
                out[name] = jnp.full(leaf.shape[:-1] + (1,), hist,
                                     leaf.dtype)
            else:
                out[name] = leaf  # shared arena
        out["prefill_len"] = jnp.full(
            node["len"].shape[:-1] + (1,), chunk_true, jnp.int32)
        return out

    def walk(node):
        if isinstance(node, dict):
            if "block_table" in node:
                return page_node(node)
            return {k: walk(v) for k, v in node.items()}
        return jax.lax.dynamic_slice_in_dim(node, slot, 1, axis=1)

    return walk(caches)


def merge_slot(caches: Any, new_caches: Any, slot) -> Any:
    """Fold the outputs of a chunked-prefill step (over a ``slot_view``)
    back into the full cache. Arena leaves are taken wholesale (the
    scatter already wrote through shared storage), the full block-table
    leaf is kept from the OLD tree (the view's row is slot-local), the
    transient ``prefill_len`` leaf is dropped, and ``len`` + every
    pass-through leaf are dynamic-update-sliced into the slot's batch
    row (updates smaller than capacity anchor at 0, matching how the
    engine's contiguous ``_insert_slot`` already behaves)."""

    def page_node(old, new):
        out = {}
        for name, leaf in old.items():
            if name == "block_table":
                out[name] = leaf
            elif name == "len":
                out[name] = jax.lax.dynamic_update_slice_in_dim(
                    leaf, new[name].astype(leaf.dtype), slot, axis=1)
            else:
                out[name] = new[name]
        return out

    def walk(old, new):
        if isinstance(old, dict):
            if "block_table" in old:
                return page_node(old, new)
            return {k: walk(v, new[k]) for k, v in old.items()}
        return jax.lax.dynamic_update_slice_in_dim(
            old, new.astype(old.dtype), slot, axis=1)

    return walk(caches, new_caches)


def write_prefill_into_blocks(caches: Any, fresh: Any, slot, bt_row,
                              true_len, meta: PagingConfig, *,
                              window: int = 0) -> Any:
    """Commit a fresh single-request (B=1) prefill cache into the paged
    tree — the paged analogue of ``kvcache.pad_prefill_cache`` +
    ``_insert_slot``.

    Pageable leaves scatter their first ``true_len`` positions through
    ``bt_row`` (ring-converted first when ``window > 0``, so a prompt
    longer than the window lands in ring order and never needs more
    than ``blocks_per_slot`` blocks); positions beyond ``true_len``
    route to the sentinel and drop. ``len`` becomes ``true_len``.
    Pass-through leaves are dynamic-update-sliced into the slot row."""
    bt_row = jnp.asarray(bt_row, jnp.int32)
    bs, W, NB = meta.block_size, meta.blocks_per_slot, meta.sentinel

    def scatter(arena, vals, n_valid, P):
        # vals: (..., P, F...) with the leading scan axes intact; the
        # time axis sits right after them (fresh leaves are squeezed at
        # batch below), so index (lead..., phys, off) lines up with the
        # arena's (lead..., NB, bs, F...) layout.
        i = jnp.arange(P)
        phys = jnp.where(i < n_valid,
                         bt_row[jnp.clip(i // bs, 0, W - 1)], NB)
        off = i % bs
        idx = (slice(None),) * (arena.ndim - 2 - (vals.ndim - 2)) \
            + (phys, off)
        return arena.at[idx].set(vals.astype(arena.dtype), mode="drop")

    def page_node(old, new, axes):
        out = {}
        for name, leaf in old.items():
            if name == "block_table":
                out[name] = leaf
                continue
            if name == "len":
                upd = jnp.full(leaf.shape[:-1] + (1,), true_len,
                               leaf.dtype)
                out[name] = jax.lax.dynamic_update_slice_in_dim(
                    leaf, upd, slot, axis=1)
                continue
            t = axes[name]
            x = new[name]
            if window:
                x = _to_ring_dynamic(x, x.ndim + t, meta.page_len,
                                     true_len)
            n_valid = jnp.minimum(true_len, meta.page_len)
            P = x.shape[x.ndim + t]
            # squeeze the B=1 batch axis (just before the time axis)
            vals = jax.lax.index_in_dim(x, 0, axis=x.ndim + t - 1,
                                        keepdims=False)
            out[name] = scatter(leaf, vals, n_valid, P)
        return out

    def walk(old, new):
        if isinstance(old, dict):
            if _is_attn_node(old) and "block_table" in old:
                return page_node(old, new, _ATTN_TIME_AXES)
            if _is_mla_node(old) and "block_table" in old:
                return page_node(old, new, _MLA_TIME_AXES)
            return {k: walk(v, new[k]) for k, v in old.items()}
        return jax.lax.dynamic_update_slice_in_dim(
            old, new.astype(old.dtype), slot, axis=1)

    return walk(caches, fresh)


def gather_block_view(arena: jax.Array, block_table: jax.Array,
                      view_len: Optional[int] = None) -> jax.Array:
    """Materialize the per-slot contiguous view: ``(B, W)`` table over a
    ``(NB, bs, F...)`` arena -> ``(B, W*bs, F...)``. Sentinel ids clamp
    (``mode="clip"`` — never NaN-fill, which would survive masked
    softmax as ``0 * NaN``) to in-bounds garbage that the caller's
    validity mask hides. With ``W*bs == page_len`` this is shape- and
    value-identical (at valid positions) to the contiguous cache — the
    foundation of the paged path's token-identity guarantee."""
    B, W = block_table.shape
    bs = arena.shape[1]
    view = jnp.take(arena, block_table, axis=0, mode="clip")
    view = view.reshape((B, W * bs) + arena.shape[2:])
    if view_len is not None:
        view = view[:, :view_len]
    return view


def paged_state(tables: np.ndarray, pool: BlockPool,
                owned: Sequence[Sequence[int]]
                ) -> Dict[str, Any]:
    """Host-side paging state for EngineSnapshot (arenas + device block
    tables already ride the snapshot's ``/caches/...`` arrays)."""
    return {
        "block_tables": np.array(tables, dtype=np.int32, copy=True),
        "pool_free": pool.state(),
        "owned": tuple(tuple(int(b) for b in o) for o in owned),
    }
