"""Engine observability: aggregate counters for the serving loop.

One ``EngineMetrics`` instance lives on each ``Engine``; the engine
increments it inline (submit / admit / prefill / decode / finish) and
``Engine.metrics()`` returns ``snapshot()`` — a plain dict safe to log,
JSON-serialize or emit as bench rows. The invariants tests pin:

  tokens_generated == prefills + decode_slot_steps
                   == number of token-bearing StreamEvents
  finished         == finished_stop + finished_length
  submitted        == admitted + rejected + still queued/running
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict


@dataclasses.dataclass
class EngineMetrics:
    num_slots: int
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    finished: int = 0
    finished_stop: int = 0
    finished_length: int = 0
    prefills: int = 0
    prefill_prompt_tokens: int = 0
    decode_steps: int = 0
    decode_slot_steps: int = 0       # active lanes summed over decode steps
    tokens_generated: int = 0
    queue_wait_s: float = 0.0        # summed over admitted requests
    prefill_s: float = 0.0           # summed wall time of prefill calls
    decode_s: float = 0.0            # summed wall time of batched decode steps
    started_at: float = dataclasses.field(default_factory=time.perf_counter)

    def count_finish(self, reason: str) -> None:
        self.finished += 1
        if reason == "stop":
            self.finished_stop += 1
        elif reason == "length":
            self.finished_length += 1
        else:
            raise ValueError(f"not a finish reason for a served request: "
                             f"{reason!r}")

    @property
    def slot_occupancy(self) -> float:
        """Mean fraction of slots doing useful work per batched decode
        step — the paper's weight-tile amortization factor (§V-C)."""
        if self.decode_steps == 0:
            return 0.0
        return self.decode_slot_steps / (self.decode_steps * self.num_slots)

    @property
    def decode_tokens_per_s(self) -> float:
        if self.decode_s <= 0.0:
            return 0.0
        return self.decode_slot_steps / self.decode_s

    @property
    def tokens_per_s(self) -> float:
        dt = time.perf_counter() - self.started_at
        if dt <= 0.0:
            return 0.0
        return self.tokens_generated / dt

    def snapshot(self) -> Dict[str, float]:
        out = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self) if f.name != "started_at"}
        out["uptime_s"] = time.perf_counter() - self.started_at
        out["slot_occupancy"] = self.slot_occupancy
        out["decode_tokens_per_s"] = self.decode_tokens_per_s
        out["tokens_per_s"] = self.tokens_per_s
        return out
