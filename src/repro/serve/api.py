"""Typed request-level serving surface.

The paper's deployment shape (§V-C, Fig. 7(c)) is a continuously-batched
decode loop that keeps slots full so every streamed weight-index tile is
amortized across requests. This module defines the request-level API that
loop serves:

  SamplingParams     : per-request decoding strategy (greedy / temperature
                       + top-k + top-p, seeded).
  GenerationRequest  : prompt + budget + sampling + stop conditions
                       (eos_ids / stop_token_ids / max_new_tokens).
  StreamEvent        : one incremental token (or a rejection), as returned
                       by ``Engine.step()`` / yielded by ``Engine.stream()``.
  RequestOutput      : the terminal record — tokens, finish_reason
                       ("stop" | "length" | "rejected") and per-request
                       timing (queue wait, prefill latency, decode tok/s).

It also owns the JIT-STABLE sampling/stopping math executed inside the
batched decode step: every per-request knob is data (a per-slot device
array), never a static argument, so a mixed-sampling workload traces the
decode step exactly once. ``sample_tokens`` applies temperature / top-k /
top-p batched over slots with per-slot PRNG keys; ``sample_and_stop``
additionally evaluates the per-slot stop sets and budgets so the host
loop only reads back a ``(next_tok, done_mask)`` pair.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

FINISH_REASONS = (
    "stop", "length", "rejected",
    # resilience layer (serve/resilience.py):
    #   error    : the request's own logits went non-finite (numerics
    #              quarantine) — the slot is freed, the rest of the batch
    #              streams on
    #   timeout  : per-request deadline_s / engine queue TTL expired
    #   *-after-restore : the request was in flight when a crashed engine
    #              was restored from a snapshot; its stream replayed
    #              token-identically, but the reason records the restore
    "error", "timeout", "stop-after-restore", "length-after-restore",
)


class RequestEvicted(KeyError):
    """Raised by ``Engine.stream()`` for a uid that WAS served but whose
    terminal output and event buffer were FIFO-evicted past
    ``EngineConfig.max_retained`` — distinct from a never-submitted
    (unknown) uid, which stays a plain KeyError."""

# width of the per-slot stop-token set device array (eos_ids +
# stop_token_ids, padded with -1); a request needing more raises at submit
MAX_STOP_IDS = 8


# ---------------------------------------------------------------------------
# Request-side types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding strategy.

    ``greedy=True`` (the default) is exact argmax decoding — temperature /
    top_k / top_p / seed are ignored. With ``greedy=False`` the token is
    drawn from softmax(logits / temperature) restricted to the top_k
    highest-probability tokens (0 disables) and the top_p nucleus (1.0
    disables), using a PRNG stream derived from ``seed`` — two requests
    with equal params and seed draw identical streams regardless of
    submission order or slot placement.

    ``logprobs=True`` additionally surfaces the chosen token's
    log-probability — ``log_softmax`` of the model's UNSCALED logits at
    the emitted token, for greedy and sampled rows alike — on every
    ``StreamEvent`` and on ``RequestOutput.logprobs``."""

    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    logprobs: bool = False

    def __post_init__(self):
        if not self.greedy and self.temperature <= 0.0:
            raise ValueError(
                f"temperature must be > 0 when sampling, got {self.temperature}")
        if isinstance(self.top_k, bool) or not isinstance(self.top_k, int) \
                or self.top_k < 0:
            raise ValueError(f"top_k must be an int >= 0, got {self.top_k!r}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


GREEDY = SamplingParams()


@dataclasses.dataclass(frozen=True, eq=False)
class GenerationRequest:
    """One generation request: prompt, budget, sampling and stop control.

    ``eos_ids`` and ``stop_token_ids`` both terminate the request with
    ``finish_reason="stop"`` the step the token is EMITTED (the stop token
    is included in the output); exhausting ``max_new_tokens`` finishes
    with ``finish_reason="length"``.

    ``deadline_s`` is a per-request wall-clock budget measured from
    submit: once exceeded the engine finishes the request with
    ``finish_reason="timeout"`` — at admission (a queued request never
    wastes a prefill), between decode steps (a wedged request stops
    holding its slot and KV allocation) and while ``stream()``ing. None
    means no deadline (the engine's ``queue_ttl_s`` still bounds queue
    wait).

    ``speculate=False`` opts this request out of speculative decoding on
    an engine running with ``speculate_k > 0``: its slot caps emission
    at one token per step (pure data — the batch still shares the one
    traced multi-token step). The stream is token-identical either
    way; the opt-out only trades tokens/step for not attending over
    draft garbage."""

    prompt: np.ndarray
    max_new_tokens: int = 16
    sampling: SamplingParams = GREEDY
    eos_ids: Tuple[int, ...] = ()
    stop_token_ids: Tuple[int, ...] = ()
    deadline_s: Optional[float] = None
    speculate: bool = True

    def __post_init__(self):
        prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("prompt must contain at least one token")
        object.__setattr__(self, "prompt", prompt)
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        object.__setattr__(self, "eos_ids", tuple(int(t) for t in self.eos_ids))
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))
        if self.deadline_s is not None and self.deadline_s < 0:
            raise ValueError(
                f"deadline_s must be None or >= 0, got {self.deadline_s}")

    @property
    def prompt_len(self) -> int:
        """Number of prompt tokens (after int32 flatten)."""
        return int(self.prompt.shape[0])

    @property
    def stop_set(self) -> frozenset:
        """Union of ``eos_ids`` and ``stop_token_ids`` (the per-slot
        stop-token device array is built from this)."""
        return frozenset(self.eos_ids) | frozenset(self.stop_token_ids)


# ---------------------------------------------------------------------------
# Output-side types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One incremental engine event for a request.

    A token event carries the emitted ``token`` and its 0-based ``index``
    in the generated stream; the terminal event of a request additionally
    sets ``finish_reason``. A rejected submission produces a single
    tokenless terminal event (token=None, index=-1). ``logprob`` is the
    chosen-token log-probability when the request set
    ``SamplingParams.logprobs`` (None otherwise)."""

    uid: int
    index: int
    token: Optional[int]
    finish_reason: Optional[str] = None
    logprob: Optional[float] = None

    @property
    def done(self) -> bool:
        """True for the terminal event of the request's stream."""
        return self.finish_reason is not None


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """Terminal record of a request: everything generated plus timing.

    ``queue_wait_s``  : submit -> prefill start.
    ``prefill_s``     : wall time of the (bucketed) prefill call.
    ``decode_s``      : wall time from first decode step to finish.
    ``decode_tokens_per_s`` derives from the decode-phase tokens (the
    first token comes out of prefill)."""

    uid: int
    tokens: Tuple[int, ...]
    finish_reason: str
    queue_wait_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    # chosen-token logprobs, parallel to ``tokens``; empty unless the
    # request set SamplingParams.logprobs
    logprobs: Tuple[float, ...] = ()

    def __post_init__(self):
        if self.finish_reason not in FINISH_REASONS:
            raise ValueError(
                f"finish_reason must be one of {FINISH_REASONS}, "
                f"got {self.finish_reason!r}")

    @property
    def num_tokens(self) -> int:
        """Generated-token count (stop token included when emitted)."""
        return len(self.tokens)

    @property
    def decode_tokens_per_s(self) -> float:
        """Decode-phase throughput; 0.0 when the request never decoded
        (single-token output or rejection)."""
        decode_tokens = max(len(self.tokens) - 1, 0)
        if decode_tokens == 0 or self.decode_s <= 0.0:
            return 0.0
        return decode_tokens / self.decode_s


# ---------------------------------------------------------------------------
# Prefill length bucketing
# ---------------------------------------------------------------------------


def prefill_buckets(max_len: int, min_bucket: int = 8) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to (and always including)
    ``max_len``. Prefill pads each prompt to its bucket, so the jitted
    prefill step retraces at most once per bucket instead of once per
    distinct prompt length."""
    if max_len < 1:
        raise ValueError(f"max_len must be >= 1, got {max_len}")
    out: List[int] = []
    b = min(min_bucket, max_len)
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


def bucket_for(prompt_len: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket holding ``prompt_len``.

    Raises: ValueError when it exceeds the largest bucket (admission
    rejects such prompts before this is reached)."""
    for b in buckets:
        if prompt_len <= b:
            return b
    raise ValueError(
        f"prompt length {prompt_len} exceeds the largest bucket {buckets[-1]}")


def chunk_spans(prompt_len: int, chunk: int,
                buckets: Tuple[int, ...] = ()) -> List[Tuple[int, int, int]]:
    """The ``(start, length, bucket)`` spans chunked prefill splits a
    prompt into: ``chunk``-sized pieces (last one ragged), each padded to
    its bucket when bucketing is on (``bucket == length`` otherwise).
    Mirrors the engine's per-tick chunk walk (serve/engine.py
    ``_prefill_one``) so schedulers/benchmarks can predict the device
    call sequence without an engine instance."""
    if prompt_len < 1:
        raise ValueError(f"prompt_len must be >= 1, got {prompt_len}")
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    spans: List[Tuple[int, int, int]] = []
    pos = 0
    while pos < prompt_len:
        c = min(chunk, prompt_len - pos)
        b = bucket_for(c, buckets) if buckets else c
        spans.append((pos, c, b))
        pos += c
    return spans


# ---------------------------------------------------------------------------
# In-jit batched sampling / stopping
# ---------------------------------------------------------------------------


def _top_k_top_p_mask(scaled: jax.Array, top_k: jax.Array,
                      top_p: jax.Array) -> jax.Array:
    """Keep-mask over temperature-scaled logits (B, V) under per-row top_k
    (0 = disabled) and top_p (1.0 = disabled). Jit-stable: k and p are
    data, the mask is computed from the full sort."""
    V = scaled.shape[-1]
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V)
    kth = jnp.take_along_axis(sorted_desc, (k_eff - 1)[:, None], axis=-1)
    keep = scaled >= kth
    # nucleus: smallest prefix of the sorted distribution reaching top_p
    # (the first token is always kept)
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep_sorted = (cum - probs) < top_p[:, None]
    thr = jnp.min(jnp.where(keep_sorted, sorted_desc, jnp.inf), axis=-1)
    keep &= scaled >= thr[:, None]
    return keep


def sample_tokens(logits: jax.Array, keys: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array, greedy: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Batched per-slot token sampling.

    logits (B, V) float; keys (B, 2) uint32 raw PRNG keys; temperature
    (B,) f32; top_k (B,) i32; top_p (B,) f32; greedy (B,) bool. Returns
    (tokens (B,) i32, advanced keys (B, 2)). Greedy rows take the exact
    argmax of the unscaled logits (bit-identical to the pre-redesign
    host argmax); sampled rows draw from the masked scaled distribution.
    Keys advance for every row every step, so a slot's stream depends
    only on its seed and step count — not on its neighbors."""
    lf = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    temp = jnp.maximum(temperature.astype(jnp.float32), 1e-6)[:, None]
    scaled = lf / temp
    keep = _top_k_top_p_mask(scaled, top_k, top_p)
    masked = jnp.where(keep, scaled, -jnp.inf)

    def one(key, row):
        k_next, k_use = jax.random.split(key)
        return jax.random.categorical(k_use, row).astype(jnp.int32), k_next

    sampled, new_keys = jax.vmap(one)(keys, masked)
    tok = jnp.where(greedy, greedy_tok, sampled)
    return tok, new_keys


def token_logprobs(logits: jax.Array, tok: jax.Array) -> jax.Array:
    """Per-row log-probability of ``tok`` under softmax of the UNSCALED
    logits — the model's own distribution, not the temperature/top-k
    shaped sampling distribution, so greedy and sampled rows report the
    same quantity. logits (B, V), tok (B,) -> (B,) f32."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, tok[:, None], axis=-1)[:, 0]
    return gold - logz


def sample_and_stop(logits: jax.Array, *, keys: jax.Array,
                    temperature: jax.Array, top_k: jax.Array,
                    top_p: jax.Array, greedy: jax.Array,
                    stop_ids: jax.Array, remaining: jax.Array,
                    active: jax.Array
                    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """The serving decode epilogue: sample one token per slot, then
    evaluate the per-slot stop condition AND logits validity on device.

    stop_ids (B, MAX_STOP) i32 padded with -1; remaining (B,) i32 tokens
    still allowed including this one; active (B,) bool. Returns
    (next_tok, done, bad, new_keys): ``done`` is True on the step a slot
    emits a stop-set token or exhausts its budget — the host never scans
    generated streams. ``bad`` is True for an active slot whose logits
    row contains any NaN/Inf — the numerics-quarantine mask. It is an
    all-finite reduction computed on device and read back WITH the
    (next_tok, done) pair, so per-slot validity costs no extra device
    sync; the engine finishes bad slots with ``finish_reason="error"``
    while the rest of the batch streams on. Inactive lanes emit token 0
    and stay not-done, not-bad. A bad lane's sampled token is
    meaningless and is never emitted (the engine drops it); ``done`` is
    masked False there so one readback has one disposition per lane."""
    tok, new_keys = sample_tokens(logits, keys, temperature, top_k, top_p,
                                  greedy)
    bad = active & ~jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=-1)
    hit_stop = jnp.any(tok[:, None] == stop_ids, axis=-1)
    done = active & ~bad & (hit_stop | (remaining <= 1))
    return jnp.where(active, tok, 0), done, bad, new_keys
