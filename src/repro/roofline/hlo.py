"""HLO-text analysis: per-device FLOPs and collective bytes with while-loop
trip-count accounting.

XLA's built-in cost_analysis() visits a while body ONCE — with scan-over-
layers models that undercounts by num_layers. This parser:

  1. splits the post-optimization HLO module into computations,
  2. records every instruction's output shape,
  3. counts dot/convolution FLOPs per computation (contraction size looked
     up from operand definitions),
  4. sums collective wire bytes per computation (ring-model multipliers,
     group size parsed from replica_groups),
  5. walks the call graph from ENTRY, multiplying callee costs by while
     trip counts (largest integer constant in the loop condition).

Shapes in the SPMD-partitioned module are per-device, so all results are
per-device values.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# NOTE: tuple shapes may contain /*index=N*/ comments (hence `.+?`, not
# `[^=]+?`); the opcode is the first bare `word(` after the shape text.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+"
    r"([\w\-]+)\((.*?)\)(.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_REPL_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_REPL_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _parse_shape(text: str) -> Tuple[int, int]:
    """Returns (elements, bytes) for a shape string; tuples are summed."""
    total_e, total_b = 0, 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        for d in dims.split(","):
            if d:
                elems *= int(d)
        total_e += elems
        total_b += elems * _DTYPE_BYTES[dt]
    return total_e, total_b


@dataclasses.dataclass
class Instruction:
    name: str
    shape_text: str
    opcode: str
    operands: List[str]
    tail: str
    args: str = ""

    @property
    def out_bytes(self) -> int:
        return _parse_shape(self.shape_text)[1]

    @property
    def out_elems(self) -> int:
        return _parse_shape(self.shape_text)[0]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instruction]
    order: List[str]


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), {}, [])
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                continue
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, shape_text, opcode, args, tail = m.groups()
                operands = _OPERAND_RE.findall(args)
                cur.instrs[name] = Instruction(name, shape_text.strip(),
                                               opcode, operands, tail, args)
                cur.order.append(name)
    return comps, entry


def _dot_flops(instr: Instruction, comp: Computation) -> int:
    """2 * prod(out dims) * contraction size (from lhs operand shape)."""
    out_elems = instr.out_elems
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.tail)
    if not m or not instr.operands:
        return 2 * out_elems  # degenerate
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs = comp.instrs.get(instr.operands[0])
    if lhs is None:
        return 2 * out_elems
    dims_m = _SHAPE_RE.search(lhs.shape_text)
    if not dims_m:
        return 2 * out_elems
    dims = [int(x) for x in dims_m.group(2).split(",") if x]
    csize = 1
    for c in cdims:
        if c < len(dims):
            csize *= dims[c]
    return 2 * out_elems * csize


def _group_size(tail: str, default: int = 2) -> int:
    m = _REPL_GROUPS_IOTA_RE.search(tail)
    if m:
        return int(m.group(2))
    m = _REPL_GROUPS_LIST_RE.search(tail)
    if m:
        return len(m.group(1).split(","))
    return default


def _collective_bytes(instr: Instruction) -> int:
    """Per-device wire bytes (ring model)."""
    out_b = instr.out_bytes
    g = _group_size(instr.tail)
    op = instr.opcode.replace("-start", "")
    if op == "all-reduce":
        return int(2 * out_b * (g - 1) / max(g, 1))
    if op == "all-gather":
        return int(out_b * (g - 1) / max(g, 1))
    if op == "reduce-scatter":
        return int(out_b * (g - 1))
    if op == "all-to-all":
        return int(out_b * (g - 1) / max(g, 1))
    if op == "collective-permute":
        return out_b
    return 0


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition (scan counters
    compare the induction variable against the trip count)."""
    best = 1
    for instr in cond.instrs.values():
        if instr.opcode == "constant" and instr.args.strip().isdigit():
            best = max(best, int(instr.args.strip()))
        for m in _CONST_RE.finditer(instr.tail):
            best = max(best, int(m.group(1)))
    return best


_CALL_ATTRS = ("calls=", "to_apply=", "body=", "condition=",
               "true_computation=", "false_computation=")


def _callees(instr: Instruction) -> List[Tuple[str, str]]:
    out = []
    for attr in _CALL_ATTRS:
        for m in re.finditer(re.escape(attr) + r"%?([\w\.\-]+)", instr.tail):
            out.append((attr[:-1], m.group(1)))
    return out


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    collective_bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)


def analyze(text: str) -> HloCosts:
    comps, entry = parse_hlo(text)
    if entry is None:
        return HloCosts()
    memo: Dict[str, HloCosts] = {}

    def walk(name: str) -> HloCosts:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        cost = HloCosts()
        memo[name] = cost  # guard cycles
        if comp is None:
            return cost
        for iname in comp.order:
            instr = comp.instrs[iname]
            op = instr.opcode
            if op in ("dot", "convolution"):
                cost.flops += _dot_flops(instr, comp)
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                b = _collective_bytes(instr)
                cost.collective_bytes += b
                cost.collective_counts[base] = cost.collective_counts.get(base, 0) + 1
                cost.collective_bytes_by_op[base] = (
                    cost.collective_bytes_by_op.get(base, 0.0) + b
                )
            callees = _callees(instr)
            if op == "while":
                body = next((c for a, c in callees if a == "body"), None)
                cond = next((c for a, c in callees if a == "condition"), None)
                trips = _trip_count(comps[cond]) if cond in comps else 1
                for sub in (body, cond):
                    if sub:
                        s = walk(sub)
                        cost.flops += trips * s.flops
                        cost.collective_bytes += trips * s.collective_bytes
                        for k, v in s.collective_counts.items():
                            cost.collective_counts[k] = (
                                cost.collective_counts.get(k, 0) + trips * v
                            )
                        for k, v in s.collective_bytes_by_op.items():
                            cost.collective_bytes_by_op[k] = (
                                cost.collective_bytes_by_op.get(k, 0.0) + trips * v
                            )
            else:
                for _, sub in callees:
                    s = walk(sub)
                    cost.flops += s.flops
                    cost.collective_bytes += s.collective_bytes
                    for k, v in s.collective_counts.items():
                        cost.collective_counts[k] = cost.collective_counts.get(k, 0) + v
                    for k, v in s.collective_bytes_by_op.items():
                        cost.collective_bytes_by_op[k] = (
                            cost.collective_bytes_by_op.get(k, 0.0) + v
                        )
        return cost

    return walk(entry)
