"""Tbl. V-VII accuracy proxy (no pretrained checkpoints offline): train a
small LM on the synthetic task, then compare quantization schemes:

  FP32 (dense)  |  VQ C=4 (4-bit)  |  VQ C=2 (2-bit)  |  RTN INT4 | RTN INT2

Paper's qualitative claims this reproduces: 4-bit is near-lossless for
both; at 2-bit, scalar round-to-nearest collapses while VQ stays usable
(Tbl. V: AWQ INT2 ppl 2.2e5 vs AQLM 6.69).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.vq import VQWeight
from repro.data import DataConfig, global_batch_at
from repro.models import build_model
from repro.models.common import RunConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _rtn_quantize_tree(params, bits: int):
    """Round-to-nearest weight-only quantization of the same FC set."""
    from repro.core.quantize import _eligible

    def walk(node, path):
        if isinstance(node, dict):
            if "w" in node and not isinstance(node["w"], dict) \
                    and _eligible(path, node["w"]):
                w = node["w"]
                absmax = jnp.max(jnp.abs(w), axis=-2, keepdims=True)
                scale = jnp.maximum(absmax, 1e-8) / (2 ** (bits - 1) - 1)
                q = jnp.round(w / scale)
                q = jnp.clip(q, -(2 ** (bits - 1) - 1), 2 ** (bits - 1) - 1)
                out = dict(node)
                out["w"] = q * scale
                return out
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        return node

    return walk(params, ())


def run(report, steps: int = 60):
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=3e-3)
    opt = adamw_init(params, ocfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=16)
    rc = RunConfig(mode="train", remat=False, attn_chunk=16)
    step_fn = jax.jit(
        lambda p, o, b: _one_step(model, p, o, b, ocfg, rc))
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in global_batch_at(dcfg, step).items()}
        params, opt, _ = step_fn(params, opt, batch)

    eval_batch = {k: jnp.asarray(v)
                  for k, v in global_batch_at(dcfg, 10_000).items()}

    def ppl(p, vq_mode="none"):
        loss = model.loss(p, eval_batch, rc.replace_policy(vq_mode=vq_mode))
        return float(jnp.exp(loss))

    key = jax.random.PRNGKey(1)
    rows = [("FP32", ppl(params))]
    for C, name in ((4, "VQ-4bit(C=4)"), (2, "VQ-2bit(C=2)")):
        cfg_c = dataclasses.replace(cfg, vq_C=C)
        q = build_model(cfg_c).quantize(params, method="fit", key=key)
        rows.append((name, ppl(q, "eva")))
    for bits, name in ((4, "RTN-INT4"), (2, "RTN-INT2")):
        rows.append((name, ppl(_rtn_quantize_tree(params, bits))))

    base = rows[0][1]
    for name, p in rows:
        report(f"tbl5/{name}", 0.0, f"ppl={p:.3f};vs_fp32={p/base:.2f}x")
    d = dict(rows)
    report("tbl5/claim_2bit", 0.0,
           f"VQ2/RTN2_ppl_ratio={d['VQ-2bit(C=2)']/d['RTN-INT2']:.4f}"
           "(paper: VQ survives 2-bit, RTN collapses)")
    return rows


def _one_step(model, params, opt, batch, ocfg, rc):
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, rc))(params)
    new_p, new_o, _ = adamw_update(grads, opt, params, ocfg)
    return new_p, new_o, loss
