"""Core matmul formulations for EVA.

Four execution paths, all algebraically computing ``y = x @ W_hat``:

  fp_matmul       : dense high-precision matmul (the FP16/BF16 baseline).
  int8_matmul     : int8 x int8 -> int32 GEMM (the paper's prefill path).
  dequant_matmul  : conventional VQ — reconstruct W_hat from (I, B, scale)
                    then GEMV/GEMM (the paper's Fig. 1(b) baseline with all
                    its memory traffic).
  eva_matmul      : the paper's contribution — VQ-GEMM (O = X·B) followed by
                    the conflict-free output-codebook lookup + add-only
                    reduction epilogue (Fig. 1(c)).

`impl` selects the pure-jnp expression ("jnp", used by distributed lowering
and as the oracle) or the Pallas TPU kernel ("pallas", validated in
interpret mode on CPU; compiled for TPU on real hardware).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vq import VQWeight

# Default V-tile for the blocked epilogue. Mirrors the paper's v=32 tile
# height (Tbl. II); on TPU this bounds the gathered intermediate to
# (C, M, 32, N_tile) in VMEM.
DEFAULT_BLOCK_V = 32


def fp_matmul(x: jax.Array, w: jax.Array, *, out_dtype=None) -> jax.Array:
    """Dense baseline: y = x @ w with fp32 accumulation."""
    out_dtype = out_dtype or x.dtype
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return y.astype(out_dtype)


def quantize_int8(x: jax.Array, axis: int = -1):
    """Symmetric per-slice int8 quantization: returns (q, scale)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_matmul(x: jax.Array, w: jax.Array, *, out_dtype=None) -> jax.Array:
    """Prefill path: dynamic per-token int8 activations x per-channel int8
    weights -> int32 accumulate -> fp dequant. Mirrors the paper's INT8
    systolic-array prefill mode (the TPU MXU is natively int8-capable)."""
    out_dtype = out_dtype or x.dtype
    xq, xs = quantize_int8(x, axis=-1)             # (..., K), (..., 1)
    wq, ws = quantize_int8(w, axis=0)              # (K, N), (1, N)
    acc = jax.lax.dot_general(
        xq, wq, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return (acc.astype(jnp.float32) * xs * ws).astype(out_dtype)


def dequant_matmul(x: jax.Array, vq: VQWeight, *, out_dtype=None) -> jax.Array:
    """Conventional VQ baseline: on-the-fly reconstruct W_hat, then matmul.

    Expressed so the weight reconstruction materializes (K, N) — exactly the
    memory-traffic pattern EVA eliminates; used as the numerical oracle."""
    from repro.core.vq import dequantize

    out_dtype = out_dtype or x.dtype
    w_hat = dequantize(vq).astype(jnp.float32)
    return fp_matmul(x.astype(jnp.float32), w_hat, out_dtype=out_dtype)


def compute_output_codebook(x: jax.Array, vq: VQWeight) -> jax.Array:
    """Step 1 (VQ-GEMM): O = X·B.

    x: (..., K) -> O: (C, M, V, 2^n) fp32 where M = prod(leading dims).
    This is the GEMM the paper maps onto the 32x8 systolic array; cost is
    M*K*2^n MACs, independent of N.
    """
    K = vq.K
    M = x.size // K
    X = x.reshape(M, vq.V, vq.d).astype(jnp.float32)
    # (M, V, d) x (C, d, k) -> (C, M, V, k)
    return jnp.einsum("mvd,cdk->cmvk", X, vq.codebooks.astype(jnp.float32))


def eva_matmul(
    x: jax.Array,
    vq: VQWeight,
    *,
    block_v: Optional[int] = None,
    out_dtype=None,
    impl: str = "jnp",
    interpret: bool = False,
    flat_gather: bool = False,
) -> jax.Array:
    """EVA decode matmul: y = x @ W_hat via output-codebook lookup.

      O = X·B                         (VQ-GEMM, MXU)
      y[m,j] = s[j] * sum_c sum_v O[c,m,v, I[c,v,j]]   (epilogue, add-only)

    Default epilogue is the DIRECT gather+reduce: under pjit the gathered
    intermediate is sharded tile-sized (indices keep their V/N sharding —
    an explicit V-block scan would force index all-gathers when V is
    sharded) and XLA fuses gather into the reduction. `block_v` switches
    to a scan-blocked epilogue for memory-constrained single-host runs
    (mirrors the paper's v=32 tiling; the Pallas kernel always tiles).
    """
    if impl == "pallas":
        from repro.kernels.fused_vq_matmul import ops as fused_ops

        return fused_ops.fused_vq_matmul(x, vq, out_dtype=out_dtype, interpret=interpret)
    if impl != "jnp":
        raise ValueError(f"unknown impl {impl!r}")

    out_dtype = out_dtype or x.dtype
    lead_shape = x.shape[:-1]
    K = vq.K
    M = x.size // K
    V, N, C = vq.V, vq.N, vq.C

    O = compute_output_codebook(x, vq)  # (C, M, V, k)
    I = vq.idx.astype(jnp.int32)        # (C, V, N)

    if block_v is None:
        if flat_gather:
            # §Perf variant: single-axis gather with precomputed flat
            # indices — GSPMD partitions 1-D gathers with a replicated
            # operand locally, where the 4-D take_along_axis reshards
            # 3-tuple s32 gather indices across the mesh.
            k = O.shape[-1]
            v_iota = jnp.arange(V, dtype=jnp.int32)[None, :, None]
            c_iota = jnp.arange(C, dtype=jnp.int32)[:, None, None]
            flat = ((c_iota * V + v_iota) * k + I).reshape(-1)   # (C*V*N,)
            O2 = O.transpose(1, 0, 2, 3).reshape(M, C * V * k)
            g = jnp.take(O2, flat, axis=1)                       # (M, C*V*N)
            acc = g.reshape(M, C, V, N).sum(axis=(1, 2))
        else:
            g = jnp.take_along_axis(O, I[:, None].astype(jnp.int32), axis=3)
            acc = g.sum(axis=(0, 2))                             # (M, N)
    else:
        bv = min(block_v, V)
        # pad V to a multiple of bv (index 0 with zeroed O rows)
        rem = (-V) % bv
        if rem:
            O = jnp.pad(O, ((0, 0), (0, 0), (0, rem), (0, 0)))
            I = jnp.pad(I, ((0, 0), (0, rem), (0, 0)))
        nblk = O.shape[2] // bv
        O_blk = O.reshape(C, M, nblk, bv, O.shape[-1]).transpose(2, 0, 1, 3, 4)
        I_blk = I.reshape(C, nblk, bv, N).transpose(1, 0, 2, 3)

        def body(acc, blk):
            o_b, i_b = blk  # (C,M,bv,k), (C,bv,N)
            g = jnp.take_along_axis(o_b, i_b[:, None].astype(jnp.int32), axis=3)
            return acc + g.sum(axis=(0, 2)), None

        acc0 = jnp.zeros((M, N), jnp.float32)
        acc, _ = jax.lax.scan(body, acc0, (O_blk, I_blk))
    y = acc * vq.scale[None, :].astype(jnp.float32)
    return y.reshape(*lead_shape, N).astype(out_dtype)


def split_grouped_outputs(y: jax.Array, vq: VQWeight) -> Tuple[jax.Array, ...]:
    """Slice the output of a grouped-family matmul (y = x @ [W1|..|Wg])
    back into per-projection outputs at the recorded split points.

    The wide matmul amortizes one VQ-GEMM / output-codebook computation
    over every member; this split is free (pure slicing)."""
    if not vq.splits:
        return (y,)
    offs = list(np.cumsum(vq.splits[:-1]))
    return tuple(jnp.split(y, offs, axis=-1))


def vq_matmul(
    x: jax.Array,
    vq: VQWeight,
    *,
    mode: str = "eva",
    out_dtype=None,
    impl: str = "jnp",
    interpret: bool = False,
    flat_gather: bool = False,
) -> jax.Array:
    """Unified entry point used by the model layers."""
    if mode == "eva":
        return eva_matmul(x, vq, out_dtype=out_dtype, impl=impl,
                          interpret=interpret, flat_gather=flat_gather)
    if mode == "dequant":
        return dequant_matmul(x, vq, out_dtype=out_dtype)
    raise ValueError(f"unknown vq matmul mode {mode!r}")


# ---------------------------------------------------------------------------
# Analytic op counts (used by tests + the accelerator model)
# ---------------------------------------------------------------------------


def gemv_macs(M: int, K: int, N: int) -> int:
    return M * K * N


def vq_gemm_macs(M: int, K: int, n: int, C: int, d: int) -> int:
    """MACs of the VQ-GEMM stage: (M*K/d) rows x 2^n cols x d depth, per
    codebook."""
    return C * M * (K // d) * (2 ** n) * d


def epilogue_adds(M: int, K: int, N: int, C: int, d: int) -> int:
    """Add-only epilogue work: one add per (m, v, j, c)."""
    return C * M * (K // d) * N


def compute_collapse_ratio(N: int, n: int) -> float:
    """Paper §III-B advantage 3: GEMV MACs / VQ-GEMM MACs = N / 2^n."""
    return N / float(2 ** n)


def grouped_compute_collapse_ratio(splits: Tuple[int, ...], n: int) -> float:
    """Effective collapse ratio of a grouped projection family: the single
    shared VQ-GEMM serves sum(N_i) output channels -> sum(N_i) / 2^n
    (vs N_i / 2^n for each member executed separately)."""
    return compute_collapse_ratio(sum(splits), n)
