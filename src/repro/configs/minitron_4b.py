"""Minitron-4B — width/depth-pruned Nemotron [arXiv:2407.14679; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    rope_theta=10000.0,
    vq_C=2,
)

SMOKE = ModelConfig(
    name="minitron-4b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=384,
    vocab_size=512,
    rope_theta=10000.0,
    vq_C=2,
)
