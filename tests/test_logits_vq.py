"""VQ-Logits compressed LM head tests: exact parity of the gather
formulation against the expanded dense oracle (standalone and through a
full smoke transformer), planner registration/cost ranking, the
attach pass, and end-to-end serving with a compressed head."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import logits_vq as lvq
from repro.core import plan as plan_mod
from repro.core import quantize
from repro.models import build_model
from repro.models.common import RunConfig
from repro.serve import Engine, EngineConfig, GenerationRequest

KEY = jax.random.PRNGKey(0)


def _policy():
    return plan_mod.PlanPolicy()


def test_expand_matches_definition():
    head = lvq.synthetic_logits_vq(KEY, 16, 64, 7)
    w = np.asarray(lvq.expand(head))
    cb = np.asarray(head.codebook)
    assign = np.asarray(head.assign)
    scale = np.asarray(head.scale)
    for v in (0, 13, 63):
        np.testing.assert_array_equal(w[:, v], scale[v] * cb[:, assign[v]])


def test_gather_backend_exact_vs_dense_oracle():
    head = lvq.synthetic_logits_vq(KEY, 32, 128, 9)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32), jnp.float32)
    spec = lvq.vq_logits_spec(head, M=4, x_dtype=x.dtype,
                              out_dtype=jnp.float32)
    gather = lvq._plan_vql_gather(spec, _policy())
    dequant = lvq._plan_vql_dequant(spec, _policy())
    y_g = np.asarray(gather.run(x, head))
    y_d = np.asarray(dequant.run(x, head))
    y_ref = np.asarray(x @ lvq.expand(head))
    np.testing.assert_array_equal(y_g, y_d)
    np.testing.assert_allclose(y_g, y_ref, rtol=1e-6, atol=1e-6)


def test_planner_ranks_gather_over_dequant_and_dispatches():
    head = lvq.synthetic_logits_vq(KEY, 64, 512, 16)
    x = jnp.ones((2, 64), jnp.float32)
    plan = plan_mod.plan_node({"vql": head}, x, mode="decode",
                              policy=_policy(), out_dtype=jnp.float32)
    assert plan.spec.kind == "vq_logits" and plan.spec.k == 16
    # Kc << V makes the gather formulation the strict cost winner
    assert plan.backend == "vql_gather_jnp"
    y = np.asarray(plan.execute(x, head))
    np.testing.assert_allclose(y, np.asarray(x @ lvq.expand(head)),
                               rtol=1e-6, atol=1e-6)


def test_fit_reconstructs_clustered_head_exactly():
    """Columns drawn from kc distinct directions (with varying scales)
    are exactly recoverable by the k-means fit."""
    kc, d, v = 4, 16, 64
    dirs = jax.random.normal(KEY, (kc, d))
    dirs = dirs / jnp.linalg.norm(dirs, axis=1, keepdims=True)
    assign = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (v,), 0, kc))
    scales = np.asarray(jax.random.uniform(jax.random.PRNGKey(3), (v,),
                                           minval=0.5, maxval=2.0))
    w = (np.asarray(dirs)[assign] * scales[:, None]).T     # (D, V)
    head = lvq.fit_logits_vq(jax.random.PRNGKey(4), w, kc, iters=30)
    np.testing.assert_allclose(np.asarray(lvq.expand(head)), w,
                               rtol=1e-4, atol=1e-5)


def test_attach_pass_idempotent_and_guarded():
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    q = quantize.attach_vq_logits_head(params, 32)
    assert "vql" in q["lm_head"] and q["lm_head"]["vql"].Kc == 32
    # idempotent: re-attach refits from the implied dense weight
    q2 = quantize.attach_vq_logits_head(q, 16)
    assert q2["lm_head"]["vql"].Kc == 16
    # tied-embedding models have no separate head
    tied = {k: v for k, v in params.items() if k != "lm_head"}
    with pytest.raises(ValueError, match="lm_head"):
        quantize.attach_vq_logits_head(tied, 8)


def test_smoke_transformer_logits_exact_with_synthetic_head():
    """A synthetic head consumed natively through models.common.linear
    produces bit-comparable logits to the same model with the expanded
    dense head substituted in."""
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    head = lvq.synthetic_logits_vq(jax.random.PRNGKey(5), cfg.d_model,
                                   cfg.padded_vocab, 24)
    p_vql = dict(params)
    p_vql["lm_head"] = {"vql": head}
    p_dense = dict(params)
    p_dense["lm_head"] = {"w": lvq.expand(head)}
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 7), 0,
                              cfg.vocab_size, jnp.int32)
    rc = RunConfig(mode="prefill", remat=False)
    lo_v, _ = model.prefill(params=p_vql, batch={"tokens": toks}, rc=rc)
    lo_d, _ = model.prefill(params=p_dense, batch={"tokens": toks}, rc=rc)
    np.testing.assert_allclose(np.asarray(lo_v), np.asarray(lo_d),
                               rtol=1e-5, atol=1e-5)


def test_engine_serves_with_vql_head_matches_expanded_dense():
    """End-to-end: the serving engine decoding through a VQ-Logits head
    emits the same greedy stream as with the equivalent dense head."""
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    head = lvq.synthetic_logits_vq(jax.random.PRNGKey(7), cfg.d_model,
                                   cfg.padded_vocab, 24)
    rc = RunConfig(mode="decode", remat=False, attn_chunk=16)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 8)]

    def serve(lm_head_node):
        p = dict(params)
        p["lm_head"] = lm_head_node
        eng = Engine(model, p, rc, EngineConfig(num_slots=2, max_len=32))
        uids = [eng.submit(GenerationRequest(prompt=pr, max_new_tokens=6))
                for pr in prompts]
        steps = 0
        while not eng.idle:
            eng.step()
            steps += 1
            assert steps < 100
        return [list(eng.output(u).tokens) for u in uids]

    assert serve({"vql": head}) == serve({"w": lvq.expand(head)})


def test_preplan_covers_vql_nodes():
    head = lvq.synthetic_logits_vq(KEY, 64, 512, 16)
    params = {"lm_head": {"vql": head}}
    plans = plan_mod.preplan_params(params, _policy(), mode="decode", m=2,
                                    act_dtype=jnp.float32)
    assert any(pl.spec.kind == "vq_logits" for _, pl in plans)
