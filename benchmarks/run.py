"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The analytic accelerator
model (accel_model.py) mirrors the paper's simulator; `measured/*` rows
are real wall-clock CPU executions of the JAX ops.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        fig8_dse, fig10_decode, fig11_batch, fig12_e2e, fig14_spurious,
        measured, tbl_iii_vq_configs, tbl_v_accuracy_proxy,
        tbl_viii_throughput, tbl_x_oc_advantage,
    )

    print("name,us_per_call,derived")

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.3f},{derived}", flush=True)

    modules = [
        ("tbl_iii", tbl_iii_vq_configs),
        ("fig8", fig8_dse),
        ("tbl_viii", tbl_viii_throughput),
        ("fig10", fig10_decode),
        ("fig11", fig11_batch),
        ("fig12", fig12_e2e),
        ("fig14", fig14_spurious),
        ("tbl_x", tbl_x_oc_advantage),
        ("tbl_v", tbl_v_accuracy_proxy),
        ("measured", measured),
    ]
    failures = []
    for name, mod in modules:
        try:
            mod.run(report)
        except Exception as e:  # keep the harness running
            failures.append((name, e))
            report(f"{name}/ERROR", -1.0, f"{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
