"""Continuous-batching serving engine.

The EVA deployment shape (paper §V-C / Fig. 7(c)): prefill runs per-request
(INT8 GEMM path), decode runs as one batched step over all active slots so
every streamed weight-index tile is reused across requests. Slots free up
as requests finish and queued requests are admitted with a fresh prefill —
classic continuous batching, expressed with jit-stable shapes (fixed slot
count, fixed cache capacity).

All caches are batched on axis 1 (axis 0 is the scanned layer/group axis),
so slot insertion is a tree-wide dynamic_update_slice at index b.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod
from repro.models.api import Model
from repro.models.common import RunConfig
from repro.serve.kvcache import pad_prefill_cache
from repro.serve.scheduler import Request, Scheduler

log = logging.getLogger(__name__)


def _insert_slot(batched: Any, single: Any, b: int) -> Any:
    """Write a single-request cache (batch size 1 at axis 1) into slot b of
    the batched cache tree."""

    def one(dst, src):
        idx = [0] * dst.ndim
        idx[1] = b
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), tuple(idx))

    return jax.tree_util.tree_map(one, batched, single)


@dataclasses.dataclass
class EngineConfig:
    num_slots: int = 4
    max_len: int = 256
    greedy: bool = True
    eos_id: int = -1              # <0: run to max_new_tokens


class Engine:
    def __init__(self, model: Model, params: Any, rc: RunConfig,
                 ecfg: EngineConfig, extras: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.rc = rc
        self.ecfg = ecfg
        self.extras = extras or {}
        self.sched = Scheduler(ecfg.num_slots)
        cfg = model.cfg
        self.window = cfg.sliding_window or cfg.local_window
        self.caches = model.init_cache(ecfg.num_slots, ecfg.max_len)
        self.positions = np.zeros((ecfg.num_slots,), np.int64)
        self.last_token = np.zeros((ecfg.num_slots,), np.int64)

        # Plan once at slot capacity. The decode entries are exact: the
        # batched step always runs at M = num_slots tokens in flight, so
        # this warms the Planner cache before the first trace (the traced
        # step then only hits it). The prefill entries are capacity-bound
        # ESTIMATES at M = max_len — real prefills trace at the prompt
        # length and plan on demand (regime choices like direct-vs-recon
        # flip with M) — logged for introspection, labeled as such.
        self.plans: Dict[str, Any] = {
            "decode": plan_mod.preplan_params(
                params, rc.policy, mode="decode", m=ecfg.num_slots,
                act_dtype=cfg.act_dtype),
            "prefill@cap": plan_mod.preplan_params(
                params, rc.policy, mode="prefill", m=ecfg.max_len,
                act_dtype=cfg.act_dtype),
        }
        for phase, plans in self.plans.items():
            uniq: Dict[str, int] = {}
            rankings: Dict[str, int] = {}
            for _path, pl in plans:
                uniq[pl.describe()] = uniq.get(pl.describe(), 0) + 1
                rk = pl.describe_ranking()
                if rk:  # >1 eligible backend: show the predicted-time order
                    rankings[rk] = rankings.get(rk, 0) + 1
            for desc, count in sorted(uniq.items()):
                log.info("%s plan [%d leaves] %s", phase, count, desc)
            for rk, count in sorted(rankings.items()):
                log.info("%s ranking [%d leaves] %s", phase, count, rk)

        self._decode_fn = jax.jit(
            functools.partial(self._decode_impl, rc=rc.replace(mode="decode")),
        )

    # ------------------------------------------------------------- prefill
    def _prefill_one(self, slot: int, req: Request):
        rc_p = self.rc.replace(mode="prefill")
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        for k, v in self.extras.items():
            batch[k] = v[None] if v.ndim == 2 else v[:1]
        logits, cache = self.model.prefill(self.params, batch, rc_p)
        cache = pad_prefill_cache(
            cache, self.ecfg.max_len, window=self.window
        )
        self.caches = _insert_slot(self.caches, cache, slot)
        tok = int(np.argmax(np.asarray(logits[0, -1])))
        req.generated.append(tok)
        self.positions[slot] = req.prompt_len
        self.last_token[slot] = tok

    def _stopped(self, req: Request) -> bool:
        """Stopping condition over the tokens generated so far."""
        return len(req.generated) >= req.max_new_tokens or (
            self.ecfg.eos_id >= 0 and bool(req.generated)
            and req.generated[-1] == self.ecfg.eos_id
        )

    # -------------------------------------------------------------- decode
    def _decode_impl(self, params, tokens, positions, caches, *, rc):
        logits, new_caches = self.model.decode(params, tokens, positions, caches, rc)
        next_tok = jnp.argmax(logits[:, 0, : self.model.cfg.vocab_size], axis=-1)
        return next_tok, new_caches

    def step(self) -> List[Request]:
        """One engine tick: admit+prefill new requests, one batched decode
        step, retire finished requests. Returns finished requests.

        A request retires in the SAME step its stopping condition is met
        (eos emitted / max_new_tokens reached) — including straight out of
        prefill — so it never occupies a slot for an extra batched decode
        step. Free slots are masked out of the decode inputs (token 0 at
        position 0) instead of replaying their stale last_token."""
        finished: List[Request] = []
        for slot in self.sched.admit():
            req = self.sched.slots[slot]
            self._prefill_one(slot, req)
            # eos in the prefill-sampled token / max_new_tokens == 1:
            # retire before the request joins a decode batch at all
            if self._stopped(req):
                finished.append(self.sched.finish(slot))

        active = self.sched.active_slots()
        if active:
            mask = np.zeros_like(self.last_token, dtype=bool)
            mask[active] = True
            tokens = jnp.asarray(np.where(mask, self.last_token, 0)[:, None],
                                 jnp.int32)
            positions = jnp.asarray(np.where(mask, self.positions, 0)[:, None],
                                    jnp.int32)
            next_tok, self.caches = self._decode_fn(
                self.params, tokens, positions, self.caches
            )
            next_tok = np.asarray(next_tok)
            for b in active:
                req = self.sched.slots[b]
                self.positions[b] += 1
                req.generated.append(int(next_tok[b]))
                self.last_token[b] = int(next_tok[b])
                # retire in the step the stopping condition is met — the
                # slot is free for admission on the next tick
                if self._stopped(req):
                    finished.append(self.sched.finish(b))
        return finished

    # ---------------------------------------------------------- high level
    def generate(self, prompts: List[np.ndarray], max_new_tokens: int
                 ) -> Dict[int, List[int]]:
        uids = [self.sched.submit(p, max_new_tokens) for p in prompts]
        results: Dict[int, List[int]] = {}
        guard = 0
        while not self.sched.idle:
            for req in self.step():
                results[req.uid] = req.generated[:req.max_new_tokens]
            guard += 1
            if guard > 100000:  # pragma: no cover
                raise RuntimeError("engine did not converge")
        # order results by submission
        return {u: results[u] for u in uids}
