"""xLSTM family (arXiv:2405.04517): alternating mLSTM / sLSTM blocks.

mLSTM: matrix memory C (hd x hd per head) with exponential input gate and
sigmoid forget gate; PARALLELIZABLE — we implement both the sequential
recurrence (decode + oracle) and a chunkwise-parallel prefill/train form
(intra-chunk quadratic attention-like computation + inter-chunk state
scan), property-tested against each other.

sLSTM: scalar memory with exponential gating and block-diagonal (per-head)
recurrent weights — inherently sequential; prefill/train scan over time.

Block structure (d_ff = 0 per the assignment — projections live inside the
blocks):
  mLSTM block: x + down( mLSTM(up_h(norm(x))) * silu(up_g(norm(x))) )
  sLSTM block: x + out( sLSTM(norm(x)) ), then x + ffn(norm(x)) (pf=4/3)
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig, RunConfig

MLSTM_PF = 2      # mLSTM up-projection factor
SLSTM_PF = 4 / 3  # sLSTM FFN projection factor


def _di(cfg):  # mLSTM inner dim
    return MLSTM_PF * cfg.d_model


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def make_mlstm_block(key, cfg: ModelConfig) -> Any:
    di = _di(cfg)
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": cm.make_rmsnorm(cfg.d_model),
        "up_h": cm.make_linear(ks[0], cfg.d_model, di),
        "up_g": cm.make_linear(ks[1], cfg.d_model, di),
        "wq": cm.make_linear(ks[2], di, di),
        "wk": cm.make_linear(ks[3], di, di),
        "wv": cm.make_linear(ks[4], di, di),
        "w_if": cm.make_linear(ks[5], di, 2 * H, bias=True),  # i~, f~ per head
        "down": cm.make_linear(ks[6], di, cfg.d_model),
    }


def _mlstm_gates(p, h, H):
    g = cm.linear(p["w_if"], h, RunConfig(mode="train"))  # gates stay dense
    gi, gf = jnp.split(g.astype(jnp.float32), 2, axis=-1)  # (B,S,H) each
    return gi, jax.nn.log_sigmoid(gf)  # log f in (-inf, 0)


def mlstm_sequential(q, k, v, log_i, log_f, state):
    """Reference recurrence. q/k/v: (B,S,H,hd); log_i/log_f: (B,S,H);
    state: dict(C (B,H,hd,hd), n (B,H,hd), m (B,H)). Returns (out, state)."""
    B, S, H, hd = q.shape
    qs = q.astype(jnp.float32) / math.sqrt(hd)

    def step(st, xs):
        C, n, m = st
        qt, kt, vt, li, lf = xs  # (B,H,hd), ..., (B,H)
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        C = f_[..., None, None] * C + i_[..., None, None] * (
            vt[..., :, None] * kt[..., None, :]
        )  # (B,H,hd_v,hd_k)
        n = f_[..., None] * n + i_[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (
        qs.transpose(1, 0, 2, 3), k.astype(jnp.float32).transpose(1, 0, 2, 3),
        v.astype(jnp.float32).transpose(1, 0, 2, 3),
        log_i.transpose(1, 0, 2), log_f.transpose(1, 0, 2),
    )
    (C, n, m), hs = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
    return hs.transpose(1, 0, 2, 3), {"C": C, "n": n, "m": m}


def mlstm_chunkwise(q, k, v, log_i, log_f, state, *, chunk: int = 256):
    """Chunkwise-parallel mLSTM (production prefill/train path).

    Per chunk of length L: intra-chunk contributions via a stabilized
    quadratic form (like attention with a decay mask), inter-chunk state
    carried with a scan. Property-tested against mlstm_sequential.
    """
    B, S, H, hd = q.shape
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    nc = q.shape[1] // L

    def to_chunks(x):
        return x.reshape(B, nc, L, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))

    qc = to_chunks(q.astype(jnp.float32) / math.sqrt(hd))
    kc = to_chunks(k.astype(jnp.float32))
    vc = to_chunks(v.astype(jnp.float32))
    lic = to_chunks(log_i)
    lfc = to_chunks(log_f)

    def chunk_step(st, xs):
        C, n, m = st                       # (B,H,hd,hd), (B,H,hd), (B,H)
        qt, kt, vt, li, lf = xs            # (B,L,H,hd), ..., (B,L,H)
        csum = jnp.cumsum(lf, axis=1)      # inclusive cumsum of log f
        # decay from chunk start to position t (inclusive of f_t)
        b = csum                           # (B,L,H)
        total = csum[:, -1]                # (B,H)
        # stabilizers
        # m_intra[t] = max_{s<=t} (b_t - b_s + li_s); m_state[t] = b_t + m
        a = li - csum                      # (B,L,H): li_s - b_s
        m_intra = jax.lax.cummax(a, axis=1) + b
        m_state = b + m[:, None, :]
        m_t = jnp.maximum(m_intra, m_state)             # (B,L,H)
        # inter-chunk (state) contribution
        w_state = jnp.exp(m_state - m_t)                # (B,L,H)
        num_state = jnp.einsum("bhvk,blhk->blhv", C, qt) * w_state[..., None]
        den_state = jnp.einsum("bhk,blhk->blh", n, qt) * w_state
        # intra-chunk contribution: D[t,s] = exp(b_t - b_s + li_s - m_t), s<=t
        Dlog = b[:, :, None, :] - b[:, None, :, :] + li[:, None, :, :]  # (B,t,s,H)
        mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, :, :, None]
        Dlog = jnp.where(mask, Dlog, -1e30)
        D = jnp.exp(Dlog - m_t[:, :, None, :])          # (B,t,s,H)
        scores = jnp.einsum("blhk,bshk->blsh", qt, kt) * D
        num_intra = jnp.einsum("blsh,bshv->blhv", scores, vt)
        den_intra = scores.sum(axis=2)                   # (B,L,H)
        num = num_state + num_intra
        den = den_state + den_intra
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to end of chunk
        m_end = jnp.maximum(total + m, jax.lax.cummax(a, axis=1)[:, -1] + total)
        # decay of old state: exp(total + m - m_end)
        sdec = jnp.exp(total + m - m_end)                # (B,H)
        # each position s contributes exp(total - b_s + li_s - m_end)
        w_s = jnp.exp(total[:, None] - b + li - m_end[:, None])  # (B,L,H)
        C_new = sdec[..., None, None] * C + jnp.einsum(
            "bshv,bshk->bhvk", vt * w_s[..., None], kt
        )
        n_new = sdec[..., None] * n + jnp.einsum("bshk,bsh->bhk", kt, w_s)
        return (C_new, n_new, m_end), h

    (C, n, m), hs = jax.lax.scan(
        chunk_step, (state["C"], state["n"], state["m"]), (qc, kc, vc, lic, lfc)
    )
    out = hs.transpose(1, 0, 2, 3, 4).reshape(B, nc * L, H, hd)[:, :S]
    return out, {"C": C, "n": n, "m": m}


def mlstm_block_fwd(p, x, rc: RunConfig, cfg: ModelConfig, state=None):
    B, S, D = x.shape
    H = cfg.num_heads
    di = _di(cfg)
    hd = di // H
    xn = cm.rmsnorm(p["norm"], x, cfg.norm_eps)
    h = cm.linear(p["up_h"], xn, rc)
    g = cm.linear(p["up_g"], xn, rc)
    if "wqkv" in p:
        # grouped q/k/v (all consume h; quantize pass family anchored by
        # the "w_if" sibling): one wide EVA matmul, outputs sliced at the
        # recorded (di, di, di) split points.
        q, k, v = cm.grouped_linear(p["wqkv"], h, rc)
    else:
        q, k, v = (cm.linear(p[w], h, rc) for w in ("wq", "wk", "wv"))
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, H, hd)
    v = v.reshape(B, S, H, hd)
    log_i, log_f = _mlstm_gates(p, h, H)

    if state is None:
        state = init_mlstm_state(cfg, B)
    if rc.mode == "decode":
        out, new_state = mlstm_sequential(q, k, v, log_i, log_f, state)
    else:
        out, new_state = mlstm_chunkwise(q, k, v, log_i, log_f, state,
                                         chunk=min(rc.attn_chunk, 256))
    out = out.reshape(B, S, di).astype(x.dtype) * jax.nn.silu(g)
    y = cm.linear(p["down"], out, rc)
    new_state = new_state if rc.mode in ("decode", "prefill") else None
    return x + y, new_state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    H = cfg.num_heads
    hd = _di(cfg) // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def make_slstm_block(key, cfg: ModelConfig) -> Any:
    D, H = cfg.d_model, cfg.num_heads
    hd = D // H
    ks = jax.random.split(key, 8)
    d_ffn = int(SLSTM_PF * D) // 8 * 8
    return {
        "norm": cm.make_rmsnorm(D),
        "wz": cm.make_linear(ks[0], D, D, bias=True),
        "wi": cm.make_linear(ks[1], D, H, bias=True),
        "wf": cm.make_linear(ks[2], D, H, bias=True),
        "wo": cm.make_linear(ks[3], D, D, bias=True),
        # block-diagonal recurrent weights, per head (kept dense, small)
        "rz": jax.random.normal(ks[4], (H, hd, hd), jnp.float32) / math.sqrt(hd),
        "out": cm.make_linear(ks[5], D, D),
        "ffn_norm": cm.make_rmsnorm(D),
        "ffn": cm.make_gelu_mlp(ks[6], D, d_ffn),
    }


def slstm_scan(p, z_in, i_in, f_in, o_in, state, H, hd):
    """Sequential sLSTM. *_in: (B, S, ...) preactivations from the input;
    the recurrent contribution (R h) is added inside the scan."""
    B, S, D = z_in.shape

    def step(st, xs):
        c, n, hprev, m = st                     # (B,H,hd),(B,H,hd),(B,H,hd),(B,H)
        zt, it, ft, ot = xs                     # (B,D),(B,H),(B,H),(B,D)
        rec = jnp.einsum("bhk,hvk->bhv", hprev, p["rz"])  # (B,H,hd)
        z = jnp.tanh(zt.reshape(B, H, hd) + rec)
        li = it                                  # log-space input gate preact
        lf = jax.nn.log_sigmoid(ft)              # sigmoid forget (stable)
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c = f_[..., None] * c + i_[..., None] * z
        n = f_[..., None] * n + i_[..., None]
        h = jax.nn.sigmoid(ot.reshape(B, H, hd)) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    xs = (
        z_in.transpose(1, 0, 2).astype(jnp.float32),
        i_in.transpose(1, 0, 2).astype(jnp.float32),
        f_in.transpose(1, 0, 2).astype(jnp.float32),
        o_in.transpose(1, 0, 2).astype(jnp.float32),
    )
    st0 = (state["c"], state["n"], state["h"], state["m"])
    (c, n, h, m), hs = jax.lax.scan(step, st0, xs)
    return hs.transpose(1, 0, 2, 3), {"c": c, "n": n, "h": h, "m": m}


def slstm_block_fwd(p, x, rc: RunConfig, cfg: ModelConfig, state=None):
    B, S, D = x.shape
    H = cfg.num_heads
    hd = D // H
    xn = cm.rmsnorm(p["norm"], x, cfg.norm_eps)
    z_in = cm.linear(p["wz"], xn, rc)
    i_in = cm.linear(p["wi"], xn, rc, out_dtype=jnp.float32)
    f_in = cm.linear(p["wf"], xn, rc, out_dtype=jnp.float32)
    o_in = cm.linear(p["wo"], xn, rc)
    if state is None:
        state = init_slstm_state(cfg, B)
    hs, new_state = slstm_scan(p, z_in, i_in, f_in, o_in, state, H, hd)
    y = cm.linear(p["out"], hs.reshape(B, S, D).astype(x.dtype), rc)
    x = x + y
    h2 = cm.rmsnorm(p["ffn_norm"], x, cfg.norm_eps)
    x = x + cm.gelu_mlp_fwd(p["ffn"], h2, rc)
    new_state = new_state if rc.mode in ("decode", "prefill") else None
    return x, new_state


def init_slstm_state(cfg: ModelConfig, batch: int):
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": jnp.zeros((batch, H), jnp.float32)}


# ---------------------------------------------------------------------------
# full model: pattern = ("mlstm", "slstm") * (L/2)
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Any:
    period = len(cfg.xlstm_pattern)
    assert cfg.num_layers % period == 0
    n_groups = cfg.num_layers // period
    ks = jax.random.split(key, 4)

    def group_init(k):
        gks = jax.random.split(k, period)
        g = {}
        for i, kind in enumerate(cfg.xlstm_pattern):
            maker = make_mlstm_block if kind == "mlstm" else make_slstm_block
            g[f"b{i}_{kind}"] = maker(gks[i], cfg)
        return g

    return {
        "embedding": cm.make_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
        "groups": jax.vmap(group_init)(jax.random.split(ks[1], n_groups)),
        "final_norm": cm.make_rmsnorm(cfg.d_model),
        "lm_head": cm.make_linear(ks[2], cfg.d_model, cfg.padded_vocab),
    }


def _group_fwd(gp, x, rc, cfg, cache):
    new_cache = {}
    for i, kind in enumerate(cfg.xlstm_pattern):
        name = f"b{i}_{kind}"
        st = None if cache is None else cache[name]
        if kind == "mlstm":
            x, ns = mlstm_block_fwd(gp[name], x, rc, cfg, st)
        else:
            x, ns = slstm_block_fwd(gp[name], x, rc, cfg, st)
        new_cache[name] = ns
    return x, (new_cache if rc.mode in ("decode", "prefill") else None)


def forward(params, tokens, rc: RunConfig, cfg: ModelConfig, *,
            positions=None, caches=None):
    B, S = tokens.shape
    x = cm.embed(params["embedding"], tokens, cfg.act_dtype)

    body = functools.partial(_group_fwd, rc=rc, cfg=cfg)

    def step(carry, xs):
        gp, cache = xs
        if rc.remat and rc.mode == "train":
            fn = jax.checkpoint(
                lambda g_, x_: body(g_, x_, cache=None),
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
            y, nc = fn(gp, carry)
        else:
            y, nc = body(gp, carry, cache=cache)
        return y, nc

    if caches is None:
        x, new_caches = jax.lax.scan(lambda c, gp: step(c, (gp, None)), x, params["groups"])
    else:
        x, new_caches = jax.lax.scan(step, x, (params["groups"], caches))

    if rc.mode == "prefill" and rc.lm_head_last_only:
        x = x[:, -1:]  # §Perf: skip the vocab projection for prompt tokens
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.lm_head(params["lm_head"], x, rc)
    out = new_caches if caches is not None or rc.mode == "prefill" else None
    return logits, out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Any:
    period = len(cfg.xlstm_pattern)
    n_groups = cfg.num_layers // period

    def one(_):
        g = {}
        for i, kind in enumerate(cfg.xlstm_pattern):
            if kind == "mlstm":
                g[f"b{i}_{kind}"] = init_mlstm_state(cfg, batch)
            else:
                g[f"b{i}_{kind}"] = init_slstm_state(cfg, batch)
        return g

    return jax.vmap(one)(jnp.arange(n_groups))
