"""Calibrated per-backend cost models for the ranked Planner.

`core/plan.py`'s Planner collects every backend whose matcher accepts a
(LinearSpec, PlanPolicy) pair and picks the one with the LOWEST predicted
execution time. The prediction is a four-term linear model over the
plan's analytic `PlanCost`:

    t_us = overhead_us * launches
         + macs                                  * us_per_mac
         + lookup_adds                           * us_per_add
         + (weight_bytes + intermediate_bytes)   * us_per_byte

The four constants are PER BACKEND. They come from one of two places:

  * calibrated : `fit_calibration()` fits them (non-negative least
    squares) from measured benchmark rows — `benchmarks/run.py measured
    --json BENCH_measured.json` emits `backend=`/`macs=`/`lookup_adds=`/
    `weight_bytes=` per row exactly for this — and `save_calibration()`
    persists them as a versioned CALIBRATION.json. Interpret-mode rows
    (CPU emulation of the Pallas kernels) are EXCLUDED from fitting:
    their timings say nothing about the kernels' real cost.
  * analytic   : when CALIBRATION.json is absent (or a backend has no
    fitted entry) the shared `ANALYTIC` constants apply — order-of-
    magnitude CPU-host rates whose only hard requirement is a
    deterministic ranking. The chosen provenance is recorded on the
    MatmulPlan (`describe()` prints it), so every log/bench row says
    which model ranked it.

The default calibration file is `CALIBRATION.json` in the current
working directory; override with the EVA_CALIBRATION environment
variable. `Planner` loads it at construction and
`Planner.reload_calibration()` swaps it without invalidating cached
plans (plan identity is independent of the cost model).

CLI — refit from a committed bench file:

    PYTHONPATH=src python -m repro.core.calibrate BENCH_measured.json \
        -o CALIBRATION.json
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

SCHEMA = "eva-calibration/v1"
DEFAULT_PATH = "CALIBRATION.json"
ENV_VAR = "EVA_CALIBRATION"

# Derived-row fields a bench row must carry to be a calibration sample
# (emitted by benchmarks/measured.py + benchmarks/smoke.py; enforced by
# benchmarks/schema.py so the committed BENCH_measured.json stays
# machine-readable for fitting).
COST_FIELDS = ("macs", "lookup_adds", "weight_bytes")

# Fewest samples a fitted entry needs before the Planner trusts it for
# ranking: the model has 4 free parameters, so an NNLS over fewer rows
# fits its samples perfectly (mean_abs_rel_err ~ 0) while the individual
# constants are arbitrary splits of the total. Entries below the floor
# are still persisted (with their honest `rows` count) for inspection —
# `Planner._usable_entry` just declines to rank with them.
MIN_FIT_ROWS = 4


@dataclasses.dataclass(frozen=True)
class BackendCalibration:
    """Fitted constants of one backend's time model (all microseconds)."""

    overhead_us: float
    us_per_mac: float
    us_per_add: float
    us_per_byte: float
    rows: int = 0                  # samples the fit used (0 = analytic)
    mean_abs_rel_err: float = 0.0  # fit quality over its own samples


# Analytic fallback: order-of-magnitude CPU-host rates. Only the RANKING
# these produce matters (it must be deterministic); absolute numbers are
# provenance-labeled "analytic" everywhere they surface. The byte and
# launch terms make the two-kernel split backend analytically more
# expensive than the fused kernel (it round-trips the (C, M, V, 2^n)
# intermediate through HBM and launches twice), which matches the
# paper's no-fusion-cost argument — measured calibration can flip it.
ANALYTIC = BackendCalibration(
    overhead_us=50.0,      # per kernel launch / dispatch
    us_per_mac=2e-4,       # ~5 GMAC/s
    us_per_add=2e-3,       # ~0.5 G gather-adds/s
    us_per_byte=1e-4,      # ~10 GB/s
)


@dataclasses.dataclass(frozen=True)
class Calibration:
    """A versioned set of per-backend fitted constants."""

    version: str
    source: str
    backends: Mapping[str, BackendCalibration]

    def get(self, backend: str) -> Optional[BackendCalibration]:
        return self.backends.get(backend)


def predict_us(cost: Any, entry: BackendCalibration) -> float:
    """Predicted execution time (us) of a plan's `PlanCost` under one
    backend's constants. `cost` is duck-typed (macs / lookup_adds /
    weight_bytes / intermediate_bytes / launches)."""
    return (
        entry.overhead_us * getattr(cost, "launches", 1)
        + cost.macs * entry.us_per_mac
        + cost.lookup_adds * entry.us_per_add
        + (cost.weight_bytes + getattr(cost, "intermediate_bytes", 0))
        * entry.us_per_byte
    )


def _nnls(A: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Non-negative least squares by greedy column dropping: solve the
    unconstrained lstsq, zero the most-negative coefficient, repeat.
    Deterministic, dependency-free, adequate for the handful of bench
    rows per backend."""
    active = list(range(A.shape[1]))
    coef = np.zeros(A.shape[1])
    while active:
        sol, *_ = np.linalg.lstsq(A[:, active], y, rcond=None)
        if (sol >= 0).all():
            for j, c in zip(active, sol):
                coef[j] = float(c)
            return coef
        active.pop(int(np.argmin(sol)))
    return coef


def _row_features(derived: Mapping[str, Any]) -> np.ndarray:
    return np.array([
        float(derived.get("launches", 1)),
        float(derived["macs"]),
        float(derived["lookup_adds"]),
        float(derived["weight_bytes"]) + float(derived.get("intermediate_bytes", 0)),
    ])


def eligible_rows(doc: Mapping[str, Any]) -> List[Tuple[str, np.ndarray, float]]:
    """(backend, features, us) samples from a bench-rows document.

    A row qualifies when it carries `backend` plus every COST_FIELDS
    entry, timed a real (non-interpret, non-failed) execution."""
    out = []
    for row in doc.get("rows", ()):
        derived = row.get("derived") or {}
        if not isinstance(derived, dict):
            continue
        backend = derived.get("backend")
        us = row.get("us_per_call", -1.0)
        if (not backend or us is None or us <= 0
                or derived.get("interpret")
                or any(f not in derived for f in COST_FIELDS)):
            continue
        out.append((str(backend), _row_features(derived), float(us)))
    return out


def fit_calibration(doc: Mapping[str, Any], *, source: str = "<inline>"
                    ) -> Calibration:
    """Fit per-backend constants from an `eva-bench-rows/v1` document."""
    by_backend: Dict[str, List[Tuple[np.ndarray, float]]] = {}
    for backend, feat, us in eligible_rows(doc):
        by_backend.setdefault(backend, []).append((feat, us))

    backends: Dict[str, BackendCalibration] = {}
    for backend, samples in sorted(by_backend.items()):
        A = np.stack([f for f, _ in samples])
        y = np.array([t for _, t in samples])
        coef = _nnls(A, y)
        pred = A @ coef
        rel = np.abs(pred - y) / np.maximum(y, 1e-9)
        backends[backend] = BackendCalibration(
            overhead_us=float(coef[0]), us_per_mac=float(coef[1]),
            us_per_add=float(coef[2]), us_per_byte=float(coef[3]),
            rows=len(samples), mean_abs_rel_err=float(rel.mean()),
        )
    return Calibration(version=SCHEMA, source=source, backends=backends)


def fit_calibration_file(bench_path: str) -> Calibration:
    with open(bench_path) as f:
        doc = json.load(f)
    return fit_calibration(doc, source=os.path.basename(bench_path))


# ---------------------------------------------------------------------------
# Persistence
# ---------------------------------------------------------------------------


def save_calibration(calib: Calibration, path: str) -> None:
    doc = {
        "schema": calib.version,
        "source": calib.source,
        "backends": {
            name: dataclasses.asdict(entry)
            for name, entry in sorted(calib.backends.items())
        },
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


def load_calibration(path: str) -> Optional[Calibration]:
    """Load a CALIBRATION.json; None when missing, unreadable or the
    version doesn't match (analytic fallback stays in force — a stale
    incompatible file must never poison ranking silently)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    if doc.get("schema") != SCHEMA:
        return None
    backends = {}
    try:
        for name, entry in doc.get("backends", {}).items():
            backends[name] = BackendCalibration(
                overhead_us=float(entry["overhead_us"]),
                us_per_mac=float(entry["us_per_mac"]),
                us_per_add=float(entry["us_per_add"]),
                us_per_byte=float(entry["us_per_byte"]),
                rows=int(entry.get("rows", 0)),
                mean_abs_rel_err=float(entry.get("mean_abs_rel_err", 0.0)),
            )
    except (KeyError, TypeError, ValueError):
        return None
    return Calibration(version=SCHEMA, source=str(doc.get("source", path)),
                       backends=backends)


def default_calibration_path() -> str:
    return os.environ.get(ENV_VAR, DEFAULT_PATH)


def load_default_calibration() -> Optional[Calibration]:
    return load_calibration(default_calibration_path())


def main(argv: Optional[Sequence[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="Fit CALIBRATION.json from a bench-rows JSON")
    ap.add_argument("bench", help="BENCH_measured.json (eva-bench-rows/v1)")
    ap.add_argument("-o", "--out", default=DEFAULT_PATH)
    args = ap.parse_args(list(argv) if argv is not None else None)
    calib = fit_calibration_file(args.bench)
    save_calibration(calib, args.out)
    for name, e in sorted(calib.backends.items()):
        print(f"{name:20s} rows={e.rows:2d} overhead={e.overhead_us:10.1f}us "
              f"mac={e.us_per_mac:.3e} add={e.us_per_add:.3e} "
              f"byte={e.us_per_byte:.3e} err={e.mean_abs_rel_err:.1%}")
    print(f"wrote {args.out} ({len(calib.backends)} backends, "
          f"source={calib.source})")


if __name__ == "__main__":
    main()
