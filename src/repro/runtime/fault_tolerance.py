"""Fault-tolerance machinery: step watchdog / straggler detection and the
checkpoint-restart driver loop.

At 1000+ nodes the failure model is: (a) hard node loss -> training raises
(collective timeout / data host gone) -> restart from the last committed
checkpoint, possibly at smaller world size (elastic.py); (b) stragglers ->
per-step wall time watchdog flags hosts whose step time exceeds
median * threshold so the scheduler can evict them.

This module is hardware-agnostic: failures are injected in tests through
the data pipeline (`fail_at`) and through a step callback.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time: float
    median: float
    ratio: float
    is_straggler: bool


class StepWatchdog:
    """Tracks per-step wall time; flags stragglers vs the rolling median.

    On a real deployment each host feeds its own step times and the
    controller aggregates; here the same logic runs host-local.
    """

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 warmup_steps: int = 5):
        self.window: Deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.warmup_steps = warmup_steps
        self.reports: List[StragglerReport] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self) -> StragglerReport:
        assert self._t0 is not None, "start_step not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        self._step += 1
        med = sorted(self.window)[len(self.window) // 2] if self.window else dt
        ratio = dt / max(med, 1e-9)
        is_straggler = (self._step > self.warmup_steps
                        and len(self.window) >= 5
                        and ratio > self.threshold)
        # stragglers don't poison the baseline window
        if not is_straggler:
            self.window.append(dt)
        rep = StragglerReport(self._step, dt, med, ratio, is_straggler)
        self.reports.append(rep)
        return rep

    @property
    def straggler_steps(self) -> List[int]:
        return [r.step for r in self.reports if r.is_straggler]


@dataclasses.dataclass
class RestartStats:
    restarts: int = 0
    last_resume_step: int = 0
    failures: List[str] = dataclasses.field(default_factory=list)


def run_with_restarts(
    train_loop: Callable[[int], int],
    *,
    max_restarts: int = 3,
    on_failure: Optional[Callable[[Exception, int], int]] = None,
) -> RestartStats:
    """Drive `train_loop(start_step) -> last_step` with checkpoint-restart.

    `train_loop` must raise on failure and is expected to resume from the
    last committed checkpoint (it receives the resume step returned by
    `on_failure`, default: same step). Mirrors the controller loop a real
    cluster runs around the SPMD program.

    Only exceptions raised by `train_loop` itself count as training
    failures. An exception raised by the `on_failure` callback is a
    CONTROLLER bug, not a node loss: it propagates directly — unwrapped,
    not recorded in `failures`, and without Python's implicit
    "during handling of the above exception" chaining (the callback runs
    outside the except block), so callers can tell the two apart.
    `last_resume_step` is updated on every restart, callback or not.
    """
    stats = RestartStats()
    start_step = 0
    while True:
        try:
            train_loop(start_step)
            return stats
        except Exception as e:  # noqa: BLE001 - controller catches anything
            err = e
        stats.restarts += 1
        stats.failures.append(f"{type(err).__name__}: {err}")
        if stats.restarts > max_restarts:
            raise RuntimeError(
                f"exceeded {max_restarts} restarts; last: {err}"
            ) from err
        if on_failure is not None:
            # callback errors propagate from HERE, outside the except
            # block: no implicit exception chaining, no burned restart
            # recorded against the training loop
            start_step = on_failure(err, stats.restarts)
        stats.last_resume_step = start_step
