from repro.serve.engine import Engine, EngineConfig
from repro.serve.kvcache import pad_prefill_cache, cache_bytes
from repro.serve.scheduler import Request, Scheduler
