"""Fig. 14: spurious computations — codebook (centroid) utilization.

Paper's analysis: with a uniform index distribution the expected number of
utilized centroids is E[U] = 2^n (1 - (1 - 2^-n)^N); at 2^n=256, N=1024
that's 98.2% (they observe 97.11%). We check both the formula and the
empirical utilization of (a) uniform synthetic indices and (b) k-means
fitted indices (the entropy argument: good VQ drives indices uniform).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vq import fit_vq, synthetic_vq


def expected_utilization(n: int, N: int) -> float:
    k = 2 ** n
    return 1.0 - (1.0 - 1.0 / k) ** N


def run(report):
    key = jax.random.PRNGKey(0)
    rows = []
    for N in (256, 512, 1024, 4096):
        th = expected_utilization(8, N)
        vq = synthetic_vq(key, 512, N, d=8, n=8, C=1)
        # utilization per v-row: fraction of centroids referenced by >=1
        # output channel
        idx = np.asarray(vq.idx[0])  # (V, N)
        used = np.mean([len(np.unique(r)) / 256.0 for r in idx])
        rows.append((N, th, used))
        report(f"fig14/N{N}", 0.0,
               f"theory={th:.4f};empirical={used:.4f}")
    # fitted indices on structured weights stay near-uniform (entropy arg)
    W = jax.random.normal(key, (256, 512)) * 0.2
    vq = fit_vq(key, W, d=8, n=6, C=1, kmeans_iters=8, refine_rounds=0)
    idx = np.asarray(vq.idx[0])
    hist = np.bincount(idx.reshape(-1), minlength=64)
    used_frac = (hist > 0).mean()
    # normalized entropy of the index distribution
    p = hist / hist.sum()
    ent = -(p[p > 0] * np.log(p[p > 0])).sum() / np.log(64)
    report("fig14/fitted_utilization", 0.0,
           f"used={used_frac:.3f};norm_entropy={ent:.3f}(paper: ~uniform)")
    return rows
