"""Jit'd wrapper for the dequant-GEMV baseline kernel + its plan backend.

Registers "dequant_pallas" with core/plan.py — before the plan API,
``vq_matmul(mode="dequant")`` silently dropped ``impl``/``interpret`` and
this kernel was unreachable from the model layers; a
``PlanPolicy(vq_mode="dequant", impl="pallas")`` now routes here."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import ops as core_ops
from repro.core import plan as plan_mod
from repro.core.vq import VQWeight
from repro.kernels.dequant_gemv.kernel import dequant_gemv_pallas
from repro.kernels.dequant_gemv.ref import dequant_gemv_ref


def _auto_tiles(M: int, V: int, N: int, d: int) -> Tuple[int, int]:
    """This kernel's VMEM footprint per grid step is the reconstructed
    weight slab (bv, bn, d) fp32 plus the (M, bv, d) x tile — no OC
    scratch — so it gets its own model rather than the fused kernel's:
    start at the paper's v=32 / 512-lane tiles and shrink bn, then bv,
    until 4*d*(bv*bn + M*bv) fits the tile budget."""
    bv, bn = min(32, V), min(512, N)
    while bn > 128 and 4 * d * (bv * bn + M * bv) > core_ops.FUSED_GATHER_TILE_BYTES:
        bn //= 2
    while bv > 8 and 4 * d * (bv * bn + M * bv) > core_ops.FUSED_GATHER_TILE_BYTES:
        bv //= 2
    return bv, min(bn, N)


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_n", "interpret", "use_pallas", "out_dtype")
)
def dequant_gemv(
    x: jax.Array,
    vq: VQWeight,
    *,
    block_v="auto",
    block_n="auto",
    interpret: bool = False,
    use_pallas: bool = True,
    out_dtype=None,
) -> jax.Array:
    """block_v/block_n accept "auto" or explicit ints; non-divisible V/N
    are padded (zeroed X rows gather index 0 -> contribute 0)."""
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    K, N, V, d, C = vq.K, vq.N, vq.V, vq.d, vq.C
    M = x.size // K
    X = x.reshape(M, V, d).astype(jnp.float32)
    cb = vq.codebooks.transpose(0, 2, 1).astype(jnp.float32)  # (C, k, d)
    # stream indices at storage width (uint8 for n<=8); in-kernel upcast
    I = vq.idx
    scale = vq.scale.astype(jnp.float32)

    if not use_pallas:
        y = dequant_gemv_ref(X, cb, I, scale)
        return y.reshape(*lead, N).astype(out_dtype)

    auto_bv, auto_bn = _auto_tiles(M, V, N, d)
    bv = auto_bv if block_v == "auto" else min(block_v, V)
    bn = auto_bn if block_n == "auto" else min(block_n, N)
    pad_v = (-V) % bv
    pad_n = (-N) % bn
    if pad_v:
        X = jnp.pad(X, ((0, 0), (0, pad_v), (0, 0)))
        I = jnp.pad(I, ((0, 0), (0, pad_v), (0, 0)))
    if pad_n:
        I = jnp.pad(I, ((0, 0), (0, 0), (0, pad_n)))
        scale = jnp.pad(scale, (0, pad_n))
    y = dequant_gemv_pallas(X, cb, I, scale, block_v=bv, block_n=bn, interpret=interpret)
    if pad_n:
        y = y[:, :N]
    return y.reshape(*lead, N).astype(out_dtype)


# ---------------------------------------------------------------------------
# Plan backend
# ---------------------------------------------------------------------------


def _match_dequant_pallas(spec: plan_mod.LinearSpec,
                          policy: plan_mod.PlanPolicy) -> bool:
    return (spec.kind == "vq" and policy.vq_mode == "dequant"
            and policy.impl == "pallas")


def _plan_dequant_pallas(spec: plan_mod.LinearSpec,
                         policy: plan_mod.PlanPolicy) -> plan_mod.MatmulPlan:
    auto_bv, auto_bn = _auto_tiles(spec.M, spec.V, spec.N, spec.d)
    bv = auto_bv if policy.block_v is None else min(policy.block_v, spec.V)
    bn = auto_bn
    out_dt = jnp.dtype(spec.out_dtype)
    interpret = policy.interpret

    def run(x, vq):
        return dequant_gemv(x, vq, block_v=bv, block_n=bn,
                            interpret=interpret, out_dtype=out_dt)

    cost = plan_mod.PlanCost(
        macs=spec.M * spec.K * spec.N,
        lookup_adds=spec.C * spec.V * spec.N * spec.d,
        weight_bytes=plan_mod.vq_weight_bytes(spec),
    )
    return plan_mod.MatmulPlan("dequant_pallas", spec, policy,
                               (("bv", bv), ("bn", bn)), cost, run)


plan_mod.register_backend("dequant_pallas", _match_dequant_pallas,
                          _plan_dequant_pallas)
