"""Llama-3.2-Vision family: a 40-layer GQA decoder where every 5th layer
is a gated cross-attention layer over image patch embeddings.

Per the assignment, the vision tower is a STUB: `input_specs()` provides
precomputed patch embeddings (B, n_img, d_model); the model applies only a
projection. Cross-attn layers use tanh-gated residuals (zero-init gates,
as in the reference implementation). 40 = 8 super-blocks x (4 self + 1
cross), scanned over super-blocks.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig, RunConfig

N_IMG_TOKENS = 1601  # one 448px tile -> (448/14)^2 + 1 = 1025; llama3.2 uses 1601


def _init_self_layer(key, cfg: ModelConfig) -> Any:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": cm.make_rmsnorm(cfg.d_model),
        "attn": cm.make_attention(ks[0], cfg),
        "mlp_norm": cm.make_rmsnorm(cfg.d_model),
        "mlp": cm.make_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def _init_cross_layer(key, cfg: ModelConfig) -> Any:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": cm.make_rmsnorm(cfg.d_model),
        "xattn": cm.make_attention(ks[0], cfg),
        "attn_gate": jnp.zeros((), jnp.float32),
        "mlp_norm": cm.make_rmsnorm(cfg.d_model),
        "mlp": cm.make_mlp(ks[1], cfg.d_model, cfg.d_ff),
        "mlp_gate": jnp.zeros((), jnp.float32),
        "qnorm": cm.make_rmsnorm(cfg.head_dim),
        "knorm": cm.make_rmsnorm(cfg.head_dim),
    }


def init_params(key, cfg: ModelConfig) -> Any:
    period = cfg.cross_attn_period             # 5
    assert cfg.num_layers % period == 0
    n_groups = cfg.num_layers // period        # 8
    ks = jax.random.split(key, 5)

    def group_init(k):
        gks = jax.random.split(k, period)
        g = {}
        for i in range(period - 1):
            g[f"self{i}"] = _init_self_layer(gks[i], cfg)
        g["cross"] = _init_cross_layer(gks[-1], cfg)
        return g

    return {
        "embedding": cm.make_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
        "img_proj": cm.make_linear(ks[1], cfg.d_model, cfg.d_model, bias=True),
        "groups": jax.vmap(group_init)(jax.random.split(ks[2], n_groups)),
        "final_norm": cm.make_rmsnorm(cfg.d_model),
        "lm_head": cm.make_linear(ks[3], cfg.d_model, cfg.padded_vocab),
    }


def _self_fwd(lp, x, rc, cfg, positions, cache):
    h = cm.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
    a, nc = cm.attention_fwd(lp["attn"], h, rc, cfg, positions=positions, cache=cache)
    x = x + a
    h = cm.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
    return x + cm.mlp_fwd(lp["mlp"], h, rc), nc


def _cross_fwd(lp, x, rc, cfg, img, cache):
    """Gated cross-attention over image tokens. At decode, image K/V come
    from the cache (computed during prefill)."""
    B, S, D = x.shape
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = cm.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
    q = cm.linear(lp["xattn"]["wq"], h, rc).reshape(B, S, H, hd)
    q = cm.rmsnorm(lp["qnorm"], q, cfg.norm_eps)

    if rc.mode == "decode" and cache is not None:
        k, v = cache["xk"], cache["xv"]
        o = cm.decode_attention(q, k, v, cache["xlen"])
        new_cache = cache
    else:
        k = cm.linear(lp["xattn"]["wk"], img, rc).reshape(B, -1, Hk, hd)
        k = cm.rmsnorm(lp["knorm"], k, cfg.norm_eps)
        v = cm.linear(lp["xattn"]["wv"], img, rc).reshape(B, -1, Hk, hd)
        o = cm.blocked_attention(q, k, v, causal=False, chunk=rc.attn_chunk)
        new_cache = None
        if rc.mode == "prefill":
            new_cache = {
                "xk": k, "xv": v,
                "xlen": jnp.full((B,), k.shape[1], jnp.int32),
            }
    a = cm.linear(lp["xattn"]["wo"], o.reshape(B, S, H * hd), rc)
    x = x + jnp.tanh(lp["attn_gate"]).astype(x.dtype) * a
    h = cm.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
    f = cm.mlp_fwd(lp["mlp"], h, rc)
    return x + jnp.tanh(lp["mlp_gate"]).astype(x.dtype) * f, new_cache


def _group_fwd(gp, x, rc, cfg, positions, img, cache):
    period = cfg.cross_attn_period
    new_cache = {}
    for i in range(period - 1):
        c = None if cache is None else cache[f"self{i}"]
        x, nc = _self_fwd(gp[f"self{i}"], x, rc, cfg, positions, c)
        new_cache[f"self{i}"] = nc
    c = None if cache is None else cache["cross"]
    x, nc = _cross_fwd(gp["cross"], x, rc, cfg, img, c)
    new_cache["cross"] = nc
    return x, (new_cache if rc.mode in ("decode", "prefill") else None)


def forward(params, tokens, rc: RunConfig, cfg: ModelConfig, *,
            image_embeds: Optional[jax.Array] = None,
            positions=None, caches=None):
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = cm.embed(params["embedding"], tokens, cfg.act_dtype)
    img = None
    if image_embeds is not None:
        img = cm.linear(params["img_proj"], image_embeds.astype(cfg.act_dtype), rc)

    body = functools.partial(_group_fwd, rc=rc, cfg=cfg, positions=positions, img=img)

    def step(carry, xs):
        gp, cache = xs
        if rc.remat and rc.mode == "train":
            fn = jax.checkpoint(
                lambda g_, x_: body(g_, x_, cache=None),
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
            y, nc = fn(gp, carry)
        else:
            y, nc = body(gp, carry, cache=cache)
        return y, nc

    if caches is None:
        x, new_caches = jax.lax.scan(lambda c, gp: step(c, (gp, None)), x, params["groups"])
    else:
        x, new_caches = jax.lax.scan(step, x, (params["groups"], caches))

    if rc.mode == "prefill" and rc.lm_head_last_only:
        x = x[:, -1:]  # §Perf: skip the vocab projection for prompt tokens
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.lm_head(params["lm_head"], x, rc)
    out = new_caches if caches is not None or rc.mode == "prefill" else None
    return logits, out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               n_img: int = N_IMG_TOKENS) -> Any:
    dtype = dtype or cfg.act_dtype
    period = cfg.cross_attn_period
    n_groups = cfg.num_layers // period

    def one(_):
        g = {}
        for i in range(period - 1):
            g[f"self{i}"] = {
                "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
                "len": jnp.zeros((batch,), jnp.int32),
            }
        g["cross"] = {
            "xk": jnp.zeros((batch, n_img, cfg.num_kv_heads, cfg.head_dim), dtype),
            "xv": jnp.zeros((batch, n_img, cfg.num_kv_heads, cfg.head_dim), dtype),
            "xlen": jnp.full((batch,), n_img, jnp.int32),
        }
        return g

    return jax.vmap(one)(jnp.arange(n_groups))
