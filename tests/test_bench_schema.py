"""benchmarks/schema.py: the eva-bench-rows/v1 gate CI runs against both
a fresh smoke emission and the committed BENCH_measured.json."""
import copy
import json
import os

from benchmarks import schema

REPO = os.path.join(os.path.dirname(__file__), "..")

VALID = {
    "schema": "eva-bench-rows/v1",
    "rows": [
        {"module": "fig10", "name": "fig10/decode", "us_per_call": 12.5,
         "derived": {"note": "analytic"}},
        {"module": "measured", "name": "measured/eva_4096x4096",
         "us_per_call": 14715.6,
         "derived": {"plan": "eva_direct M=1 K=4096 N=4096",
                     "backend": "eva_direct", "macs": 1, "lookup_adds": 2,
                     "weight_bytes": 3}},
        {"module": "measured", "name": "measured/ERROR", "us_per_call": -1.0,
         "derived": {"note": "ValueError:boom"}},
    ],
    "failures": ["measured: boom"],
}


def test_valid_doc_passes():
    assert schema.validate_rows(VALID) == []


def test_wrong_schema_version():
    doc = dict(VALID, schema="eva-bench-rows/v0")
    assert any("schema" in e for e in schema.validate_rows(doc))


def test_measured_row_missing_plan_fails():
    doc = copy.deepcopy(VALID)
    del doc["rows"][1]["derived"]["plan"]
    errs = schema.validate_rows(doc)
    assert any("derived.plan" in e for e in errs)


def test_measured_row_missing_cost_fields_fails():
    for field in ("macs", "lookup_adds", "weight_bytes"):
        doc = copy.deepcopy(VALID)
        del doc["rows"][1]["derived"][field]
        errs = schema.validate_rows(doc)
        assert any(f"derived.{field}" in e for e in errs), field


def test_smoke_module_held_to_same_contract():
    doc = copy.deepcopy(VALID)
    doc["rows"][1]["module"] = "smoke"
    del doc["rows"][1]["derived"]["backend"]
    assert any("derived.backend" in e for e in schema.validate_rows(doc))


def test_non_calibrated_modules_only_need_core_fields():
    doc = copy.deepcopy(VALID)
    doc["rows"][0]["derived"] = {}  # fig10 rows carry no plan
    assert schema.validate_rows(doc) == []


def test_malformed_rows_reported():
    doc = copy.deepcopy(VALID)
    doc["rows"][0].pop("us_per_call")
    doc["rows"].append("not a row")
    doc["rows"].append({"module": "measured", "name": "measured/x",
                        "us_per_call": 1.0, "derived": "not a dict"})
    errs = schema.validate_rows(doc)
    assert any("us_per_call" in e for e in errs)
    assert any("must be an object" in e for e in errs)
    assert any("derived must be an object" in e for e in errs)


def test_error_rows_exempt_from_calibration_fields():
    doc = copy.deepcopy(VALID)
    # the harness's failure rows carry only the exception text
    assert schema.validate_rows(doc) == []


def test_committed_bench_file_validates():
    """The schema gate CI applies to BENCH_measured.json must hold for
    the file as committed in this very PR."""
    path = os.path.join(REPO, "BENCH_measured.json")
    assert schema.validate_file(path) == []


def test_committed_calibration_loads():
    """CALIBRATION.json (fitted from the committed bench rows) must load
    under the current schema version — the Planner reads it at
    construction."""
    from repro.core import calibrate

    path = os.path.join(REPO, "CALIBRATION.json")
    calib = calibrate.load_calibration(path)
    assert calib is not None, "CALIBRATION.json missing or version-skewed"
    assert calib.backends, "no fitted backends"
    # interpret-only backends must never have fitted entries on this host
    assert calib.get("eva_fused_pallas") is None
    assert calib.get("eva_split_pallas") is None


def test_validate_file_reports_unreadable(tmp_path):
    errs = schema.validate_file(str(tmp_path / "missing.json"))
    assert errs and "unreadable" in errs[0]
    bad = tmp_path / "bad.json"
    bad.write_text("{")
    assert schema.validate_file(str(bad))


def test_spec_decode_row_requires_headline_fields():
    doc = copy.deepcopy(VALID)
    row = {"module": "serve", "name": "serve/spec_decode_trace",
           "us_per_call": 100.0,
           "derived": {"tokens": 80, "tok_per_s": 10.0, "requests": 2,
                       "kv_bytes_in_use": 0, "blocks_in_use": 0,
                       "blocks_free": 0, "tokens_per_step": 1.5,
                       "acceptance_rate": 0.18, "drafted": 168,
                       "accepted": 30}}
    doc["rows"].append(row)
    assert schema.validate_rows(doc) == []
    for field in schema.SPEC_FIELDS:
        broken = copy.deepcopy(doc)
        del broken["rows"][-1]["derived"][field]
        errs = schema.validate_rows(broken)
        assert any(f"derived.{field}" in e for e in errs), field


def test_other_serve_rows_exempt_from_spec_fields():
    doc = copy.deepcopy(VALID)
    doc["rows"].append(
        {"module": "serve", "name": "serve/request_trace",
         "us_per_call": 100.0,
         "derived": {"tokens": 18, "tok_per_s": 10.0, "requests": 3,
                     "kv_bytes_in_use": 0, "blocks_in_use": 0,
                     "blocks_free": 0}})
    assert schema.validate_rows(doc) == []
