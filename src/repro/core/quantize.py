"""Model-level VQ quantization pass: converts a dense checkpoint into the
EVA serving representation by replacing every eligible FC weight
(attention projections, MLP/expert matrices) with a VQWeight
(indices + additive codebooks + per-channel scale).

Embeddings, lm_head, norms, routers, gates, convs and recurrence
parameters stay high-precision — the same split as the paper (attention
computation and non-FC parameters remain FP16).

Same-input projection families are GROUPED by default: wq/wk/wv of an
attention block (GQA attention AND xlstm's mLSTM block, whose q/k/v all
consume the up-projected h) become one "wqkv" leaf, MLA's wq/wkv_a pair
(both consume the block input x) becomes "wq_kva", and gate/up of an MLP
become "gu" — each a single wide VQWeight with recorded split points
(see core/vq.py's grouped-codebook layout). The model layers then issue
ONE EVA matmul per family and slice the output, amortizing the VQ-GEMM /
output-codebook computation g-fold. Cross-attention blocks (whisper
"cross_attn", vision "xattn") are excluded — their q projection consumes
a different input than k/v.

Shard-aware grouping: pass the target ``mesh`` (or a model-axis shard
count) and a family whose member boundaries do NOT land on shard
boundaries of the wide N axis is left UNGROUPED — the members keep clean
column sharding instead of the grouped leaf silently falling back to
V-sharding with a per-layer psum (the splits_shard_aligned rule shared
with runtime/sharding.py). Every grouping decision can be captured in a
``report`` list for inspection.

Three methods:
  fit        — k-means additive VQ on real weights (small/smoke models)
  synthetic  — random valid indices/codebooks (benchmarks, huge dry-runs)
  specs      — ShapeDtypeStruct stand-ins (lowering only, no allocation)
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vq import (KVQuantConfig, VQWeight, fit_kv_codebooks, fit_vq,
                           kv_grid_codebooks, splits_shard_aligned,
                           synthetic_vq, vq_specs)

if TYPE_CHECKING:  # only for annotations — avoids a core<->models cycle
    from repro.models.common import ModelConfig

# param-tree path segments under which FC weights live
_BLOCK_SEGMENTS = (
    "layers", "pre_layers", "groups", "trail", "encoder", "decoder", "experts",
)
_MIN_DIM = 64  # don't quantize tiny matrices (per-head gates etc.)

# same-input projection families: (member keys, grouped key, required
# sibling that disambiguates the layout consumer). "wo" distinguishes
# attention_fwd's dict from xlstm's mlstm block (which also has wq/wk/wv
# but consumes them itself — its family is anchored by "w_if" instead);
# "down" anchors mlp_fwd/_expert_ffn; "wkv_b" is unique to the MLA dict,
# whose wq and wkv_a both consume the block input x.
_GROUP_FAMILIES = (
    (("wq", "wk", "wv"), "wqkv", "wo"),       # attention_fwd qkv
    (("wq", "wk", "wv"), "wqkv", "w_if"),     # xlstm mlstm qkv (input: h)
    (("wq", "wkv_a"), "wq_kva", "wkv_b"),     # MLA q + kv_a (input: x)
    (("gate", "up"), "gu", "down"),
)
# dict names whose members do NOT share an input (cross-attention)
_NO_GROUP_KEYS = ("cross_attn", "xattn")


def _eligible(path: Tuple[str, ...], w) -> bool:
    if not any(seg in path for seg in _BLOCK_SEGMENTS):
        return False
    if w.ndim < 2:
        return False
    K, N = w.shape[-2], w.shape[-1]
    return K >= _MIN_DIM and N >= _MIN_DIM


def _quantize_leaf(w, cfg: ModelConfig, method: str, key,
                   splits: Tuple[int, ...] = ()) -> VQWeight:
    """w: (..., K, N) possibly with stacked leading dims. `splits` marks w
    as the column-concatenation of a grouped projection family."""
    lead = w.shape[:-2]
    K, N = w.shape[-2], w.shape[-1]
    d, n, C = cfg.vq_d, cfg.vq_n, cfg.vq_C
    if K % d != 0:
        raise ValueError(f"K={K} not divisible by vq_d={d}")
    V = K // d
    k = 2 ** n
    idx_dtype = jnp.uint8 if n <= 8 else jnp.int32

    if method == "specs":
        return VQWeight(
            idx=jax.ShapeDtypeStruct((*lead, C, V, N), idx_dtype),
            codebooks=jax.ShapeDtypeStruct((*lead, C, d, k), jnp.float32),
            scale=jax.ShapeDtypeStruct((*lead, N), jnp.float32),
            K=K, N=N, d=d, n=n, splits=splits,
        )
    if method == "synthetic":
        kk = jax.random.fold_in(key, hash(str(w.shape)) % (2 ** 31))
        base = synthetic_vq(kk, K, N, d=d, n=n, C=C, splits=splits)
        # indices must differ per stacked layer — tile with per-layer perm-ish noise
        if lead:
            nlead = int(np.prod(lead))
            keys = jax.random.split(kk, nlead)
            idx = jax.vmap(
                lambda k_: jax.random.randint(k_, (C, V, N), 0, k).astype(idx_dtype)
            )(keys).reshape(*lead, C, V, N)
            cbs = jax.vmap(
                lambda k_: (jax.random.normal(k_, (C, d, k)) / np.sqrt(K * C))
            )(keys).reshape(*lead, C, d, k)
            return VQWeight(idx=idx, codebooks=cbs,
                            scale=jnp.ones((*lead, N), jnp.float32),
                            K=K, N=N, d=d, n=n, splits=splits)
        return base
    if method == "fit":
        flat = w.reshape(-1, K, N)
        keys = jax.random.split(key, flat.shape[0])

        def fit_one(args):
            kk, wi = args
            return fit_vq(kk, wi, d=d, n=n, C=C, kmeans_iters=10, refine_rounds=0)

        vqs = jax.lax.map(fit_one, (keys, flat))
        def reshape_leaf(a):
            return a.reshape(*lead, *a.shape[1:]) if lead else a[0]
        return VQWeight(
            idx=reshape_leaf(vqs.idx),
            codebooks=reshape_leaf(vqs.codebooks),
            scale=reshape_leaf(vqs.scale),
            K=K, N=N, d=d, n=n, splits=splits,
        )
    raise ValueError(f"unknown method {method}")


_BF16_MIN_SIZE = 65536  # large non-VQ serving leaves (emb/lm_head) -> bf16


def _to_serving_dtype(leaf):
    """Cast large fp32 dense leaves to bf16 for serving (embeddings and
    lm_head stay unquantized per the paper but need not stay fp32)."""
    if not hasattr(leaf, "dtype") or leaf.dtype != jnp.float32:
        return leaf
    if int(np.prod(leaf.shape)) < _BF16_MIN_SIZE:
        return leaf
    if isinstance(leaf, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct(leaf.shape, jnp.bfloat16)
    return leaf.astype(jnp.bfloat16)


def _concat_cols(leaves):
    """Column-concatenate member leaves; ShapeDtypeStructs are synthesized
    (specs mode never allocates)."""
    if isinstance(leaves[0], jax.ShapeDtypeStruct):
        shp = leaves[0].shape
        N = sum(l.shape[-1] for l in leaves)
        return jax.ShapeDtypeStruct((*shp[:-1], N), leaves[0].dtype)
    return jnp.concatenate(leaves, axis=-1)


def _model_shards(mesh) -> int:
    """Number of ways the 'model' mesh axis splits N. Accepts a Mesh /
    AbstractMesh (anything with .shape and .axis_names) or a bare int
    shard count; None -> 1 (shard-agnostic grouping)."""
    if mesh is None:
        return 1
    if isinstance(mesh, int):
        return max(mesh, 1)
    if "model" not in getattr(mesh, "axis_names", ()):
        return 1
    return int(mesh.shape["model"])


def quantize_params(params: Any, cfg: ModelConfig, *, method: str = "fit",
                    key: Optional[jax.Array] = None,
                    serving_bf16: bool = True,
                    quantize_lm_head: bool = False,
                    group_projections: bool = True,
                    mesh: Union[None, int, Any] = None,
                    report: Optional[List[Dict[str, Any]]] = None) -> Any:
    """Walk the param tree and replace eligible {"w": ...} linears with
    {"vq": VQWeight} (preserving biases). Remaining large dense leaves
    (embeddings, lm_head) are cast to bf16 when `serving_bf16`.
    `quantize_lm_head` additionally VQ-compresses the output projection —
    beyond the paper (which keeps it FP16); worth ~0.3 GB/device of decode
    traffic on qwen2-72b (EXPERIMENTS.md §Perf cell 1).
    `group_projections` fuses same-input families (attention and mLSTM
    wq/wk/wv -> "wqkv", MLA wq/wkv_a -> "wq_kva", gate/up -> "gu") into
    single wide VQWeights with recorded splits — the decode path then
    runs one EVA matmul per family.

    `mesh` (a Mesh/AbstractMesh or an int model-axis shard count) makes
    grouping SHARD-AWARE: families whose member boundaries don't land on
    shard boundaries of the wide N axis stay ungrouped, so their members
    keep clean column sharding (instead of the grouped leaf falling back
    to per-layer-psum V-sharding). `report`, when given, is appended one
    dict per family decision: {"path", "family", "members", "splits",
    "grouped", "reason"}."""
    key = key if key is not None else jax.random.PRNGKey(0)
    extra = ("lm_head",) if quantize_lm_head else ()
    shards = _model_shards(mesh)

    def eligible(path, w):
        if extra and any(seg in path for seg in extra):
            return w.ndim >= 2 and w.shape[-2] >= _MIN_DIM \
                and w.shape[-1] >= _MIN_DIM
        return _eligible(path, w)

    def groupable(node, path, members, sibling):
        if path and path[-1] in _NO_GROUP_KEYS:
            return False
        if sibling not in node or not all(m in node for m in members):
            return False
        leaves = []
        for m in members:
            sub = node[m]
            if not (isinstance(sub, dict) and "w" in sub
                    and not isinstance(sub["w"], VQWeight)
                    and eligible(path + (m,), sub["w"])):
                return False
            leaves.append(sub["w"])
        # one shared codebook set needs identical (lead..., K) shapes
        if any(l.shape[:-1] != leaves[0].shape[:-1] for l in leaves):
            return False
        has_b = [("b" in node[m]) for m in members]
        return all(has_b) or not any(has_b)

    def group(node, path):
        """Replace groupable families in a dict with single wide leaves."""
        out = dict(node)
        for members, gkey, sibling in _GROUP_FAMILIES:
            if not groupable(out, path, members, sibling):
                continue
            splits = tuple(int(out[m]["w"].shape[-1]) for m in members)
            if not splits_shard_aligned(splits, sum(splits), shards):
                # shard-aware grouping: a misaligned family would lose
                # clean column sharding (V-sharding fallback, per-layer
                # psum) — keep the members separate on this mesh
                if report is not None:
                    report.append({
                        "path": "/".join(path), "family": gkey,
                        "members": members, "splits": splits,
                        "grouped": False,
                        "reason": f"member boundaries not aligned to "
                                  f"{shards} model-axis shards "
                                  f"(N={sum(splits)})",
                    })
                continue
            if report is not None:
                report.append({
                    "path": "/".join(path), "family": gkey,
                    "members": members, "splits": splits, "grouped": True,
                    "reason": "aligned" if shards > 1 else "unsharded",
                })
            wcat = _concat_cols([out[m]["w"] for m in members])
            grouped = {"vq": _quantize_leaf(wcat, cfg, method, key,
                                            splits=splits)}
            if "b" in out[members[0]]:
                grouped["b"] = _concat_cols([out[m]["b"] for m in members])
            for m in members:
                del out[m]
            out[gkey] = grouped
        return out

    def walk(node, path):
        if isinstance(node, dict):
            if "vq" in node:
                # already quantized (grouped this pass, or a prior pass):
                # leave the node — incl. its bias dtype — untouched, same
                # as the ungrouped replacement branch below
                return node
            if "w" in node and not isinstance(node["w"], VQWeight) \
                    and eligible(path, node["w"]):
                new = {kk: vv for kk, vv in node.items() if kk != "w"}
                new["vq"] = _quantize_leaf(node["w"], cfg, method, key)
                return new
            if group_projections:
                node = group(node, path)
            return {kk: walk(vv, path + (kk,)) for kk, vv in node.items()}
        if serving_bf16 and not isinstance(node, VQWeight):
            return _to_serving_dtype(node)
        return node

    return walk(params, ())


# ---------------------------------------------------------------------------
# KV-VQ codebook attachment (serving-time KV cache compression)
# ---------------------------------------------------------------------------
#
# KV codebooks live in the PARAM tree, one node per attention layer
# (stacked with the scanned layer params), NOT in the cache: every cache
# leaf is zero-initialized, slot-sliced and block-scattered by the
# serving memory layer (serve/paging.py), which would corrupt resident
# codebooks. Attached under the attention param dict as
#   p["kv_cb"] = {"k": (L, Hk, R, 256, vec_d), "v": ...}        (GQA)
#   p["kv_cb"] = {"lat": (L, 1, R, 256, vec_d)}                 (MLA latent)
# so the layer scan hands each layer its own (Hk, R, 256, vec_d) slice
# and models/common.attention_fwd can encode at cache-append time.

# cache-subtree name for each param-tree layer-stack segment
_KV_STACK_SEGMENTS = {"layers": "body", "pre_layers": "pre"}


def _is_gqa_attn_node(node: Any, path: Tuple[str, ...]) -> bool:
    return (isinstance(node, dict) and "wo" in node
            and ("wq" in node or "wqkv" in node) and "wkv_b" not in node
            and (not path or path[-1] not in _NO_GROUP_KEYS))


def _is_mla_attn_node(node: Any) -> bool:
    return isinstance(node, dict) and "wkv_b" in node


def _node_lead(node: dict) -> Tuple[int, ...]:
    """Stacked leading dims of an attention param node (scan layers)."""
    anchor = node["wo"] if "wo" in node else node["wkv_b"]
    if "vq" in anchor:
        return tuple(anchor["vq"].idx.shape[:-3])
    return tuple(anchor["w"].shape[:-2])


def attach_kv_codebooks(params: Any, cfg: "ModelConfig", kvq: KVQuantConfig,
                        *, codebooks: Optional[Any] = None) -> Any:
    """Attach per-layer KV codebooks to every attention param node.

    Args:
      params: model params (fp or already VQ-quantized — detection keys
        survive both).
      cfg: the ModelConfig (supplies num_kv_heads / head_dim /
        kv_lora_rank geometry).
      kvq: frozen KV-VQ geometry/variant.
      codebooks: optional calibrated codebook tree from
        ``calibrate_kv_codebooks`` keyed like the cache
        ({"body": {"k": (L, Hk, R, 256, vd), ...}, "pre": ...}); when
        None every layer gets the deterministic ``kv_grid_codebooks``
        lattice (calibration-free default).

    Returns:
      A new param tree with ``kv_cb`` nodes attached (idempotent:
      existing ``kv_cb`` nodes are replaced).

    Raises:
      ValueError: when head_dim / kv_lora_rank is not divisible by the
        config's vec_d.
    """
    def build(num_heads: int, dim: int, lead: Tuple[int, ...],
              fitted: Optional[jax.Array]) -> jax.Array:
        if fitted is not None:
            return fitted  # already (L, Hk, R, E, vd)
        cb = kv_grid_codebooks(num_heads, dim, kvq)
        return jnp.broadcast_to(cb, lead + cb.shape)

    def walk(node, path, stack):
        if not isinstance(node, dict):
            return node
        seg = _KV_STACK_SEGMENTS.get(path[-1]) if path else None
        stack = seg or stack
        fitted = (codebooks or {}).get(stack) if stack else None
        if _is_gqa_attn_node(node, path):
            lead = _node_lead(node)
            out = dict(node)
            out["kv_cb"] = {
                "k": build(cfg.num_kv_heads, cfg.head_dim, lead,
                           (fitted or {}).get("k")),
                "v": build(cfg.num_kv_heads, cfg.head_dim, lead,
                           (fitted or {}).get("v")),
            }
            return out
        if _is_mla_attn_node(node):
            lead = _node_lead(node)
            out = dict(node)
            out["kv_cb"] = {
                "lat": build(1, cfg.kv_lora_rank, lead,
                             (fitted or {}).get("lat")),
            }
            return out
        return {k: walk(v, path + (k,), stack) for k, v in node.items()}

    return walk(params, (), None)


def attach_vq_logits_head(params: Any, kc: int, *, key=None,
                          iters: int = 20) -> Any:
    """Replace the dense LM head with a VQ-Logits compressed head
    (``core.logits_vq``): the ``{"w": (D, V)}`` node under ``lm_head``
    becomes ``{"vql": VQLogitsHead}``, fitted by k-means over the head's
    scale-normalized columns. Idempotent: an already-attached head is
    re-fitted from its implied dense weight.

    Raises:
      ValueError: when params carry no separate ``lm_head`` node
        (tied-embedding models score through the embedding table) or the
        head is weight-VQ quantized (compress one family at a time).
    """
    from repro.core import logits_vq as lvq

    if not (isinstance(params, dict)
            and isinstance(params.get("lm_head"), dict)):
        raise ValueError(
            "attach_vq_logits_head: params have no lm_head node "
            "(tie_embeddings models have no separate head to compress)")
    node = params["lm_head"]
    if "vql" in node:
        w = lvq.expand(node["vql"])
    elif "vq" in node:
        raise ValueError(
            "attach_vq_logits_head: lm_head is weight-VQ quantized; "
            "attach the logits head before quantize_lm_head, not after")
    else:
        w = node["w"]
    if key is None:
        key = jax.random.PRNGKey(0)
    head = lvq.fit_logits_vq(key, w, kc, iters=iters)
    out = dict(params)
    out["lm_head"] = {"vql": head}
    return out


def kv_codebook_tree(params: Any) -> Dict[str, Any]:
    """Collect attached ``kv_cb`` nodes keyed by cache subtree name
    ({"body": {...}, "pre": {...}}) — the layout
    ``serve/kvcache.encode_prefill_cache`` consumes.

    Raises:
      ValueError: when params carry no kv_cb nodes (attach first)."""
    out: Dict[str, Any] = {}

    def walk(node, stack):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            if k == "kv_cb" and stack:
                out[stack] = v
            else:
                walk(v, _KV_STACK_SEGMENTS.get(k, stack))

    walk(params, None)
    if not out:
        raise ValueError("params carry no kv_cb nodes "
                         "(run attach_kv_codebooks first)")
    return out


def calibrate_kv_codebooks(model: Any, params: Any, batch: Dict[str, Any],
                           kvq: KVQuantConfig, *,
                           key: Optional[jax.Array] = None) -> Dict[str, Any]:
    """Fit per-layer/per-head KV codebooks from calibration prompts.

    Runs one fp prefill of ``batch`` (e.g. {"tokens": (B, S)}) and
    k-means-fits each layer's K/V (or MLA latent) distribution through
    ``core.vq.fit_kv_codebooks``.

    Returns:
      A codebook tree for ``attach_kv_codebooks(codebooks=...)``:
      {"body": {"k": (L, Hk, R, 256, vec_d), "v": ...}, "pre": ...}
      (MLA subtrees carry {"lat": (L, 1, R, 256, vec_d)}).
    """
    from repro.models.common import RunConfig  # local: avoid import cycle

    key = key if key is not None else jax.random.PRNGKey(0)
    rc = RunConfig(mode="prefill", remat=False, attn_chunk=16)
    _, cache = model.prefill(params, batch, rc)

    def fit_stack(samples: jax.Array, k_: jax.Array) -> jax.Array:
        # samples: (L, T, Hk, dim) -> (L, Hk, R, E, vd)
        keys = jax.random.split(k_, samples.shape[0])
        return jax.lax.map(
            lambda a: fit_kv_codebooks(a[0], a[1], kvq), (keys, samples))

    out: Dict[str, Any] = {}
    for name, node in cache.items():
        if not isinstance(node, dict):
            continue
        L = jax.tree_util.tree_leaves(node)[0].shape[0]
        if "k" in node and "v" in node:
            k_smp = node["k"].reshape(L, -1, *node["k"].shape[-2:])
            v_smp = node["v"].reshape(L, -1, *node["v"].shape[-2:])
            key, k1, k2 = jax.random.split(key, 3)
            out[name] = {"k": fit_stack(k_smp, k1),
                         "v": fit_stack(v_smp, k2)}
        elif "latent" in node:
            lat = node["latent"]
            lat_smp = lat.reshape(L, -1, 1, lat.shape[-1])
            key, k1 = jax.random.split(key)
            out[name] = {"lat": fit_stack(lat_smp, k1)}
    if not out:
        raise ValueError("prefill cache carries no quantizable KV nodes")
    return out


def count_vq_layers(params: Any) -> int:
    n = 0

    def walk(node):
        nonlocal n
        if isinstance(node, dict):
            if "vq" in node:
                n += 1
            for v in node.values():
                walk(v)

    walk(params)
    return n


def compressed_model_bytes(params: Any) -> Tuple[int, int]:
    """Returns (vq_bytes, dense_bytes_bf16_equivalent) over VQ'd leaves."""
    vq_b, dense_b = 0, 0

    def walk(node):
        nonlocal vq_b, dense_b
        if isinstance(node, dict):
            if "vq" in node:
                v: VQWeight = node["vq"]
                lead = int(np.prod(v.idx.shape[:-3])) if v.idx.ndim > 3 else 1
                vq_b += lead * v.compressed_bytes()
                dense_b += lead * v.K * v.N * 2
            for x in node.values():
                if isinstance(x, dict):
                    walk(x)

    walk(params)
    return vq_b, dense_b
