"""KV/state-cache utilities for serving.

Families store different cache structures (full KV, SWA/local ring
buffers, MLA latent caches, RG-LRU / xLSTM recurrent states). The engine
needs one operation over all of them: convert the variable-length caches
returned by prefill into fixed-capacity decode caches.

Conventions (see models/*.init_cache):
  {"k","v","len"}            attention cache, time axis -3 (ring iff window)
  {"latent","k_rope","len"}  MLA cache, time axis -2
  {"xk","xv","xlen"} / {"cross_k","cross_v","cross_len"}   static memories
  anything else              recurrent state, already fixed-size
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def _pad_time(x: jax.Array, axis: int, capacity: int) -> jax.Array:
    S = x.shape[axis]
    if S == capacity:
        return x
    if S > capacity:
        raise ValueError(f"prefill length {S} exceeds capacity {capacity}")
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, capacity - S)
    return jnp.pad(x, pad)


def _to_ring(x: jax.Array, axis: int, window: int) -> jax.Array:
    """Reorder the last `window` positions of a full-length cache into ring
    order (slot = position % window)."""
    S = x.shape[axis]
    if S <= window:
        return _pad_time(x, axis, window)
    s = jnp.arange(window)
    pos = S - window + ((s - (S - window)) % window)
    return jnp.take(x, pos, axis=axis)


def pad_prefill_cache(cache: Any, capacity: int, *, window: int = 0) -> Any:
    """Walk the cache tree and pad/ring-convert every attention cache to
    its decode capacity. Recurrent states and static cross memories pass
    through unchanged."""
    eff_cap = min(capacity, window) if window else capacity

    def walk(node):
        if isinstance(node, dict):
            if "k" in node and "v" in node and "len" in node:
                out = dict(node)
                fix = _to_ring if window else _pad_time
                arg = window if window else eff_cap
                out["k"] = fix(node["k"], node["k"].ndim - 3, arg)
                out["v"] = fix(node["v"], node["v"].ndim - 3, arg)
                for s in ("k_s", "v_s"):  # int8-cache scales: (.., S, Hk)
                    if s in node:
                        out[s] = fix(node[s], node[s].ndim - 2, arg)
                return out
            if "latent" in node and "k_rope" in node:
                out = dict(node)
                out["latent"] = _pad_time(node["latent"], node["latent"].ndim - 2, eff_cap)
                out["k_rope"] = _pad_time(node["k_rope"], node["k_rope"].ndim - 2, eff_cap)
                return out
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(cache)


def cache_bytes(cache: Any) -> int:
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(cache)
        if hasattr(x, "size")
    )
