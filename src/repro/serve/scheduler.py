"""Request scheduler for continuous batching.

Requests arrive with a prompt and a max_new_tokens budget; the scheduler
admits them into free decode slots (paper §V-C: EU-stage weight-tile reuse
across requests is what makes multi-batch decode cheap — the engine keeps
slots as full as possible so every streamed WI tile is reused by all
active requests).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


class Scheduler:
    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * num_slots
        self._uid = 0

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        return self._uid

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self) -> List[int]:
        """Move queued requests into free slots; returns slot indices that
        need prefill."""
        admitted = []
        for i in self.free_slots():
            if not self.queue:
                break
            self.slots[i] = self.queue.popleft()
            admitted.append(i)
        return admitted

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def finish(self, slot: int) -> Request:
        r = self.slots[slot]
        assert r is not None
        r.done = True
        self.slots[slot] = None
        return r

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active_slots()
