from repro.kernels.fused_vq_matmul.ops import fused_vq_matmul
from repro.kernels.fused_vq_matmul.ref import fused_vq_matmul_ref
