"""Whisper-medium — encoder-decoder, conv frontend stubbed
[arXiv:2212.04356]. 24L(enc)+24L(dec) d_model=1024 16H d_ff=4096
vocab=51865 (padded to 51968 for TP; see DESIGN.md §4).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="whisper",
    is_encoder_decoder=True,
    encoder_layers=24,
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    vq_C=2,
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke",
    family="whisper",
    is_encoder_decoder=True,
    encoder_layers=2,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=500,
    vq_C=2,
)
