"""Fault-tolerant checkpointing.

Design (multi-host posture, npz-based since tensorstore is unavailable
offline):
  * a checkpoint is a directory  step_<N>/  holding one .npz per top-level
    state group plus a tree manifest (structure + leaf dtypes/shapes),
  * writes go to  step_<N>.tmp/  and are atomically renamed after fsync —
    a crash mid-save never corrupts the latest valid checkpoint,
  * an optional background thread makes saves asynchronous (training
    continues while the previous step serializes),
  * retention keeps the most recent K checkpoints,
  * restore() reads the manifest and rebuilds the exact pytree (including
    VQWeight nodes and optimizer NamedTuples) and can re-shard onto a new
    mesh (elastic restart) since leaves are stored unsharded.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vq import VQWeight
from repro.optim.adamw import AdamWState

_SENTINEL_NONE = "__none__"


# --------------------------------------------------------------- pytree io


def _flatten_with_paths(tree: Any, prefix: str = "") -> List[Tuple[str, Any]]:
    out: List[Tuple[str, Any]] = []
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out += _flatten_with_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, VQWeight):
        # meta layout: [K, N, d, n, *splits] — splits (grouped-projection
        # family widths) appended so old 4-element checkpoints still load
        out += _flatten_with_paths(
            {"idx": tree.idx, "codebooks": tree.codebooks, "scale": tree.scale,
             "__vqmeta__": np.asarray(
                 [tree.K, tree.N, tree.d, tree.n, *tree.splits])},
            f"{prefix}/__vq__",
        )
    elif isinstance(tree, AdamWState):
        out += _flatten_with_paths(
            {"step": tree.step, "m": tree.m, "v": tree.v,
             "master": tree.master if tree.master is not None else _SENTINEL_NONE},
            f"{prefix}/__adamw__",
        )
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _flatten_with_paths(v, f"{prefix}/__seq__{i}")
    elif tree is None or (isinstance(tree, str) and tree == _SENTINEL_NONE):
        out.append((f"{prefix}/__none__", None))
    else:
        out.append((prefix, tree))
    return out


def _unflatten_from_paths(flat: Dict[str, Any]) -> Any:
    """Rebuild nested structure from path -> leaf."""
    root: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = [p for p in path.split("/") if p]
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if "__none__" in node:
            return None
        if "__vq__" in node:
            sub = node["__vq__"]
            meta = np.asarray(sub["__vqmeta__"]).astype(int)
            return VQWeight(
                idx=jnp.asarray(sub["idx"]),
                codebooks=jnp.asarray(sub["codebooks"]),
                scale=jnp.asarray(sub["scale"]),
                K=int(meta[0]), N=int(meta[1]), d=int(meta[2]), n=int(meta[3]),
                splits=tuple(int(s) for s in meta[4:]),
            )
        if "__adamw__" in node:
            sub = node["__adamw__"]
            return AdamWState(
                step=jnp.asarray(sub["step"]),
                m=rebuild(sub["m"]), v=rebuild(sub["v"]),
                master=rebuild(sub["master"]),
            )
        if any(k.startswith("__seq__") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][7:]))
            return tuple(rebuild(v) for _, v in items)
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


# Public aliases: the path-flattened format is also the serving engine's
# snapshot wire format (serve/resilience.EngineSnapshot serializes KV
# caches — contiguous or paged, where the "/caches/..." paths carry the
# shared block arenas and device block-table leaves (serve/paging.py) —
# plus per-slot sampling state through it), so the flatteners are part
# of the module's API, not private helpers. The paging HOST state (block
# tables, pool free-list order, per-slot ownership) rides EngineSnapshot
# as plain Python fields alongside the scheduler queue: process-local,
# not persisted here.
flatten_with_paths = _flatten_with_paths
unflatten_from_paths = _unflatten_from_paths


# ----------------------------------------------------------------- manager


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = False

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[Exception] = None

    # ---- paths
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name, "MANIFEST.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ---- save
    def _write(self, step: int, state: Dict[str, Any]):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "groups": {}}
        for group, tree in state.items():
            flat = _flatten_with_paths(tree)
            arrays = {}
            paths = []
            for i, (path, leaf) in enumerate(flat):
                paths.append(path)
                if leaf is not None:
                    arrays[f"a{i}"] = np.asarray(leaf)
            np.savez(os.path.join(tmp, f"{group}.npz"), **arrays)
            manifest["groups"][group] = paths
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def save(self, step: int, state: Dict[str, Any], *, block: bool = False):
        """state: {"params": ..., "opt": ..., "extra": ...}. Device arrays
        are fetched to host before the async thread starts (snapshot)."""
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(x) if hasattr(x, "shape") else x, state
        )
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            raise self._last_error
        if self.async_save and not block:
            def run():
                try:
                    self._write(step, host_state)
                except Exception as e:  # pragma: no cover
                    self._last_error = e
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            raise self._last_error

    # ---- restore
    def restore(self, step: Optional[int] = None) -> Tuple[int, Dict[str, Any]]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        state = {}
        for group, paths in manifest["groups"].items():
            data = np.load(os.path.join(d, f"{group}.npz"))
            flat = {}
            for i, path in enumerate(paths):
                if path.endswith("/__none__"):
                    flat[path] = None
                else:
                    flat[path] = jnp.asarray(data[f"a{i}"])
            state[group] = _unflatten_from_paths(flat)
        return step, state
