"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

Wires together: model zoo, data pipeline, AdamW(+ZeRO specs), checkpoint
manager (async, atomic), watchdog, restart loop, and optional int8
error-feedback gradient compression across the 'pod' axis.
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, DataPipeline
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step, train_shardings
from repro.models.api import build_model
from repro.models.common import RunConfig
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import StepWatchdog, run_with_restarts, to_named


def build_trainer(arch: str, *, smoke: bool, seq_len: int, global_batch: int,
                  lr: float, mesh=None, remat: bool = True):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    mesh = mesh or make_local_mesh(model=1)
    rc = RunConfig(mode="train", remat=remat,
                   attn_chunk=min(seq_len, 1024))
    opt_cfg = AdamWConfig(lr=lr)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch)
    return model, mesh, rc, opt_cfg, dcfg


def train(arch: str = "qwen3-0.6b", *, smoke: bool = True, steps: int = 20,
          seq_len: int = 64, global_batch: int = 8, lr: float = 1e-3,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 10,
          fail_at: Optional[int] = None, max_restarts: int = 2,
          log_every: int = 5, mesh=None, seed: int = 0) -> Dict[str, Any]:
    model, mesh, rc, opt_cfg, dcfg = build_trainer(
        arch, smoke=smoke, seq_len=seq_len, global_batch=global_batch, lr=lr,
        mesh=mesh,
    )
    mgr = (CheckpointManager(ckpt_dir, keep=2, async_save=True)
           if ckpt_dir else None)
    watchdog = StepWatchdog()
    losses: Dict[int, float] = {}
    # a failure is injected once — the "failed node" is replaced on restart
    fault = {"fail_at": fail_at}

    step_fn = make_train_step(model, opt_cfg, rc, total_steps=max(steps, 2),
                              warmup=max(steps // 10, 1))

    def init_state():
        params = model.init(jax.random.PRNGKey(seed))
        opt = adamw_init(params, opt_cfg)
        return params, opt

    def train_loop(start_step: int) -> int:
        params, opt = init_state()
        resume = start_step
        if mgr is not None and mgr.latest_step() is not None:
            resume, state = mgr.restore()
            params, opt = state["params"], state["opt"]
        in_sh, out_sh = train_shardings(model, mesh, params, opt,
                                        pipe_batch_spec(params))
        with mesh:
            jitted = jax.jit(step_fn, donate_argnums=(0, 1))
            pipe = DataPipeline(dcfg, start_step=resume,
                                fail_at=fault["fail_at"])
            try:
                step = resume
                for batch in pipe:
                    if step >= steps:
                        break
                    watchdog.start_step()
                    params, opt, metrics = jitted(
                        params, opt,
                        {k: jnp.asarray(v) for k, v in batch.items()},
                    )
                    loss = float(metrics["loss"])
                    losses[step] = loss
                    watchdog.end_step()
                    step += 1
                    if log_every and step % log_every == 0:
                        print(f"step {step:5d} loss {loss:.4f} "
                              f"gnorm {float(metrics['gnorm']):.3f}",
                              flush=True)
                    if mgr is not None and step % ckpt_every == 0:
                        mgr.save(step, {"params": params, "opt": opt})
            finally:
                pipe.close()
        if mgr is not None:
            mgr.save(steps, {"params": params, "opt": opt}, block=True)
            mgr.wait()
        return steps

    def pipe_batch_spec(params):
        return {
            "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        }

    def on_failure(e, n):
        fault["fail_at"] = None  # replaced node: don't re-inject
        return (mgr.latest_step() or 0) if mgr else 0

    stats = run_with_restarts(
        train_loop, max_restarts=max_restarts, on_failure=on_failure,
    )
    return {"losses": losses, "restarts": stats.restarts,
            "stragglers": watchdog.straggler_steps,
            "final_loss": losses[max(losses)] if losses else float("nan")}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                seq_len=args.seq_len, global_batch=args.global_batch,
                lr=args.lr, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, fail_at=args.fail_at)
    print(f"final loss: {out['final_loss']:.4f} restarts: {out['restarts']}")


if __name__ == "__main__":
    main()
