"""Model facade: one uniform interface over all architecture families.

    model = Model(cfg)
    params = model.init(key)                      # dense training params
    logits, _ = model.forward(params, batch, rc)  # train-mode forward
    loss = model.loss(params, batch, rc)
    caches = model.init_cache(batch_size, max_len)
    logits, caches = model.prefill(params, batch, rc)
    logits, caches = model.decode(params, tokens, positions, caches, rc)

    model.input_specs(shape)        # ShapeDtypeStruct inputs for dry-runs
    model.param_specs(quantized)    # ShapeDtypeStruct params (no alloc)
    model.cache_specs(batch, seq)   # ShapeDtypeStruct caches
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import quantize_params
from repro.models import common as cm
from repro.models import rglru, transformer, vision, whisper, xlstm
from repro.models.common import ModelConfig, RunConfig

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "xlstm": xlstm,
    "rglru": rglru,
    "whisper": whisper,
    "vision": vision,
}

# assigned input shapes: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    @property
    def module(self):
        return _FAMILY[self.cfg.family]

    # ------------------------------------------------------------------ init
    def init(self, key) -> Any:
        return self.module.init_params(key, self.cfg)

    def quantize(self, params, *, method: str = "fit", key=None,
                 quantize_lm_head: bool = False, mesh=None,
                 report=None) -> Any:
        """`mesh` enables shard-aware grouping (families whose member
        boundaries are not shard-aligned under the target mesh stay
        ungrouped); `report` (a list) captures every grouping decision."""
        return quantize_params(params, self.cfg, method=method, key=key,
                               quantize_lm_head=quantize_lm_head,
                               mesh=mesh, report=report)

    # --------------------------------------------------------------- forward
    def _extra_kwargs(self, batch: Dict[str, Any]) -> Dict[str, Any]:
        kw = {}
        if self.cfg.family == "whisper" and "frames" in batch:
            kw["frames"] = batch["frames"]
        if self.cfg.family == "vision" and "image_embeds" in batch:
            kw["image_embeds"] = batch["image_embeds"]
        return kw

    def forward(self, params, batch: Dict[str, Any], rc: RunConfig,
                caches=None) -> Tuple[jax.Array, Any]:
        return self.module.forward(
            params, batch["tokens"], rc, self.cfg,
            positions=batch.get("positions"),
            caches=caches, **self._extra_kwargs(batch),
        )

    def loss(self, params, batch: Dict[str, Any], rc: RunConfig) -> jax.Array:
        logits, _ = self.forward(params, batch, rc)
        logits = self._mask_pad_vocab(logits)
        return cm.cross_entropy_loss(logits, batch["labels"],
                                     batch.get("loss_mask"))

    def _mask_pad_vocab(self, logits):
        pad = self.cfg.padded_vocab - self.cfg.vocab_size
        if pad:
            neg = jnp.full((*logits.shape[:-1], pad), -1e30, logits.dtype)
            logits = jnp.concatenate(
                [logits[..., : self.cfg.vocab_size], neg], axis=-1
            )
        return logits

    # ------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_len: int, dtype=None,
                   kv_int8: bool = False, kv_int4: bool = False,
                   kvq=None) -> Any:
        """Allocate decode caches. ``kv_int8``/``kv_int4``/``kvq`` (a
        core.vq.KVQuantConfig — vector-quantized uint8-index KV) select
        compressed layouts on the attention families; other families
        ignore them (recurrent state is not a KV cache)."""
        if kvq is not None and self.cfg.family in ("dense", "moe"):
            return self.module.init_cache(self.cfg, batch, max_len, dtype,
                                          kvq=kvq)
        if (kv_int8 or kv_int4) and self.cfg.family in ("dense", "moe"):
            return self.module.init_cache(self.cfg, batch, max_len, dtype,
                                          kv_int8=kv_int8, kv_int4=kv_int4)
        return self.module.init_cache(self.cfg, batch, max_len, dtype)

    def prefill(self, params, batch: Dict[str, Any], rc: RunConfig):
        rc = rc.replace(mode="prefill")
        logits, caches = self.forward(params, batch, rc)
        return logits, caches

    def decode(self, params, tokens, positions, caches, rc: RunConfig):
        """tokens (B,1), positions (B,1)."""
        rc = rc.replace(mode="decode")
        batch = {"tokens": tokens, "positions": positions}
        return self.forward(params, batch, rc, caches=caches)

    # ------------------------------------------------------------- dry-run
    def input_specs(self, shape: str, *, global_batch: Optional[int] = None,
                    kv_int8: bool = False, kv_int4: bool = False
                    ) -> Tuple[str, Dict[str, Any]]:
        """Returns (step_kind, specs). decode shapes include cache specs."""
        seq, gb, kind = SHAPES[shape]
        gb = global_batch or gb
        i32 = jnp.int32
        specs: Dict[str, Any] = {}
        if kind == "train":
            specs["tokens"] = jax.ShapeDtypeStruct((gb, seq), i32)
            specs["labels"] = jax.ShapeDtypeStruct((gb, seq), i32)
        elif kind == "prefill":
            specs["tokens"] = jax.ShapeDtypeStruct((gb, seq), i32)
        else:  # decode: one new token against a cache of length seq
            specs["tokens"] = jax.ShapeDtypeStruct((gb, 1), i32)
            specs["positions"] = jax.ShapeDtypeStruct((gb, 1), i32)
            specs["caches"] = self.cache_specs(gb, seq, kv_int8=kv_int8,
                                               kv_int4=kv_int4)
        if self.cfg.family == "whisper" and kind != "decode":
            specs["frames"] = jax.ShapeDtypeStruct(
                (gb, whisper.S_SRC, self.cfg.d_model), self.cfg.act_dtype
            )
        if self.cfg.family == "vision" and kind != "decode":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (gb, vision.N_IMG_TOKENS, self.cfg.d_model), self.cfg.act_dtype
            )
        return kind, specs

    def param_specs(self, *, quantized: bool = False,
                    quantize_lm_head: bool = False) -> Any:
        dense = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        if not quantized:
            return dense
        return quantize_params(dense, self.cfg, method="specs",
                               quantize_lm_head=quantize_lm_head)

    def cache_specs(self, batch: int, max_len: int, kv_int8: bool = False,
                    kv_int4: bool = False, kvq=None) -> Any:
        """ShapeDtypeStruct cache tree for the given compression knobs
        (used by serve/paging.py byte accounting and launch dry-runs)."""
        return jax.eval_shape(
            functools.partial(self.init_cache, batch, max_len,
                              kv_int8=kv_int8, kv_int4=kv_int4, kvq=kvq)
        )

    def supports_shape(self, shape: str) -> bool:
        """long_500k only for sub-quadratic archs (see DESIGN.md §4)."""
        if shape != "long_500k":
            return True
        if self.cfg.family in ("xlstm", "rglru"):
            return True
        # SWA bounds the cache -> sub-quadratic decode state
        return self.cfg.sliding_window > 0


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def param_count(params) -> int:
    return sum(
        x.size for x in jax.tree_util.tree_leaves(params)
        if hasattr(x, "size")
    )
