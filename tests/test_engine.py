"""Serving-engine tests: continuous batching correctness and scheduling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.common import RunConfig
from repro.serve import Engine, EngineConfig, Scheduler
from repro.serve.kvcache import pad_prefill_cache

KEY = jax.random.PRNGKey(0)


def _greedy_reference(model, params, prompt, max_new, rc, cap):
    """Sequential single-request greedy decode."""
    cfg = model.cfg
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None], jnp.int32)},
        rc.replace(mode="prefill"),
    )
    window = cfg.sliding_window or cfg.local_window
    caches = pad_prefill_cache(caches, cap, window=window)
    out = [int(np.argmax(np.asarray(logits[0, -1, :cfg.vocab_size])))]
    pos = len(prompt)
    while len(out) < max_new:
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, caches = model.decode(
            params, tok, jnp.asarray([[pos]], jnp.int32), caches,
            rc.replace(mode="decode"),
        )
        out.append(int(np.argmax(np.asarray(logits[0, 0, :cfg.vocab_size]))))
        pos += 1
    return out


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    rc = RunConfig(mode="decode", remat=False, attn_chunk=16)
    return cfg, model, params, rc


def test_continuous_batching_matches_sequential(setup):
    cfg, model, params, rc = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 7, 4, 6)]
    max_new = 6
    ecfg = EngineConfig(num_slots=2, max_len=32)  # slots < requests: queueing
    eng = Engine(model, params, rc, ecfg)
    got = eng.generate(prompts, max_new)
    for uid, prompt in zip(got, prompts):
        ref = _greedy_reference(model, params, prompt, max_new, rc, 32)
        assert got[uid] == ref, (uid, got[uid], ref)


def test_scheduler_slot_lifecycle():
    s = Scheduler(num_slots=2)
    u1 = s.submit(np.ones(3, np.int32), 4)
    u2 = s.submit(np.ones(4, np.int32), 4)
    u3 = s.submit(np.ones(5, np.int32), 4)
    admitted = s.admit()
    assert len(admitted) == 2 and len(s.queue) == 1
    r = s.finish(admitted[0])
    assert r.uid == u1
    assert s.admit() == [admitted[0]]  # freed slot reused for u3
    assert not s.idle
    s.finish(0), s.finish(1)
    assert s.idle


def test_engine_vq_quantized(setup):
    """The engine runs end-to-end on EVA-quantized weights."""
    cfg, model, params, rc = setup
    qparams = model.quantize(params, method="synthetic", key=KEY)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(3)]
    rc_vq = rc.replace(vq_mode="eva")
    eng = Engine(model, qparams, rc_vq, EngineConfig(num_slots=3, max_len=24))
    got = eng.generate(prompts, 4)
    assert all(len(v) == 4 for v in got.values())
    # eva and dequant paths agree token-for-token
    eng2 = Engine(model, qparams, rc.replace(vq_mode="dequant"),
                  EngineConfig(num_slots=3, max_len=24))
    got2 = eng2.generate(prompts, 4)
    assert list(got.values()) == list(got2.values())
