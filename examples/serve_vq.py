"""Serving example: the request-level API with EVA-quantized weights.

Submits a stream of variable-length requests — mixed greedy and sampled
(temperature/top-k/top-p), each with its own eos — then streams one
request token-by-token while the engine keeps every slot busy. Prefill
runs per request at power-of-two bucket lengths (INT8 path), decode runs
as one batched EVA step across all active slots with sampling and
stopping INSIDE the jitted step (the paper's multi-batch weight-tile
reuse, Fig. 7(c)).

    PYTHONPATH=src python examples/serve_vq.py --arch mixtral-8x22b
    PYTHONPATH=src python examples/serve_vq.py --paged --block-size 8
    PYTHONPATH=src python examples/serve_vq.py --paged --kv-bits 4

With --paged the engine serves from the block-table KV memory
subsystem (serve/paging.py): shared block arenas + per-slot tables,
chunked prefill, and out-of-blocks preemption — token-identical to
the contiguous layout.

--kv-bits picks the KV cache storage width (README "KV-VQ memory
model"): 16 = model dtype, 8 = per-channel int8, 4/2 = vector-quantized
uint8 codebook indices (core/vq.py) consumed natively by the decode
kernel. The example prints bytes-per-block for the chosen width next to
the fp baseline — the ratio is the concurrency gain at fixed KV HBM.
"""
import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.plan import PlanPolicy
from repro.models import build_model
from repro.models.common import RunConfig
from repro.core.vq import KVQuantConfig
from repro.serve import (Engine, EngineConfig, GenerationRequest,
                         SamplingParams, make_paging_config)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--eos", type=int, default=None,
                    help="per-request stop token id")
    ap.add_argument("--paged", action="store_true",
                    help="block-table KV memory (serve/paging.py)")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--kv-bits", type=int, default=16,
                    choices=(16, 8, 4, 2),
                    help="KV storage width: 16=model dtype, 8=int8, "
                         "4/2=vector-quantized (KV-VQ)")
    args = ap.parse_args()

    # INFO logging shows the engine's pre-planned per-bucket prefill and
    # decode matmul plans (backend + resolved tiles per layer shape)
    logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.quantize(model.init(key), method="synthetic", key=key)

    rc = RunConfig(mode="decode", plan_policy=PlanPolicy(vq_mode="eva"),
                   remat=False, attn_chunk=32)
    eng = Engine(model, params, rc,
                 EngineConfig(num_slots=args.slots, max_len=64,
                              paged=args.paged, block_size=args.block_size,
                              prefill_chunk=args.prefill_chunk,
                              kv_bits=args.kv_bits))
    if args.kv_bits != 16:
        # the concurrency headline: compressed blocks mean the fp KV
        # budget funds proportionally more slots at the same HBM
        meta_fp = make_paging_config(model, args.slots, 64,
                                     block_size=args.block_size)
        kw = ({"kvq": KVQuantConfig(kv_bits=args.kv_bits)}
              if args.kv_bits in (4, 2) else {"kv_int8": True})
        meta_q = make_paging_config(model, args.slots, 64,
                                    block_size=args.block_size, **kw)
        gain = meta_fp.bytes_per_block / meta_q.bytes_per_block
        print(f"  kv_bits={args.kv_bits}: {meta_q.bytes_per_block} B/block "
              f"vs {meta_fp.bytes_per_block} fp — {gain:.1f}x slots at "
              f"fixed KV HBM (~{int(args.slots * gain)} vs {args.slots})")

    rng = np.random.default_rng(0)
    eos_ids = () if args.eos is None else (args.eos,)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(4, 16))).astype(np.int32)
        sampling = SamplingParams() if i % 2 == 0 else SamplingParams(
            greedy=False, temperature=0.8, top_k=40, top_p=0.95, seed=i)
        reqs.append(GenerationRequest(prompt=prompt,
                                      max_new_tokens=args.max_new,
                                      sampling=sampling, eos_ids=eos_ids))
    print(f"serving {len(reqs)} requests on {args.slots} slots "
          f"({cfg.name}, {cfg.vq_C * cfg.vq_n / cfg.vq_d:.0f}-bit VQ)")
    t0 = time.time()
    uids = [eng.submit(r) for r in reqs]

    # stream the first request token-by-token (the engine advances every
    # slot along the way), then drain the rest
    print(f"  streaming request {uids[0]}:", end="", flush=True)
    for ev in eng.stream(uids[0]):
        print(f" {ev.token}", end="", flush=True)
    print()
    while not eng.idle:
        eng.step()
    dt = time.time() - t0

    for uid in uids[:4]:
        out = eng.output(uid)
        print(f"  request {uid}: {list(out.tokens)} "
              f"({out.finish_reason}, queue {out.queue_wait_s*1e3:.0f}ms, "
              f"prefill {out.prefill_s*1e3:.0f}ms, "
              f"{out.decode_tokens_per_s:.1f} tok/s)")
    m = eng.metrics()
    print(f"{m['tokens_generated']} tokens in {dt:.1f}s "
          f"({m['tokens_generated']/dt:.1f} tok/s on CPU); "
          f"occupancy {m['slot_occupancy']:.2f}, "
          f"decode steps {m['decode_steps']}")
    if args.paged:
        print(f"  paged KV: peak {m['peak_blocks_in_use']} blocks "
              f"({m['peak_kv_bytes_in_use']/1e6:.2f} MB), "
              f"{m['prefill_chunks']} prefill chunks, "
              f"{m['preemptions']} preemptions")


if __name__ == "__main__":
    main()
