"""Pure-jnp oracle for the fused EVA matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_vq_matmul_ref(
    x: jax.Array,          # (M, V, d)
    codebooks: jax.Array,  # (C, d, k)
    I: jax.Array,          # (C, V, N)
    scale: jax.Array,      # (N,)
) -> jax.Array:
    O = jnp.einsum(
        "mvd,cdk->cmvk", x.astype(jnp.float32), codebooks.astype(jnp.float32)
    )
    g = jnp.take_along_axis(O, I[:, None, :, :].astype(jnp.int32), axis=3)
    return g.sum(axis=(0, 2)) * scale[None, :].astype(jnp.float32)
