"""Fault-tolerance: watchdog, restart driver, checkpoint-resume equivalence,
elastic restart at a different dp size."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train
from repro.runtime import (
    RestartStats, StepWatchdog, run_with_restarts, valid_dp_sizes,
)

ARCH = "qwen3-0.6b"
COMMON = dict(smoke=True, seq_len=16, global_batch=4, lr=3e-3, log_every=0)


class TestWatchdog:
    def test_flags_stragglers(self):
        wd = StepWatchdog(window=10, threshold=2.0, warmup_steps=2)
        for i in range(12):
            wd.start_step()
            time.sleep(0.03 if i != 8 else 0.12)
            wd.end_step()
        assert 9 in wd.straggler_steps  # step numbering is 1-based
        assert len(wd.straggler_steps) <= 2

    def test_straggler_does_not_poison_baseline(self):
        wd = StepWatchdog(window=10, threshold=2.0, warmup_steps=1)
        for i in range(8):
            wd.start_step()
            time.sleep(0.02)
            wd.end_step()
        wd.start_step(); time.sleep(0.2); rep = wd.end_step()
        assert rep.is_straggler
        wd.start_step(); time.sleep(0.02); rep2 = wd.end_step()
        assert not rep2.is_straggler


class TestRestartDriver:
    def test_restart_until_success(self):
        calls = []

        def loop(start):
            calls.append(start)
            if len(calls) < 3:
                raise RuntimeError("node lost")
            return 10

        stats = run_with_restarts(loop, max_restarts=5,
                                  on_failure=lambda e, n: 5)
        assert stats.restarts == 2
        assert calls == [0, 5, 5]

    def test_gives_up_after_max_restarts(self):
        def loop(start):
            raise RuntimeError("always fails")

        with pytest.raises(RuntimeError, match="exceeded"):
            run_with_restarts(loop, max_restarts=2)

    def test_on_failure_errors_propagate_unwrapped(self):
        """A crash in the on_failure callback is a CONTROLLER bug, not a
        training failure: it must propagate as-is — not wrapped in the
        max-restarts RuntimeError, and without Python's implicit 'during
        handling of the above exception' chaining."""
        class ControllerBug(Exception):
            pass

        def loop(start):
            raise RuntimeError("node lost")

        def bad_callback(err, n):
            raise ControllerBug("callback exploded")

        with pytest.raises(ControllerBug) as exc_info:
            run_with_restarts(loop, max_restarts=5, on_failure=bad_callback)
        # no implicit chaining: the callback ran outside the except block
        assert exc_info.value.__context__ is None

    def test_last_resume_step_set_without_callback(self):
        """Regression: last_resume_step was only updated when on_failure
        was provided; the default path (resume at the same step) left it
        stale at 0 even after restarts."""
        calls = []

        def loop(start):
            calls.append(start)
            if len(calls) < 2:
                raise RuntimeError("node lost")
            return 10

        stats = run_with_restarts(loop, max_restarts=3)
        assert stats.restarts == 1
        assert stats.last_resume_step == 0 and calls == [0, 0]

        calls.clear()
        stats = run_with_restarts(loop, max_restarts=3,
                                  on_failure=lambda e, n: 7)
        assert stats.last_resume_step == 7 and calls == [0, 7]


class TestEndToEndRecovery:
    def test_injected_failure_resumes_and_matches(self, tmp_path):
        """Training with a mid-run data failure + restart reaches the same
        final state as an uninterrupted run (checkpoint + deterministic
        data pipeline make recovery exact)."""
        ref = train(ARCH, steps=10, ckpt_dir=str(tmp_path / "ref"),
                    ckpt_every=4, **COMMON)
        out = train(ARCH, steps=10, ckpt_dir=str(tmp_path / "ft"),
                    ckpt_every=4, fail_at=6, max_restarts=2, **COMMON)
        assert out["restarts"] == 1
        assert out["final_loss"] == pytest.approx(ref["final_loss"], rel=1e-5)

    def test_loss_decreases(self, tmp_path):
        out = train(ARCH, steps=16, ckpt_dir=None, **COMMON)
        losses = [out["losses"][s] for s in sorted(out["losses"])]
        assert losses[-1] < losses[0] * 0.95


class TestElastic:
    def test_valid_dp_sizes(self):
        assert valid_dp_sizes(global_batch=256, num_devices=512,
                              model_parallel=16) == [
            dp for dp in range(1, 33) if 256 % dp == 0
        ]
