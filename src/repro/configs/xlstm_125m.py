"""xLSTM-125M — alternating mLSTM/sLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H vocab=50304, d_ff=0 (projections live in the blocks).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    xlstm_pattern=("mlstm", "slstm"),
    vq_C=2,
)

SMOKE = ModelConfig(
    name="xlstm-125m-smoke",
    family="xlstm",
    num_layers=2,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    head_dim=32,
    d_ff=0,
    vocab_size=512,
    xlstm_pattern=("mlstm", "slstm"),
    vq_C=2,
)
