"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The analytic accelerator
model (accel_model.py) mirrors the paper's simulator; `measured/*` rows
are real wall-clock CPU executions of the JAX ops and carry the chosen
``plan=`` (core/plan.py MatmulPlan.describe()) per row.

``--json <path>`` additionally writes the rows machine-readably (the
``derived`` field parsed into key/value pairs — chosen plan, cost-model
terms, speedups, baseline timings) so the perf trajectory is tracked
across PRs and `core/calibrate.py` can fit the Planner's per-backend
time constants; ``--calibrate <path>`` runs that fit on the freshly
emitted rows and writes a versioned CALIBRATION.json. The `smoke`
module is the tiny-shape variant CI uses to gate the JSON schema
(benchmarks/schema.py) without paying full measured timings, e.g.

    python -m benchmarks.run measured --json BENCH_measured.json \
        --calibrate CALIBRATION.json
    python -m benchmarks.run smoke --json bench_smoke.json

Usage:
    python -m benchmarks.run                    # every module
    python -m benchmarks.run measured fig10     # just the named module(s)
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback
from typing import Any, Dict, List, Optional, Sequence

JSON_SCHEMA = "eva-bench-rows/v1"


def parse_derived(derived: str) -> Dict[str, Any]:
    """Parse a ';'-separated derived string into a dict: ``k=v`` pairs
    become fields (numeric where possible), bare text accumulates under
    "note"."""
    out: Dict[str, Any] = {}
    notes: List[str] = []
    for part in derived.split(";"):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
        else:
            notes.append(part)
    if notes:
        out["note"] = "; ".join(notes)
    return out


def write_json(path: str, rows: List[Dict[str, Any]],
               failures: Sequence[str]) -> None:
    with open(path, "w") as f:
        json.dump({"schema": JSON_SCHEMA, "rows": rows,
                   "failures": list(failures)}, f, indent=1)
        f.write("\n")


def main(argv: Optional[Sequence[str]] = None) -> None:
    from benchmarks import (
        fig8_dse, fig10_decode, fig11_batch, fig12_e2e, fig14_spurious,
        measured, serve, smoke, tbl_iii_vq_configs, tbl_v_accuracy_proxy,
        tbl_viii_throughput, tbl_x_oc_advantage,
    )

    modules = [
        ("tbl_iii", tbl_iii_vq_configs),
        ("fig8", fig8_dse),
        ("tbl_viii", tbl_viii_throughput),
        ("fig10", fig10_decode),
        ("fig11", fig11_batch),
        ("fig12", fig12_e2e),
        ("fig14", fig14_spurious),
        ("tbl_x", tbl_x_oc_advantage),
        ("tbl_v", tbl_v_accuracy_proxy),
        ("measured", measured),
        ("serve", serve),
    ]
    known = {name for name, _ in modules} | {"smoke"}
    # tiny-shape CI smoke: only when named explicitly (not part of "all")
    smoke_mod = ("smoke", smoke)

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("modules", nargs="*", metavar="MODULE",
                    help=f"module(s) to run (default all): {sorted(known)}")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (derived fields parsed)")
    ap.add_argument("--calibrate", default=None, metavar="PATH",
                    help="fit per-backend time constants from the emitted "
                         "rows and write a versioned CALIBRATION.json")
    args = ap.parse_args(list(argv) if argv is not None else None)

    selected = set(args.modules)
    unknown = selected - known
    if unknown:
        sys.exit(f"unknown benchmark module(s) {sorted(unknown)}; "
                 f"choose from {sorted(known)}")
    if "smoke" in selected:
        modules.append(smoke_mod)

    rows: List[Dict[str, Any]] = []
    current_module = [""]

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.3f},{derived}", flush=True)
        rows.append({"module": current_module[0], "name": name,
                     "us_per_call": round(us, 3),
                     "derived": parse_derived(derived)})

    print("name,us_per_call,derived")
    failures = []
    for name, mod in modules:
        if selected and name not in selected:
            continue
        current_module[0] = name
        try:
            mod.run(report)
        except Exception as e:  # keep the harness running
            failures.append((name, e))
            report(f"{name}/ERROR", -1.0, f"{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if args.json:
        write_json(args.json, rows, [f"{n}: {e}" for n, e in failures])
    if args.calibrate:
        if failures:
            # never persist a fit from partial rows: a crashed module
            # would silently degrade every Planner loading the file
            print(f"calibration NOT written ({args.calibrate}): "
                  f"{len(failures)} module failure(s)", file=sys.stderr)
        else:
            from repro.core import calibrate as calibrate_mod

            # fit ONLY from the measured module's rows: smoke rows are
            # throwaway tiny-shape CI timings and must never overwrite a
            # valid calibration with under-sampled entries
            fit_rows = [r for r in rows if r.get("module") == "measured"]
            source = args.json or "benchmarks.run (unwritten rows)"
            calib = calibrate_mod.fit_calibration(
                {"schema": JSON_SCHEMA, "rows": fit_rows}, source=source)
            usable = sum(e.rows >= calibrate_mod.MIN_FIT_ROWS
                         for e in calib.backends.values())
            if not usable:
                print(f"calibration NOT written ({args.calibrate}): no "
                      f"backend reached {calibrate_mod.MIN_FIT_ROWS} "
                      "measured rows (run the `measured` module)",
                      file=sys.stderr)
            else:
                calibrate_mod.save_calibration(calib, args.calibrate)
                print(f"calibration: {len(calib.backends)} backends "
                      f"({usable} rankable) -> {args.calibrate}",
                      file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
