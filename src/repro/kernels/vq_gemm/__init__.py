from repro.kernels.vq_gemm.ops import vq_gemm
from repro.kernels.vq_gemm.ref import vq_gemm_ref
