"""Serving driver: quantize a model to the EVA representation and serve a
synthetic request stream through the request-level continuous-batching
engine (typed submit/step/stream surface, serve/api.py).

    PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
        --requests 8 --max-new 16 --sample
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.plan import PlanPolicy
from repro.models.api import build_model
from repro.models.common import RunConfig
from repro.serve import Engine, EngineConfig, GenerationRequest, SamplingParams


def serve(arch: str = "llama2-7b", *, smoke: bool = True, requests: int = 8,
          max_new: int = 16, prompt_len: int = 12, num_slots: int = 4,
          vq_mode: str = "eva", quantize: bool = True,
          impl: str = "jnp", seed: int = 0,
          sample: bool = False, temperature: float = 0.8, top_k: int = 40,
          top_p: float = 0.95, eos: Any = None) -> Dict[str, Any]:
    """Drive a synthetic trace through the engine. ``sample=True`` mixes
    sampled requests (temperature/top_k/top_p, per-request seeds) among
    the greedy ones; ``eos`` adds a per-request stop token."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    if quantize:
        params = model.quantize(params, method="synthetic", key=key)
    rc = RunConfig(mode="decode", remat=False, attn_chunk=64,
                   plan_policy=PlanPolicy(
                       vq_mode=vq_mode if quantize else "none", impl=impl))
    ecfg = EngineConfig(num_slots=num_slots,
                        max_len=prompt_len + max_new + 8)
    extras = {}
    if cfg.family == "whisper":
        extras["frames"] = np.asarray(
            jax.random.normal(key, (16, cfg.d_model), jnp.float32))
    if cfg.family == "vision":
        extras["image_embeds"] = np.asarray(
            jax.random.normal(key, (8, cfg.d_model), jnp.float32))
    eng = Engine(model, params, rc, ecfg, extras=extras)
    rng = np.random.default_rng(seed)
    eos_ids = () if eos is None else (int(eos),)
    reqs = []
    for i in range(requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              rng.integers(4, prompt_len + 1))
        sp = SamplingParams() if not sample or i % 2 == 0 else SamplingParams(
            greedy=False, temperature=temperature, top_k=top_k, top_p=top_p,
            seed=i)
        reqs.append(GenerationRequest(prompt=prompt, max_new_tokens=max_new,
                                      sampling=sp, eos_ids=eos_ids))
    t0 = time.time()
    uids = [eng.submit(r) for r in reqs]
    events = []
    while not eng.idle:
        events.extend(eng.step())
    dt = time.time() - t0
    results = {u: list(eng.output(u).tokens) for u in uids}
    total_tokens = sum(len(v) for v in results.values())
    return {
        "results": results,
        "outputs": {u: eng.output(u) for u in uids},
        "events": events,
        "metrics": eng.metrics(),
        "wall_s": dt,
        "tokens": total_tokens,
        "tok_per_s": total_tokens / max(dt, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--vq-mode", default="eva", choices=["eva", "dequant"])
    ap.add_argument("--no-quantize", dest="quantize", action="store_false")
    ap.add_argument("--sample", action="store_true",
                    help="mix sampled requests among the greedy ones")
    ap.add_argument("--eos", type=int, default=None,
                    help="per-request stop token id")
    args = ap.parse_args()
    out = serve(args.arch, smoke=args.smoke, requests=args.requests,
                max_new=args.max_new, num_slots=args.slots,
                vq_mode=args.vq_mode, quantize=args.quantize,
                sample=args.sample, eos=args.eos)
    m = out["metrics"]
    print(f"served {len(out['results'])} requests, {out['tokens']} tokens, "
          f"{out['tok_per_s']:.1f} tok/s")
    print(f"engine: admitted={m['admitted']} rejected={m['rejected']} "
          f"finished={m['finished']} (stop={m['finished_stop']} "
          f"length={m['finished_length']}) decode_steps={m['decode_steps']} "
          f"occupancy={m['slot_occupancy']:.2f}")


if __name__ == "__main__":
    main()
