"""Measured (wall-clock, jitted, CPU) benchmarks of the actual JAX ops —
complements the analytic accelerator model with real executions:

  * eva_matmul vs dequant_matmul vs dense matmul at paper decode shapes
    (M=1, LLaMA-2-7B layer sizes): the compute-collapse (N/2^n) shows up
    as a real CPU speedup because the FLOPs genuinely shrink.
  * Pallas kernels in interpret mode at reduced shapes (correct-path
    timing only; interpret mode is not representative of TPU perf).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as core_ops
from repro.core.vq import synthetic_vq


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(report):
    key = jax.random.PRNGKey(0)
    shapes = [(4096, 4096), (4096, 11008), (11008, 4096)]
    rows = []
    for K, N in shapes:
        x = jax.random.normal(key, (1, K), jnp.float32)
        w = jax.random.normal(key, (K, N), jnp.float32) * 0.02
        vq = synthetic_vq(key, K, N, d=8, n=8, C=2)

        t_dense = _time(jax.jit(core_ops.fp_matmul), x, w)
        t_deq = _time(jax.jit(core_ops.dequant_matmul), x, vq)
        t_eva = _time(jax.jit(core_ops.eva_matmul), x, vq)
        rows.append((K, N, t_dense, t_deq, t_eva))
        report(f"measured/eva_{K}x{N}", t_eva * 1e6,
               f"dense_us={t_dense*1e6:.0f};dequant_us={t_deq*1e6:.0f};"
               f"speedup_vs_dequant={t_deq/t_eva:.2f}")

    # batched decode (continuous batching regime)
    K, N = 4096, 4096
    vq = synthetic_vq(key, K, N, d=8, n=8, C=2)
    for M in (1, 8, 32):
        x = jax.random.normal(key, (M, K), jnp.float32)
        t_eva = _time(jax.jit(core_ops.eva_matmul), x, vq)
        t_deq = _time(jax.jit(core_ops.dequant_matmul), x, vq)
        report(f"measured/batch{M}_{K}x{N}", t_eva * 1e6,
               f"dequant_us={t_deq*1e6:.0f};speedup={t_deq/t_eva:.2f}")

    # pallas kernels, interpret mode (validation-path timing)
    from repro.kernels.fused_vq_matmul import fused_vq_matmul
    vq_s = synthetic_vq(key, 256, 512, d=8, n=8, C=2)
    x_s = jax.random.normal(key, (1, 256), jnp.float32)
    t_fused = _time(
        lambda a, b: fused_vq_matmul(a, b, interpret=True, block_v=8,
                                     block_n=128), x_s, vq_s, iters=3)
    report("measured/pallas_fused_interpret_256x512", t_fused * 1e6,
           "interpret-mode (CPU emulation, not TPU-representative)")
    return rows
