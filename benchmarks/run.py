"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The analytic accelerator
model (accel_model.py) mirrors the paper's simulator; `measured/*` rows
are real wall-clock CPU executions of the JAX ops.

Usage:
    python -m benchmarks.run              # every module
    python -m benchmarks.run measured     # just the named module(s)
"""
from __future__ import annotations

import sys
import traceback
from typing import Optional, Sequence


def main(argv: Optional[Sequence[str]] = None) -> None:
    from benchmarks import (
        fig8_dse, fig10_decode, fig11_batch, fig12_e2e, fig14_spurious,
        measured, tbl_iii_vq_configs, tbl_v_accuracy_proxy,
        tbl_viii_throughput, tbl_x_oc_advantage,
    )

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.3f},{derived}", flush=True)

    modules = [
        ("tbl_iii", tbl_iii_vq_configs),
        ("fig8", fig8_dse),
        ("tbl_viii", tbl_viii_throughput),
        ("fig10", fig10_decode),
        ("fig11", fig11_batch),
        ("fig12", fig12_e2e),
        ("fig14", fig14_spurious),
        ("tbl_x", tbl_x_oc_advantage),
        ("tbl_v", tbl_v_accuracy_proxy),
        ("measured", measured),
    ]
    selected = set(sys.argv[1:] if argv is None else argv)
    known = {name for name, _ in modules}
    unknown = selected - known
    if unknown:
        sys.exit(f"unknown benchmark module(s) {sorted(unknown)}; "
                 f"choose from {sorted(known)}")
    print("name,us_per_call,derived")
    failures = []
    for name, mod in modules:
        if selected and name not in selected:
            continue
        try:
            mod.run(report)
        except Exception as e:  # keep the harness running
            failures.append((name, e))
            report(f"{name}/ERROR", -1.0, f"{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
