"""Pallas TPU kernels for EVA's compute hot-spots.

  vq_gemm         — Step 1: output-codebook GEMM  O = X·B
  oc_lookup       — Step 2: conflict-free OC lookup + add-only reduction
  fused_vq_matmul — flagship: both steps fused, OC resident in VMEM
  dequant_gemv    — conventional-VQ baseline (centroid gather + GEMV)
  int8_gemm       — prefill int8 GEMM (reconfigurable-PE INT8 mode)

All kernels are TPU-targeted (pl.pallas_call + BlockSpec VMEM tiling) and
validated against pure-jnp oracles in interpret mode on CPU.
"""
