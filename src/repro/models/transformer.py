"""Decoder-only transformer family.

Covers the dense GQA archs (minitron-4b, qwen3-0.6b, llama3-8b, qwen2-72b,
llama2-7b) and the MoE archs (mixtral-8x22b with SWA, deepseek-v2-lite with
MLA + shared/routed experts + a leading dense layer).

Layer stacks are scanned (stacked params, one layer's HLO regardless of
depth); `first_dense_layers` splits the stack into an unstacked prefix +
a scanned body (deepseek's layer 0 is a dense MLP).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig, RunConfig


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, *, moe: bool) -> Any:
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": cm.make_rmsnorm(cfg.d_model),
        "mlp_norm": cm.make_rmsnorm(cfg.d_model),
    }
    if cfg.use_mla:
        p["attn"] = cm.make_mla(ks[0], cfg)
    else:
        p["attn"] = cm.make_attention(ks[0], cfg)
    if moe:
        p["moe"] = cm.make_moe(ks[1], cfg)
    else:
        p["mlp"] = cm.make_mlp(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init_params(key, cfg: ModelConfig) -> Any:
    ks = jax.random.split(key, 5)
    n_scan = cfg.num_layers - cfg.first_dense_layers
    is_moe = cfg.family == "moe"

    layer_keys = jax.random.split(ks[0], n_scan)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg, moe=is_moe))(layer_keys)

    params = {
        "embedding": cm.make_embedding(ks[1], cfg.padded_vocab, cfg.d_model),
        "layers": stacked,
        "final_norm": cm.make_rmsnorm(cfg.d_model),
    }
    if cfg.first_dense_layers:
        pre_keys = jax.random.split(ks[2], cfg.first_dense_layers)
        params["pre_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, moe=False)
        )(pre_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.make_linear(ks[3], cfg.d_model, cfg.padded_vocab)
    return params


# ---------------------------------------------------------------------------
# layer forward
# ---------------------------------------------------------------------------


def _layer_fwd(
    lp: Any,
    x: jax.Array,
    rc: RunConfig,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Optional[Dict],
    moe: bool,
) -> Tuple[jax.Array, Optional[Dict]]:
    h = cm.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, new_cache = cm.mla_fwd(
            lp["attn"], h, rc, cfg, positions=positions, cache=cache
        )
    else:
        a, new_cache = cm.attention_fwd(
            lp["attn"], h, rc, cfg,
            positions=positions, cache=cache, window=cfg.sliding_window,
        )
    x = x + a
    h = cm.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
    if moe:
        f = cm.moe_fwd(lp["moe"], h, rc, cfg)
    else:
        f = cm.mlp_fwd(lp["mlp"], h, rc)
    return x + f, new_cache


def _scan_layers(
    stacked: Any,
    x: jax.Array,
    rc: RunConfig,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    caches: Optional[Any],
    moe: bool,
):
    body = functools.partial(_layer_fwd, rc=rc, cfg=cfg, positions=positions, moe=moe)

    def step(carry, xs):
        lp, cache = xs
        fn = body
        if rc.remat and rc.mode == "train":
            fn = jax.checkpoint(
                lambda lp_, x_, c_: body(lp_, x_, cache=c_),
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
            y, nc = fn(lp, carry, cache)
        else:
            y, nc = body(lp, carry, cache=cache)
        return y, nc

    if caches is None:
        n_layers = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        caches_xs = None
        x, new_caches = jax.lax.scan(
            lambda c, lp: step(c, (lp, None)), x, stacked
        )
    else:
        x, new_caches = jax.lax.scan(step, x, (stacked, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# model forward (train / prefill / decode)
# ---------------------------------------------------------------------------


def forward(
    params: Any,
    tokens: jax.Array,            # (B, S) int32
    rc: RunConfig,
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,
    caches: Optional[Any] = None,  # {"pre": ..., "body": ...} stacked per layer
) -> Tuple[jax.Array, Optional[Any]]:
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = cm.embed(params["embedding"], tokens, cfg.act_dtype)
    is_moe = cfg.family == "moe"

    new_caches: Dict[str, Any] = {}
    if cfg.first_dense_layers:
        pre_caches = None if caches is None else caches["pre"]
        x, nc = _scan_layers(
            params["pre_layers"], x, rc, cfg,
            positions=positions, caches=pre_caches, moe=False,
        )
        new_caches["pre"] = nc

    body_caches = None if caches is None else caches["body"]
    x, nc = _scan_layers(
        params["layers"], x, rc, cfg,
        positions=positions, caches=body_caches, moe=is_moe,
    )
    new_caches["body"] = nc

    if rc.mode == "prefill" and rc.lm_head_last_only:
        x = x[:, -1:]  # §Perf: skip the vocab projection for prompt tokens
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.lm_head(
        params.get("lm_head"), x, rc, emb_params=params["embedding"]
    )
    out_caches = new_caches if caches is not None or rc.mode == "prefill" else None
    return logits, out_caches


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               kv_int8: bool = False, kv_int4: bool = False,
               kvq=None) -> Any:
    """Stacked decode caches. SWA archs get a ring buffer of window size;
    kv_int8/int4 store quantized values + per-(token, head) bf16 scales
    (§Perf). ``kvq`` (a core.vq.KVQuantConfig) selects the vector-
    quantized layout instead: uint8 codebook indices (R*G per head) +
    the same per-(token, head) bf16 scale leaves — 4-bit or 2-bit KV
    riding the int8 ``k_s``/``v_s`` plumbing (codebooks live in params,
    see core/quantize.attach_kv_codebooks)."""
    dtype = dtype or cfg.act_dtype
    S = max_len if cfg.sliding_window == 0 else min(max_len, cfg.sliding_window)
    n_scan = cfg.num_layers - cfg.first_dense_layers
    if kvq is not None and (kv_int8 or kv_int4):
        raise ValueError("kvq is mutually exclusive with kv_int8/kv_int4")

    def one_layer(_):
        if cfg.use_mla:
            if kvq is not None:
                return {
                    "latent": jnp.zeros(
                        (batch, S, kvq.idx_width(cfg.kv_lora_rank)),
                        jnp.uint8),
                    "latent_s": jnp.zeros((batch, S, 1), jnp.bfloat16),
                    "k_rope": jnp.zeros((batch, S, cfg.qk_rope_dim), dtype),
                    "len": jnp.zeros((batch,), jnp.int32),
                }
            return {
                "latent": jnp.zeros((batch, S, cfg.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, S, cfg.qk_rope_dim), dtype),
                "len": jnp.zeros((batch,), jnp.int32),
            }
        if kvq is not None:
            w = kvq.idx_width(cfg.head_dim)
            return {
                "k": jnp.zeros((batch, S, cfg.num_kv_heads, w), jnp.uint8),
                "v": jnp.zeros((batch, S, cfg.num_kv_heads, w), jnp.uint8),
                "k_s": jnp.zeros((batch, S, cfg.num_kv_heads), jnp.bfloat16),
                "v_s": jnp.zeros((batch, S, cfg.num_kv_heads), jnp.bfloat16),
                "len": jnp.zeros((batch,), jnp.int32),
            }
        if kv_int8 or kv_int4:
            qdt = jnp.int4 if kv_int4 else jnp.int8
            return {
                "k": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), qdt),
                "v": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), qdt),
                "k_s": jnp.zeros((batch, S, cfg.num_kv_heads), jnp.bfloat16),
                "v_s": jnp.zeros((batch, S, cfg.num_kv_heads), jnp.bfloat16),
                "len": jnp.zeros((batch,), jnp.int32),
            }
        return {
            "k": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), dtype),
            "len": jnp.zeros((batch,), jnp.int32),
        }

    body = jax.vmap(one_layer)(jnp.arange(n_scan))
    caches = {"body": body}
    if cfg.first_dense_layers:
        caches["pre"] = jax.vmap(one_layer)(jnp.arange(cfg.first_dense_layers))
    return caches
