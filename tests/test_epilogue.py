"""Epilogue-selection subsystem: every epilogue formulation (direct /
flat / blocked at several block sizes / recon / "auto") must match the
dequant oracle on odd V/N, grouped splits and M in {1, 8, 32}; the
selection heuristic's regime boundaries are pinned; conflicting argument
combinations raise loudly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops
from repro.core.vq import split_grouped, synthetic_vq

KEY = jax.random.PRNGKey(0)

# (K, N, splits): odd V (K=80 -> V=10, K=88 -> V=11) and N that pad
# against the explicit block sizes below; one grouped family with odd
# member widths.
SHAPES = [
    (80, 70, ()),
    (88, 132, ()),
    (96, 96, (50, 26, 20)),
]

# (epilogue kwarg, block_v kwarg)
EPILOGUE_ARGS = [
    ("direct", "auto"),
    ("flat", "auto"),
    ("blocked", 4),
    ("blocked", 8),
    ("blocked", 32),
    ("blocked", "auto"),
    ("recon", 4),
    ("recon", "auto"),
    ("auto", "auto"),
]


def _mk(K, N, splits, M):
    vq = synthetic_vq(KEY, K, N, d=8, n=8, C=2, splits=splits)
    x = jax.random.normal(jax.random.fold_in(KEY, K * N + M), (M, K),
                          jnp.float32)
    return x, vq


class TestEquivalence:
    @pytest.mark.parametrize("K,N,splits", SHAPES)
    @pytest.mark.parametrize("M", [1, 8, 32])
    @pytest.mark.parametrize("epilogue,block_v", EPILOGUE_ARGS)
    def test_epilogue_matches_dequant_oracle(self, K, N, splits, M,
                                             epilogue, block_v):
        x, vq = _mk(K, N, splits, M)
        got = ops.eva_matmul(x, vq, epilogue=epilogue, block_v=block_v,
                             out_dtype=jnp.float32)
        ref = ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_bare_int_block_v_selects_blocked_scan(self):
        x, vq = _mk(80, 70, (), 3)
        ref = ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
        # supported spellings: bare int block_v (v-blocked scan), defaults
        for kw in (dict(block_v=5), dict()):
            got = ops.eva_matmul(x, vq, out_dtype=jnp.float32, **kw)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)

    def test_removed_legacy_spellings_raise(self):
        """The PR-3 deprecation cycle is over: flat_gather= is gone from
        the signature and passing None for block_v raises instead of
        selecting the direct epilogue."""
        x, vq = _mk(80, 70, (), 3)
        with pytest.raises(TypeError):
            ops.eva_matmul(x, vq, flat_gather=True)  # lint-ok (removal test)
        with pytest.raises(ValueError, match="removed"):
            ops.eva_matmul(x, vq, block_v=None)  # lint-ok (removal test)

    def test_grouped_auto_epilogue_matches_per_member_oracles(self):
        """One wide auto-epilogue matmul + split == independent dequant
        oracles per member, in both the direct (M=1) and recon (M=32)
        regimes."""
        for M in (1, 32):
            x, vq = _mk(96, 96, (50, 26, 20), M)
            y = ops.eva_matmul(x, vq, out_dtype=jnp.float32)
            parts = ops.split_grouped_outputs(y, vq)
            for part, member in zip(parts, split_grouped(vq)):
                ref = ops.dequant_matmul(x, member, out_dtype=jnp.float32)
                np.testing.assert_allclose(np.asarray(part), np.asarray(ref),
                                           rtol=2e-4, atol=2e-4)

    def test_auto_is_default_through_vq_matmul(self):
        x, vq = _mk(80, 70, (), 8)
        got = ops.vq_matmul(x, vq, out_dtype=jnp.float32)
        ref = ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


class TestSelection:
    """Pin the heuristic's regime boundaries (measured crossovers on the
    CI host, benchmarks/measured.py batch + crossover sweeps)."""

    def test_single_token_decode_is_direct(self):
        # paper decode shape M=1, llama-2-7b layer: footprint 17 MB
        assert ops.select_epilogue(1, 512, 4096, 2, 256, 8) == ("direct", None)

    def test_small_batch_stays_direct_below_spill(self):
        # M=4, K=N=4096: 71 MB gathered footprint, still direct (measured
        # ~36 ms direct vs ~180 ms blocked)
        assert ops.select_epilogue(4, 512, 4096, 2, 256, 8) == ("direct", None)

    def test_small_batch_spills_to_blocked_on_wide_n(self):
        # M=4, N=11008: 184 MB footprint thrashes -> v-blocked gather
        kind, bv = ops.select_epilogue(4, 512, 11008, 2, 256, 8)
        assert kind == "blocked"
        assert ops._MIN_BLOCK_V <= bv < 512
        # the live slab must fit the slab budget
        assert 4 * 2 * 4 * bv * (11008 + 256) <= ops.EPILOGUE_SLAB_BYTES

    def test_batched_decode_is_recon(self):
        # M >= d: gather work C*M*V*N exceeds the C*V*N*d reconstruction
        # gathers -> slab-tiled reconstruct-and-GEMM (the measured/batch32
        # fix: recon ~72 ms vs dequant ~260 ms vs direct ~790 ms)
        for M in (8, 16, 32):
            kind, bv = ops.select_epilogue(M, 512, 4096, 2, 256, 8)
            assert kind == "recon"
            assert 1 <= bv <= 512
            # reconstructed slab (bv*d, N) fp32 within its cache target
            assert 4 * bv * 8 * 4096 <= ops.RECON_SLAB_BYTES

    def test_boundary_is_at_m_equals_d(self):
        assert ops.select_epilogue(7, 512, 4096, 2, 256, 8)[0] != "recon"
        assert ops.select_epilogue(8, 512, 4096, 2, 256, 8)[0] == "recon"
        # d=4 weights cross over at M=4
        assert ops.select_epilogue(4, 512, 4096, 2, 256, 4)[0] == "recon"

    def test_distributed_is_flat(self):
        for M in (1, 32):
            assert ops.select_epilogue(M, 512, 4096, distributed=True) == \
                ("flat", None)

    def test_block_v_shrinks_with_n(self):
        _, bv_small = ops.select_epilogue(4, 2048, 11008, 2, 256, 8)
        _, bv_large = ops.select_epilogue(4, 2048, 44032, 2, 256, 8)
        assert bv_large <= bv_small

    def test_tiny_shapes_never_scan(self):
        # smoke-model shapes: one block would cover V -> direct
        assert ops.select_epilogue(1, 8, 64, 2, 256, 8) == ("direct", None)

    def test_gather_footprint_model(self):
        assert ops.epilogue_gather_bytes(1, 512, 4096, 2) == \
            4 * 2 * 512 * (4096 + 256)

    def test_auto_under_mesh_context_selects_flat(self):
        """Inside an active mesh context the auto resolution must pick the
        SPMD-friendly flat epilogue (the V-block scans would reshape a
        sharded V axis into collectives). The mesh flag is captured into
        the LinearSpec at derivation, so the cached plans differ."""
        from jax.sharding import Mesh
        from repro.core import plan as plan_mod

        x, vq = _mk(4096, 4096, (), 32)  # M=32 >= d -> recon off-mesh
        auto = plan_mod.PlanPolicy(vq_mode="eva", epilogue="auto")
        assert plan_mod.plan_vq(x, vq, auto).backend == "eva_recon"
        with Mesh(np.array(jax.devices()[:1]), ("model",)):
            assert plan_mod.plan_vq(x, vq, auto).backend == "eva_flat"
            # explicit requests still win over the mesh preference
            forced = plan_mod.PlanPolicy(vq_mode="eva", epilogue="recon",
                                         block_v=64)
            pl = plan_mod.plan_vq(x, vq, forced)
            assert pl.backend == "eva_recon" and pl.config_dict["bv"] == 64


class TestResolveErrors:
    """The epilogue arguments are one coherent policy with loud errors on
    conflicting combinations — statically contradictory ones raise from
    PlanPolicy at construction, legacy-surface conflicts from the
    eva_matmul wrapper."""

    def _call(self, **kw):
        x, vq = _mk(80, 70, (), 2)
        return ops.eva_matmul(x, vq, **kw)

    def test_block_v_with_non_blocked_epilogue(self):
        for epi in ("direct", "flat", "auto"):
            with pytest.raises(ValueError, match="block_v"):
                self._call(epilogue=epi, block_v=8)

    def test_none_block_v_always_raises(self):
        # the legacy "None means direct" spelling is removed for EVERY
        # epilogue — including an explicit direct request
        for epi in ("blocked", "recon", "auto", "flat", "direct", None):
            with pytest.raises(ValueError, match="removed"):
                self._call(epilogue=epi, block_v=None)  # lint-ok

    def test_unknown_epilogue(self):
        with pytest.raises(ValueError, match="unknown epilogue"):
            self._call(epilogue="bogus")

    def test_bad_block_v_values(self):
        with pytest.raises(ValueError, match="block_v"):
            self._call(block_v=0)
        with pytest.raises(ValueError, match="block_v"):
            self._call(block_v="huge")

    def test_pallas_rejects_jnp_epilogues(self):
        with pytest.raises(ValueError, match="pallas"):
            self._call(impl="pallas", epilogue="flat", interpret=True)

    def test_pallas_validates_block_v(self):
        # the pallas branch shares the jnp path's loud block_v contract
        for bad in (0, -3, "huge"):
            with pytest.raises(ValueError, match="block_v"):
                self._call(impl="pallas", interpret=True, block_v=bad)

    def test_pallas_accepts_auto_and_block_v(self):
        x, vq = _mk(80, 70, (), 2)
        ref = ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
        for kw in (dict(), dict(block_v=4)):
            got = ops.eva_matmul(x, vq, impl="pallas", interpret=True,
                                 out_dtype=jnp.float32, **kw)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)


class TestFusedTiles:
    """The fused Pallas wrapper's auto tile/m-tile sizing — the tile
    model now lives with the kernel wrapper (kernels/fused_vq_matmul),
    sized against the shared VMEM budgets in core/ops."""

    def test_oc_scratch_budget_respected(self):
        from repro.kernels.fused_vq_matmul.ops import select_fused_tiles

        mt, bv, bn = select_fused_tiles(64, 512, 4096, 2, 256)
        v_pad = 512 + ((-512) % bv)
        assert 2 * mt * v_pad * 256 * 4 <= ops.FUSED_OC_SCRATCH_BYTES
        assert 2 * mt * bv * bn * 4 <= ops.FUSED_GATHER_TILE_BYTES

    def test_small_shapes_single_tile(self):
        from repro.kernels.fused_vq_matmul.ops import select_fused_tiles

        mt, bv, bn = select_fused_tiles(1, 10, 70, 2, 256)
        assert mt == 1 and bv == 10 and bn == 70

    def test_block_v_upper_bound_is_paper_tile(self):
        from repro.kernels.fused_vq_matmul.ops import select_fused_tiles

        _, bv, _ = select_fused_tiles(1, 512, 4096, 2, 256)
        assert bv <= ops.DEFAULT_BLOCK_V

    def test_fused_plan_freezes_tiles(self):
        """The eva_fused_pallas plan carries (mt, bv, bn) resolved once —
        nothing re-derived at execute time."""
        from repro.core import plan as plan_mod
        from repro.kernels.fused_vq_matmul.ops import select_fused_tiles

        x, vq = _mk(4096, 4096, (), 4)
        pl = plan_mod.plan_vq(x, vq, plan_mod.PlanPolicy(
            vq_mode="eva", impl="pallas", interpret=True))
        cfgd = pl.config_dict
        _, bv, bn = select_fused_tiles(4, vq.V, vq.N, vq.C, 256)
        assert pl.backend == "eva_fused_pallas"
        assert cfgd["bv"] == bv and cfgd["bn"] == bn and cfgd["mt"] >= 1
