"""Fig. 10: decode latency + energy of EVA vs baselines on LLaMA FC layers
(batch = 1), including the headline speedups (11.17x over FIGLUT etc.).
"""
from __future__ import annotations

from benchmarks.accel_model import model_decode_cost
from repro.configs import get_config

MODELS = ["llama2_7b", "llama3_8b"]
BASELINES = ["SA", "ANT", "FIGNA", "FIGLUT"]
PAPER = {"SA": 31.56, "ANT": 32.53, "FIGNA": 33.50, "FIGLUT": 11.17}
PAPER_ENERGY = {"SA": 12.48, "ANT": 15.96, "FIGNA": 14.96, "FIGLUT": 7.17}


def run(report):
    rows = []
    for m in MODELS:
        cfg = get_config(m)
        eva = model_decode_cost("EVA", cfg, batch=1, bits=2)
        for b in BASELINES:
            c = model_decode_cost(b, cfg, batch=1, bits=2)
            sp = c.latency_s / eva.latency_s
            ee = c.energy / eva.energy
            rows.append((m, b, sp, ee))
            tag = (f"speedup={sp:.2f};paper={PAPER[b]:.2f};"
                   f"eff={ee:.2f};paper_eff={PAPER_ENERGY[b]:.2f}"
                   if m == "llama2_7b" else f"speedup={sp:.2f};eff={ee:.2f}")
            report(f"fig10/{m}/EVA_vs_{b}", c.latency_s * 1e6, tag)
        # W-bit scaling (paper: W2 is 1.99x / 1.49x faster than W4 / W3)
        for bits, paper in ((4, 1.99), (3, 1.49)):
            cw = model_decode_cost("EVA", cfg, batch=1, bits=bits)
            report(f"fig10/{m}/EVA_W2_vs_W{bits}", cw.latency_s * 1e6,
                   f"ratio={cw.latency_s/eva.latency_s:.2f};paper={paper}")
    return rows
