"""Pallas TPU kernel for single-token decode attention over a long KV
cache (flash-decoding): the cache is streamed HBM->VMEM in S-blocks with
an online-softmax accumulator held in VMEM — the second perf-critical
decode op next to the EVA matmul (at 32k context the cache read dominates
the decode step; see EXPERIMENTS.md §Roofline).

GQA layout: q (B, H, hd), cache (B, S, Hk, hd), groups g = H // Hk.
Grid: (B, num_s_blocks) with S innermost; per step the kernel computes
scores for one cache block against all heads and folds them into the
(m, l, acc) online-softmax state in VMEM scratch.

``_flash_decode_kvq_kernel`` is the vector-quantized variant — the EVA
trick in reverse. The cache stores uint8 codebook indices, never fp K/V:
the wrapper dots the query against the K codebook ONCE per step (a
(B, Hk, g, R*G, E) table whose cost is independent of S), the kernel
streams the uint8 index blocks, gathers per-token scores from that
table, runs the same online softmax, and reconstructs V contributions
from the V codebook rows after softmax weighting. HBM traffic per step
is the compressed cache (R*G bytes/token/head + one scale) instead of
2*hd fp values.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, n_s_blocks: int,
                         block_s: int):
    s_blk = pl.program_id(1)

    @pl.when(s_blk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (H, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bs, Hk, hd)
    v = v_ref[0].astype(jnp.float32)                  # (bs, Hk, hd)
    H, hd = q.shape
    bs, Hk, _ = k.shape
    g = H // Hk
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(Hk, g, hd)
    s = jnp.einsum("kgd,skd->kgs", qg, k) * scale     # (Hk, g, bs)
    pos = s_blk * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, -1e30)

    m_prev = m_scr[...]                               # (Hk, g)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[..., None]
                    + jnp.einsum("kgs,skd->kgd", p, v))
    m_scr[...] = m_new

    @pl.when(s_blk == n_s_blocks - 1)
    def _finalize():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = o.reshape(H, hd).astype(o_ref.dtype)


def _flash_decode_kvq_kernel(qd_ref, kidx_ref, vidx_ref, ks_ref, vs_ref,
                             cbv_ref, len_ref, o_ref,
                             m_scr, l_scr, acc_scr, *, n_s_blocks: int,
                             block_s: int):
    s_blk = pl.program_id(1)

    @pl.when(s_blk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qd = qd_ref[0]                                    # (Hk, g, RG, E) f32
    kidx = kidx_ref[0].astype(jnp.int32)              # (bs, Hk, RG)
    vidx = vidx_ref[0].astype(jnp.int32)              # (bs, Hk, RG)
    ks = ks_ref[0].astype(jnp.float32)                # (bs, Hk)
    vs = vs_ref[0].astype(jnp.float32)                # (bs, Hk)
    cbv = cbv_ref[...].astype(jnp.float32)            # (Hk, R, E, vd)
    Hk, g, RG, E = qd.shape
    bs = kidx.shape[0]
    _, R, _, vd = cbv.shape
    G = RG // R
    hd = G * vd

    # scores: the query/K-codebook dots are precomputed in qd (already
    # 1/sqrt(hd)-scaled); per token just gather-and-sum the R*G entries
    # its indices select, then apply the per-(token, head) scale.
    ki = jnp.broadcast_to(
        jnp.transpose(kidx, (1, 2, 0))[:, None], (Hk, g, RG, bs))
    s = jnp.take_along_axis(qd, ki, axis=-1).sum(axis=2)   # (Hk, g, bs)
    s = s * jnp.transpose(ks, (1, 0))[:, None, :]
    pos = s_blk * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, -1e30)

    m_prev = m_scr[...]                               # (Hk, g)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)

    # V reconstruction after softmax weighting: gather each token's R*G
    # codebook rows (flat row id (hk*R + r)*E + idx), sum residual
    # stages, scale — then fold into the accumulator like fp V.
    h_i = jax.lax.broadcasted_iota(jnp.int32, (bs, Hk, R, G), 1)
    r_i = jax.lax.broadcasted_iota(jnp.int32, (bs, Hk, R, G), 2)
    flat = (h_i * R + r_i) * E + vidx.reshape(bs, Hk, R, G)
    cb2 = jnp.transpose(cbv.reshape(Hk * R * E, vd), (1, 0))  # (vd, HkRE)
    rows = jnp.take_along_axis(
        cb2, jnp.broadcast_to(flat.reshape(1, bs * Hk * RG),
                              (vd, bs * Hk * RG)), axis=1)
    vhat = jnp.transpose(rows.reshape(vd, bs, Hk, R, G).sum(axis=3),
                         (1, 2, 3, 0)).reshape(bs, Hk, hd)
    vhat = vhat * vs[..., None]
    acc_scr[...] = (acc_scr[...] * corr[..., None]
                    + jnp.einsum("kgs,skd->kgd", p, vhat))
    m_scr[...] = m_new

    @pl.when(s_blk == n_s_blocks - 1)
    def _finalize():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = o.reshape(Hk * g, hd).astype(o_ref.dtype)


def flash_decode_kvq_pallas(
    qd: jax.Array,       # (B, Hk, g, R*G, E) f32 query/K-codebook dots
    k_idx: jax.Array,    # (B, S, Hk, R*G) uint8
    v_idx: jax.Array,    # (B, S, Hk, R*G) uint8
    k_s: jax.Array,      # (B, S, Hk)
    v_s: jax.Array,      # (B, S, Hk)
    cb_v: jax.Array,     # (Hk, R, E, vd) V codebooks
    lengths: jax.Array,  # (B,) int32
    *,
    out_dtype,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hk, g, RG, E = qd.shape
    S = k_idx.shape[1]
    _, R, _, vd = cb_v.shape
    hd = (RG // R) * vd
    assert S % block_s == 0, (S, block_s)
    n_s_blocks = S // block_s
    grid = (B, n_s_blocks)

    kernel = functools.partial(_flash_decode_kvq_kernel,
                               n_s_blocks=n_s_blocks, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hk, g, RG, E), lambda b, s: (b, 0, 0, 0, 0)),
            pl.BlockSpec((1, block_s, Hk, RG), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, block_s, Hk, RG), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, block_s, Hk), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, Hk), lambda b, s: (b, s, 0)),
            pl.BlockSpec((Hk, R, E, vd), lambda b, s: (0, 0, 0, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, Hk * g, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk * g, hd), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((Hk, g), jnp.float32),
            pltpu.VMEM((Hk, g), jnp.float32),
            pltpu.VMEM((Hk, g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qd, k_idx, v_idx, k_s, v_s, cb_v, lengths)


def flash_decode_pallas(
    q: jax.Array,        # (B, H, hd)
    k: jax.Array,        # (B, S, Hk, hd)
    v: jax.Array,        # (B, S, Hk, hd)
    lengths: jax.Array,  # (B,) int32 valid cache lengths
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    _, S, Hk, _ = k.shape
    assert H % Hk == 0 and S % block_s == 0, (H, Hk, S, block_s)
    g = H // Hk
    n_s_blocks = S // block_s
    grid = (B, n_s_blocks)

    kernel = functools.partial(_flash_decode_kernel,
                               n_s_blocks=n_s_blocks, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, block_s, Hk, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, block_s, Hk, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hk, g), jnp.float32),
            pltpu.VMEM((Hk, g), jnp.float32),
            pltpu.VMEM((Hk, g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths)
