"""Pure-jnp oracle for the int8 prefill GEMM."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_gemm_ref(xq, wq, xs, ws) -> jax.Array:
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * xs.astype(jnp.float32) * ws.astype(jnp.float32)
