"""Optimizers: AdamW (with fp32 master weights for bf16 params) and
SGD+momentum. Pure-pytree implementation (no optax dependency), designed
to be shardable: optimizer state mirrors the param tree so any param
PartitionSpec applies leaf-wise, and the ZeRO-1 mode additionally shards
m/v/master over the data axis (see runtime/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    use_master: bool = False   # keep fp32 master copies (bf16 training)


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any  # fp32 master params or None-like empty tree


def _tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def adamw_init(params: Any, cfg: AdamWConfig) -> AdamWState:
    master = (
        jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), params)
        if cfg.use_master else None
    )
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=_tree_zeros_like(params, jnp.float32),
        v=_tree_zeros_like(params, jnp.float32),
        master=master,
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
) -> Tuple[Any, AdamWState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p, mast):
        g32 = g.astype(jnp.float32)
        m_ = cfg.b1 * m + (1 - cfg.b1) * g32
        v_ = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m_ / b1c
        vhat = v_ / b2c
        base = mast if mast is not None else p.astype(jnp.float32)
        new32 = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                             + cfg.weight_decay * base)
        return new32, m_, v_

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    flat_p = tdef.flatten_up_to(params)
    flat_mast = (
        tdef.flatten_up_to(state.master) if state.master is not None
        else [None] * len(flat_p)
    )
    new32s, ms, vs = [], [], []
    for g, m, v, p, mast in zip(flat_g, flat_m, flat_v, flat_p, flat_mast):
        n32, m_, v_ = upd(g, m, v, p, mast)
        new32s.append(n32)
        ms.append(m_)
        vs.append(v_)
    new_params = tdef.unflatten(
        [n32.astype(p.dtype) for n32, p in zip(new32s, flat_p)]
    )
    new_master = tdef.unflatten(new32s) if state.master is not None else None
    new_state = AdamWState(step=step, m=tdef.unflatten(ms),
                           v=tdef.unflatten(vs), master=new_master)
    return new_params, new_state, gnorm


# ----------------------------------------------------------------- SGD-M ---


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 1e-2
    momentum: float = 0.9
    grad_clip: float = 0.0


class SGDState(NamedTuple):
    step: jax.Array
    mom: Any


def sgd_init(params: Any, cfg: SGDConfig) -> SGDState:
    return SGDState(step=jnp.zeros((), jnp.int32),
                    mom=_tree_zeros_like(params, jnp.float32))


def sgd_update(grads, state: SGDState, params, cfg: SGDConfig,
               lr_scale=1.0):
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)

    def upd(g, mom, p):
        mom_ = cfg.momentum * mom + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * lr_scale * mom_).astype(p.dtype), mom_

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    outs = [upd(g, m, p) for g, m, p in zip(
        flat_g, tdef.flatten_up_to(state.mom), tdef.flatten_up_to(params))]
    return (
        tdef.unflatten([o[0] for o in outs]),
        SGDState(step=state.step + 1, mom=tdef.unflatten([o[1] for o in outs])),
        gnorm,
    )
