"""Pure-jnp oracles for the flash-decode kernels.

``flash_decode_ref`` is the plain masked-softmax oracle over an fp
cache. ``flash_decode_kvq_ref`` is the DEQUANTIZE ORACLE for the KV-VQ
kernel: reconstruct the full fp cache through ``core.vq.kv_decode``,
then run the fp oracle — the Pallas KVQ kernel is parity-pinned against
this path (tests/test_kvvq.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.vq import kv_decode


def flash_decode_ref(q, k, v, lengths) -> jax.Array:
    """q (B,H,hd), k/v (B,S,Hk,hd), lengths (B,) -> (B,H,hd)."""
    B, H, hd = q.shape
    S, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    qg = q.reshape(B, Hk, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def flash_decode_kvq_ref(q, k_idx, v_idx, k_s, v_s, lengths,
                         cb_k, cb_v) -> jax.Array:
    """Dequantize-then-attend oracle for the KV-VQ decode kernel.

    Args:
      q: (B, H, hd) queries.
      k_idx/v_idx: (B, S, Hk, R*G) uint8 codebook indices.
      k_s/v_s: (B, S, Hk) per-(token, head) scales.
      lengths: (B,) valid cache lengths.
      cb_k/cb_v: (Hk, R, E, vd) K/V codebooks.

    Returns: (B, H, hd) attention output in q.dtype.
    """
    k = kv_decode(k_idx, k_s, cb_k)
    v = kv_decode(v_idx, v_s, cb_v)
    return flash_decode_ref(q, k, v, lengths)
