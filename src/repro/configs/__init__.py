"""Architecture registry: `get_config(arch_id)` / `get_smoke_config(arch_id)`.

One module per assigned architecture (exact published config) plus the
paper's own evaluation model (llama2-7b). Smoke configs are reduced
same-family variants for CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

ARCH_IDS: List[str] = [
    "minitron_4b",
    "qwen3_0_6b",
    "llama3_8b",
    "qwen2_72b",
    "whisper_medium",
    "xlstm_125m",
    "deepseek_v2_lite_16b",
    "mixtral_8x22b",
    "recurrentgemma_2b",
    "llama_3_2_vision_11b",
    # the paper's own model (Tbl. III-X)
    "llama2_7b",
]


def _norm(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch)}")
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
