"""Jit'd wrapper for the VQ-GEMM kernel (handles padding + reshape).

This module owns the kernel's tile model (`select_gemm_block_mv`): the
per-grid-step VMEM footprint is the x tile (bmv, d) plus the O tile
(bmv, k) fp32, sized against the shared FUSED_GATHER_TILE_BYTES budget
in core/ops.py. The two-kernel `eva_split_pallas` backend (registered
from kernels/oc_lookup/ops.py) consumes it to freeze block_mv at plan
time."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ops as core_ops
from repro.kernels.vq_gemm.kernel import vq_gemm_pallas
from repro.kernels.vq_gemm.ref import vq_gemm_ref


def select_gemm_block_mv(MV: int, d: int, k: int) -> int:
    """Largest power-of-two MV tile whose (bmv, d) x tile + (bmv, k) O
    tile fp32 fit the shared tile budget, clamped to [8, 1024] AND to
    the next power of two above the actual problem (the wrapper pads MV
    up to a tile multiple — a decode-sized MV must not pad to a full
    budget-sized tile of dead rows)."""
    per_row = 4 * (d + k)
    bmv = max(8, core_ops.FUSED_GATHER_TILE_BYTES // max(per_row, 1))
    pow2_ceil_mv = 1 << max(int(MV) - 1, 1).bit_length()
    bmv = min(bmv, 1024, pow2_ceil_mv)
    return max(8, core_ops._pow2_floor(bmv))


@functools.partial(jax.jit, static_argnames=("block_mv", "interpret", "use_pallas"))
def vq_gemm(
    x: jax.Array,            # (..., K)
    codebooks: jax.Array,    # (C, d, k)
    *,
    block_mv: int = 256,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    """Compute the output codebook O (C, M, V, k) for activations x."""
    C, d, k = codebooks.shape
    K = x.shape[-1]
    assert K % d == 0
    V = K // d
    M = x.size // K
    x_flat = x.reshape(M * V, d)

    if not use_pallas:
        O = vq_gemm_ref(x_flat, codebooks)
    else:
        MV = M * V
        pad = (-MV) % block_mv
        if pad:
            x_flat = jnp.pad(x_flat, ((0, pad), (0, 0)))
        O = vq_gemm_pallas(x_flat, codebooks, block_mv=block_mv, interpret=interpret)
        if pad:
            O = O[:, :MV]
    return O.reshape(C, M, V, k)
