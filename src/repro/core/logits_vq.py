"""VQ-Logits: a vector-quantized LM head (arXiv:2505.10202 style).

The dense output head is the single largest decode matmul: ``(M, D) @
(D, V)`` with V the (padded) vocabulary. VQ-Logits replaces the V
per-token output embeddings with a small codebook of ``Kc`` codeword
embeddings plus a ``(V,)`` token→codeword assignment and a per-token
scale: the implied dense head is

    W[:, v] = scale[v] * codebook[:, assign[v]]

so scoring factors into one small matmul against the codebook — ``(M, D)
@ (D, Kc)`` — followed by a gather ("scatter to full logits") along the
assignment. MACs drop from ``M*D*V`` to ``M*D*Kc`` with ``Kc << V``.

The head is a param-tree node ``{"vql": VQLogitsHead}``, attached by
``core.quantize.attach_vq_logits_head`` and consumed by
``models.common.linear`` through the same ``core.plan`` dispatch as
every other weight family: ``plan_node`` derives a ``kind="vq_logits"``
spec and the two jnp formulations below compete on the cost model —
gather-scoring (the point of the scheme) vs. expand-to-dense (the exact
oracle, also used by parity tests).

Constructors mirror ``core.vq``: ``synthetic_logits_vq`` draws a random
head whose implied dense weight is exact by construction (for parity
tests), ``fit_logits_vq`` compresses a trained dense head by k-means
over its scale-normalized columns.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import ops
from repro.core import plan as plan_mod
from repro.core import vq as vq_mod


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class VQLogitsHead:
    """Compressed LM head: ``W[:, v] = scale[v] * codebook[:, assign[v]]``.

    codebook : (D, Kc) float — codeword output embeddings (columns)
    assign   : (V,) int32    — token → codeword id
    scale    : (V,) float32  — per-token magnitude (1.0 for synthetic)
    """

    codebook: jax.Array
    assign: jax.Array
    scale: jax.Array

    @property
    def D(self) -> int:
        return int(self.codebook.shape[0])

    @property
    def Kc(self) -> int:
        return int(self.codebook.shape[1])

    @property
    def V(self) -> int:
        return int(self.assign.shape[0])

    def tree_flatten(self):
        return (self.codebook, self.assign, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def expand(head: VQLogitsHead) -> jax.Array:
    """Materialize the implied dense head ``(D, V)`` — the exact oracle."""
    w = jnp.take(head.codebook, head.assign, axis=1)
    return w * head.scale[None, :].astype(w.dtype)


def synthetic_logits_vq(key, d_model: int, vocab: int, kc: int,
                        dtype=jnp.float32) -> VQLogitsHead:
    """Random head whose implied dense weight is exact by construction:
    parity tests compare a model using this head against the same model
    with ``{"w": expand(head)}`` and demand bit-identical logits."""
    k_cb, k_as = jax.random.split(key)
    cb = (jax.random.normal(k_cb, (d_model, kc), jnp.float32)
          / jnp.sqrt(jnp.float32(d_model))).astype(dtype)
    assign = jax.random.randint(k_as, (vocab,), 0, kc, jnp.int32)
    return VQLogitsHead(cb, assign, jnp.ones((vocab,), jnp.float32))


def fit_logits_vq(key, w, kc: int, *, iters: int = 20) -> VQLogitsHead:
    """Compress a trained dense head ``w (D, V)`` by k-means over its
    scale-normalized columns. ``scale[v]`` is the column L2 norm, so the
    clustered points live on (near) the unit sphere and the codebook
    captures direction, not magnitude."""
    w = jnp.asarray(w, jnp.float32)
    d_model, vocab = w.shape
    scale = jnp.linalg.norm(w, axis=0)
    safe = jnp.maximum(scale, 1e-12)
    points = (w / safe[None, :]).T                       # (V, D)
    centroids, assign = vq_mod.kmeans(key, points, kc, iters=iters)
    return VQLogitsHead(centroids.T, assign.astype(jnp.int32),
                        scale.astype(jnp.float32))


def vq_logits_spec(head: VQLogitsHead, *, M: int, x_dtype,
                   out_dtype) -> plan_mod.LinearSpec:
    """Spec for a VQ-Logits head site. Field mapping (cf.
    ``kvq_attention_spec``): K=d_model, N=vocab, k=codebook size Kc."""
    return plan_mod.LinearSpec(
        M=int(M), K=head.D, N=head.V, kind="vq_logits",
        x_dtype=jnp.dtype(x_dtype).name, out_dtype=jnp.dtype(out_dtype).name,
        k=head.Kc,
    )


# ---------------------------------------------------------------------------
# Planner backends
# ---------------------------------------------------------------------------


def _plan_vql_gather(spec: plan_mod.LinearSpec,
                     policy: plan_mod.PlanPolicy) -> plan_mod.MatmulPlan:
    """Codebook-vocab scoring + gather: the VQ-Logits formulation."""
    out_dt = jnp.dtype(spec.out_dtype)

    def run(x, head: VQLogitsHead):
        cb = head.codebook
        if cb.dtype != x.dtype:
            cb = cb.astype(x.dtype)
        y = ops.fp_matmul(x, cb, out_dtype=out_dt)        # (..., Kc)
        y = jnp.take(y, head.assign, axis=-1)             # (..., V)
        return y * head.scale.astype(out_dt)

    itemsize = jnp.dtype(spec.x_dtype).itemsize
    cost = plan_mod.PlanCost(
        macs=spec.M * spec.K * spec.k,
        lookup_adds=spec.M * spec.N,
        weight_bytes=spec.K * spec.k * itemsize + spec.N * 8,
        intermediate_bytes=spec.M * spec.k * out_dt.itemsize,
    )
    return plan_mod.MatmulPlan("vql_gather_jnp", spec, policy, (), cost, run)


def _plan_vql_dequant(spec: plan_mod.LinearSpec,
                      policy: plan_mod.PlanPolicy) -> plan_mod.MatmulPlan:
    """Expand-to-dense oracle: materialize the implied head, dense GEMM.
    Never the cost winner at decode M, but competes in the same ranking
    and anchors parity."""
    out_dt = jnp.dtype(spec.out_dtype)

    def run(x, head: VQLogitsHead):
        w = expand(head)
        if w.dtype != x.dtype:
            w = w.astype(x.dtype)
        return ops.fp_matmul(x, w, out_dtype=out_dt)

    itemsize = jnp.dtype(spec.x_dtype).itemsize
    cost = plan_mod.PlanCost(
        macs=spec.M * spec.K * spec.N,
        lookup_adds=spec.K * spec.N,
        weight_bytes=spec.K * spec.k * itemsize + spec.N * 8,
        intermediate_bytes=spec.K * spec.N * itemsize,
    )
    return plan_mod.MatmulPlan("vql_dequant_jnp", spec, policy, (), cost, run)


def _register_backends() -> None:
    plan_mod.register_backend(
        "vql_gather_jnp",
        lambda s, p: s.kind == "vq_logits",
        _plan_vql_gather,
    )
    plan_mod.register_backend(
        "vql_dequant_jnp",
        lambda s, p: s.kind == "vq_logits",
        _plan_vql_dequant,
    )


_register_backends()
