"""Jit'd wrapper for the VQ-GEMM kernel (handles padding + reshape)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.vq_gemm.kernel import vq_gemm_pallas
from repro.kernels.vq_gemm.ref import vq_gemm_ref


@functools.partial(jax.jit, static_argnames=("block_mv", "interpret", "use_pallas"))
def vq_gemm(
    x: jax.Array,            # (..., K)
    codebooks: jax.Array,    # (C, d, k)
    *,
    block_mv: int = 256,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    """Compute the output codebook O (C, M, V, k) for activations x."""
    C, d, k = codebooks.shape
    K = x.shape[-1]
    assert K % d == 0
    V = K // d
    M = x.size // K
    x_flat = x.reshape(M * V, d)

    if not use_pallas:
        O = vq_gemm_ref(x_flat, codebooks)
    else:
        MV = M * V
        pad = (-MV) % block_mv
        if pad:
            x_flat = jnp.pad(x_flat, ((0, pad), (0, 0)))
        O = vq_gemm_pallas(x_flat, codebooks, block_mv=block_mv, interpret=interpret)
        if pad:
            O = O[:, :MV]
    return O.reshape(C, M, V, k)
