"""Shared model building blocks: configs, norms, rotary embeddings,
linear layers (dense / int8 / VQ), attention variants (GQA, SWA, local,
MLA), MoE, and cache containers.

Everything is pure-functional: params are pytrees of arrays (or VQWeight
nodes after quantization), and every block is written to be scanned over a
stacked leading layer axis with jax.lax.scan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vq import KVQuantConfig, VQWeight, kv_decode, kv_encode
from repro.core import ops as core_ops
from repro.core import plan as plan_mod
from repro.core.plan import PlanPolicy

Params = Any
PyTree = Any


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | xlstm | rglru | whisper | vision
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0          # >0: SWA for all attn layers (mixtral)
    local_window: int = 0            # >0: local attention window (recurrentgemma)
    # MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # hybrid (recurrentgemma): layers % pattern applied in order
    rec_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0
    conv_width: int = 4
    # xlstm
    xlstm_pattern: Tuple[str, ...] = ()  # e.g. ("mlstm", "slstm")
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    # vision (llama-3.2-vision): one cross-attn layer per `cross_attn_period`
    cross_attn_period: int = 0
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # VQ config (paper defaults: d=8, n=8, C=q)
    vq_d: int = 8
    vq_n: int = 8
    vq_C: int = 2

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 for TP-friendly sharding
        (whisper's 51865 -> 51968; see DESIGN.md §4)."""
        return ((self.vocab_size + 127) // 128) * 128


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Static execution-mode knobs threaded through every block.

    How a matmul executes is a single typed field: ``plan_policy``
    (core/plan.py PlanPolicy) — vq_mode, impl, epilogue + block_v,
    int8_prefill and interpret in one frozen, validated object. Every
    linear layer derives a LinearSpec from its (input, weight) and
    fetches a MatmulPlan from the LRU-cached Planner under this policy;
    the plan carries the chosen backend and all resolved numbers
    (epilogue kind, v-blocks, kernel tiles), so nothing is re-derived at
    execute time. Contradictory policies raise ValueError at
    construction, not at the first matmul.

        RunConfig(mode="decode",
                  plan_policy=PlanPolicy(vq_mode="eva", impl="pallas"))

    The PR-3 flat-knob shims (vq_mode/impl/int8_prefill/interpret/
    epilogue/epilogue_block_v as RunConfig fields) finished their
    deprecation cycle and are REMOVED — constructing a RunConfig with
    one raises TypeError; to derive a config with a different execution
    knob, use the policy-replace helper:

        rc.replace_policy(vq_mode="dequant")

    Non-execution knobs (mode, attention chunking, remat, the §Perf
    levers) stay flat fields.
    """
    mode: str = "train"          # train | prefill | decode
    plan_policy: PlanPolicy = PlanPolicy()  # execution policy (see above)
    attn_chunk: int = 1024       # kv/q chunk for blocked attention
    attn_skip_oob_chunks: bool = False  # hillclimb: skip fully-masked chunks
    remat: bool = True
    # ---- perf-iteration levers (EXPERIMENTS.md §Perf) ----
    lm_head_last_only: bool = False  # prefill: project only the last token
    mla_absorb: bool = False         # MLA decode in latent space (weight absorption)
    kv_cache_int8: bool = False      # int8-quantized KV cache (GQA decode)
    kv_cache_int4: bool = False      # int4-quantized KV cache (more aggressive)
    # vector-quantized KV cache (core/vq.py KVQuantConfig; frozen and
    # hashable). Carries the scale variant the append-time encoder must
    # use; cache detection itself is structural (uint8 "k"/"latent_s")
    kv_vq: Optional[KVQuantConfig] = None

    @property
    def policy(self) -> PlanPolicy:
        """The execution policy (alias of ``plan_policy``)."""
        return self.plan_policy

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)

    def replace_policy(self, **kw) -> "RunConfig":
        """Derive a RunConfig with some policy knobs replaced, e.g.
        ``rc.replace_policy(vq_mode="dequant")``."""
        return dataclasses.replace(
            self, plan_policy=dataclasses.replace(self.plan_policy, **kw))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _dense_init(key, K, N, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(K)
    return jax.random.normal(key, (K, N), dtype) * scale


def make_linear(key, K, N, *, bias=False, dtype=jnp.float32) -> Params:
    p = {"w": _dense_init(key, K, N, dtype)}
    if bias:
        p["b"] = jnp.zeros((N,), dtype)
    return p


# ---------------------------------------------------------------------------
# Linear apply — the single place where EVA enters the model
# ---------------------------------------------------------------------------


def linear(p: Params, x: jax.Array, rc: RunConfig, *, out_dtype=None) -> jax.Array:
    """Apply a (possibly VQ-quantized) linear layer under the current
    execution mode.

      train           -> dense bf16/fp32 matmul
      prefill (+int8) -> int8 GEMM (paper's reconfigurable-PE INT8 mode)
      decode  (vq)    -> EVA VQ-GEMM + OC lookup (or dequant baseline)

    All formulation/impl/epilogue choice lives behind the plan API: the
    (spec, policy) pair resolves through the LRU-cached Planner to a
    MatmulPlan whose backend and tile numbers are frozen at plan time —
    this function contains no epilogue or impl branching, and inside a
    jitted step the planner is only consulted while tracing."""
    out_dtype = out_dtype or x.dtype
    pl = plan_mod.plan_node(p, x, mode=rc.mode, policy=rc.policy,
                            out_dtype=out_dtype)
    if "vq" in p:
        leaf = p["vq"]
    elif "vql" in p:
        leaf = p["vql"]
    else:
        leaf = p["w"]
    y = pl.execute(x, leaf)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def grouped_linear(p: Params, x: jax.Array, rc: RunConfig,
                   *, out_dtype=None) -> Tuple[jax.Array, ...]:
    """Apply a grouped-projection linear (one wide VQWeight holding a
    same-input family, e.g. [Wq|Wk|Wv]) and slice the output at the
    recorded split points.

    One EVA matmul serves the whole family: the VQ-GEMM / output-codebook
    computation is amortized over every member and the fused Pallas kernel
    sweeps one widened N with a single VMEM-resident OC scratch."""
    y = linear(p, x, rc, out_dtype=out_dtype)
    return core_ops.split_grouped_outputs(y, p["vq"])


# ---------------------------------------------------------------------------
# Norms & rotary
# ---------------------------------------------------------------------------


def make_rmsnorm(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["g"].astype(jnp.float32)).astype(dt)


def make_layernorm(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32), "b2": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"] + p["b2"]).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked (flash-style) attention with online softmax
# ---------------------------------------------------------------------------


def _attn_chunk_scores(q, k, scale):
    # q: (B, Sq, H, hd), k: (B, Ck, Hk, hd) -> scores (B, H, Sq, Ck)
    B, Sq, H, hd = q.shape
    Hk = k.shape[2]
    group = H // Hk
    qg = q.reshape(B, Sq, Hk, group, hd)
    s = jnp.einsum("bshgd,bchd->bhgsc", qg.astype(jnp.float32), k.astype(jnp.float32))
    return (s * scale).reshape(B, Hk * group, Sq, k.shape[1])


def _attn_chunk_apply(p, v):
    # p: (B, H, Sq, Ck), v: (B, Ck, Hk, hd) -> (B, Sq, H, hd)
    B, H, Sq, Ck = p.shape
    Hk = v.shape[2]
    group = H // Hk
    pg = p.reshape(B, Hk, group, Sq, Ck)
    o = jnp.einsum("bhgsc,bchd->bshgd", pg, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hk * group, v.shape[-1])


def blocked_attention(
    q: jax.Array,              # (B, Sq, H, hd)
    k: jax.Array,              # (B, Skv, Hk, hd)
    v: jax.Array,              # (B, Skv, Hk, hd)
    *,
    causal: bool,
    window: int = 0,           # >0: only attend within `window` positions back
    q_offset: int = 0,         # absolute position of q[0] (for cached decode)
    chunk: int = 1024,
    skip_oob_chunks: bool = False,
) -> jax.Array:
    """Memory-bounded attention: q processed in chunks (unrolled), kv scanned
    with online softmax. `skip_oob_chunks` statically skips kv chunks that
    are fully masked (causal future / outside the sliding window) — the
    'triangular schedule' perf option (§Perf)."""
    B, Sq, H, hd = q.shape
    hd_v = v.shape[-1]          # may differ from hd (MLA)
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    cq = min(chunk, Sq)
    ck = min(chunk, Skv)
    # pad to multiples
    pq, pk = (-Sq) % cq, (-Skv) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // cq, k.shape[1] // ck

    k_chunks = k.reshape(B, nk, ck, *k.shape[2:]).transpose(1, 0, 2, 3, 4)
    v_chunks = v.reshape(B, nk, ck, *v.shape[2:]).transpose(1, 0, 2, 3, 4)
    kv_pos = (jnp.arange(nk * ck)).reshape(nk, ck)

    outs = []
    for iq in range(nq):
        qi = q[:, iq * cq:(iq + 1) * cq]
        q_pos = q_offset + iq * cq + jnp.arange(cq)          # (cq,)
        q_last = q_offset + iq * cq + cq - 1
        q_first = q_offset + iq * cq

        def kv_step(carry, inputs):
            m, l, acc = carry
            kc, vc, pos_c = inputs
            s = _attn_chunk_scores(qi, kc, scale)            # (B,H,cq,ck)
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= pos_c[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= pos_c[None, :] > (q_pos[:, None] - window)
            # mask out kv padding
            mask &= (pos_c < Skv)[None, :]
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            o = _attn_chunk_apply(p, vc)                     # (B,cq,H,hd)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + o
            return (m_new, l_new, acc_new), None

        # choose which kv chunks this q chunk touches
        if skip_oob_chunks:
            sel = []
            for jk in range(nk):
                lo, hi = jk * ck, jk * ck + ck - 1
                if causal and lo > q_last:
                    continue
                if window > 0 and hi <= q_first - window:
                    continue
                sel.append(jk)
            sel = np.asarray(sel, np.int32)
        else:
            sel = np.arange(nk, dtype=np.int32)

        kc_sel = k_chunks[sel]
        vc_sel = v_chunks[sel]
        pos_sel = kv_pos[sel]
        m0 = jnp.full((B, H, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, cq, H, hd_v), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc_sel, vc_sel, pos_sel))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        outs.append(out)

    out = jnp.concatenate(outs, axis=1)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,          # (B, Sq, H, hd) — Sq > 1 for speculative verify
    k_cache: jax.Array,    # (B, S, Hk, hd)
    v_cache: jax.Array,    # (B, S, Hk, hd)
    cache_len: jax.Array,  # (B,) valid lengths (ring caches pass full S)
    *,
    window: int = 0,
    ring: bool = False,
) -> jax.Array:
    """Attention over a (possibly ring-buffered) KV cache.

    ``cache_len`` counts entries INCLUDING the Sq queries just written:
    query i sits at absolute position ``cache_len - Sq + i`` and only
    attends entries at or before itself — at Sq == 1 this reduces to the
    classic ``pos < cache_len`` single-token mask. Ring (SWA) caches are
    single-token only."""
    B, S, Hk, hd = k_cache.shape
    Sq = q.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = _attn_chunk_scores(q, k_cache, scale)           # (B, H, Sq, S)
    pos = jnp.arange(S)[None, :]                        # (1, S)
    if ring:
        # ring buffer: every slot written within the last `window` steps is
        # valid once cache_len >= window; before that only slots < cache_len
        if Sq != 1:
            raise ValueError("ring caches decode one token at a time")
        valid = (pos < jnp.minimum(cache_len, S)[:, None])[:, None, :]
    else:
        qpos = cache_len[:, None] - Sq + jnp.arange(Sq)[None, :]  # (B, Sq)
        valid = pos[None] <= qpos[..., None]            # (B, Sq, S)
    s = jnp.where(valid[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = _attn_chunk_apply(p, v_cache)                   # (B,Sq,H,hd)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (covers dense archs, SWA, local attn, whisper self/cross)
# ---------------------------------------------------------------------------


def _paged_view(arena: jax.Array, block_table: jax.Array) -> jax.Array:
    """Gather a slot-contiguous view from a paged cache arena:
    ``(NB, bs, F...)`` indexed by a ``(B, W)`` block table ->
    ``(B, W*bs, F...)``. Sentinel ids (== NB) CLAMP to the last real
    block (never ``mode="fill"``: NaN fill values survive ``0 * NaN``
    through the masked softmax) — finite garbage the attention validity
    mask (``pos < len``) zeroes out. ``W*bs`` equals the contiguous
    cache's time length by construction (serve/paging.py), so
    downstream attention math is unchanged."""
    B, W = block_table.shape
    bs = arena.shape[1]
    view = jnp.take(arena, block_table, axis=0, mode="clip")
    return view.reshape((B, W * bs) + arena.shape[2:])


def _quantize_kv(x: jax.Array, dtype=jnp.int8):
    """Per-(token, head) symmetric int quantization of a K/V slice.
    x: (B, S, Hk, hd) -> (intN values, per-(B,S,Hk) scales)."""
    qmax = 127.0 if dtype == jnp.int8 else 7.0
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -qmax, qmax).astype(dtype)
    return q, scale.astype(jnp.bfloat16)


def _kvq_decode_attention(q, k_idx, v_idx, k_s, v_s, lengths, cb_k, cb_v,
                          rc: RunConfig, window: int) -> jax.Array:
    """Attend over a KV-VQ cache view (contiguous shape — paged callers
    gather first). Full-cache sites resolve through the planner: every
    backend matching kind="kvq_attn" (the dequantize-jnp oracle and,
    under impl="pallas", the fused kernel) is cost-ranked and the
    cheapest executes. Ring/SWA caches skip the planner — ring validity
    semantics live in decode_attention — and always dequantize, as do
    multi-query windows (speculative verify): the kvq_attn backends are
    single-query formulations."""
    if window == 0 and q.shape[1] == 1:
        B, S, Hk, idx_w = k_idx.shape
        H, hd = q.shape[2], q.shape[3]
        spec = plan_mod.kvq_attention_spec(
            B=B, S=S, H=H, Hk=Hk, hd=hd, idx_width=idx_w,
            entries=cb_k.shape[-2], x_dtype=q.dtype, out_dtype=q.dtype)
        kplan = plan_mod.plan(spec, rc.policy)
        return kplan.execute(
            (q, k_idx, v_idx, k_s, v_s, lengths, cb_k, cb_v), None)
    k_view = kv_decode(k_idx, k_s, cb_k)
    v_view = kv_decode(v_idx, v_s, cb_v)
    return decode_attention(q, k_view, v_view, lengths,
                            window=window, ring=window > 0)


def make_attention(key, cfg: ModelConfig, *, bias: Optional[bool] = None) -> Params:
    bias = cfg.qkv_bias if bias is None else bias
    ks = jax.random.split(key, 4)
    p = {
        "wq": make_linear(ks[0], cfg.d_model, cfg.q_dim, bias=bias),
        "wk": make_linear(ks[1], cfg.d_model, cfg.kv_dim, bias=bias),
        "wv": make_linear(ks[2], cfg.d_model, cfg.kv_dim, bias=bias),
        "wo": make_linear(ks[3], cfg.q_dim, cfg.d_model, bias=False),
    }
    if cfg.qk_norm:
        p["qnorm"] = make_rmsnorm(cfg.head_dim)
        p["knorm"] = make_rmsnorm(cfg.head_dim)
    return p


def attention_fwd(
    p: Params,
    x: jax.Array,                     # (B, S, D)
    rc: RunConfig,
    cfg: ModelConfig,
    *,
    positions: jax.Array,             # (B, S)
    cache: Optional[Dict] = None,     # {"k","v","len"} for decode
    window: int = 0,
    causal: bool = True,
    kv_source: Optional[jax.Array] = None,  # cross-attention memory (B, Skv, D)
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, D = x.shape
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    kv_in = kv_source if kv_source is not None else x
    Skv_in = kv_in.shape[1]
    if "wqkv" in p:
        # grouped QKV (self-attention only — the quantization pass never
        # groups cross-attention): ONE wide EVA matmul, outputs sliced at
        # the recorded (q_dim, kv_dim, kv_dim) split points.
        if kv_source is not None:
            raise ValueError("grouped wqkv is invalid for cross-attention")
        q, k, v = grouped_linear(p["wqkv"], x, rc)
        q = q.reshape(B, S, H, hd)
        k = k.reshape(B, Skv_in, Hk, hd)
        v = v.reshape(B, Skv_in, Hk, hd)
    else:
        q = linear(p["wq"], x, rc).reshape(B, S, H, hd)
        k = linear(p["wk"], kv_in, rc).reshape(B, Skv_in, Hk, hd)
        v = linear(p["wv"], kv_in, rc).reshape(B, Skv_in, Hk, hd)

    if cfg.qk_norm:
        q = rmsnorm(p["qnorm"], q, cfg.norm_eps)
        k = rmsnorm(p["knorm"], k, cfg.norm_eps)
    if kv_source is None:  # rope only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if (rc.mode == "decode" and cache is not None and kv_source is None
            and "block_table" in cache):
        # paged decode (serve/paging.py): scatter the new token through
        # the slot's block table, attend over the gathered view. The
        # view is shape-identical to the contiguous cache, so the same
        # decode_attention / flash_decode math applies token-for-token;
        # sentinel rows (freed / mid-prefill slots) drop the write.
        bt = cache["block_table"]                      # (B, W)
        bs_blk = cache["k"].shape[1]
        W = bt.shape[1]
        Spage = W * bs_blk
        NB = cache["k"].shape[0]
        cache_len = cache["len"]                       # (B,)
        # S > 1: speculative verify writes the whole draft window at
        # absolute positions len..len+S-1; positions past the slot's
        # capacity route to the sentinel and drop (they can never belong
        # to an emitted token — the engine caps emission at `remaining`).
        pos_w = cache_len[:, None] + jnp.arange(S, dtype=cache_len.dtype)
        slot = (pos_w % Spage) if window > 0 else pos_w
        blk = jnp.take_along_axis(bt, jnp.clip(slot // bs_blk, 0, W - 1),
                                  axis=1)                # (B, S)
        phys = jnp.where(slot < Spage, blk, NB)
        off = slot % bs_blk
        new_len = cache_len + S
        if "k_s" in cache and cache["k"].dtype == jnp.uint8:
            # KV-VQ paged decode: encode the new token(s) against the
            # params-resident codebooks (p["kv_cb"]), scatter uint8
            # indices + scales through the block table, attend natively
            # over the compressed arena view.
            variant = rc.kv_vq.variant if rc.kv_vq is not None else "outlier"
            cb_k, cb_v = p["kv_cb"]["k"], p["kv_cb"]["v"]
            k_idx, k_sc = kv_encode(k, cb_k, variant)
            v_idx, v_sc = kv_encode(v, cb_v, variant)
            k_arena = cache["k"].at[phys, off].set(k_idx, mode="drop")
            v_arena = cache["v"].at[phys, off].set(v_idx, mode="drop")
            ks_arena = cache["k_s"].at[phys, off].set(
                k_sc.astype(cache["k_s"].dtype), mode="drop")
            vs_arena = cache["v_s"].at[phys, off].set(
                v_sc.astype(cache["v_s"].dtype), mode="drop")
            o = _kvq_decode_attention(
                q, _paged_view(k_arena, bt), _paged_view(v_arena, bt),
                _paged_view(ks_arena, bt), _paged_view(vs_arena, bt),
                new_len, cb_k, cb_v, rc, window)
            new_cache = {"k": k_arena, "v": v_arena, "k_s": ks_arena,
                         "v_s": vs_arena, "len": new_len,
                         "block_table": bt}
        elif "k_s" in cache:
            cdt = cache["k"].dtype
            kq, ks_ = _quantize_kv(k, cdt)
            vq_, vs_ = _quantize_kv(v, cdt)
            k_arena = cache["k"].at[phys, off].set(kq, mode="drop")
            v_arena = cache["v"].at[phys, off].set(vq_, mode="drop")
            ks_arena = cache["k_s"].at[phys, off].set(ks_, mode="drop")
            vs_arena = cache["v_s"].at[phys, off].set(vs_, mode="drop")
            k_view = (_paged_view(k_arena, bt).astype(jnp.bfloat16)
                      * _paged_view(ks_arena, bt)[..., None].astype(jnp.bfloat16))
            v_view = (_paged_view(v_arena, bt).astype(jnp.bfloat16)
                      * _paged_view(vs_arena, bt)[..., None].astype(jnp.bfloat16))
            o = decode_attention(q, k_view, v_view, new_len,
                                 window=window, ring=window > 0)
            new_cache = {"k": k_arena, "v": v_arena, "k_s": ks_arena,
                         "v_s": vs_arena, "len": new_len,
                         "block_table": bt}
        else:
            k_arena = cache["k"].at[phys, off].set(
                k.astype(cache["k"].dtype), mode="drop")
            v_arena = cache["v"].at[phys, off].set(
                v.astype(cache["v"].dtype), mode="drop")
            if rc.policy.impl == "pallas" and window == 0 and S == 1:
                from repro.kernels.flash_decode import flash_decode_paged

                o = flash_decode_paged(q, k_arena, v_arena, bt, new_len,
                                       interpret=rc.policy.interpret)
            else:
                o = decode_attention(
                    q, _paged_view(k_arena, bt), _paged_view(v_arena, bt),
                    new_len, window=window, ring=window > 0,
                )
            new_cache = {"k": k_arena, "v": v_arena, "len": new_len,
                         "block_table": bt}
    elif rc.mode == "decode" and cache is not None and kv_source is None:
        # write the new token(s) into the (ring) cache. Multi-token
        # windows (speculative verify) scatter per position with
        # mode="drop" — NEVER dynamic_update_slice, whose clamped start
        # would shift the whole slab backward over committed entries
        # when len + S exceeds capacity.
        Sc = cache["k"].shape[1]
        cache_len = cache["len"]                       # (B,)
        pos_w = cache_len[:, None] + jnp.arange(S, dtype=cache_len.dtype)
        slot = (pos_w % Sc) if window > 0 else pos_w   # (B, S); OOB drops
        b_iota = jnp.arange(B)[:, None]
        kvq_cache = "k_s" in cache and cache["k"].dtype == jnp.uint8
        int8_cache = "k_s" in cache and not kvq_cache  # §Perf: int8/int4 KV
        if kvq_cache:
            # KV-VQ contiguous decode: encode the new tokens' K/V against
            # the per-head codebooks, write uint8 indices + scales into
            # the (ring) cache, attend via the planned backend
            variant = rc.kv_vq.variant if rc.kv_vq is not None else "outlier"
            cb_k, cb_v = p["kv_cb"]["k"], p["kv_cb"]["v"]
            k_idx, k_sc = kv_encode(k, cb_k, variant)
            v_idx, v_sc = kv_encode(v, cb_v, variant)
            k_cache = cache["k"].at[b_iota, slot].set(k_idx, mode="drop")
            v_cache = cache["v"].at[b_iota, slot].set(v_idx, mode="drop")
            k_s = cache["k_s"].at[b_iota, slot].set(
                k_sc.astype(cache["k_s"].dtype), mode="drop")
            v_s = cache["v_s"].at[b_iota, slot].set(
                v_sc.astype(cache["v_s"].dtype), mode="drop")
            new_len = cache_len + S
            o = _kvq_decode_attention(q, k_cache, v_cache, k_s, v_s,
                                      new_len, cb_k, cb_v, rc, window)
            new_cache = {"k": k_cache, "v": v_cache, "k_s": k_s, "v_s": v_s,
                         "len": new_len}
        elif int8_cache:
            cdt = cache["k"].dtype
            kq, ks_ = _quantize_kv(k, cdt)
            vq_, vs_ = _quantize_kv(v, cdt)
            k_cache = cache["k"].at[b_iota, slot].set(kq, mode="drop")
            v_cache = cache["v"].at[b_iota, slot].set(vq_, mode="drop")
            k_s = cache["k_s"].at[b_iota, slot].set(ks_, mode="drop")
            v_s = cache["v_s"].at[b_iota, slot].set(vs_, mode="drop")
            new_len = cache_len + S
            o = decode_attention(
                q,
                k_cache.astype(jnp.bfloat16) * k_s[..., None].astype(jnp.bfloat16),
                v_cache.astype(jnp.bfloat16) * v_s[..., None].astype(jnp.bfloat16),
                new_len, window=window, ring=window > 0,
            )
            new_cache = {"k": k_cache, "v": v_cache, "k_s": k_s, "v_s": v_s,
                         "len": new_len}
        else:
            k_cache = cache["k"].at[b_iota, slot].set(
                k.astype(cache["k"].dtype), mode="drop")
            v_cache = cache["v"].at[b_iota, slot].set(
                v.astype(cache["v"].dtype), mode="drop")
            new_len = cache_len + S
            if rc.policy.impl == "pallas" and window == 0 and S == 1:
                from repro.kernels.flash_decode import flash_decode

                o = flash_decode(q, k_cache, v_cache, new_len,
                                 interpret=rc.policy.interpret)
            else:
                o = decode_attention(
                    q, k_cache, v_cache, new_len, window=window,
                    ring=window > 0,
                )
            new_cache = {"k": k_cache, "v": v_cache, "len": new_len}
    elif rc.mode == "decode" and cache is not None and kv_source is not None:
        # cross-attention decode: static memory cache
        o = decode_attention(q, cache["k"], cache["v"], cache["len"])
        new_cache = cache
    elif (cache is not None and "block_table" in cache
          and kv_source is None):
        # chunked-prefill continuation over a paged slot view
        # (serve/paging.slot_view): scatter this chunk's K/V through the
        # block table at their absolute positions, then attend over the
        # gathered view with the query offset at the committed history
        # length ``cache["len"]``. Pad positions beyond the chunk's true
        # length (``cache["prefill_len"]``) route to the sentinel and
        # drop, so bucket padding never corrupts committed prompt KV.
        if rc.mode != "prefill":
            raise ValueError(
                "paged cache reached attention_fwd outside decode/prefill")
        if "k_s" in cache:
            raise NotImplementedError(
                "chunked prefill over quantized (int8/KV-VQ) KV caches "
                "is not supported")
        if B != 1:
            raise ValueError(
                f"chunked-prefill continuation requires B == 1, got {B}")
        bt = cache["block_table"]                      # (1, W)
        bs_blk = cache["k"].shape[1]
        W = bt.shape[1]
        Spage = W * bs_blk
        NB = cache["k"].shape[0]
        hist = cache["len"]                            # (1,) committed len
        true_c = cache["prefill_len"]                  # (1,) chunk true len
        p0 = positions[0]                              # (S,) absolute
        idx = jnp.arange(S)
        valid = (idx < true_c[0]) & (p0 < Spage)
        blk_ids = jnp.take(bt[0], jnp.clip(p0 // bs_blk, 0, W - 1))
        phys = jnp.where(valid, blk_ids, NB)
        off = p0 % bs_blk
        k_arena = cache["k"].at[phys, off].set(
            k[0].astype(cache["k"].dtype), mode="drop")
        v_arena = cache["v"].at[phys, off].set(
            v[0].astype(cache["v"].dtype), mode="drop")
        # traced q_offset forbids the static chunk-skip schedule
        o = blocked_attention(
            q, _paged_view(k_arena, bt), _paged_view(v_arena, bt),
            causal=causal, window=window, q_offset=hist[0],
            chunk=rc.attn_chunk, skip_oob_chunks=False,
        )
        new_cache = {"k": k_arena, "v": v_arena, "len": hist + true_c,
                     "block_table": bt, "prefill_len": true_c}
    else:
        o = blocked_attention(
            q, k, v,
            causal=causal, window=window,
            chunk=rc.attn_chunk, skip_oob_chunks=rc.attn_skip_oob_chunks,
        )
        if rc.mode == "prefill":
            new_cache = {"k": k, "v": v, "len": positions[:, -1] + 1}

    y = linear(p["wo"], o.reshape(B, S, H * hd), rc)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v2): compressed KV latent cache
# ---------------------------------------------------------------------------


def make_mla(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    H = cfg.num_heads
    qk_head = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": make_linear(ks[0], cfg.d_model, H * qk_head),
        "wkv_a": make_linear(ks[1], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim),
        "kv_norm": make_rmsnorm(cfg.kv_lora_rank),
        "wkv_b": make_linear(ks[2], cfg.kv_lora_rank, H * (cfg.qk_nope_dim + cfg.v_head_dim)),
        "wo": make_linear(ks[3], H * cfg.v_head_dim, cfg.d_model),
    }


def mla_fwd(
    p: Params,
    x: jax.Array,
    rc: RunConfig,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Multi-head Latent Attention: KV compressed to (kv_lora_rank +
    qk_rope_dim) per token — the decode cache stores only the latent."""
    B, S, D = x.shape
    H = cfg.num_heads
    dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank

    if "wq_kva" in p:
        # grouped q + kv_a (both consume x): ONE wide EVA matmul sliced at
        # the recorded (H*(dn+dr), r+dr) split points — the VQ-GEMM /
        # output-codebook stage is shared by both projections.
        q, kv_a = grouped_linear(p["wq_kva"], x, rc)
        q = q.reshape(B, S, H, dn + dr)
    else:
        q = linear(p["wq"], x, rc).reshape(B, S, H, dn + dr)
        kv_a = linear(p["wkv_a"], x, rc)                  # (B, S, r + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    latent, k_rope = kv_a[..., :r], kv_a[..., r:]
    latent = rmsnorm(p["kv_norm"], latent, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,dr)

    def expand(latent_, k_rope_):
        kv = linear(p["wkv_b"], latent_, rc).reshape(
            latent_.shape[0], latent_.shape[1], H, dn + dv
        )
        k_nope, vv = kv[..., :dn], kv[..., dn:]
        kk = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_, (*k_nope.shape[:3], dr))], axis=-1
        )
        return kk, vv

    new_cache = None
    if (cache is not None and "block_table" in cache
            and rc.mode != "decode"):
        raise NotImplementedError(
            "chunked prefill for MLA latent caches is not supported "
            "(serve/engine.py gates chunking off for use_mla models)")
    if rc.mode == "decode" and cache is not None:
        cache_len = cache["len"]
        new_len = cache_len + 1
        if "block_table" in cache:
            # paged decode: scatter latent/k_rope through the block
            # table, run the (absorbed or expanded) attention over the
            # gathered view — same math, view shape == contiguous shape.
            bt = cache["block_table"]                  # (B, W)
            bs_blk = cache["latent"].shape[1]
            Sc = bt.shape[1] * bs_blk
            slot = jnp.minimum(cache_len, Sc - 1)
            blk = jnp.take_along_axis(bt, (slot // bs_blk)[:, None],
                                      axis=1)[:, 0]
            off = slot % bs_blk
            kr_arena = cache["k_rope"].at[blk, off].set(
                k_rope.astype(cache["k_rope"].dtype).reshape(B, dr),
                mode="drop")
            kr_cache = _paged_view(kr_arena, bt)       # (B, Sc, dr)
            if "latent_s" in cache:
                # KV-VQ latent: encode against the (single-"head")
                # latent codebook, scatter uint8 indices + scale, then
                # dequantize the gathered view — the absorb/expand math
                # below is layout-blind.
                variant = (rc.kv_vq.variant if rc.kv_vq is not None
                           else "outlier")
                cb_lat = p["kv_cb"]["lat"]             # (1, R, E, vd)
                idx, sc = kv_encode(latent[:, :, None, :], cb_lat, variant)
                lat_arena = cache["latent"].at[blk, off].set(
                    idx.reshape(B, -1), mode="drop")
                ls_arena = cache["latent_s"].at[blk, off].set(
                    sc.reshape(B, 1).astype(cache["latent_s"].dtype),
                    mode="drop")
                lat_cache = kv_decode(
                    _paged_view(lat_arena, bt)[:, :, None, :],
                    _paged_view(ls_arena, bt), cb_lat)[:, :, 0, :]
                new_cache = {"latent": lat_arena, "latent_s": ls_arena,
                             "k_rope": kr_arena, "len": new_len,
                             "block_table": bt}
            else:
                lat_arena = cache["latent"].at[blk, off].set(
                    latent.astype(cache["latent"].dtype).reshape(B, r),
                    mode="drop")
                lat_cache = _paged_view(lat_arena, bt)  # (B, Sc, r)
                new_cache = {"latent": lat_arena, "k_rope": kr_arena,
                             "len": new_len, "block_table": bt}
        else:
            Sc = cache["latent"].shape[1]
            slot = jnp.minimum(cache_len, Sc - 1)
            upd = lambda c, s_, n: jax.lax.dynamic_update_slice(c, n, (s_, 0))
            kr_cache = jax.vmap(upd)(
                cache["k_rope"], slot,
                k_rope.astype(cache["k_rope"].dtype).reshape(B, 1, dr)
            )
            if "latent_s" in cache:
                variant = (rc.kv_vq.variant if rc.kv_vq is not None
                           else "outlier")
                cb_lat = p["kv_cb"]["lat"]
                idx, sc = kv_encode(latent[:, :, None, :], cb_lat, variant)
                lat_idx = jax.vmap(upd)(
                    cache["latent"], slot, idx.reshape(B, 1, -1))
                ls_cache = jax.vmap(upd)(
                    cache["latent_s"], slot,
                    sc.reshape(B, 1, 1).astype(cache["latent_s"].dtype))
                lat_cache = kv_decode(
                    lat_idx[:, :, None, :], ls_cache, cb_lat)[:, :, 0, :]
                new_cache = {"latent": lat_idx, "latent_s": ls_cache,
                             "k_rope": kr_cache, "len": new_len}
            else:
                lat_cache = jax.vmap(upd)(
                    cache["latent"], slot,
                    latent.astype(cache["latent"].dtype).reshape(B, 1, r)
                )
                new_cache = {"latent": lat_cache, "k_rope": kr_cache,
                             "len": new_len}
        if rc.mla_absorb:
            # Weight-absorbed MLA (§Perf): attention runs in the latent
            # space — wkv_b is folded into the query/output sides so the
            # S-length cache is never re-expanded through wkv_b.
            # wkv_b is tiny (r x H(dn+dv)); dequantize it if VQ'd.
            if "vq" in p["wkv_b"]:
                from repro.core.vq import dequantize as _deq

                wb = _deq(p["wkv_b"]["vq"])
            else:
                wb = p["wkv_b"]["w"]
            wb = wb.astype(jnp.float32).reshape(r, H, dn + dv)
            Wk, Wv = wb[..., :dn], wb[..., dn:]
            latf = lat_cache.astype(jnp.float32)          # (B, S, r)
            krf = kr_cache.astype(jnp.float32)            # (B, S, dr)
            q_eff = jnp.einsum("bshd,rhd->bshr",
                               q_nope.astype(jnp.float32), Wk)  # (B,1,H,r)
            # queries are tiny — replicate them over 'model' so the scores
            # stay S-sharded like the latent cache (otherwise GSPMD
            # all-to-alls the whole cache to head-sharded layout, §Perf)
            dpq = ("pod", "data")
            q_eff = _maybe_constrain(q_eff, (dpq, None, None, None))
            q_rope_r = _maybe_constrain(
                q_rope.astype(jnp.float32), (dpq, None, None, None))
            s_nope = jnp.einsum("bshr,bSr->bhsS", q_eff, latf)
            s_rope = jnp.einsum("bshd,bSd->bhsS", q_rope_r, krf)
            scores = (s_nope + s_rope) / jnp.sqrt(float(dn + dr))
            pos = jnp.arange(Sc)[None, :]
            valid = pos < new_len[:, None]
            scores = jnp.where(valid[:, None, None, :], scores, -1e30)
            attn = jax.nn.softmax(scores, axis=-1)        # (B,H,1,S)
            o_lat = jnp.einsum("bhsS,bSr->bshr", attn, latf)
            o = jnp.einsum("bshr,rhv->bshv", o_lat, Wv).astype(x.dtype)
        else:
            # faithful baseline: expand the whole latent cache per step
            kk, vv = expand(lat_cache, kr_cache[:, :, None, :])
            qq = jnp.concatenate([q_nope, q_rope], axis=-1)   # (B,1,H,dn+dr)
            o = decode_attention(qq, kk, vv, new_len)
    else:
        kk, vv = expand(latent, k_rope)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = blocked_attention(
            qq, kk, vv, causal=True, chunk=rc.attn_chunk,
            skip_oob_chunks=rc.attn_skip_oob_chunks,
        )
        if rc.mode == "prefill":
            new_cache = {
                "latent": latent, "k_rope": k_rope.reshape(B, S, dr),
                "len": positions[:, -1] + 1,
            }

    y = linear(p["wo"], o.reshape(B, S, H * dv), rc)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs and MoE
# ---------------------------------------------------------------------------


def make_mlp(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "gate": make_linear(ks[0], d_model, d_ff),
        "up": make_linear(ks[1], d_model, d_ff),
        "down": make_linear(ks[2], d_ff, d_model),
    }


def mlp_fwd(p: Params, x: jax.Array, rc: RunConfig) -> jax.Array:
    if "gu" in p:  # grouped gate+up: one wide EVA matmul, sliced
        g, u = grouped_linear(p["gu"], x, rc)
        return linear(p["down"], jax.nn.silu(g) * u, rc)
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x, rc)) * linear(p["up"], x, rc), rc)


def make_gelu_mlp(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 2)
    return {"up": make_linear(ks[0], d_model, d_ff, bias=True),
            "down": make_linear(ks[1], d_ff, d_model, bias=True)}


def gelu_mlp_fwd(p: Params, x: jax.Array, rc: RunConfig) -> jax.Array:
    return linear(p["down"], jax.nn.gelu(linear(p["up"], x, rc)), rc)


def make_moe(key, cfg: ModelConfig) -> Params:
    """Experts stored stacked on a leading E axis (EP-shardable)."""
    ks = jax.random.split(key, 5)
    E, dff = cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    def stack_init(k, K, N):
        return jax.vmap(lambda kk: _dense_init(kk, K, N))(jax.random.split(k, E))
    p = {
        "router": {"wr": _dense_init(ks[0], cfg.d_model, E)},
        "experts": {
            "gate": {"w": stack_init(ks[1], cfg.d_model, dff)},
            "up": {"w": stack_init(ks[2], cfg.d_model, dff)},
            "down": {"w": stack_init(ks[3], dff, cfg.d_model)},
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = make_mlp(ks[4], cfg.d_model, dff * cfg.num_shared_experts)
    return p


def _expert_ffn(ep: Params, x: jax.Array, rc: RunConfig) -> jax.Array:
    """x: (E, cap, D) with per-expert stacked params (leading E)."""
    if "gu" in ep:  # grouped gate+up per expert (splits survive the vmap)
        def one_g(e_gu, e_down, xe):
            g, u = grouped_linear(e_gu, xe, rc)
            return linear(e_down, jax.nn.silu(g) * u, rc)

        return jax.vmap(one_g)(ep["gu"], ep["down"], x)

    def one(e_gate, e_up, e_down, xe):
        h = jax.nn.silu(linear(e_gate, xe, rc)) * linear(e_up, xe, rc)
        return linear(e_down, h, rc)

    return jax.vmap(one)(ep["gate"], ep["up"], ep["down"], x)


def _mesh_divides(axis: str, dim: int) -> bool:
    try:
        from jax._src import mesh as mesh_lib

        mesh = mesh_lib.thread_resources.env.physical_mesh
        if mesh.empty or axis not in mesh.axis_names:
            return False
        return dim % mesh.shape[axis] == 0
    except Exception:
        return False


def _maybe_constrain(x: jax.Array, spec_axes) -> jax.Array:
    """Apply a sharding constraint when running under a mesh context.

    MoE dispatch/combine buffers have no input sharding to propagate from;
    without an explicit constraint SPMD tends to replicate them, turning
    expert FFNs into (chips x) redundant compute. spec_axes maps axis ->
    preferred mesh axis name (skipped when the axis is absent)."""
    try:
        from jax._src import mesh as mesh_lib
        from jax.sharding import NamedSharding, PartitionSpec

        mesh = mesh_lib.thread_resources.env.physical_mesh
        if mesh.empty:
            return x
        parts = []
        for ax in spec_axes:
            if ax is None or (isinstance(ax, str) and ax not in mesh.axis_names):
                parts.append(None)
            elif isinstance(ax, tuple):
                sel = tuple(a for a in ax if a in mesh.axis_names)
                parts.append(sel if sel else None)
            else:
                parts.append(ax)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*parts))
        )
    except Exception:  # no mesh / incompatible: run unconstrained
        return x


def moe_fwd(p: Params, x: jax.Array, rc: RunConfig, cfg: ModelConfig) -> jax.Array:
    """Token-choice top-k MoE with capacity-based dense dispatch
    (einsum dispatch/combine — shardable over the expert axis)."""
    orig_shape = x.shape
    D = x.shape[-1]
    xt = x.reshape(-1, D)                                   # (T, D)
    T = xt.shape[0]
    E, k = cfg.num_experts, cfg.top_k

    logits = core_ops.fp_matmul(xt, p["router"]["wr"].astype(xt.dtype),
                                out_dtype=jnp.float32)      # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                    # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(math.ceil(T * k / E * cfg.capacity_factor)))
    cap = min(cap, T)
    # position of each (t, k) selection within its expert's capacity buffer
    sel_onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)     # (T, k, E)
    flat = sel_onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                       # (T*k, E)
    pos = jnp.einsum("se,se->s", pos, flat).astype(jnp.int32)   # (T*k,)
    keep = pos < cap
    expert_of = topi.reshape(T * k)
    weight_of = (topv.reshape(T * k) * keep).astype(jnp.float32)

    # dispatch: (E, cap, D) — expert axis on 'model' (EP) when divisible,
    # else capacity over 'data'; without these constraints SPMD replicates
    # the dispatch buffer and every chip computes every expert.
    ep_ok = _mesh_divides("model", E)
    disp_spec = ("model", None, None) if ep_ok else (None, "data", None)
    tok_of = jnp.repeat(jnp.arange(T), k)
    slot = jnp.minimum(pos, cap - 1)
    if T * k * E * cap <= (1 << 22):
        # §Perf: decode-sized dispatch via one-hot einsums — GSPMD
        # partitions matmuls far better than scatters (the scatter path
        # produced ~5x extra all-to-all/permute traffic per layer).
        oh = (jax.nn.one_hot(expert_of, E, dtype=jnp.float32)
              * keep[:, None].astype(jnp.float32))               # (S', E)
        ohc = oh[:, :, None] * jax.nn.one_hot(slot, cap,
                                              dtype=jnp.float32)[:, None, :]
        disp = jnp.einsum("sec,sd->ecd", ohc,
                          xt[tok_of].astype(jnp.float32)).astype(xt.dtype)
        disp = _maybe_constrain(disp, disp_spec)
        out_e = _expert_ffn(p["experts"], disp, rc)              # (E, cap, D)
        out_e = _maybe_constrain(out_e, disp_spec)
        gathered = jnp.einsum("sec,ecd->sd", ohc,
                              out_e.astype(jnp.float32))         # (T*k, D)
    else:
        disp = jnp.zeros((E, cap, D), xt.dtype)
        disp = disp.at[expert_of, slot].add(
            jnp.where(keep[:, None], xt[tok_of], 0).astype(xt.dtype)
        )
        disp = _maybe_constrain(disp, disp_spec)
        out_e = _expert_ffn(p["experts"], disp, rc)              # (E, cap, D)
        out_e = _maybe_constrain(out_e, disp_spec)
        gathered = out_e[expert_of, slot].astype(jnp.float32)    # (T*k, D)
    comb = (gathered.astype(jnp.float32) * weight_of[:, None]).reshape(T, k, D).sum(1)
    y = comb.astype(x.dtype)
    if cfg.num_shared_experts:
        y = y + mlp_fwd(p["shared"], xt, rc)
    return y.reshape(orig_shape)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def make_embedding(key, vocab: int, d: int) -> Params:
    return {"emb": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["emb"], tokens, axis=0).astype(dtype)


def lm_head(p: Params, x: jax.Array, rc: RunConfig, emb_params=None) -> jax.Array:
    if p is None:  # tied
        w = emb_params["emb"].T
        return core_ops.fp_matmul(x, w.astype(x.dtype), out_dtype=jnp.float32)
    return linear(p, x, rc, out_dtype=jnp.float32)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array, mask=None) -> jax.Array:
    """logits (B,S,V) fp32, labels (B,S) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
