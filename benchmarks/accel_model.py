"""Analytic accelerator model reproducing the paper's simulator.

Models latency (cycles) and energy (J) of five architectures on FC-layer
workloads (M tokens, K x N weights):

  SA      32x32 INT8 weight-stationary systolic array (QSERVE W8A8)
  ANT     SA with ANT's adaptive 8-bit datatype (encode/decode overhead)
  FIGNA   FP16-activation/INT4-weight pre-aligned integer PEs
  FIGLUT  LUT-based FP-INT GEMM (4-input LUTs over activation partial sums)
  EVA     this paper: 32x8 FP16 VQ-GEMM + epilogue units (OC lookup)
          plus the reconfigured 32x32 INT8 mode for prefill (EVA-A8W8)

Shared configuration follows Tbl. IV: 500 MHz, 4-channel DDR4 64 GB/s
(128 B/cycle), double-buffered on-chip SRAM so compute and DRAM streaming
overlap: latency = max(compute, memory) per layer.

Energy model (28 nm-class constants, pJ): INT8 MAC 0.2, FP16 MAC 1.2,
FP16 add 0.4, LUT lookup 0.15, SRAM 0.6 pJ/B, DRAM 20 pJ/B. Absolute
numbers are approximate; the *ratios* are what Tbl. VIII / Fig. 10
validate.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

FREQ = 500e6
DRAM_BPS = 64e9
DRAM_B_PER_CYC = DRAM_BPS / FREQ  # 128

E_MAC_I8 = 0.2e-12
E_MAC_FP16 = 1.2e-12
E_ADD_FP16 = 0.4e-12
E_LUT = 0.15e-12
E_SRAM_B = 0.6e-12
E_DRAM_B = 20e-12
# DRAM background + on-chip leakage: energy ~ P_STATIC x latency dominates
# slow GEMV (the paper's Fig. 10(b): 'DRAM access dominates total energy',
# driven by DRAMsim3 background power over the long decode)
P_STATIC = 1.5

ARRAY = 32  # 32x32 PE array


@dataclasses.dataclass
class LayerCost:
    compute_cycles: float
    mem_bytes: float
    compute_energy: float

    @property
    def mem_cycles(self) -> float:
        return self.mem_bytes / DRAM_B_PER_CYC

    @property
    def cycles(self) -> float:
        return max(self.compute_cycles, self.mem_cycles)

    @property
    def latency_s(self) -> float:
        return self.cycles / FREQ

    @property
    def energy(self) -> float:
        return (self.compute_energy + self.mem_bytes * (E_DRAM_B + E_SRAM_B)
                + P_STATIC * self.latency_s)

    def __add__(self, o: "LayerCost") -> "LayerCost":
        return LayerCost(self.compute_cycles + o.compute_cycles,
                         self.mem_bytes + o.mem_bytes,
                         self.compute_energy + o.compute_energy)


def _systolic_cycles(M: int, K: int, N: int, *, fill: int = 2 * ARRAY - 1) -> float:
    """Weight-stationary 32x32 array: each (32,32) weight tile is loaded and
    M activations stream through; fill+drain (2*32-1 cycles) dominates at
    M=1 — the paper's 'one lane active' GEMV pathology (~3% utilization)."""
    tiles = math.ceil(K / ARRAY) * math.ceil(N / ARRAY)
    return tiles * (M + fill)


def sa_cost(M: int, K: int, N: int, w_bits: int = 8, a_bits: int = 8) -> LayerCost:
    comp = _systolic_cycles(M, K, N)
    macs = M * K * N
    mem = K * N * w_bits / 8 + M * K * a_bits / 8 + M * N * 2
    return LayerCost(comp, mem, macs * E_MAC_I8)


def ant_cost(M: int, K: int, N: int) -> LayerCost:
    c = sa_cost(M, K, N, 8, 8)
    # adaptive-type decode adds pipeline overhead (calibrated to the
    # paper's 0.97x of SA throughput)
    return LayerCost(c.compute_cycles * 1.03, c.mem_bytes,
                     c.compute_energy * 1.15)


def figna_cost(M: int, K: int, N: int, w_bits: int = 4) -> LayerCost:
    c = _systolic_cycles(M, K, N) * 1.06  # pre-align stage
    macs = M * K * N
    mem = K * N * w_bits / 8 + M * K * 2 + M * N * 2  # FP16 activations
    return LayerCost(c, mem, macs * E_MAC_I8 * 1.3)


def figlut_cost(M: int, K: int, N: int, w_bits: int = 2) -> LayerCost:
    """FIGLUT: build 16-entry LUTs over groups of 4 activations, then one
    lookup+add per 4 weights per bit-plane (BCQ). Each token's table
    broadcast feeds a 32-PE column, so only min(M,32) of the 32 columns
    are active at small batch (the paper's 4.34% utilization at M=1)."""
    groups = math.ceil(K / 4)
    table_build = M * groups * 16 * 0.5           # adds to build tables
    lanes = ARRAY * min(max(M, 1), ARRAY)         # 32 x min(M,32) LUT lanes
    # bit-serial BCQ passes + partial-sum alignment overhead (x1.4)
    lookups = M * groups * N * w_bits / lanes * 1.4
    comp = table_build / ARRAY + lookups
    mem = K * N * w_bits / 8 + M * K * 2 + M * N * 2
    energy = (M * groups * 16 * E_ADD_FP16
              + M * groups * N * w_bits * (E_LUT + E_ADD_FP16))
    return LayerCost(comp, mem, energy)


def eva_cost(M: int, K: int, N: int, *, d: int = 8, n: int = 8, C: int = 2,
             num_eu: int = 4, v: int = 32) -> LayerCost:
    """EVA decode path (Tbl. IV config): 32x8 FP16 VQ-GEMM + `num_eu`
    32-input adder-tree epilogue units, WC/OC stationary on-chip."""
    V = math.ceil(K / d)
    tiles = math.ceil(V / v) * max(M, 1)
    k = 2 ** n
    # VQ-GEMM: (v x d) @ (d x 2^n) on a 32x8 array -> 2^n cycles/codebook
    gemm = tiles * C * k
    # EU: v*N*C adds per tile, num_eu*32 adds/cycle
    eu = tiles * (v * N * C) / (num_eu * ARRAY)
    # pipelined: GEMM overlaps EU (Fig. 7b)
    comp = max(gemm, eu) + min(gemm, eu) * 0.02
    idx_bytes = V * N * C * (n / 8)
    mem = idx_bytes + M * K * 2 + M * N * 2 + C * d * k * 2
    energy = (tiles * C * k * d * E_MAC_FP16        # OC GEMM
              + tiles * v * N * C * (E_ADD_FP16 + E_LUT)  # lookup+add
              + N * M * E_MAC_FP16)                 # per-channel scale
    return LayerCost(comp, mem, energy)


def eva_int8_cost(M: int, K: int, N: int) -> LayerCost:
    """EVA's prefill mode: the 32x32 INT8 reconfigured array == SA."""
    return sa_cost(M, K, N, 8, 8)


ARCHS = {
    "SA": lambda M, K, N, bits: sa_cost(M, K, N),
    "ANT": lambda M, K, N, bits: ant_cost(M, K, N),
    "FIGNA": lambda M, K, N, bits: figna_cost(M, K, N, w_bits=4),
    "FIGLUT": lambda M, K, N, bits: figlut_cost(M, K, N, w_bits=bits),
    "EVA": lambda M, K, N, bits: eva_cost(M, K, N, C=bits),
    "EVA-A8W8": lambda M, K, N, bits: eva_int8_cost(M, K, N),
}


# ------------------------------------------------------------ workloads ---


def fc_layers(cfg) -> List[Tuple[int, int]]:
    """(K, N) list of the FC layers in one transformer block + counts."""
    D = cfg.d_model
    layers = [
        (D, cfg.q_dim), (D, cfg.kv_dim), (D, cfg.kv_dim), (cfg.q_dim, D),
    ]
    if cfg.num_experts:
        dff = cfg.moe_d_ff or cfg.d_ff
        for _ in range(cfg.top_k + cfg.num_shared_experts):
            layers += [(D, dff), (D, dff), (dff, D)]
    else:
        layers += [(D, cfg.d_ff), (D, cfg.d_ff), (cfg.d_ff, D)]
    return layers


def model_decode_cost(arch: str, cfg, *, batch: int = 1, bits: int = 2,
                      num_layers: int = None) -> LayerCost:
    """Per-token FC cost of `num_layers` blocks (paper runs block 1)."""
    L = num_layers if num_layers is not None else cfg.num_layers
    total = LayerCost(0, 0, 0)
    fn = ARCHS[arch]
    for (K, N) in fc_layers(cfg):
        total = total + fn(batch, K, N, bits)
    return LayerCost(total.compute_cycles * L, total.mem_bytes * L,
                     total.compute_energy * L)


def model_prefill_cost(arch: str, cfg, *, tokens: int, bits: int = 2,
                       num_layers: int = None) -> LayerCost:
    """Prefill: all archs run their GEMM mode; EVA uses the INT8 array."""
    L = num_layers if num_layers is not None else cfg.num_layers
    fn = ARCHS["EVA-A8W8"] if arch == "EVA" else ARCHS[arch]
    total = LayerCost(0, 0, 0)
    for (K, N) in fc_layers(cfg):
        total = total + fn(tokens, K, N, bits)
    return LayerCost(total.compute_cycles * L, total.mem_bytes * L,
                     total.compute_energy * L)
