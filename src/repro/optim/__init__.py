from repro.optim.adamw import (
    AdamWConfig, AdamWState, adamw_init, adamw_update,
    SGDConfig, SGDState, sgd_init, sgd_update,
    clip_by_global_norm, global_norm,
)
from repro.optim.schedule import warmup_cosine, warmup_linear, constant
from repro.optim.compress import (
    compress_psum, init_error_feedback, compression_ratio,
)
