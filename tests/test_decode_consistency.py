"""Serving-path integration: prefill + step-by-step decode reproduces the
full-sequence forward exactly (fp32, drop-free MoE), for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_model
from repro.core.plan import PlanPolicy
from repro.models.common import RunConfig
from repro.serve.kvcache import pad_prefill_cache

KEY = jax.random.PRNGKey(0)
B, S_PROMPT, N_GEN, CAP = 2, 12, 4, 32


def _fp32_cfg(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    if cfg.num_experts:
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.num_experts) / cfg.top_k
        )
    return cfg


def _extras(cfg):
    ex = {}
    if cfg.family == "whisper":
        ex["frames"] = jax.random.normal(KEY, (B, 16, cfg.d_model), jnp.float32)
    if cfg.family == "vision":
        ex["image_embeds"] = jax.random.normal(KEY, (B, 8, cfg.d_model), jnp.float32)
    return ex


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = _fp32_cfg(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (B, S_PROMPT + N_GEN), 0, cfg.vocab_size)
    extras = _extras(cfg)

    logits_full, _ = model.forward(
        params, {"tokens": tokens, **extras},
        RunConfig(mode="train", remat=False, attn_chunk=8),
    )
    logits_pre, caches = model.prefill(
        params, {"tokens": tokens[:, :S_PROMPT], **extras},
        RunConfig(mode="prefill", remat=False, attn_chunk=8),
    )
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1]), np.asarray(logits_full[:, S_PROMPT - 1]),
        rtol=1e-4, atol=1e-4,
    )
    window = cfg.sliding_window or cfg.local_window
    caches = pad_prefill_cache(caches, CAP, window=window)
    rc_d = RunConfig(mode="decode", remat=False)
    for t in range(S_PROMPT, S_PROMPT + N_GEN):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits_d, caches = model.decode(params, tokens[:, t:t + 1], pos,
                                        caches, rc_d)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(logits_full[:, t]),
            rtol=1e-4, atol=1e-4,
        )


@pytest.mark.parametrize("arch", ["llama2_7b", "mixtral_8x22b",
                                  "recurrentgemma_2b", "xlstm_125m",
                                  "deepseek_v2_lite_16b"])
def test_quantized_decode_eva_equals_dequant(arch):
    """Paper's exactness claim at model level: the EVA path and the
    conventional dequant path produce identical logits."""
    cfg = _fp32_cfg(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    qparams = model.quantize(params, method="synthetic", key=KEY)
    tokens = jax.random.randint(KEY, (B, S_PROMPT + 1), 0, cfg.vocab_size)
    extras = _extras(cfg)
    _, caches = model.prefill(
        params, {"tokens": tokens[:, :S_PROMPT], **extras},
        RunConfig(mode="prefill", remat=False, attn_chunk=8),
    )
    window = cfg.sliding_window or cfg.local_window
    caches = pad_prefill_cache(caches, CAP, window=window)
    pos = jnp.full((B, 1), S_PROMPT, jnp.int32)
    tok = tokens[:, S_PROMPT:S_PROMPT + 1]
    l_eva, _ = model.decode(qparams, tok, pos, caches,
                            RunConfig(mode="decode", plan_policy=PlanPolicy(vq_mode="eva"), remat=False))
    l_deq, _ = model.decode(qparams, tok, pos, caches,
                            RunConfig(mode="decode", plan_policy=PlanPolicy(vq_mode="dequant"), remat=False))
    np.testing.assert_allclose(np.asarray(l_eva), np.asarray(l_deq),
                               rtol=1e-4, atol=1e-4)


def test_quantized_decode_pallas_impl():
    cfg = _fp32_cfg("llama2_7b")
    model = build_model(cfg)
    params = model.init(KEY)
    qparams = model.quantize(params, method="synthetic", key=KEY)
    caches = model.init_cache(B, CAP)
    pos = jnp.zeros((B, 1), jnp.int32)
    tok = jnp.zeros((B, 1), jnp.int32)
    l_jnp, _ = model.decode(qparams, tok, pos, caches,
                            RunConfig(mode="decode", plan_policy=PlanPolicy(vq_mode="eva"), remat=False))
    l_pal, _ = model.decode(
        qparams, tok, pos, caches,
        RunConfig(mode="decode", remat=False, plan_policy=PlanPolicy(
            vq_mode="eva", impl="pallas", interpret=True)),
    )
    np.testing.assert_allclose(np.asarray(l_jnp), np.asarray(l_pal),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow  # ~58 s (W+24 single-token steps); the fast SWA smoke
# stays in test_prefill_decode_matches_full_forward[mixtral_8x22b]
def test_ring_cache_swa_long_decode():
    """SWA ring cache: decoding far past the window stays consistent with
    a full-cache reference restricted to the window."""
    cfg = _fp32_cfg("mixtral_8x22b")  # sliding_window=64 in smoke
    model = build_model(cfg)
    params = model.init(KEY)
    W = cfg.sliding_window
    total = W + 24  # run well past one window
    tokens = jax.random.randint(KEY, (1, total), 0, cfg.vocab_size)

    logits_full, _ = model.forward(
        params, {"tokens": tokens},
        RunConfig(mode="train", remat=False, attn_chunk=16),
    )
    _, caches = model.prefill(
        params, {"tokens": tokens[:, :8]},
        RunConfig(mode="prefill", remat=False, attn_chunk=16),
    )
    caches = pad_prefill_cache(caches, W, window=W)
    rc_d = RunConfig(mode="decode", remat=False)
    for t in range(8, total):
        pos = jnp.full((1, 1), t, jnp.int32)
        logits_d, caches = model.decode(params, tokens[:, t:t + 1], pos,
                                        caches, rc_d)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(logits_full[:, -1]),
        rtol=1e-3, atol=1e-3,
    )
