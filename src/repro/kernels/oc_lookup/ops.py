"""Jit'd wrapper for the OC-lookup kernel (padding + dtype handling)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ops as core_ops
from repro.kernels.oc_lookup.kernel import oc_lookup_pallas
from repro.kernels.oc_lookup.ref import oc_lookup_ref


def _auto_tiles(M: int, V: int, N: int, C: int, k: int):
    """This kernel never M-tiles (the wrapper receives the full O), so its
    per-grid-step VMEM is the O BlockSpec (C, M, bv, k) fp32 plus the
    gathered (C, M, bv, bn) fp32 — i.e. 4*C*M*bv*(k + bn) bytes, with the
    FULL M, unlike the fused kernel's m_tile-bounded scratch. Start at
    the paper's v=32 / 512-lane tiles and shrink bn, then bv."""
    bv, bn = min(32, V), min(512, N)
    while bn > 128 and 4 * C * M * bv * (k + bn) > core_ops.FUSED_GATHER_TILE_BYTES:
        bn //= 2
    while bv > 8 and 4 * C * M * bv * (k + bn) > core_ops.FUSED_GATHER_TILE_BYTES:
        bv //= 2
    return bv, min(bn, N)


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_n", "interpret", "use_pallas")
)
def oc_lookup(
    O: jax.Array,
    I: jax.Array,
    scale: jax.Array,
    *,
    block_v="auto",
    block_n="auto",
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    """block_v/block_n accept "auto" (VMEM footprint model below) or
    explicit ints; non-divisible V/N are padded (padded O rows are zero
    -> contribute 0)."""
    C, M, V, k = O.shape
    N = I.shape[-1]
    # indices stream in their storage dtype (uint8 for n<=8); the kernel
    # upcasts per tile — see the uint8 streaming contract in kernel.py
    scale = scale.astype(jnp.float32)
    if not use_pallas:
        return oc_lookup_ref(O, I, scale)

    auto_bv, auto_bn = _auto_tiles(M, V, N, C, k)
    bv = auto_bv if block_v == "auto" else min(block_v, V)
    bn = auto_bn if block_n == "auto" else min(block_n, N)
    pad_v = (-V) % bv
    pad_n = (-N) % bn
    if pad_v:
        # padded rows gather index 0 from zeroed O rows -> contribute 0
        O = jnp.pad(O, ((0, 0), (0, 0), (0, pad_v), (0, 0)))
        I = jnp.pad(I, ((0, 0), (0, pad_v), (0, 0)))
    if pad_n:
        I = jnp.pad(I, ((0, 0), (0, 0), (0, pad_n)))
        scale = jnp.pad(scale, (0, pad_n))
    y = oc_lookup_pallas(O, I, scale, block_v=bv, block_n=bn, interpret=interpret)
    if pad_n:
        y = y[:, :N]
    return y
