from repro.kernels.int8_gemm.ops import int8_matmul_kernel
from repro.kernels.int8_gemm.ref import int8_gemm_ref
