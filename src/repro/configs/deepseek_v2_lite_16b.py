"""DeepSeek-V2-Lite (16B) — MLA (kv_lora=512) + MoE, 2 shared + 64 routed
experts top-6, expert d_ff=1408, first layer dense [arXiv:2405.04434; hf].

27L d_model=2048 16H vocab=102400.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=192,       # qk_nope 128 + qk_rope 64
    d_ff=10944,         # dense first-layer FFN
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10000.0,
    vq_C=2,
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke",
    family="moe",
    num_layers=3,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=48,
    d_ff=512,
    vocab_size=512,
    use_mla=True,
    kv_lora_rank=64,
    qk_nope_dim=32,
    qk_rope_dim=16,
    v_head_dim=32,
    num_experts=8,
    num_shared_experts=2,
    top_k=2,
    moe_d_ff=256,
    first_dense_layers=1,
    vq_C=2,
)
