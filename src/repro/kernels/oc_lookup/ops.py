"""Jit'd wrapper for the OC-lookup kernel (padding + dtype handling) +
the two-kernel ``eva_split_pallas`` plan backend.

The split backend is the paper-faithful no-fusion formulation: kernel 1
(kernels/vq_gemm) materializes the full (C, M, V, 2^n) output-codebook
buffer in HBM, kernel 2 (this module's oc_lookup) runs the structured,
conflict-free gather + add-only reduction over it. Against the fused
kernel it trades one extra HBM round-trip of the OC buffer (priced as
``PlanCost.intermediate_bytes``) and a second launch for per-kernel tile
freedom — the ranked Planner decides per shape which side of that trade
wins (analytically the fused kernel; measured calibration can flip it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ops as core_ops
from repro.core import plan as plan_mod
from repro.core.vq import VQWeight
from repro.kernels.oc_lookup.kernel import oc_lookup_pallas
from repro.kernels.oc_lookup.ref import oc_lookup_ref
from repro.kernels.vq_gemm.ops import select_gemm_block_mv, vq_gemm


def select_lookup_tiles(M: int, V: int, N: int, C: int, k: int):
    """This kernel never M-tiles (the wrapper receives the full O), so its
    per-grid-step VMEM is the O BlockSpec (C, M, bv, k) fp32 plus the
    gathered (C, M, bv, bn) fp32 — i.e. 4*C*M*bv*(k + bn) bytes, with the
    FULL M, unlike the fused kernel's m_tile-bounded scratch. Start at
    the paper's v=32 / 512-lane tiles and shrink bn, then bv."""
    bv, bn = min(32, V), min(512, N)
    while bn > 128 and 4 * C * M * bv * (k + bn) > core_ops.FUSED_GATHER_TILE_BYTES:
        bn //= 2
    while bv > 8 and 4 * C * M * bv * (k + bn) > core_ops.FUSED_GATHER_TILE_BYTES:
        bv //= 2
    return bv, min(bn, N)


_auto_tiles = select_lookup_tiles  # historical name


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_n", "interpret", "use_pallas")
)
def oc_lookup(
    O: jax.Array,
    I: jax.Array,
    scale: jax.Array,
    *,
    block_v="auto",
    block_n="auto",
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    """block_v/block_n accept "auto" (VMEM footprint model below) or
    explicit ints; non-divisible V/N are padded (padded O rows are zero
    -> contribute 0)."""
    C, M, V, k = O.shape
    N = I.shape[-1]
    # indices stream in their storage dtype (uint8 for n<=8); the kernel
    # upcasts per tile — see the uint8 streaming contract in kernel.py
    scale = scale.astype(jnp.float32)
    if not use_pallas:
        return oc_lookup_ref(O, I, scale)

    auto_bv, auto_bn = _auto_tiles(M, V, N, C, k)
    bv = auto_bv if block_v == "auto" else min(block_v, V)
    bn = auto_bn if block_n == "auto" else min(block_n, N)
    pad_v = (-V) % bv
    pad_n = (-N) % bn
    if pad_v:
        # padded rows gather index 0 from zeroed O rows -> contribute 0
        O = jnp.pad(O, ((0, 0), (0, 0), (0, pad_v), (0, 0)))
        I = jnp.pad(I, ((0, 0), (0, pad_v), (0, 0)))
    if pad_n:
        I = jnp.pad(I, ((0, 0), (0, 0), (0, pad_n)))
        scale = jnp.pad(scale, (0, pad_n))
    y = oc_lookup_pallas(O, I, scale, block_v=bv, block_n=bn, interpret=interpret)
    if pad_n:
        y = y[:, :N]
    return y


# ---------------------------------------------------------------------------
# Two-kernel EVA matmul: vq_gemm -> HBM OC buffer -> oc_lookup (no fusion)
# ---------------------------------------------------------------------------


def eva_split_matmul(
    x: jax.Array,
    vq: VQWeight,
    *,
    block_mv="auto",
    block_v="auto",
    block_n="auto",
    interpret: bool = False,
    use_pallas: bool = True,
    out_dtype=None,
) -> jax.Array:
    """EVA decode matmul as TWO kernels with the (C, M, V, 2^n) output
    codebook materialized in HBM between them — the paper's architecture
    drawn at kernel granularity, no fusion. A grouped family is just a
    wider N in the lookup stage (the OC buffer is N-independent, so the
    amortization argument is identical to the fused kernel's)."""
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    N = vq.N
    C, d, k = vq.codebooks.shape
    M = x.size // vq.K
    bmv = select_gemm_block_mv(M * vq.V, d, k) if block_mv == "auto" \
        else int(block_mv)
    O = vq_gemm(x, vq.codebooks, block_mv=bmv, interpret=interpret,
                use_pallas=use_pallas)                    # (C, M, V, k)
    y = oc_lookup(O, vq.idx, vq.scale, block_v=block_v, block_n=block_n,
                  interpret=interpret, use_pallas=use_pallas)
    return y.reshape(*lead, N).astype(out_dtype)


# ---------------------------------------------------------------------------
# Plan backend: eva_split_pallas competes with eva_fused_pallas under
# impl="pallas" — the first genuinely overlapping registration, resolved
# by the Planner's calibrated predicted-time ranking.
# ---------------------------------------------------------------------------


def _match_eva_split(spec: plan_mod.LinearSpec, policy: plan_mod.PlanPolicy
                     ) -> bool:
    # epilogue != "auto" stays the fused registration's loud error (jnp
    # epilogues never apply to a Pallas impl)
    return (spec.kind == "vq" and policy.impl == "pallas"
            and policy.vq_mode in ("eva", "none")
            and policy.epilogue == "auto")


def _plan_eva_split(spec: plan_mod.LinearSpec, policy: plan_mod.PlanPolicy
                    ) -> plan_mod.MatmulPlan:
    auto_bv, auto_bn = select_lookup_tiles(spec.M, spec.V, spec.N, spec.C,
                                           spec.k)
    bv = auto_bv if policy.block_v is None else min(policy.block_v, spec.V)
    bn = auto_bn
    # a pinned block_v may be far larger than the auto sizing assumed:
    # re-shrink bn until the gathered tile honors the VMEM budget again
    while bn > 128 and 4 * spec.C * spec.M * bv * (spec.k + bn) \
            > core_ops.FUSED_GATHER_TILE_BYTES:
        bn //= 2
    bmv = select_gemm_block_mv(spec.M * spec.V, spec.d, spec.k)
    out_dt = jnp.dtype(spec.out_dtype)
    interpret = policy.interpret

    def run(x, vq):
        return eva_split_matmul(x, vq, block_mv=bmv, block_v=bv, block_n=bn,
                                interpret=interpret, out_dtype=out_dt)

    oc_bytes = 4 * spec.C * spec.M * spec.V * spec.k
    cost = plan_mod.PlanCost(
        macs=core_ops.vq_gemm_macs(spec.M, spec.K,
                                   max(spec.k.bit_length() - 1, 0),
                                   spec.C, spec.d),
        lookup_adds=core_ops.epilogue_adds(spec.M, spec.K, spec.N, spec.C,
                                           spec.d),
        weight_bytes=plan_mod.vq_weight_bytes(spec),
        intermediate_bytes=2 * oc_bytes,   # OC write + read-back through HBM
        launches=2,
    )
    return plan_mod.MatmulPlan(
        "eva_split_pallas", spec, policy,
        (("bmv", bmv), ("bv", bv), ("bn", bn)), cost, run)


plan_mod.register_backend("eva_split_pallas", _match_eva_split,
                          _plan_eva_split)
