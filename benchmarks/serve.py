"""Serving-engine throughput: a synthetic request trace through the
request-level engine (serve/api.py submit/step/stream surface).

Emits `eva-bench-rows/v1` throughput rows (module "serve"): every timed
row carries the engine totals — tokens / tok_per_s / requests pulled from
``Engine.metrics()`` — so the serving trajectory is schema-gated and
tracked across PRs the same way the matmul rows are. The trace mixes
greedy and sampled requests (temperature/top-k/top-p) plus a per-request
eos so the in-jit sampling/stopping path is what gets timed; shapes are
tiny so CI can afford real executions.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.vq import KVQuantConfig
from repro.models import build_model
from repro.models.common import RunConfig
from repro.serve import (Engine, EngineConfig, GenerationRequest,
                         SamplingParams, make_paging_config)


def _metrics_fields(m, wall_s: float) -> str:
    # tok_per_s over the measured trace window (submit -> idle), NOT the
    # engine uptime — uptime includes construction/pre-planning, which
    # would shift the tracked trajectory whenever startup cost changes.
    # The KV memory gauges ride every row (schema.SERVE_FIELDS): a
    # contiguous engine reports its constant worst-case kv_bytes_in_use
    # and zero blocks, a paged one its pool accounting + peaks
    tok_per_s = m["tokens_generated"] / max(wall_s, 1e-9)
    return (f"tokens={m['tokens_generated']};tok_per_s={tok_per_s:.1f};"
            f"requests={m['finished']};decode_steps={m['decode_steps']};"
            f"occupancy={m['slot_occupancy']:.3f};"
            f"prefills={m['prefills']};rejected={m['rejected']};"
            f"kv_bytes_in_use={m['kv_bytes_in_use']};"
            f"blocks_in_use={m['blocks_in_use']};"
            f"blocks_free={m['blocks_free']};"
            f"peak_blocks_in_use={m['peak_blocks_in_use']};"
            f"peak_kv_bytes_in_use={m['peak_kv_bytes_in_use']};"
            f"preemptions={m['preemptions']};"
            f"prefill_chunks={m['prefill_chunks']}")


def _trace(eng, reqs):
    t0 = time.perf_counter()
    uids = [eng.submit(r) for r in reqs]
    events = []
    while not eng.idle:
        events.extend(eng.step())
    wall = time.perf_counter() - t0
    assert all(eng.output(u) is not None for u in uids)
    return eng.metrics(), wall, events


def _requests(cfg, rng, max_new):
    return [
        GenerationRequest(  # greedy
            prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
            max_new_tokens=max_new),
        GenerationRequest(  # temperature + top-k
            prompt=rng.integers(0, cfg.vocab_size, 7).astype(np.int32),
            max_new_tokens=max_new, eos_ids=(3,),
            sampling=SamplingParams(greedy=False, temperature=0.8, top_k=20,
                                    seed=1)),
        GenerationRequest(  # nucleus
            prompt=rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
            max_new_tokens=max_new, eos_ids=(3,),
            sampling=SamplingParams(greedy=False, top_p=0.9, seed=2)),
    ]


def run(report):
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.quantize(model.init(key), method="synthetic", key=key)
    rc = RunConfig(mode="decode", remat=False,
                   attn_chunk=16).replace_policy(vq_mode="eva")
    eng = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=32))

    rng = np.random.default_rng(0)
    max_new = 6
    reqs = _requests(cfg, rng, max_new)
    m, wall, events = _trace(eng, reqs)

    tokens = m["tokens_generated"]
    report("serve/request_trace", wall * 1e6 / max(len(reqs), 1),
           f"{_metrics_fields(m, wall)};wall_us={wall*1e6:.0f};"
           f"events={len(events)}")
    report("serve/per_token", wall * 1e6 / max(tokens, 1),
           _metrics_fields(m, wall))
    # steady-state batched decode (the paper's multi-batch amortized step):
    # engine-measured decode wall over decode steps
    if m["decode_steps"]:
        report("serve/decode_step", m["decode_s"] * 1e6 / m["decode_steps"],
               f"{_metrics_fields(m, wall)};"
               f"decode_tok_per_s={m['decode_tokens_per_s']:.1f}")

    # paged engine over the same trace (serve/paging.py): block-pool KV
    # with chunked prefill; the row's gauges track pool behavior
    eng_p = Engine(model, params, rc,
                   EngineConfig(num_slots=2, max_len=32, paged=True,
                                block_size=4, prefill_chunk=4))
    mp, wall_p, events_p = _trace(eng_p, _requests(cfg, rng, max_new))
    report("serve/paged_request_trace", wall_p * 1e6 / max(len(reqs), 1),
           f"{_metrics_fields(mp, wall_p)};wall_us={wall_p*1e6:.0f};"
           f"events={len(events_p)}")

    # speculative decoding (serve/speculative.py): a repetition-heavy
    # workload (tiny vocab forces the greedy stream into cycles the
    # self-drafter can learn) through a speculate_k engine. The row's
    # headline numbers are schema-gated (schema.SPEC_FIELDS):
    # tokens_per_step must sit above 1.0 — the multi-token win — and
    # acceptance_rate explains how far above
    cfg_s = dataclasses.replace(cfg, vocab_size=64)
    model_s = build_model(cfg_s)
    params_s = model_s.quantize(model_s.init(key), method="synthetic",
                                key=key)
    eng_s = Engine(model_s, params_s, rc,
                   EngineConfig(num_slots=2, max_len=64, speculate_k=3))
    reqs_s = [GenerationRequest(
        prompt=rng.integers(0, cfg_s.vocab_size, n).astype(np.int32),
        max_new_tokens=40) for n in (5, 7)]
    ms, wall_s, events_s = _trace(eng_s, reqs_s)
    report("serve/spec_decode_trace", wall_s * 1e6 / max(len(reqs_s), 1),
           f"{_metrics_fields(ms, wall_s)};wall_us={wall_s*1e6:.0f};"
           f"events={len(events_s)};speculate_k=3;"
           f"tokens_per_step={ms['decode_tokens_per_step']:.3f};"
           f"acceptance_rate={ms['draft_acceptance_rate']:.3f};"
           f"drafted={ms['drafted_tokens']};"
           f"accepted={ms['accepted_draft_tokens']};"
           f"rejected_drafts={ms['rejected_draft_tokens']};"
           f"extra_tokens={ms['extra_decode_tokens']}")

    # KV-VQ engine (kv_bits=4, paged): the same trace served over
    # vector-quantized uint8 index arenas (core/vq.py; README "KV-VQ
    # memory model"). The row's kv_bytes gauges report the COMPRESSED
    # footprint, and concurrency_at_fixed_hbm is the headline serving
    # win: how many slots the fp engine's KV block budget funds once
    # blocks shrink to index+scale width (same block count, smaller
    # bytes_per_block)
    meta_fp = make_paging_config(model, 2, 32, block_size=4)
    meta_q = make_paging_config(model, 2, 32, block_size=4,
                                kvq=KVQuantConfig(kv_bits=4))
    conc = 2 * meta_fp.bytes_per_block / max(meta_q.bytes_per_block, 1)
    eng_q = Engine(model, params, rc,
                   EngineConfig(num_slots=2, max_len=32, kv_bits=4,
                                paged=True, block_size=4))
    mq, wall_q, events_q = _trace(eng_q, _requests(cfg, rng, max_new))
    report("serve/kvvq_request_trace", wall_q * 1e6 / max(len(reqs), 1),
           f"{_metrics_fields(mq, wall_q)};wall_us={wall_q*1e6:.0f};"
           f"events={len(events_q)};kv_bits=4;"
           f"fp_bytes_per_block={meta_fp.bytes_per_block};"
           f"kvvq_bytes_per_block={meta_q.bytes_per_block};"
           f"concurrency_at_fixed_hbm={conc:.2f}")
