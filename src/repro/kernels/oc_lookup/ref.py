"""Pure-jnp oracle for the OC-lookup epilogue."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def oc_lookup_ref(O: jax.Array, I: jax.Array, scale: jax.Array) -> jax.Array:
    """O (C,M,V,k) fp32, I (C,V,N) int, scale (N,) -> y (M,N) fp32."""
    g = jnp.take_along_axis(
        O, I[:, None, :, :].astype(jnp.int32), axis=3
    )  # (C, M, V, N)
    return g.sum(axis=(0, 2)) * scale[None, :].astype(jnp.float32)
