"""Plan-once execution API (core/plan.py): planner cache behavior,
plan-vs-legacy parity across every registered backend (all epilogues x
{jnp, pallas-interpret} x grouped/ungrouped), loud ValueError on
contradictory policies, the newly reachable Pallas dequant path from
RunConfig, shard-aware grouping, and the engine's pre-planned shapes."""
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops
from repro.core import plan as plan_mod
from repro.core.plan import LinearSpec, PlanPolicy
from repro.core.vq import synthetic_vq

KEY = jax.random.PRNGKey(0)


def _mk(K, N, splits, M):
    vq = synthetic_vq(KEY, K, N, d=8, n=8, C=2, splits=splits)
    x = jax.random.normal(jax.random.fold_in(KEY, K * N + M), (M, K),
                          jnp.float32)
    return x, vq


class TestPlannerCache:
    def test_same_spec_policy_same_plan_object(self):
        x, vq = _mk(80, 70, (), 2)
        pol = PlanPolicy(vq_mode="eva")
        assert plan_mod.plan_vq(x, vq, pol) is plan_mod.plan_vq(x, vq, pol)

    def test_distinct_policy_distinct_plan(self):
        x, vq = _mk(80, 70, (), 2)
        p1 = plan_mod.plan_vq(x, vq, PlanPolicy(vq_mode="eva"))
        p2 = plan_mod.plan_vq(x, vq, PlanPolicy(vq_mode="dequant"))
        assert p1 is not p2 and p1.backend != p2.backend

    def test_spec_is_hashable_cache_key(self):
        x, vq = _mk(96, 96, (50, 26, 20), 2)
        s1 = LinearSpec.for_vq(vq, M=2, x_dtype=x.dtype, out_dtype=x.dtype)
        s2 = LinearSpec.for_vq(vq, M=2, x_dtype=x.dtype, out_dtype=x.dtype)
        assert s1 == s2 and hash(s1) == hash(s2)
        assert s1 != dataclasses.replace(s1, M=3)

    def test_plan_not_reentered_inside_traced_decode_step(self):
        """The planner is consulted while TRACING only: executing the
        jitted step again must not touch the cache at all."""
        x, vq = _mk(80, 70, (), 2)
        planner = plan_mod.default_planner()

        @jax.jit
        def step(a):
            return ops.vq_matmul(a, vq, out_dtype=jnp.float32)

        jax.block_until_ready(step(x))           # trace: plans once
        before = planner.cache_info()
        jax.block_until_ready(step(x))           # executed path only
        after = planner.cache_info()
        assert (after.hits, after.misses) == (before.hits, before.misses)

    def test_lru_eviction_bounded(self):
        planner = plan_mod.Planner(maxsize=4)
        for M in range(1, 10):
            x, vq = _mk(80, 70, (), M)
            spec = LinearSpec.for_vq(vq, M=M, x_dtype=x.dtype,
                                     out_dtype=x.dtype)
            planner.plan(spec, PlanPolicy(vq_mode="eva"))
        assert planner.cache_info().currsize <= 4


class TestPlanParity:
    """Plan-vs-legacy-oracle parity for every registered backend."""

    @pytest.mark.parametrize("K,N,splits", [(80, 70, ()),
                                            (96, 96, (50, 26, 20))])
    @pytest.mark.parametrize("M", [1, 8])
    @pytest.mark.parametrize("policy_kw,backend", [
        (dict(vq_mode="eva", epilogue="direct"), "eva_direct"),
        (dict(vq_mode="eva", epilogue="flat"), "eva_flat"),
        (dict(vq_mode="eva", epilogue="blocked", block_v=4), "eva_blocked"),
        (dict(vq_mode="eva", epilogue="recon", block_v=4), "eva_recon"),
        (dict(vq_mode="eva", impl="pallas", interpret=True),
         "eva_fused_pallas"),
        (dict(vq_mode="dequant"), "dequant_jnp"),
        (dict(vq_mode="dequant", impl="pallas", interpret=True),
         "dequant_pallas"),
    ])
    def test_vq_backends_match_dequant_oracle(self, K, N, splits, M,
                                              policy_kw, backend):
        x, vq = _mk(K, N, splits, M)
        pl = plan_mod.plan_vq(x, vq, PlanPolicy(**policy_kw),
                              out_dtype=jnp.float32)
        assert pl.backend == backend
        got = pl.execute(x, vq)
        ref = ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_auto_selects_per_regime(self):
        x1, vq = _mk(4096, 4096, (), 1)
        x32, _ = _mk(4096, 4096, (), 32)
        auto = PlanPolicy(vq_mode="eva", epilogue="auto")
        assert plan_mod.plan_vq(x1, vq, auto).backend == "eva_direct"
        assert plan_mod.plan_vq(x32, vq, auto).backend == "eva_recon"

    def test_dense_backends(self):
        w = jax.random.normal(KEY, (64, 48), jnp.float32) * 0.1
        x = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 64),
                              jnp.float32)
        ref = np.asarray(x) @ np.asarray(w)
        for mode, pol, backend in (
                ("decode", PlanPolicy(), "fp"),
                ("prefill", PlanPolicy(int8_prefill=True), "int8_jnp"),
                ("prefill", PlanPolicy(int8_prefill=True, impl="pallas",
                                       interpret=True), "int8_pallas"),
        ):
            pl = plan_mod.plan_node({"w": w}, x, mode=mode, policy=pol,
                                    out_dtype=jnp.float32)
            assert pl.backend == backend
            got = np.asarray(pl.execute(x, w))
            tol = 0.15 if backend.startswith("int8") else 1e-5
            np.testing.assert_allclose(got, ref, rtol=tol, atol=tol)

    def test_cost_estimates_present(self):
        x, vq = _mk(80, 70, (), 1)
        pl = plan_mod.plan_vq(x, vq, PlanPolicy(vq_mode="eva"))
        assert pl.cost.macs > 0 and pl.cost.weight_bytes > 0
        assert "eva" in pl.describe() and "M=1" in pl.describe()


class TestContradictoryPolicies:
    """Ported from the resolve_epilogue error tests: contradictions are
    loud at PlanPolicy construction or at planning time."""

    def test_unknown_values(self):
        with pytest.raises(ValueError, match="unknown epilogue"):
            PlanPolicy(epilogue="bogus")
        with pytest.raises(ValueError, match="unknown impl"):
            PlanPolicy(impl="cuda")
        with pytest.raises(ValueError, match="unknown vq_mode"):
            PlanPolicy(vq_mode="int4")

    def test_block_v_validation(self):
        for bad in (0, -3, "huge", True):
            with pytest.raises(ValueError, match="block_v"):
                PlanPolicy(block_v=bad)

    def test_block_v_requires_v_blocked_epilogue_on_jnp(self):
        for epi in ("direct", "flat", "auto"):
            with pytest.raises(ValueError, match="block_v"):
                PlanPolicy(epilogue=epi, block_v=8)
        # ...but pins the kernel v-tiles under pallas
        PlanPolicy(epilogue="auto", block_v=8, impl="pallas")

    def test_dequant_mode_keeps_ignoring_block_v(self):
        """Documented pre-plan behavior: the dequant baseline has no
        epilogue, so block_v stays accepted-and-ignored on jnp (and pins
        the Pallas dequant kernel's v-tiles)."""
        PlanPolicy(vq_mode="dequant", block_v=8)  # must not raise
        x, vq = _mk(80, 70, (), 2)
        got = ops.vq_matmul(x, vq, mode="dequant", block_v=8,
                            out_dtype=jnp.float32)
        ref = ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        from repro.models.common import RunConfig

        rc = RunConfig(mode="decode",
                       plan_policy=PlanPolicy(vq_mode="dequant", block_v=8))
        assert rc.policy.block_v == 8

    def test_pallas_rejects_jnp_epilogues_at_plan_time(self):
        x, vq = _mk(80, 70, (), 2)
        with pytest.raises(ValueError, match="pallas"):
            plan_mod.plan_vq(x, vq, PlanPolicy(
                vq_mode="eva", impl="pallas", epilogue="flat"))

    def test_runconfig_flat_knobs_are_removed(self):
        """The PR-3 shim cycle is over: the flat execution knobs are no
        longer RunConfig fields — they raise TypeError at construction
        instead of silently building a policy."""
        from repro.models.common import RunConfig

        for bad in (dict(vq_mode="eva"), dict(impl="pallas"),
                    dict(int8_prefill=True), dict(interpret=True),
                    dict(epilogue="flat"), dict(epilogue_block_v=8)):  # lint-ok
            with pytest.raises(TypeError):
                RunConfig(mode="decode", **bad)  # lint-ok (removal test)
        rc = RunConfig(mode="decode")
        assert not hasattr(rc, "vq_mode") and not hasattr(rc, "impl")
        assert rc.policy == PlanPolicy()

    def test_runconfig_replace_policy(self):
        from repro.models.common import RunConfig

        rc = RunConfig(mode="decode", plan_policy=PlanPolicy(
            vq_mode="eva", impl="pallas", interpret=True))
        rc2 = rc.replace_policy(vq_mode="dequant")
        assert rc2.policy.vq_mode == "dequant"
        assert rc2.policy.impl == "pallas"  # untouched knobs survive
        rc3 = rc2.replace(plan_policy=PlanPolicy(vq_mode="eva"))
        assert rc3.policy == PlanPolicy(vq_mode="eva")


class TestRankedSelection:
    """Tentpole: the Planner collects every matching backend and picks
    the cheapest predicted time. impl='pallas' is the genuinely
    overlapping registration — eva_fused_pallas vs the two-kernel
    eva_split_pallas — so these tests pin the ranking there, with and
    without a calibration."""

    PALLAS = PlanPolicy(vq_mode="eva", impl="pallas", interpret=True)

    def _spec(self, x, vq):
        return LinearSpec.for_vq(vq, M=x.size // vq.K, x_dtype=x.dtype,
                                 out_dtype=jnp.float32)

    @staticmethod
    def _entry(overhead, rows=8):
        from repro.core import calibrate

        return calibrate.BackendCalibration(
            overhead_us=overhead, us_per_mac=0.0, us_per_add=0.0,
            us_per_byte=0.0, rows=rows)

    @classmethod
    def _calib(cls, fused_overhead, split_overhead, rows=8):
        from repro.core import calibrate

        return calibrate.Calibration(
            version=calibrate.SCHEMA, source="test",
            backends={"eva_fused_pallas": cls._entry(fused_overhead, rows),
                      "eva_split_pallas": cls._entry(split_overhead, rows)})

    def test_analytic_fallback_ranks_fused_first(self):
        """No calibration: the analytic model prices the split backend's
        OC round-trip + second launch, so fused wins — deterministically,
        with both candidates recorded and provenance labeled."""
        x, vq = _mk(80, 70, (), 2)
        planner = plan_mod.Planner(calibration=None)
        pl = planner.plan(self._spec(x, vq), self.PALLAS)
        assert pl.backend == "eva_fused_pallas"
        assert pl.provenance == "analytic"
        assert [b for b, _ in pl.ranking] == ["eva_fused_pallas",
                                              "eva_split_pallas"]
        us = [u for _, u in pl.ranking]
        assert us == sorted(us) and us[0] < us[1]
        assert "pred=" in pl.describe() and "analytic" in pl.describe()
        assert "eva_split_pallas" in pl.describe_ranking()

    def test_calibration_flips_choice_to_split(self):
        """A calibration that prices the fused kernel above the split
        backend must flip the ranked choice — and the split plan must
        match the dequant oracle (two kernels, OC buffer in between)."""
        x, vq = _mk(96, 96, (50, 26, 20), 2)  # grouped family too
        planner = plan_mod.Planner(calibration=self._calib(1e6, 1.0))
        pl = planner.plan(self._spec(x, vq), self.PALLAS)
        assert pl.backend == "eva_split_pallas"
        assert pl.provenance == "eva-calibration/v1"
        assert [b for b, _ in pl.ranking] == ["eva_split_pallas",
                                              "eva_fused_pallas"]
        got = pl.execute(x, vq)
        ref = ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_partial_calibration_never_mixes_models(self):
        """When only ONE of the competing backends has a fitted entry,
        the ranking must fall back to the analytic model for BOTH —
        fitted microseconds vs analytic fantasy numbers is not a
        comparison (a partial CALIBRATION.json must not flip choices)."""
        from repro.core import calibrate

        x, vq = _mk(80, 70, (), 2)
        partial = calibrate.Calibration(
            version=calibrate.SCHEMA, source="partial",
            backends={"eva_split_pallas": self._entry(1.0)})
        planner = plan_mod.Planner(calibration=partial)
        pl = planner.plan(self._spec(x, vq), self.PALLAS)
        assert pl.backend == "eva_fused_pallas"  # analytic order holds
        assert pl.provenance == "analytic"

    def test_underfitted_entries_not_trusted_for_ranking(self):
        """Entries resting on fewer than MIN_FIT_ROWS samples (NNLS with
        4 free parameters fits 1-3 rows perfectly but arbitrarily) must
        not drive the ranking."""
        from repro.core import calibrate

        x, vq = _mk(80, 70, (), 2)
        thin = self._calib(1e6, 1.0, rows=calibrate.MIN_FIT_ROWS - 1)
        planner = plan_mod.Planner(calibration=thin)
        pl = planner.plan(self._spec(x, vq), self.PALLAS)
        assert pl.backend == "eva_fused_pallas"
        assert pl.provenance == "analytic"

    def test_choice_is_deterministic_across_planners(self):
        x, vq = _mk(80, 70, (), 1)
        for calib in (None, self._calib(10.0, 1e6), self._calib(1e6, 10.0)):
            a = plan_mod.Planner(calibration=calib)
            b = plan_mod.Planner(calibration=calib)
            pa = a.plan(self._spec(x, vq), self.PALLAS)
            pb = b.plan(self._spec(x, vq), self.PALLAS)
            assert pa.backend == pb.backend
            assert pa.ranking == pb.ranking

    def test_cache_identity_unchanged_under_calibration_reload(self):
        """Reloading calibration swaps the cost model for FUTURE misses
        only: a cached (spec, policy) keeps returning the SAME plan
        object, so traced programs and cache stats stay coherent."""
        x, vq = _mk(80, 70, (), 2)
        planner = plan_mod.Planner(calibration=None)
        spec = self._spec(x, vq)
        p1 = planner.plan(spec, self.PALLAS)
        assert p1.backend == "eva_fused_pallas"
        planner.reload_calibration(self._calib(1e6, 1.0))
        assert planner.plan(spec, self.PALLAS) is p1  # identity preserved
        hits = planner.cache_info().hits
        assert hits >= 1
        # a NEW spec planned after the reload uses the new constants
        x2, vq2 = _mk(88, 132, (), 2)
        p2 = planner.plan(self._spec(x2, vq2), self.PALLAS)
        assert p2.backend == "eva_split_pallas"
        # clearing the cache re-ranks the original spec under the reload
        planner.cache_clear()
        assert planner.plan(spec, self.PALLAS).backend == "eva_split_pallas"

    def test_split_plan_freezes_two_kernel_tiles(self):
        x, vq = _mk(256, 512, (), 1)
        planner = plan_mod.Planner(calibration=self._calib(1e6, 1.0))
        pl = planner.plan(self._spec(x, vq), self.PALLAS)
        cfg = pl.config_dict
        assert set(cfg) == {"bmv", "bv", "bn"}
        assert pl.cost.launches == 2
        # the HBM OC round-trip is priced: write + read of (C, M, V, 2^n)
        assert pl.cost.intermediate_bytes == 2 * 4 * vq.C * 1 * vq.V * 256

    def test_single_candidate_sites_report_no_ranking(self):
        x, vq = _mk(80, 70, (), 1)
        pl = plan_mod.plan_vq(x, vq, PlanPolicy(vq_mode="eva"))
        assert len(pl.ranking) == 1 and pl.describe_ranking() == ""
        assert pl.predicted_us is not None

    def test_first_match_backend_reports_registration_order(self):
        x, vq = _mk(80, 70, (), 1)
        spec = self._spec(x, vq)
        # registration order: fused_vq_matmul.ops imports before
        # oc_lookup.ops in _KERNEL_BACKEND_MODULES
        assert plan_mod.first_match_backend(spec, self.PALLAS) == \
            "eva_fused_pallas"
        assert plan_mod.first_match_backend(
            spec, PlanPolicy(vq_mode="eva")) == "eva_direct"

    def test_engine_logs_predicted_time_ranking(self, caplog):
        """serve/engine.py pre-plan logs surface the ranking when >1
        backend was eligible (the pallas decode policy)."""
        import dataclasses as dc
        import logging

        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.models.common import RunConfig
        from repro.serve import Engine, EngineConfig

        cfg = dc.replace(get_smoke_config("llama2_7b"), dtype="float32")
        model = build_model(cfg)
        params = model.quantize(model.init(KEY), method="synthetic", key=KEY)
        rc = RunConfig(mode="decode", remat=False, attn_chunk=16,
                       plan_policy=PlanPolicy(vq_mode="eva", impl="pallas",
                                              interpret=True))
        with caplog.at_level(logging.INFO, logger="repro.serve.engine"):
            Engine(model, params, rc, EngineConfig(num_slots=2, max_len=16))
        ranking_lines = [r.message for r in caplog.records
                         if "ranking" in r.message]
        assert ranking_lines
        assert any("eva_split_pallas" in m and "eva_fused_pallas" in m
                   for m in ranking_lines)


class TestDequantPallasReachable:
    """Satellite bugfix: vq_matmul(mode='dequant') used to silently drop
    impl/interpret, so a pallas+dequant RunConfig policy never
    reached the dequant_gemv kernel from model layers."""

    def test_model_layer_routes_to_dequant_pallas(self):
        from repro.models.common import RunConfig, linear

        x, vq = _mk(80, 70, (), 2)
        rc = RunConfig(mode="decode", plan_policy=PlanPolicy(
            vq_mode="dequant", impl="pallas", interpret=True), remat=False)
        pl = plan_mod.plan_node({"vq": vq}, x, mode=rc.mode, policy=rc.policy,
                                out_dtype=jnp.float32)
        assert pl.backend == "dequant_pallas"
        got = linear({"vq": vq}, x, rc, out_dtype=jnp.float32)
        ref = ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_wrapper_routes_to_dequant_pallas(self):
        x, vq = _mk(80, 70, (), 2)
        got = ops.vq_matmul(x, vq, mode="dequant", impl="pallas",
                            interpret=True, out_dtype=jnp.float32)
        ref = ops.dequant_matmul(x, vq, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


class TestShardAwareGrouping:
    """Satellite: quantization skips grouping a family whose member
    boundaries are not shard-aligned under the target mesh, and the
    decision lands in the quantize report."""

    def _quantize(self, shards, report):
        from repro.configs import get_smoke_config
        from repro.models import build_model

        cfg = dataclasses.replace(get_smoke_config("llama2_7b"),
                                  dtype="float32")
        model = build_model(cfg)
        params = model.init(KEY)
        return model, model.quantize(params, method="synthetic", key=KEY,
                                     mesh=shards, report=report)

    def test_misaligned_family_stays_ungrouped(self):
        report = []
        # smoke llama2 qkv widths (128,128,128): boundary 128 is not a
        # multiple of the 384/16=24-wide shards -> ungrouped
        model, q = self._quantize(16, report)
        qkv = [r for r in report if r["family"] == "wqkv"]
        assert qkv and not qkv[0]["grouped"]
        assert "not aligned" in qkv[0]["reason"]
        leaves = q["layers"]["attn"]
        assert "wqkv" not in leaves and "vq" in leaves["wq"]

    def test_aligned_family_groups(self):
        report = []
        # gate/up (384,384): boundary 384 is shard-aligned at 16 shards
        model, q = self._quantize(16, report)
        gu = [r for r in report if r["family"] == "gu"]
        assert gu and gu[0]["grouped"] and gu[0]["reason"] == "aligned"
        assert "gu" in q["layers"]["mlp"]

    def test_unsharded_mesh_groups_everything(self):
        report = []
        model, q = self._quantize(None, report)
        assert all(r["grouped"] for r in report)
        assert "wqkv" in q["layers"]["attn"]

    def test_splits_shard_aligned_helper(self):
        from repro.runtime.sharding import splits_shard_aligned

        assert splits_shard_aligned((64, 64), 128, 2)
        assert not splits_shard_aligned((4096, 1024, 1024), 6144, 16)
        assert splits_shard_aligned((), 128, 2)
        assert not splits_shard_aligned((), 130, 4)
        assert splits_shard_aligned((13, 7), 20, 1)


class TestEnginePreplan:
    def test_engine_preplans_and_logs(self, caplog):
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.models.common import RunConfig
        from repro.serve import Engine, EngineConfig

        cfg = dataclasses.replace(get_smoke_config("llama2_7b"),
                                  dtype="float32")
        model = build_model(cfg)
        params = model.quantize(model.init(KEY), method="synthetic", key=KEY)
        rc = RunConfig(mode="decode", plan_policy=PlanPolicy(vq_mode="eva"),
                       remat=False, attn_chunk=16)
        with caplog.at_level(logging.INFO, logger="repro.serve.engine"):
            eng = Engine(model, params, rc,
                         EngineConfig(num_slots=3, max_len=32))
        assert eng.plans["decode"]
        # decode plans at slot capacity (M = num_slots); prefill entries
        # are EXACT per-bucket plans at the padded execution lengths
        # (replacing the old single capacity-bound prefill@cap estimate)
        vq_decode = [pl for _p, pl in eng.plans["decode"]
                     if pl.spec.kind == "vq"]
        assert vq_decode and all(pl.spec.M == 3 for pl in vq_decode)
        assert "prefill@cap" not in eng.plans
        for m in (8, 16, 32):
            assert all(pl.spec.M == m for _p, pl in eng.plans[f"prefill@{m}"])
        assert any("plan" in r.message for r in caplog.records)

    def test_decode_preplan_warms_traced_step(self):
        """The decode entries must be exact cache warm-ups: tracing the
        batched decode step at slot capacity re-uses the pre-planned
        (spec, policy) keys for every vq leaf (no new misses for them)."""
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.models.common import RunConfig
        from repro.serve import Engine, EngineConfig

        cfg = dataclasses.replace(get_smoke_config("llama2_7b"),
                                  dtype="float32")
        model = build_model(cfg)
        params = model.quantize(model.init(KEY), method="synthetic", key=KEY)
        rc = RunConfig(mode="decode", plan_policy=PlanPolicy(vq_mode="eva"),
                       remat=False, attn_chunk=16)
        eng = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=32))
        planner = plan_mod.default_planner()
        from repro.serve import api as serve_api

        before = planner.cache_info()
        eng._decode_fn(  # traces: decode + in-jit sampling state
            params, eng.caches,
            jnp.zeros((2,), jnp.int32), jnp.zeros((2,), jnp.int32),
            jnp.zeros((2, 2), jnp.uint32), jnp.ones((2,), jnp.float32),
            jnp.zeros((2,), jnp.int32), jnp.ones((2,), jnp.float32),
            jnp.ones((2,), bool),
            jnp.full((2, serve_api.MAX_STOP_IDS), -1, jnp.int32),
            jnp.ones((2,), jnp.int32), jnp.ones((2,), bool),
            jnp.zeros((2,), jnp.float32),  # per-lane fault-injection poison
        )
        after = planner.cache_info()
        # tracing plans each call site; every vq-leaf spec was pre-planned
        # (dense sites may differ in out_dtype, e.g. the fp32 lm_head)
        assert after.hits > before.hits
        new_misses = after.misses - before.misses
        assert new_misses <= 1  # at most the fp32-out lm_head site
