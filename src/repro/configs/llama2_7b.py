"""LLaMA-2-7B — the paper's primary evaluation model (Tbl. III/V/X,
Figs. 10-12) [arXiv:2307.09288]. 32L d_model=4096 32H (MHA) d_ff=11008
vocab=32000. VQ config: AQLM d=8, n=8, C=q (paper Tbl. II).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    rope_theta=10000.0,
    vq_C=2,
)

SMOKE = ModelConfig(
    name="llama2-7b-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=384,
    vocab_size=512,
    rope_theta=10000.0,
    vq_C=2,
)
