"""RecurrentGemma-2B — RG-LRU + local attention, pattern (rec, rec, attn)
[arXiv:2402.19427; hf]. 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, local window 2048.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="rglru",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    local_window=2048,
    rec_pattern=("rec", "rec", "attn"),
    d_rnn=2560,
    conv_width=4,
    rope_theta=10000.0,
    vq_C=2,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="rglru",
    num_layers=5,        # (rec, rec, attn) + 2 trailing rec
    d_model=128,
    num_heads=2,
    num_kv_heads=1,
    head_dim=64,
    d_ff=384,
    vocab_size=512,
    local_window=32,
    rec_pattern=("rec", "rec", "attn"),
    d_rnn=128,
    conv_width=4,
    vq_C=2,
)
