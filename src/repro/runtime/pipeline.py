"""Pipeline parallelism: GPipe-style microbatch pipeline over a mesh axis
(typically 'pod' — inter-pod links are the slowest, and PP's
point-to-point ppermute traffic is the cheapest collective pattern).

Implementation: the layer stack is split into `n_stages` equal stages
whose params are sharded on the leading axis over the pipeline mesh axis;
inside shard_map every stage runs the same tick loop — stage 0 feeds
microbatches in, each tick's activations hop to the next stage with
jax.lax.ppermute, and the last stage collects outputs. The whole loop is
differentiable (ppermute has a transpose rule), so pipelined training is
just jax.grad over the pipelined forward.

Bubble fraction is the usual (P-1)/(T+P-1); choose n_micro >= 4*P.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def split_stages(stacked_params: Any, n_stages: int) -> Any:
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""

    def one(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree_util.tree_map(one, stacked_params)


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,          # this stage's slice (inside shard_map)
    x_micro: jax.Array,         # (n_micro, mb, ...) — consumed by stage 0
    *,
    axis: str,
    n_stages: int,
):
    """Run the tick loop inside shard_map. Returns (n_micro, mb, ...)
    outputs, valid on the LAST stage (zeros elsewhere); callers psum or
    read the last-stage shard."""
    idx = jax.lax.axis_index(axis)
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    # mark the carries as varying over the pipeline axis (shard_map VMA typing)
    buf = jax.lax.pvary(jnp.zeros_like(x_micro[0]), (axis,))
    outs = jax.lax.pvary(jnp.zeros_like(x_micro), (axis,))
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(carry, t):
        buf, outs = carry
        feed = x_micro[jnp.clip(t, 0, n_micro - 1)]
        x_in = jnp.where(idx == 0, feed, buf)
        y = stage_fn(stage_params, x_in)
        out_t = t - (n_stages - 1)
        is_out = (idx == n_stages - 1) & (out_t >= 0)
        outs = jax.lax.dynamic_update_index_in_dim(
            outs, jnp.where(is_out, y, outs[jnp.clip(out_t, 0, n_micro - 1)]),
            jnp.clip(out_t, 0, n_micro - 1), axis=0,
        )
        buf = jax.lax.ppermute(y, axis, perm)
        return (buf, outs), None

    (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
    return outs


def make_pipelined_forward(
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    mesh: Mesh,
    *,
    axis: str = "pod",
    n_micro: int = 8,
):
    """Builds f(stage_params, x) -> y where stage_params leaves carry a
    leading (n_stages, L/n_stages) axis (see split_stages) and x is
    (batch, ...) with batch % n_micro == 0. The pipeline axis size is
    mesh.shape[axis]."""
    n_stages = mesh.shape[axis]

    def stage_fn(params_slice, x):
        def body(c, lp):
            return layer_fn(lp, c), None

        y, _ = jax.lax.scan(body, x, params_slice)
        return y

    def fwd(stage_params, x):
        B = x.shape[0]
        assert B % n_micro == 0
        x_micro = x.reshape(n_micro, B // n_micro, *x.shape[1:])

        def inner(sp, xm):
            sp = jax.tree_util.tree_map(lambda a: a[0], sp)  # drop stage dim
            outs = pipeline_apply(stage_fn, sp, xm, axis=axis,
                                  n_stages=n_stages)
            # broadcast the last stage's outputs to all stages
            outs = jax.lax.psum(
                jnp.where(jax.lax.axis_index(axis) == n_stages - 1, outs, 0.0),
                axis,
            )
            return outs

        param_specs = jax.tree_util.tree_map(
            lambda a: P(axis, *([None] * (a.ndim - 1))), stage_params
        )
        outs = shard_map(
            inner, mesh=mesh,
            in_specs=(param_specs, P()),
            out_specs=P(),
        )(stage_params, x_micro)
        return outs.reshape(B, *outs.shape[2:])

    return fwd
