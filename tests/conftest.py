import os
import sys

# src/ layout import path (tests run with PYTHONPATH=src, but make it robust)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# `hypothesis` shim: the container has no hypothesis wheel, and a hard
# ImportError in any test module aborts collection of the whole suite.
# When the real package is absent we install a minimal deterministic
# stand-in that supports the subset used here (given/settings +
# integers/sampled_from/booleans/floats strategies): each @given test runs
# `max_examples` seeded random draws instead of being skipped.
# ---------------------------------------------------------------------------
try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    def _booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def _given(**strategies):
        def deco(fn):
            # NB: no functools.wraps — copying fn's signature would make
            # pytest resolve the drawn parameters as fixtures.
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = 10
            return wrapper
        return deco

    def _settings(max_examples=10, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.floats = _floats

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Release compiled executables between test modules — the suite
    compiles thousands of programs and XLA:CPU's JIT'd code is otherwise
    retained for the whole process (LLVM eventually OOMs)."""
    yield
    jax.clear_caches()
