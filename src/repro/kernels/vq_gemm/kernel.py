"""Pallas TPU kernel for EVA Step 1 (VQ-GEMM): O = X · B.

Maps the paper's 32x8 FP16 systolic-array VQ-GEMM onto the MXU:
the (M·V, d) reshaped activations multiply the (d, 2^n) codebook.
`d` (=8) is far below the MXU's native 128-deep contraction, so on real
hardware this kernel folds the codebook axis C and an M·V tile into the
matmul to keep the MXU busy; the fused kernel (fused_vq_matmul) goes
further and never writes O to HBM.

Grid: (C, num_mv_tiles). Per step:
  x_tile (bmv, d)   — streamed (same tile revisited per codebook)
  b_tile (d, k)     — codebook c, stationary across mv tiles
  o_tile (bmv, k)   — written once
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vq_gemm_kernel(x_ref, b_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (bmv, d)
    b = b_ref[0].astype(jnp.float32)            # (d, k)
    o_ref[0] = jax.lax.dot_general(
        x, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def vq_gemm_pallas(
    x_flat: jax.Array,       # (MV, d)  activations reshaped to vectors
    codebooks: jax.Array,    # (C, d, k)
    *,
    block_mv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Returns O (C, MV, k) fp32. MV must be a multiple of block_mv
    (wrapper pads)."""
    MV, d = x_flat.shape
    C, d2, k = codebooks.shape
    assert d == d2, (d, d2)
    assert MV % block_mv == 0, (MV, block_mv)
    grid = (C, MV // block_mv)

    return pl.pallas_call(
        _vq_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_mv, d), lambda c, m: (m, 0)),
            pl.BlockSpec((1, d, k), lambda c, m: (c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_mv, k), lambda c, m: (c, m, 0)),
        out_shape=jax.ShapeDtypeStruct((C, MV, k), jnp.float32),
        interpret=interpret,
    )(x_flat, codebooks)
