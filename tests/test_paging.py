"""Paged KV-cache subsystem (serve/paging.py): block-pool invariants,
paged-vs-contiguous token/logit identity per family (int8 and SWA ring
wrap included), chunked prefill == one-shot prefill, out-of-blocks
preemption, mid-run snapshot/restore, and the KV memory gauges."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.common import RunConfig
from repro.serve import (BlockPool, Engine, EngineConfig, GenerationRequest,
                         SamplingParams, blocks_for_len, make_paging_config)
from repro.serve import paging
from repro.serve.api import chunk_spans
from repro.serve.kvcache import pad_prefill_cache

KEY = jax.random.PRNGKey(0)
CAP = 32


# ---------------------------------------------------------------- unit level


class TestGeometry:
    def test_effective_block_size_divisor(self):
        assert paging.effective_block_size(4, 32) == 4
        assert paging.effective_block_size(32, 32) == 32
        # gcd fallback when the request doesn't divide page_len
        assert paging.effective_block_size(12, 32) == 4
        assert paging.effective_block_size(7, 32) == 1
        with pytest.raises(ValueError):
            paging.effective_block_size(0, 32)

    def test_blocks_for_len_ceil_and_ring_cap(self):
        assert blocks_for_len(0, block_size=4, page_len=32) == 0
        assert blocks_for_len(1, block_size=4, page_len=32) == 1
        assert blocks_for_len(9, block_size=4, page_len=32) == 3
        assert blocks_for_len(-3, block_size=4, page_len=32) == 0
        # ring/SWA cap: a windowed cache wraps at page_len = window, so a
        # 1000-token prompt still needs only ceil(window / block_size)
        assert blocks_for_len(1000, block_size=4, page_len=32) == 8

    def test_make_paging_config_defaults_and_bounds(self):
        cfg = dataclasses.replace(get_smoke_config("llama2_7b"),
                                  dtype="float32")
        model = build_model(cfg)
        meta = make_paging_config(model, 3, CAP, block_size=4)
        assert meta.block_size == 4
        assert meta.page_len == CAP
        assert meta.blocks_per_slot == CAP // 4
        # default pool == contiguous worst case, but shared
        assert meta.num_blocks == 3 * meta.blocks_per_slot
        assert meta.sentinel == meta.num_blocks
        assert meta.bytes_per_block > 0
        # windowed: page_len snaps to the ring
        meta_w = make_paging_config(model, 2, 64, window=16, block_size=4)
        assert meta_w.page_len == 16 and meta_w.blocks_per_slot == 4
        with pytest.raises(ValueError, match="one full slot"):
            make_paging_config(model, 2, CAP, block_size=4,
                               num_blocks=CAP // 4 - 1)

    def test_chunk_spans_walk(self):
        assert chunk_spans(10, 4) == [(0, 4, 4), (4, 4, 4), (8, 2, 2)]
        assert chunk_spans(4, 8) == [(0, 4, 4)]
        # bucketed: each chunk pads to its own bucket
        assert chunk_spans(10, 4, buckets=(4, 8, 16)) == \
            [(0, 4, 4), (4, 4, 4), (8, 2, 4)]
        with pytest.raises(ValueError):
            chunk_spans(0, 4)
        with pytest.raises(ValueError):
            chunk_spans(4, 0)


class TestBlockPool:
    def test_lifo_deterministic(self):
        pool = BlockPool(4)
        assert pool.alloc(3) == [0, 1, 2]
        pool.free([1])
        assert pool.alloc(1) == [1]  # most recently freed comes back first
        pool.free([2, 0])
        assert pool.alloc(2) == [0, 2]

    def test_alloc_all_or_nothing(self):
        pool = BlockPool(3)
        assert pool.alloc(4) is None
        assert pool.free_count == 3  # refused alloc takes nothing
        got = pool.alloc(3)
        assert sorted(got) == [0, 1, 2] and pool.free_count == 0
        assert pool.alloc(1) is None
        assert pool.alloc(0) == []
        with pytest.raises(ValueError):
            pool.alloc(-1)

    def test_free_guards(self):
        pool = BlockPool(3)
        blocks = pool.alloc(2)
        pool.free(blocks)
        with pytest.raises(ValueError, match="double free"):
            pool.free([blocks[0]])
        with pytest.raises(ValueError, match="out of range"):
            pool.free([3])

    def test_state_restore_roundtrip(self):
        pool = BlockPool(5)
        pool.alloc(2)
        pool.free([0])
        state = pool.state()
        seq = [pool.alloc(1), pool.alloc(2)]
        fresh = BlockPool(5)
        fresh.restore(state)
        assert [fresh.alloc(1), fresh.alloc(2)] == seq  # exact layout replay
        with pytest.raises(ValueError, match="duplicate"):
            fresh.restore([1, 1])
        with pytest.raises(ValueError, match="out of range"):
            fresh.restore([7])


# --------------------------------------------------------------- model level


PAGED_ARCHS = ["llama2_7b", "mixtral_8x22b", "deepseek_v2_lite_16b",
               "whisper_medium", "recurrentgemma_2b", "xlstm_125m",
               "llama_3_2_vision_11b"]


def _fp32_cfg(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    if cfg.num_experts:
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.num_experts) / cfg.top_k)
    return cfg


def _extras(cfg, B=1):
    ex = {}
    if cfg.family == "whisper":
        ex["frames"] = jax.random.normal(KEY, (B, 16, cfg.d_model),
                                         jnp.float32)
    if cfg.family == "vision":
        ex["image_embeds"] = jax.random.normal(KEY, (B, 8, cfg.d_model),
                                               jnp.float32)
    return ex


def _paged_after_prefill(model, fresh, true_len, *, cap, window=0,
                         block_size=4, kv_int8=False):
    """Build a 1-slot paged cache holding `fresh` (a B=1 prefill cache)."""
    meta = make_paging_config(model, 1, cap, window=window,
                              block_size=block_size, kv_int8=kv_int8)
    caches = paging.init_paged_cache(model, 1, cap, meta, kv_int8=kv_int8)
    pool = BlockPool(meta.num_blocks)
    row = np.asarray(pool.alloc(meta.blocks_per_slot), np.int32)
    caches = paging.write_prefill_into_blocks(
        caches, fresh, 0, row, jnp.asarray(true_len, jnp.int32), meta,
        window=window)
    caches = paging.set_block_tables(caches, row[None])
    return caches, meta, row


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_decode_matches_contiguous(arch):
    """Per-family logit identity: decoding over block arenas + tables
    reproduces the contiguous cache — gather view is shape-identical, so
    the same attention arithmetic runs on both."""
    cfg = _fp32_cfg(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    S, N = 12, 3
    tokens = jax.random.randint(KEY, (1, S + N), 0, cfg.vocab_size)
    ex = _extras(cfg)
    window = cfg.sliding_window or cfg.local_window
    _, fresh = model.prefill(
        params, {"tokens": tokens[:, :S], **ex},
        RunConfig(mode="prefill", remat=False, attn_chunk=8))
    cont = pad_prefill_cache(fresh, CAP, window=window)
    paged, _, _ = _paged_after_prefill(model, fresh, S, cap=CAP,
                                       window=window)
    rc_d = RunConfig(mode="decode", remat=False)
    for t in range(S, S + N):
        pos = jnp.full((1, 1), t, jnp.int32)
        lc, cont = model.decode(params, tokens[:, t:t + 1], pos, cont, rc_d)
        lp, paged = model.decode(params, tokens[:, t:t + 1], pos, paged, rc_d)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lc),
                                   rtol=1e-5, atol=1e-5)


def test_paged_decode_int8_kv_matches_contiguous():
    """int8 KV: decode quantizes the new K/V and scatters value + scale
    leaves through the table; logits match the contiguous int8 cache
    exactly (same quantizer, same storage values)."""
    cfg = _fp32_cfg("llama2_7b")
    model = build_model(cfg)
    params = model.init(KEY)
    cap = 16
    cont = model.init_cache(1, cap, kv_int8=True)
    meta = make_paging_config(model, 1, cap, block_size=4, kv_int8=True)
    paged = paging.init_paged_cache(model, 1, cap, meta, kv_int8=True)
    pool = BlockPool(meta.num_blocks)
    row = np.asarray(pool.alloc(meta.blocks_per_slot), np.int32)
    paged = paging.set_block_tables(paged, row[None])
    tokens = jax.random.randint(KEY, (1, 6), 0, cfg.vocab_size)
    rc_d = RunConfig(mode="decode", remat=False)
    for t in range(6):
        pos = jnp.full((1, 1), t, jnp.int32)
        lc, cont = model.decode(params, tokens[:, t:t + 1], pos, cont, rc_d)
        lp, paged = model.decode(params, tokens[:, t:t + 1], pos, paged, rc_d)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lc),
                                   rtol=1e-5, atol=1e-5)


def test_paged_swa_ring_wrap_matches_contiguous():
    """Prompt longer than the window: the prefill commit ring-converts
    before scattering, so the paged ring holds the same positions as the
    contiguous ring — and never needs more than ceil(window/bs) blocks."""
    cfg = _fp32_cfg("recurrentgemma_2b")  # local_window=32 in smoke
    model = build_model(cfg)
    params = model.init(KEY)
    window = cfg.local_window
    S, N, cap = window + 8, 3, 64  # prompt wraps the ring
    tokens = jax.random.randint(KEY, (1, S + N), 0, cfg.vocab_size)
    _, fresh = model.prefill(
        params, {"tokens": tokens[:, :S]},
        RunConfig(mode="prefill", remat=False, attn_chunk=8))
    cont = pad_prefill_cache(fresh, cap, window=window)
    paged, meta, _ = _paged_after_prefill(model, fresh, S, cap=cap,
                                          window=window)
    assert meta.page_len == window
    assert meta.blocks_per_slot == -(-window // meta.block_size)
    assert meta.blocks_for(10 * window) == meta.blocks_per_slot
    rc_d = RunConfig(mode="decode", remat=False)
    for t in range(S, S + N):
        pos = jnp.full((1, 1), t, jnp.int32)
        lc, cont = model.decode(params, tokens[:, t:t + 1], pos, cont, rc_d)
        lp, paged = model.decode(params, tokens[:, t:t + 1], pos, paged, rc_d)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lc),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["llama2_7b", "whisper_medium",
                                  "llama_3_2_vision_11b"])
def test_chunked_prefill_matches_one_shot(arch):
    """Chunk 1 commits via the prefill scatter, chunk 2 runs the forward
    continuation over a slot_view; the final logits and the next decode
    step match a one-shot prefill of the whole prompt."""
    cfg = _fp32_cfg(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    S, c1 = 12, 8
    tokens = jax.random.randint(KEY, (1, S + 1), 0, cfg.vocab_size)
    ex = _extras(cfg)
    rc_p = RunConfig(mode="prefill", remat=False, attn_chunk=8)

    logits_os, fresh_os = model.prefill(
        params, {"tokens": tokens[:, :S], **ex}, rc_p)
    cont = pad_prefill_cache(fresh_os, CAP)

    _, f1 = model.prefill(params, {"tokens": tokens[:, :c1], **ex}, rc_p)
    paged, meta, row = _paged_after_prefill(model, f1, c1, cap=CAP)
    view = paging.slot_view(paged, 0, row, c1, S - c1)
    batch = {"tokens": tokens[:, c1:S],
             "positions": c1 + jnp.arange(S - c1, dtype=jnp.int32)[None],
             **ex}
    logits_ch, new_view = model.forward(params, batch, rc_p, caches=view)
    paged = paging.merge_slot(paged, new_view, 0)
    np.testing.assert_allclose(np.asarray(logits_ch[:, -1]),
                               np.asarray(logits_os[:, -1]),
                               rtol=1e-4, atol=1e-4)
    pos = jnp.full((1, 1), S, jnp.int32)
    rc_d = RunConfig(mode="decode", remat=False)
    lc, _ = model.decode(params, tokens[:, S:S + 1], pos, cont, rc_d)
    lp, _ = model.decode(params, tokens[:, S:S + 1], pos, paged, rc_d)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lc),
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- engine level


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    rc = RunConfig(mode="decode", remat=False, attn_chunk=16)
    return cfg, model, params, rc


def _mixed_requests(cfg, lengths, max_new=8):
    rng = np.random.default_rng(7)
    reqs = []
    for i, L in enumerate(lengths):
        prompt = rng.integers(0, cfg.vocab_size, int(L)).astype(np.int32)
        if i % 3 == 1:
            sp = SamplingParams(greedy=False, temperature=0.8, top_k=20,
                                seed=100 + i)
        elif i % 3 == 2:
            sp = SamplingParams(greedy=False, top_p=0.9, seed=200 + i)
        else:
            sp = SamplingParams()
        reqs.append(GenerationRequest(prompt=prompt, max_new_tokens=max_new,
                                      sampling=sp))
    return reqs


def _run(model, params, rc, ecfg, reqs):
    eng = Engine(model, params, rc, ecfg)
    uids = [eng.submit(r) for r in reqs]
    while not eng.idle:
        eng.step()
    return eng, [eng.output(u) for u in uids]


def test_paged_engine_token_identical_beyond_contiguous_memory(setup):
    """ISSUE 8 acceptance: a mixed-sampling workload whose cumulative KV
    footprint exceeds the num_slots x max_len contiguous equivalent is
    served token-identically by the paged engine — block recycling covers
    what dedicated slots could not hold at once — with decode tracing
    exactly once and chunked prefill at most once per bucket."""
    cfg, model, params, rc = setup
    max_new = 8
    reqs = _mixed_requests(cfg, (12, 9, 6, 11, 5, 8), max_new=max_new)
    footprint = sum(len(r.prompt) + max_new - 1 for r in reqs)
    assert footprint > 2 * CAP  # exceeds the contiguous equivalent

    ecfg_c = EngineConfig(num_slots=2, max_len=CAP)
    ecfg_p = EngineConfig(num_slots=2, max_len=CAP, paged=True,
                          block_size=4, prefill_chunk=4)
    _, out_c = _run(model, params, rc, ecfg_c, reqs)
    eng_p, out_p = _run(model, params, rc, ecfg_p, reqs)
    for oc, op in zip(out_c, out_p):
        assert op.tokens == oc.tokens
        assert op.finish_reason == oc.finish_reason

    assert eng_p.trace_counts["decode"] == 1
    assert eng_p.trace_counts["prefill_chunk"] >= 1
    # chunks pad to <= prefill_chunk so every chunk lands in one bucket
    assert eng_p.trace_counts["prefill_chunk"] <= 1
    assert eng_p.trace_counts["prefill"] <= 2

    m = eng_p.metrics()
    assert m["prefill_chunks"] > 0
    assert m["tokens_generated"] == m["prefills"] + m["decode_slot_steps"]


def test_paged_engine_memory_gauges_drain(setup):
    """blocks_in_use / kv_bytes_in_use rise while serving and drain to
    zero at idle; peaks are sticky and byte-consistent with the pool."""
    cfg, model, params, rc = setup
    reqs = _mixed_requests(cfg, (10, 7, 5), max_new=6)
    ecfg = EngineConfig(num_slots=2, max_len=CAP, paged=True, block_size=4)
    eng = Engine(model, params, rc, ecfg)
    for r in reqs:
        eng.submit(r)
    saw_in_use = 0
    while not eng.idle:
        eng.step()
        saw_in_use = max(saw_in_use, eng.metrics()["blocks_in_use"])
    m = eng.metrics()
    assert saw_in_use > 0
    assert m["blocks_in_use"] == 0 and m["kv_bytes_in_use"] == 0
    assert m["blocks_free"] == eng.paging.num_blocks
    assert m["peak_blocks_in_use"] == saw_in_use
    assert m["peak_kv_bytes_in_use"] == \
        saw_in_use * eng.paging.bytes_per_block
    # contiguous engine reports its constant worst-case bytes instead
    eng_c, _ = _run(model, params, rc,
                    EngineConfig(num_slots=2, max_len=CAP), reqs)
    mc = eng_c.metrics()
    assert mc["kv_bytes_in_use"] > 0 and mc["blocks_in_use"] == 0


def test_out_of_blocks_preempts_youngest_and_stays_identical(setup):
    """A pool too small for the workload's peak forces decode-time
    preemption (youngest request back to the queue); the resumed request
    re-prefills prompt + generated prefix with its saved RNG key, so the
    final streams still match the contiguous engine token-for-token."""
    cfg, model, params, rc = setup
    max_new = 8
    reqs = _mixed_requests(cfg, (20, 16, 12, 8, 6, 4), max_new=max_new)
    ecfg_c = EngineConfig(num_slots=3, max_len=64)
    # W = 16; peak demand across 3 slots exceeds 17 blocks -> preemption
    ecfg_p = EngineConfig(num_slots=3, max_len=64, paged=True,
                          block_size=4, num_blocks=17)
    _, out_c = _run(model, params, rc, ecfg_c, reqs)
    eng_p, out_p = _run(model, params, rc, ecfg_p, reqs)
    assert eng_p.metrics()["preemptions"] >= 1
    for oc, op in zip(out_c, out_p):
        assert op.tokens == oc.tokens
        assert op.finish_reason == oc.finish_reason


def test_unholdable_pool_rejected_at_construction(setup):
    cfg, model, params, rc = setup
    with pytest.raises(ValueError, match="one full slot"):
        Engine(model, params, rc,
               EngineConfig(num_slots=2, max_len=CAP, paged=True,
                            block_size=4, num_blocks=3))


def test_snapshot_restore_mid_chunk_token_identical(setup):
    """Snapshot while chunked prefill + decode are in flight; a fresh
    engine restored from it finishes with byte-identical outputs — the
    pool free-list order rides the snapshot, so even the physical block
    layout replays."""
    cfg, model, params, rc = setup
    reqs = _mixed_requests(cfg, (12, 9, 6, 11), max_new=6)
    ecfg = EngineConfig(num_slots=2, max_len=CAP, paged=True,
                        block_size=4, prefill_chunk=4)
    eng = Engine(model, params, rc, ecfg)
    uids = [eng.submit(r) for r in reqs]
    for _ in range(3):  # stop mid-flight: chunked prefill still running
        eng.step()
    snap = eng.snapshot()
    assert snap.paged and snap.block_tables is not None
    while not eng.idle:
        eng.step()
    ref = [eng.output(u) for u in uids]

    eng2 = Engine(model, params, rc, ecfg)
    eng2.restore(snap)
    while not eng2.idle:
        eng2.step()
    for u, r in zip(uids, ref):
        out = eng2.output(u)
        assert out.tokens == r.tokens
        # in-flight-across-restore requests annotate their reason
        assert out.finish_reason.replace("-after-restore", "") == \
            r.finish_reason

    # geometry mismatches refuse loudly instead of corrupting the pool
    eng3 = Engine(model, params, rc, EngineConfig(num_slots=2, max_len=CAP))
    with pytest.raises(ValueError, match="paged"):
        eng3.restore(snap)


def test_serve_cache_specs_and_pspecs_paged():
    """launch/steps.serve_cache_specs produces the paged layout and
    runtime/sharding replicates arenas + tables (arena axis is the block
    pool, not batch)."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.launch.steps import serve_cache_specs
    from repro.runtime import sharding as shd

    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    specs = serve_cache_specs(model, 2, CAP, paged=True, block_size=4)
    assert paging.is_paged(specs)
    cont = serve_cache_specs(model, 2, CAP)
    assert not paging.is_paged(cont)

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    pspecs = shd.cache_pspecs(specs, mesh)
    nodes = []

    def walk(node):
        if isinstance(node, dict):
            if "block_table" in node:
                nodes.append(node)
                return
            for v in node.values():
                walk(v)

    walk(pspecs)
    assert nodes
    for node in nodes:
        for name, spec in node.items():
            if name != "len":
                assert spec == P(*([None] * len(spec))), (name, spec)
