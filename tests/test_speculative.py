"""Speculative decoding tests: the acceptance rule in isolation, stub
and real-model stream identity (greedy + seeded sampling, contiguous +
paged + KV-quantized caches), one-trace discipline, opt-out, metrics
accounting and snapshot/restore of the drafter state."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.common import RunConfig
from repro.serve import (Engine, EngineConfig, GenerationRequest,
                         SamplingParams)
from repro.serve import speculative as spec

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# unit: drafter + acceptance rule
# ---------------------------------------------------------------------------


def test_prime_and_propose_chain():
    succ = np.full((2, 16), -1, np.int32)
    spec.prime_successors(succ, 0, [3, 4, 5, 3, 7])  # 3->4 then 3->7: later wins
    drafts = np.asarray(spec.propose_drafts(jnp.asarray(succ),
                                            jnp.asarray([4, 9]), 3))
    # slot 0 from 4: 4->5, 5->3, 3->7 (the re-primed transition)
    assert drafts[0].tolist() == [5, 3, 7]
    # slot 1 never primed: chain self-terminates immediately
    assert drafts[1].tolist() == [-1, -1, -1]


def test_update_successors_in_jit_matches_host_priming():
    succ = jnp.full((1, 16), -1, jnp.int32)
    prevs = jnp.asarray([[2, 5, 2]])
    nexts = jnp.asarray([[5, 2, 9]])
    emit = jnp.asarray([[True, True, True]])
    out = np.asarray(spec.update_successors(succ, prevs, nexts, emit))
    host = np.full((1, 16), -1, np.int32)
    spec.prime_successors(host, 0, [2, 5, 2, 9])
    assert (out == host).all()
    # masked-off transitions are not recorded
    out2 = np.asarray(spec.update_successors(
        succ, prevs, nexts, jnp.asarray([[True, False, False]])))
    assert out2[0, 2] == 5 and out2[0, 5] == -1


def _accept(toks, drafts, **kw):
    B, S = np.asarray(toks).shape
    args = dict(
        finite=jnp.ones((B, S), bool),
        stop_ids=jnp.full((B, 1), -1, jnp.int32),
        remaining=jnp.full((B,), 100, jnp.int32),
        active=jnp.ones((B,), bool),
        spec_on=jnp.ones((B,), bool),
    )
    args.update({k: jnp.asarray(v) for k, v in kw.items()})
    emit, e, acc, done, bad = spec.accept_window(
        jnp.asarray(toks), jnp.asarray(drafts), **args)
    return (np.asarray(emit), np.asarray(e), np.asarray(acc),
            np.asarray(done), np.asarray(bad))


def test_accept_full_match_emits_bonus_token():
    # drafts all match the verify samples: emit K drafts + the bonus row
    emit, e, acc, done, bad = _accept([[7, 8, 9, 5]], [[7, 8, 9]])
    assert emit[0].tolist() == [True] * 4 and e[0] == 4 and acc[0] == 3
    assert not done[0] and not bad[0]


def test_accept_first_mismatch_row_is_the_correction():
    # draft 1 wrong: emit row 0 (matched context) and row 1 (the sample
    # conditioned on the matched prefix — the baseline's correction)
    emit, e, acc, done, bad = _accept([[7, 8, 9, 5]], [[7, 3, 9]])
    assert emit[0].tolist() == [True, True, False, False]
    assert e[0] == 2 and acc[0] == 1


def test_accept_stop_token_cuts_the_window():
    # row 1 samples a stop token: rows after it must not emit, done set
    emit, e, acc, done, bad = _accept([[7, 6, 9, 5]], [[7, 6, 9]],
                                      stop_ids=[[6]])
    assert emit[0].tolist() == [True, True, False, False]
    assert e[0] == 2 and done[0] and not bad[0]


def test_accept_budget_clips_emission():
    emit, e, acc, done, bad = _accept([[7, 8, 9, 5]], [[7, 8, 9]],
                                      remaining=[2])
    assert e[0] == 2 and done[0]


def test_accept_nonfinite_row0_marks_bad():
    finite = np.ones((1, 4), bool)
    finite[0, 0] = False
    emit, e, acc, done, bad = _accept([[7, 8, 9, 5]], [[7, 8, 9]],
                                      finite=finite)
    assert bad[0] and e[0] == 0 and not done[0]


def test_accept_nonfinite_midwindow_truncates_not_bad():
    finite = np.ones((1, 4), bool)
    finite[0, 2] = False
    emit, e, acc, done, bad = _accept([[7, 8, 9, 5]], [[7, 8, 9]],
                                      finite=finite)
    assert not bad[0] and e[0] == 2


def test_accept_spec_opt_out_caps_at_one():
    emit, e, acc, done, bad = _accept([[7, 8, 9, 5]], [[7, 8, 9]],
                                      spec_on=[False])
    assert e[0] == 1 and emit[0].tolist() == [True, False, False, False]


def test_truncate_cache_len_only_touches_len_leaves():
    caches = {"body": {"k": jnp.ones((2, 3, 4)),
                       "len": jnp.asarray([[5, 7]], jnp.int32)}}
    out = spec.truncate_cache_len(caches, jnp.asarray([-2, 0]))
    assert np.asarray(out["body"]["len"]).tolist() == [[3, 7]]
    assert (np.asarray(out["body"]["k"]) == 1).all()
    # trees without len leaves (stub models) pass through untouched
    stub = {"state": jnp.zeros((1, 2, 1))}
    out2 = spec.truncate_cache_len(stub, jnp.asarray([-1, -1]))
    assert (np.asarray(out2["state"]) == 0).all()


# ---------------------------------------------------------------------------
# stub engine: deterministic stream identity + step-count win
# ---------------------------------------------------------------------------


class _CyclingModel:
    """next-token = (tok + 1) % vocab for any window width S — the
    multi-row generalization of test_engine's counting stub."""

    def __init__(self, cfg):
        self.cfg = cfg

    def init_cache(self, slots, max_len):
        return {"state": jnp.zeros((1, slots, 1), jnp.float32)}

    def prefill(self, params, batch, rc):
        nxt = (batch["tokens"][:, -1] + 1) % self.cfg.vocab_size
        return (jax.nn.one_hot(nxt, self.cfg.vocab_size)[:, None, :],
                {"state": jnp.zeros((1, 1, 1), jnp.float32)})

    def decode(self, params, tokens, positions, caches, rc):
        nxt = (tokens + 1) % self.cfg.vocab_size
        return jax.nn.one_hot(nxt, self.cfg.vocab_size), caches


def _stub_run(spec_k, prompts, max_new, stop=(), speculate=True, vocab=8):
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"),
                              vocab_size=vocab)
    eng = Engine(_CyclingModel(cfg), {}, RunConfig(mode="decode", remat=False),
                 EngineConfig(num_slots=2, max_len=64, speculate_k=spec_k))
    uids = [eng.submit(GenerationRequest(prompt=np.asarray(p, np.int32),
                                         max_new_tokens=max_new,
                                         stop_token_ids=stop,
                                         speculate=speculate))
            for p in prompts]
    steps = 0
    while not eng.idle:
        eng.step()
        steps += 1
        assert steps < 500
    return {u: list(eng.output(u).tokens) for u in uids}, eng


PROMPTS = [[3, 4, 5], [1, 2], [0, 1, 2, 3]]


def test_stub_spec_stream_identical_and_fewer_steps():
    base, be = _stub_run(0, PROMPTS, 16)
    got, eng = _stub_run(3, PROMPTS, 16)
    assert got == base
    m, mb = eng.metrics(), be.metrics()
    # the cycling stream is perfectly predictable once the table warms
    # up, so speculation must beat one-token-per-step decode
    assert m["decode_steps"] < mb["decode_steps"]
    assert m["decode_tokens_per_step"] > 1.0
    assert m["accepted_draft_tokens"] > 0
    assert eng.trace_counts["decode"] == 1  # one trace despite variable e


def test_stub_stop_token_mid_draft_window():
    # stop=6 lands mid-window for every prompt: drafts past the stop are
    # discarded and the stream ends exactly where the baseline ends
    base, _ = _stub_run(0, PROMPTS, 16, stop=(6,))
    got, eng = _stub_run(3, PROMPTS, 16, stop=(6,))
    assert got == base
    for toks in got.values():
        assert toks[-1] == 6 and 6 not in toks[:-1]
    assert eng.metrics()["finished_stop"] == len(PROMPTS)


def test_stub_per_request_opt_out():
    base, _ = _stub_run(0, PROMPTS, 16)
    got, eng = _stub_run(3, PROMPTS, 16, speculate=False)
    assert got == base
    # opted-out lanes emit at most one token per step: no extras at all
    assert eng.metrics()["extra_decode_tokens"] == 0
    assert eng.metrics()["accepted_draft_tokens"] == 0
    assert eng.trace_counts["decode"] == 1


def test_stub_metrics_invariant_with_speculation():
    _, eng = _stub_run(3, PROMPTS, 16)
    m = eng.metrics()
    assert m["tokens_generated"] == (
        m["prefills"] + m["decode_slot_steps"] - m["poisoned_slot_steps"]
        + m["extra_decode_tokens"])
    assert m["drafted_tokens"] == (m["accepted_draft_tokens"]
                                   + m["rejected_draft_tokens"])


def test_spec_requires_dense_no_window():
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), vocab_size=8,
                              sliding_window=8)
    with pytest.raises(ValueError, match="speculat"):
        Engine(_CyclingModel(cfg), {}, RunConfig(mode="decode", remat=False),
               EngineConfig(num_slots=2, max_len=64, speculate_k=3))


# ---------------------------------------------------------------------------
# real model: greedy + seeded identity across cache layouts
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    rc = RunConfig(mode="decode", remat=False, attn_chunk=16)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 7)]
    return model, params, rc, prompts


def _run(model, params, rc, prompts, spec_k, ecfg_kw, sampling=None):
    eng = Engine(model, params, rc,
                 EngineConfig(num_slots=2, max_len=48, speculate_k=spec_k,
                              **ecfg_kw))
    uids = []
    for i, p in enumerate(prompts):
        sp = sampling(i) if sampling else SamplingParams()
        uids.append(eng.submit(GenerationRequest(
            prompt=p, max_new_tokens=10, sampling=sp)))
    steps = 0
    while not eng.idle:
        eng.step()
        steps += 1
        assert steps < 300
    return {u: list(eng.output(u).tokens) for u in uids}, eng


@pytest.mark.parametrize("kw", [
    {},                                       # contiguous fp cache
    dict(num_blocks=24, block_size=8),        # paged
    dict(kv_bits=4),                          # KV-VQ encode-at-append
], ids=["contig", "paged", "kvq4"])
def test_real_model_greedy_identical(setup, kw):
    model, params, rc, prompts = setup
    base, _ = _run(model, params, rc, prompts, 0, kw)
    got, eng = _run(model, params, rc, prompts, 3, kw)
    assert got == base
    assert eng.trace_counts["decode"] == 1


def test_real_model_seeded_sampling_identical(setup):
    model, params, rc, prompts = setup
    mk = lambda i: SamplingParams(temperature=0.9, top_k=12, top_p=0.95,
                                  seed=i * 7)
    base, _ = _run(model, params, rc, prompts, 0, {}, mk)
    got, _ = _run(model, params, rc, prompts, 3, {}, mk)
    assert got == base
    pk = dict(num_blocks=24, block_size=8)
    base_p, _ = _run(model, params, rc, prompts, 0, pk, mk)
    got_p, _ = _run(model, params, rc, prompts, 3, pk, mk)
    assert got_p == base_p


def test_real_model_mixed_greedy_and_sampled(setup):
    """The issue's acceptance workload: greedy and seeded lanes sharing
    one batch, stop tokens included."""
    model, params, rc, prompts = setup
    mk = lambda i: (SamplingParams() if i % 2 == 0 else
                    SamplingParams(temperature=0.8, top_k=8, seed=11 + i))
    base, _ = _run(model, params, rc, prompts, 0, {}, mk)
    got, eng = _run(model, params, rc, prompts, 3, {}, mk)
    assert got == base
    assert eng.trace_counts["decode"] == 1


# ---------------------------------------------------------------------------
# snapshot/restore carries the drafter state
# ---------------------------------------------------------------------------


def test_snapshot_restore_spec_engine():
    cfg = dataclasses.replace(get_smoke_config("llama2_7b"), vocab_size=8)
    mk = lambda: Engine(_CyclingModel(cfg), {},
                        RunConfig(mode="decode", remat=False),
                        EngineConfig(num_slots=2, max_len=64, speculate_k=3))
    eng = mk()
    uids = [eng.submit(GenerationRequest(prompt=np.asarray(p, np.int32),
                                         max_new_tokens=16))
            for p in PROMPTS]
    for _ in range(3):
        eng.step()
    snap = eng.snapshot()
    ref = {u: list(eng.output(u).tokens) for u in uids} if eng.idle else None
    while not eng.idle:
        eng.step()
    want = {u: list(eng.output(u).tokens) for u in uids}
    eng2 = mk()
    eng2.restore(snap)
    while not eng2.idle:
        eng2.step()
    got = {u: list(eng2.output(u).tokens) for u in uids}
    assert got == want
