"""Jit'd wrapper for the fused EVA matmul kernel.

Accepts a VQWeight and activations of any leading shape; handles padding,
M-tiling (to bound the VMEM OC scratch), and dtype conversion.

The index matrix is handed to the kernel in its storage dtype (uint8 for
n <= 8) — the kernel upcasts per streamed tile, so HBM index traffic
stays at q bits/weight (see kernel.py's uint8 streaming contract). A
grouped projection family (VQWeight.splits non-empty) is just a wider N
here: one call, one OC scratch fill, every member's output columns swept
against the same VMEM-resident OC.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.vq import VQWeight
from repro.kernels.fused_vq_matmul.kernel import fused_vq_matmul_pallas
from repro.kernels.fused_vq_matmul.ref import fused_vq_matmul_ref

# Cap the OC scratch per pallas_call at 8 MiB: the scratch holds
# C * m_tile * V_padded * 2^n fp32, i.e. C*m_tile*V_padded*2^n*4 bytes.
_MAX_OC_BYTES = 8 * 1024 * 1024


def _m_tile(C: int, V: int, k: int) -> int:
    """Largest m_tile with C * m_tile * V * k * 4 bytes <= the scratch cap."""
    per_m = C * V * k * 4
    return max(1, _MAX_OC_BYTES // max(per_m, 1))


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_n", "interpret", "use_pallas", "out_dtype")
)
def fused_vq_matmul(
    x: jax.Array,
    vq: VQWeight,
    *,
    block_v: int = 32,
    block_n: int = 512,
    interpret: bool = False,
    use_pallas: bool = True,
    out_dtype=None,
) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    K, N, V, d, C = vq.K, vq.N, vq.V, vq.d, vq.C
    k = vq.codebooks.shape[-1]
    M = x.size // K
    X = x.reshape(M, V, d).astype(jnp.float32)
    # stream indices in their storage dtype (uint8 for n<=8) — the kernel
    # upcasts per tile; pre-widening here would 4x the index HBM traffic
    I = vq.idx
    scale = vq.scale.astype(jnp.float32)

    if not use_pallas:
        y = fused_vq_matmul_ref(X, vq.codebooks, I, scale)
        return y.reshape(*lead, N).astype(out_dtype)

    bv = min(block_v, V)
    bn = min(block_n, N)
    pad_v = (-V) % bv
    pad_n = (-N) % bn
    if pad_v:
        # padded V rows gather index 0 from zeroed X rows -> contribute 0
        X = jnp.pad(X, ((0, 0), (0, pad_v), (0, 0)))
        I = jnp.pad(I, ((0, 0), (0, pad_v), (0, 0)))
    if pad_n:
        I = jnp.pad(I, ((0, 0), (0, 0), (0, pad_n)))
        scale = jnp.pad(scale, (0, pad_n))

    # M-tiling bounds the OC scratch at C*mt*V_padded*k*4 bytes per call;
    # this Python loop is unrolled under jit (one pallas_call per M-tile).
    mt = _m_tile(C, X.shape[1], k)
    cb = vq.codebooks.astype(jnp.float32)
    outs = [
        fused_vq_matmul_pallas(
            X[m0:m0 + mt], cb, I, scale,
            block_v=bv, block_n=bn, interpret=interpret,
        )
        for m0 in range(0, M, mt)
    ]
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    if pad_n:
        y = y[:, :N]
    return y.reshape(*lead, N).astype(out_dtype)
