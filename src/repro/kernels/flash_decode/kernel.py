"""Pallas TPU kernel for single-token decode attention over a long KV
cache (flash-decoding): the cache is streamed HBM->VMEM in S-blocks with
an online-softmax accumulator held in VMEM — the second perf-critical
decode op next to the EVA matmul (at 32k context the cache read dominates
the decode step; see EXPERIMENTS.md §Roofline).

GQA layout: q (B, H, hd), cache (B, S, Hk, hd), groups g = H // Hk.
Grid: (B, num_s_blocks) with S innermost; per step the kernel computes
scores for one cache block against all heads and folds them into the
(m, l, acc) online-softmax state in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, n_s_blocks: int,
                         block_s: int):
    s_blk = pl.program_id(1)

    @pl.when(s_blk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (H, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bs, Hk, hd)
    v = v_ref[0].astype(jnp.float32)                  # (bs, Hk, hd)
    H, hd = q.shape
    bs, Hk, _ = k.shape
    g = H // Hk
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(Hk, g, hd)
    s = jnp.einsum("kgd,skd->kgs", qg, k) * scale     # (Hk, g, bs)
    pos = s_blk * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, -1e30)

    m_prev = m_scr[...]                               # (Hk, g)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[..., None]
                    + jnp.einsum("kgs,skd->kgd", p, v))
    m_scr[...] = m_new

    @pl.when(s_blk == n_s_blocks - 1)
    def _finalize():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = o.reshape(H, hd).astype(o_ref.dtype)


def flash_decode_pallas(
    q: jax.Array,        # (B, H, hd)
    k: jax.Array,        # (B, S, Hk, hd)
    v: jax.Array,        # (B, S, Hk, hd)
    lengths: jax.Array,  # (B,) int32 valid cache lengths
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    _, S, Hk, _ = k.shape
    assert H % Hk == 0 and S % block_s == 0, (H, Hk, S, block_s)
    g = H // Hk
    n_s_blocks = S // block_s
    grid = (B, n_s_blocks)

    kernel = functools.partial(_flash_decode_kernel,
                               n_s_blocks=n_s_blocks, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, block_s, Hk, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, block_s, Hk, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hk, g), jnp.float32),
            pltpu.VMEM((Hk, g), jnp.float32),
            pltpu.VMEM((Hk, g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths)
