"""Vector-quantization core: k-means codebook fitting and additive
(multi-codebook, AQLM-style) residual quantization of weight matrices.

Terminology follows the paper (Tbl. II):
  W      : (K, N) weight matrix
  d      : vector dimension (default 8)
  n      : index bit-width (default 8 -> 2^n = 256 centroids)
  C      : number of additive codebooks (2/3/4 -> q = C*n/d bits/weight)
  V      : K // d, height of the index matrix
  I      : (C, V, N) uint8 weight-index matrix
  B      : (C, d, 2^n) codebooks (centroids stored column-wise: B[c,:,e])
  scale  : (N,) per-output-channel scale (fp32)

The quantized representation of W is
  W_hat[:, j] = scale[j] * concat_v( sum_c B[c, :, I[c, v, j]] )
i.e. each d-element group of column j is the *sum* of one centroid from
each codebook (additive VQ), times a per-column scale.

Grouped-codebook layout
-----------------------
Same-input projection families (Wq|Wk|Wv of one attention block, or
W_gate|W_up of one MLP) may be quantized as a SINGLE wide VQ weight of
shape (K, sum_i N_i): one codebook set B serves every member, the index
matrix is the column-concatenation of the members' indices, and
``splits`` records the member widths (N_1, ..., N_g) so outputs can be
sliced apart after one wide EVA matmul.  Because the VQ-GEMM stage
(O = X·B) is independent of N, the grouped weight amortizes the output-
codebook computation g-fold (3x for QKV, 2x for gate+up) and raises the
effective compute-collapse ratio from N_i/2^n to (sum_i N_i)/2^n.
``splits == ()`` means an ordinary ungrouped weight.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class VQWeight:
    """Quantized representation of a (K, N) weight matrix.

    For a grouped-projection family N = sum(splits); `splits` is static
    metadata (part of the pytree aux data, preserved under jit/vmap/scan).
    """

    idx: jax.Array        # (C, V, N) uint8 (n<=8) or int32 (n>8)
    codebooks: jax.Array  # (C, d, 2^n) fp32
    scale: jax.Array      # (N,) fp32
    # static metadata
    K: int = 0
    N: int = 0
    d: int = 8
    n: int = 8
    splits: Tuple[int, ...] = ()   # per-member widths of a grouped family

    def tree_flatten(self):
        return (self.idx, self.codebooks, self.scale), (
            self.K, self.N, self.d, self.n, self.splits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        idx, codebooks, scale = children
        K, N, d, n, splits = aux
        return cls(idx=idx, codebooks=codebooks, scale=scale, K=K, N=N,
                   d=d, n=n, splits=splits)

    @property
    def C(self) -> int:
        return self.codebooks.shape[0] if hasattr(self.codebooks, "shape") else 0

    @property
    def V(self) -> int:
        return self.K // self.d

    @property
    def bits_per_weight(self) -> float:
        return self.C * self.n / self.d

    def compressed_bytes(self) -> int:
        idx_bytes = self.C * self.V * self.N * (1 if self.n <= 8 else 4)
        cb_bytes = self.C * self.d * (2 ** self.n) * 4
        sc_bytes = self.N * 4
        return idx_bytes + cb_bytes + sc_bytes


# ---------------------------------------------------------------------------
# k-means (Lloyd) with k-means++ style init, fully jittable.
# ---------------------------------------------------------------------------


def _kmeans_pp_init(key: jax.Array, points: jax.Array, k: int) -> jax.Array:
    """k-means++ seeding. points: (P, d) -> (k, d) initial centroids."""
    P = points.shape[0]

    def body(carry, _):
        key, cents, dists, i = carry
        key, sub = jax.random.split(key)
        # sample next centroid proportional to squared distance
        probs = dists / jnp.maximum(dists.sum(), 1e-30)
        nxt = jax.random.choice(sub, P, p=probs)
        new_c = points[nxt]
        cents = cents.at[i].set(new_c)
        new_d = jnp.sum((points - new_c) ** 2, axis=-1)
        dists = jnp.minimum(dists, new_d)
        return (key, cents, dists, i + 1), None

    key, sub = jax.random.split(key)
    first = points[jax.random.randint(sub, (), 0, P)]
    cents = jnp.zeros((k, points.shape[1]), points.dtype).at[0].set(first)
    dists = jnp.sum((points - first) ** 2, axis=-1)
    (key, cents, dists, _), _ = jax.lax.scan(body, (key, cents, dists, 1), None, length=k - 1)
    return cents


def _assign(points: jax.Array, cents: jax.Array) -> jax.Array:
    """Nearest-centroid assignment. points (P,d), cents (k,d) -> (P,) int32."""
    # ||p - c||^2 = ||p||^2 - 2 p.c + ||c||^2 ; ||p||^2 constant per point.
    d2 = -2.0 * points @ cents.T + jnp.sum(cents ** 2, axis=-1)[None, :]
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


def _update(points: jax.Array, assign: jax.Array, k: int, key: jax.Array) -> jax.Array:
    """Recompute centroids; dead centroids re-seeded from random points."""
    P, d = points.shape
    onehot_sums = jax.ops.segment_sum(points, assign, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones((P,), points.dtype), assign, num_segments=k)
    cents = onehot_sums / jnp.maximum(counts, 1.0)[:, None]
    # re-seed empty clusters from random points to avoid centroid collapse
    rnd = points[jax.random.randint(key, (k,), 0, P)]
    return jnp.where((counts > 0)[:, None], cents, rnd)


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key: jax.Array, points: jax.Array, k: int, iters: int = 20) -> Tuple[jax.Array, jax.Array]:
    """Lloyd's k-means. Returns (centroids (k,d), assignment (P,))."""
    points = points.astype(jnp.float32)
    key, init_key = jax.random.split(key)
    cents = _kmeans_pp_init(init_key, points, k)

    def body(carry, key_i):
        cents = carry
        a = _assign(points, cents)
        cents = _update(points, a, k, key_i)
        return cents, None

    keys = jax.random.split(key, iters)
    cents, _ = jax.lax.scan(body, cents, keys)
    return cents, _assign(points, cents)


# ---------------------------------------------------------------------------
# Additive VQ fit (AQLM-style greedy residual + optional refinement)
# ---------------------------------------------------------------------------


def fit_vq(
    key: jax.Array,
    W: Union[jax.Array, Sequence[jax.Array]],
    *,
    d: int = 8,
    n: int = 8,
    C: int = 2,
    kmeans_iters: int = 20,
    refine_rounds: int = 1,
) -> VQWeight:
    """Quantize W (K, N) to an additive C-codebook VQ representation.

    Greedy residual fit: codebook c is k-means over the residual after
    subtracting codebooks < c, followed by `refine_rounds` of alternating
    re-fits (each codebook refit against the residual of all others) —
    the paper's AQLM configuration at d=8, n=8, C=q.

    Grouped mode: pass a sequence of same-K matrices ([Wq, Wk, Wv] or
    [W_gate, W_up]) and they are fitted as ONE (K, sum N_i) matrix sharing
    a single codebook set; the member widths are recorded in `splits`
    (see the module docstring's grouped-codebook layout).
    """
    splits: Tuple[int, ...] = ()
    if isinstance(W, (list, tuple)):
        Ks = {int(w.shape[0]) for w in W}
        if len(Ks) != 1:
            raise ValueError(f"grouped fit_vq requires equal K, got {Ks}")
        splits = tuple(int(w.shape[1]) for w in W)
        W = jnp.concatenate([jnp.asarray(w) for w in W], axis=1)
    K, N = W.shape
    assert K % d == 0, f"K={K} not divisible by d={d}"
    V = K // d
    k = 2 ** n
    W = W.astype(jnp.float32)

    # per-output-channel scale normalizes column energy (AQLM uses per-group
    # scales; per-column is the hardware-friendly variant the paper's
    # epilogue applies as a single fp multiply after accumulation).
    scale = jnp.maximum(jnp.sqrt(jnp.mean(W ** 2, axis=0)), 1e-8)  # (N,)
    Wn = W / scale[None, :]

    # view as points: column-major grouping — vectors are d consecutive
    # elements along K for every output channel j -> (V*N, d) points
    pts = Wn.reshape(V, d, N).transpose(0, 2, 1).reshape(V * N, d)

    codebooks = []
    assigns = []
    resid = pts
    for c in range(C):
        key, sub = jax.random.split(key)
        cents, a = kmeans(sub, resid, k, iters=kmeans_iters)
        codebooks.append(cents)
        assigns.append(a)
        resid = resid - cents[a]

    # alternating refinement: refit codebook c on (pts - sum_{c'!=c} contrib)
    for _ in range(refine_rounds):
        for c in range(C):
            recon_others = jnp.zeros_like(pts)
            for c2 in range(C):
                if c2 != c:
                    recon_others = recon_others + codebooks[c2][assigns[c2]]
            target = pts - recon_others
            key, sub = jax.random.split(key)
            cents, a = kmeans(sub, target, k, iters=max(kmeans_iters // 2, 5))
            codebooks[c] = cents
            assigns[c] = a

    B = jnp.stack([cb.T for cb in codebooks])  # (C, d, k): centroid e = B[c,:,e]
    idx_dtype = jnp.uint8 if n <= 8 else jnp.int32
    I = jnp.stack([a.reshape(V, N) for a in assigns]).astype(idx_dtype)  # (C, V, N)
    return VQWeight(idx=I, codebooks=B, scale=scale, K=K, N=N, d=d, n=n,
                    splits=splits)


def dequantize(vq: VQWeight) -> jax.Array:
    """Reconstruct W_hat (K, N) from the VQ representation (the
    'conventional VQ' path the paper's baselines execute)."""
    C, d, k = vq.codebooks.shape
    V, N = vq.idx.shape[1], vq.idx.shape[2]
    cb = vq.codebooks.transpose(0, 2, 1)  # (C, k, d): row e = centroid e
    # batched gather per codebook: cents[c, v, n, :] = cb[c, idx[c,v,n], :]
    cents = jax.vmap(lambda cbc, idxc: jnp.take(cbc, idxc, axis=0))(
        cb, vq.idx.astype(jnp.int32)
    )  # (C, V, N, d)
    cents = cents.sum(axis=0)  # additive sum over codebooks -> (V, N, d)
    W = cents.transpose(0, 2, 1).reshape(V * d, N)
    return W * vq.scale[None, :]


def synthetic_vq(
    key: jax.Array, K: int, N: int, *, d: int = 8, n: int = 8, C: int = 2,
    dtype=jnp.float32, splits: Tuple[int, ...] = (),
) -> VQWeight:
    """Random-but-valid VQ weight (for serving dry-runs / benchmarks where
    fitting k-means on a 72B model is pointless). Index distribution is
    uniform, matching the paper's Fig. 14(b) entropy argument. `splits`
    marks the result as a grouped family (must sum to N)."""
    if splits:
        assert sum(splits) == N, (splits, N)
    V = K // d
    k = 2 ** n
    k_idx, k_cb, k_sc = jax.random.split(key, 3)
    idx_dtype = jnp.uint8 if n <= 8 else jnp.int32
    idx = jax.random.randint(k_idx, (C, V, N), 0, k).astype(idx_dtype)
    # scale codebooks ~ 1/sqrt(K*C) so W_hat has unit-ish variance
    codebooks = (jax.random.normal(k_cb, (C, d, k), dtype) / np.sqrt(K * C)).astype(dtype)
    scale = jnp.ones((N,), jnp.float32)
    return VQWeight(idx=idx, codebooks=codebooks, scale=scale, K=K, N=N,
                    d=d, n=n, splits=splits)


def vq_specs(K: int, N: int, *, d: int = 8, n: int = 8, C: int = 2,
             splits: Tuple[int, ...] = ()) -> VQWeight:
    """ShapeDtypeStruct stand-in with identical tree structure (dry-run)."""
    V = K // d
    k = 2 ** n
    idx_dtype = jnp.uint8 if n <= 8 else jnp.int32
    return VQWeight(
        idx=jax.ShapeDtypeStruct((C, V, N), idx_dtype),
        codebooks=jax.ShapeDtypeStruct((C, d, k), jnp.float32),
        scale=jax.ShapeDtypeStruct((N,), jnp.float32),
        K=K, N=N, d=d, n=n, splits=splits,
    )


def splits_shard_aligned(splits: Tuple[int, ...], N: int, shards: int) -> bool:
    """True when every member boundary of a grouped projection family
    (column-concatenated widths ``splits`` summing to ``N``) falls on a
    shard boundary of the N axis split ``shards``-ways.

    Shared by the sharding rules (runtime/sharding.py: misaligned grouped
    leaves fall back to V-sharding) and by the quantization pass's
    shard-aware grouping (core/quantize.py: skip grouping such families
    so the members keep clean column sharding)."""
    if shards <= 1:
        return True
    if N % shards:
        return False
    if not splits:
        return True
    shard = N // shards
    off = 0
    for width in splits[:-1]:
        off += width
        if off % shard:
            return False
    return True


def split_grouped(vq: VQWeight) -> Tuple[VQWeight, ...]:
    """Slice a grouped VQWeight back into its per-projection members
    (shared codebooks; per-member index columns and scales)."""
    if not vq.splits:
        return (vq,)
    offs = np.cumsum((0,) + vq.splits)
    return tuple(
        VQWeight(
            idx=vq.idx[..., lo:hi], codebooks=vq.codebooks,
            scale=vq.scale[..., lo:hi], K=vq.K, N=hi - lo, d=vq.d, n=vq.n,
        )
        for lo, hi in zip(offs[:-1], offs[1:])
    )


def reconstruction_error(W: jax.Array, vq: VQWeight) -> jax.Array:
    """Relative Frobenius reconstruction error ||W - W_hat|| / ||W||."""
    W_hat = dequantize(vq)
    return jnp.linalg.norm(W - W_hat) / jnp.maximum(jnp.linalg.norm(W), 1e-30)
