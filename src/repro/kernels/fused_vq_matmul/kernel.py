"""Flagship fused EVA kernel: VQ-GEMM + conflict-free OC lookup in one
pallas_call, with the output codebook resident in VMEM scratch.

This is the TPU realization of the paper's architecture (Fig. 3(c)/Fig. 4):

  * the weight codebook B (C·d·2^n fp32 ≈ 16-64 KB) is fully VMEM-resident
    (paper: 16 KB WC SRAM),
  * the output codebook O (C, M, V, 2^n) is computed ONCE per token batch
    on the MXU during the first N-tile sweep and kept in VMEM scratch
    (paper: 192 KB OC SRAM, "output and WC remain stationary on-chip"),
  * the weight-index matrix I is streamed HBM->VMEM in (bv, bn) tiles
    (paper: "WI is streamed into the chip"),
  * the output tile (M, bn) is accumulated output-stationary across the V
    sweep with add-only reduction + one final per-channel scale (paper's
    Epilogue Unit),
  * O never round-trips to HBM — the GEMM->EU handoff of Fig. 7(b).

uint8 index-streaming contract: I tiles arrive in their STORAGE dtype —
uint8 for n <= 8 (int32 only when n > 8) — and are upcast to int32
per-tile inside the kernel, after the HBM->VMEM copy. Callers must NOT
pre-widen the index matrix: a pre-call `astype(int32)` would stream 4x
the bytes the paper's q-bits/weight bandwidth model assumes (32 vs n
bits per index) and quadruple the VMEM index-tile footprint.

Grid: (num_n_tiles, num_v_tiles), V innermost. During the n==0 sweep each
v-step additionally computes its OC slab into scratch; later n-tiles reuse
it. For a grouped projection family ([Wq|Wk|Wv] or [W_gate|W_up] sharing
one codebook set, core/vq.py) the N sweep is simply wider: the same
VMEM-resident OC scratch serves every member's n-tiles, amortizing the
VQ-GEMM stage g-fold instead of recomputing it per projection. HBM
traffic per layer is therefore: x once, I once (q bits/weight), y once —
the paper's bandwidth claim (d-fold reduction vs centroid streaming,
8/16-fold vs bf16 weights at q=2).

VMEM budget: scratch is C·M·V·2^n fp32 = C*M*V*2^n*4 bytes; the wrapper
tiles M so this stays under its ~8 MB cap (e.g. C=2, M=8, V=512, n=8
-> exactly 8 MB) and callers pick block_v to bound the gathered tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(
    x_ref, b_ref, i_ref, s_ref, y_ref, o_scr,
    *, n_v_tiles: int, block_v: int,
):
    n = pl.program_id(0)
    v = pl.program_id(1)
    C = b_ref.shape[0]
    M = x_ref.shape[0]
    k = b_ref.shape[2]

    # ---- VQ-GEMM stage: fill this v-slab of the OC once (first N sweep) --
    @pl.when(n == 0)
    def _compute_oc():
        x = x_ref[...].astype(jnp.float32).reshape(M * block_v, x_ref.shape[2])
        for c in range(C):  # C is tiny and static — unrolled
            b_c = b_ref[c].astype(jnp.float32)          # (d, k)
            o_c = jax.lax.dot_general(
                x, b_c, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )                                            # (M*bv, k)
            o_scr[c, :, pl.dslice(v * block_v, block_v), :] = o_c.reshape(
                M, block_v, k
            )

    # ---- Epilogue stage: conflict-free lookup + add-only reduction -------
    @pl.when(v == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    o = o_scr[:, :, pl.dslice(v * block_v, block_v), :]  # (C, M, bv, k)
    # per-tile upcast of the streamed uint8 (or int32 for n>8) index tile
    idx = i_ref[...].astype(jnp.int32)                   # (C, bv, bn)
    g = jnp.take_along_axis(o, idx[:, None, :, :], axis=3)  # (C, M, bv, bn)
    y_ref[...] += g.sum(axis=(0, 2))

    @pl.when(v == n_v_tiles - 1)
    def _scale():
        y_ref[...] *= s_ref[...][None, :].astype(jnp.float32)


def fused_vq_matmul_pallas(
    x: jax.Array,          # (M, V, d)
    codebooks: jax.Array,  # (C, d, k)
    I: jax.Array,          # (C, V, N) uint8 (n<=8) or int32 (n>8)
    scale: jax.Array,      # (N,) fp32
    *,
    block_v: int = 32,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, V, d = x.shape
    C, d2, k = codebooks.shape
    N = I.shape[-1]
    assert d == d2 and I.shape[:2] == (C, V)
    assert V % block_v == 0 and N % block_n == 0, (V, block_v, N, block_n)
    n_v_tiles = V // block_v
    grid = (N // block_n, n_v_tiles)

    kernel = functools.partial(_fused_kernel, n_v_tiles=n_v_tiles, block_v=block_v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, block_v, d), lambda n, v: (0, v, 0)),
            pl.BlockSpec((C, d, k), lambda n, v: (0, 0, 0)),
            pl.BlockSpec((C, block_v, block_n), lambda n, v: (0, v, n)),
            pl.BlockSpec((block_n,), lambda n, v: (n,)),
        ],
        out_specs=pl.BlockSpec((M, block_n), lambda n, v: (0, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((C, M, V, k), jnp.float32)],
        interpret=interpret,
    )(x, codebooks, I, scale)
