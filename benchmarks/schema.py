"""Machine-readability gate for `eva-bench-rows/v1` bench JSON.

BENCH_measured.json is load-bearing: `core/calibrate.py` fits the
Planner's per-backend time constants from its rows, so a row that loses
its `plan`/`derived`/cost fields silently breaks calibration. This
module is the schema check CI runs against both the committed file and
a fresh tiny-shape `benchmarks/run.py smoke --json` emission — the build
fails on the first malformed row.

Validation is hand-rolled over the stdlib (the container pins its
packages; no jsonschema dependency):

  top level : {"schema": "eva-bench-rows/v1", "rows": [...],
               "failures": [str, ...]? }
  row       : {"module": str, "name": str, "us_per_call": number,
               "derived": dict}
  timed rows of the `measured`/`smoke` modules (every row except
  harness-failure rows, name `*/ERROR`) must additionally carry the
  calibration fields in `derived`:
      plan (str), backend (str),
      macs / lookup_adds / weight_bytes (non-negative numbers)
  timed rows of the `serve` module (engine throughput traces) must carry
  the engine totals in `derived`:
      tokens / tok_per_s / requests (non-negative numbers)

CLI (exit 1 on the first error, listing all of them):

    PYTHONPATH=src python -m benchmarks.schema BENCH_measured.json
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Optional, Sequence

SCHEMA = "eva-bench-rows/v1"

# modules whose timed rows must be calibration-ready
CALIBRATED_MODULES = ("measured", "smoke")
COST_FIELDS = ("macs", "lookup_adds", "weight_bytes")

# serving-engine throughput rows must carry the engine totals — and the
# KV memory accounting (serve/paging.py gauges; a contiguous engine
# reports its constant worst-case kv_bytes_in_use and zero blocks) — so
# the serving trajectory stays machine-readable across PRs
SERVE_MODULES = ("serve",)
SERVE_FIELDS = ("tokens", "tok_per_s", "requests",
                "kv_bytes_in_use", "blocks_in_use", "blocks_free")

# the speculative-decoding trace row additionally pins its headline
# numbers so the multi-token-per-step trajectory is tracked across PRs
SPEC_ROW = "serve/spec_decode_trace"
SPEC_FIELDS = ("tokens_per_step", "acceptance_rate",
               "drafted", "accepted")


def _is_num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_rows(doc: Any) -> List[str]:
    """Every schema violation in `doc` (empty list == valid)."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level must be an object, got {type(doc).__name__}"]
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema must be {SCHEMA!r}, got {doc.get('schema')!r}")
    rows = doc.get("rows")
    if not isinstance(rows, list):
        return errors + ["rows must be a list"]
    failures = doc.get("failures", [])
    if not isinstance(failures, list) or \
            not all(isinstance(f, str) for f in failures):
        errors.append("failures must be a list of strings")

    for i, row in enumerate(rows):
        where = f"rows[{i}]"
        if not isinstance(row, dict):
            errors.append(f"{where}: must be an object")
            continue
        name = row.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing/empty name")
            name = ""
        where = f"rows[{i}] ({name})" if name else where
        if not isinstance(row.get("module"), str):
            errors.append(f"{where}: missing module")
        if not _is_num(row.get("us_per_call")):
            errors.append(f"{where}: us_per_call must be a number")
        derived = row.get("derived")
        if not isinstance(derived, dict):
            errors.append(f"{where}: derived must be an object")
            continue
        if row.get("module") in CALIBRATED_MODULES \
                and not name.endswith("/ERROR"):
            if not isinstance(derived.get("plan"), str):
                errors.append(f"{where}: calibrated row missing derived.plan")
            if not isinstance(derived.get("backend"), str):
                errors.append(
                    f"{where}: calibrated row missing derived.backend")
            for f in COST_FIELDS:
                v = derived.get(f)
                if not _is_num(v) or v < 0:
                    errors.append(
                        f"{where}: calibrated row needs non-negative "
                        f"derived.{f}, got {v!r}")
        if row.get("module") in SERVE_MODULES \
                and not name.endswith("/ERROR"):
            for f in SERVE_FIELDS:
                v = derived.get(f)
                if not _is_num(v) or v < 0:
                    errors.append(
                        f"{where}: serve row needs non-negative "
                        f"derived.{f}, got {v!r}")
            if name == SPEC_ROW:
                for f in SPEC_FIELDS:
                    v = derived.get(f)
                    if not _is_num(v) or v < 0:
                        errors.append(
                            f"{where}: spec-decode row needs non-negative "
                            f"derived.{f}, got {v!r}")
    return errors


def validate_file(path: str) -> List[str]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    return validate_rows(doc)


def main(argv: Optional[Sequence[str]] = None) -> None:
    args = list(sys.argv[1:] if argv is None else argv)
    if not args:
        sys.exit("usage: python -m benchmarks.schema BENCH.json [...]")
    failed = False
    for path in args:
        errors = validate_file(path)
        if errors:
            failed = True
            print(f"{path}: {len(errors)} schema error(s)", file=sys.stderr)
            for e in errors:
                print(f"  {e}", file=sys.stderr)
        else:
            print(f"{path}: ok ({SCHEMA})")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
