"""flash_decode kernel sweeps + gradient-accumulation step equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,S,H,Hk,hd,bs", [
    (2, 64, 4, 2, 8, 16),
    (3, 100, 8, 1, 16, 32),   # MQA, non-divisible S vs block
    (1, 128, 6, 6, 32, 128),  # MHA, single block
    (2, 48, 4, 4, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(B, S, H, Hk, hd, bs, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hk, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hk, hd), dtype)
    lens = jax.random.randint(ks[3], (B,), 1, S + 1)
    got = flash_decode(q, k, v, lens, block_s=bs, interpret=True)
    ref = flash_decode(q, k, v, lens, use_pallas=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_decode_4d_query():
    q = jax.random.normal(KEY, (2, 1, 4, 8))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 32, 2, 8))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 32, 2, 8))
    lens = jnp.asarray([5, 32])
    got = flash_decode(q, k, v, lens, block_s=8, interpret=True)
    assert got.shape == (2, 1, 4, 8)


def test_grad_accumulation_matches_full_batch():
    """accum_steps=K produces the same update as the full-batch step."""
    from repro.configs import get_smoke_config
    from repro.launch.steps import make_train_step
    from repro.models import build_model
    from repro.models.common import RunConfig
    from repro.optim import AdamWConfig, adamw_init
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    ocfg = AdamWConfig(lr=1e-3)
    rc = RunConfig(mode="train", remat=False, attn_chunk=8)
    batch = {
        "tokens": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size),
    }
    s1 = make_train_step(model, ocfg, rc, accum_steps=1)
    s2 = make_train_step(model, ocfg, rc, accum_steps=2)
    p1, _, m1 = s1(params, adamw_init(params, ocfg), batch)
    p2, _, m2 = s2(params, adamw_init(params, ocfg), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
