"""Event-driven continuous-batching serving engine.

The EVA deployment shape (paper §V-C / Fig. 7(c)): prefill runs per-request
(INT8 GEMM path), decode runs as one batched step over all active slots so
every streamed weight-index tile is reused across requests. Slots free up
as requests finish and queued requests are admitted with a fresh prefill —
classic continuous batching, expressed with jit-stable shapes (fixed slot
count, fixed cache capacity).

Request-level surface (serve/api.py types):

  uid = engine.submit(GenerationRequest(...))   # admission-checked
  events = engine.step()                        # one tick -> StreamEvents
  for ev in engine.stream(uid): ...             # per-request iterator
  engine.generate(prompts, n)                   # greedy batch convenience
  engine.metrics()                              # counters snapshot

Sampling and stopping run INSIDE the jitted decode step with jit-stable
shapes: per-slot PRNG keys, temperature/top-k/top-p, stop-token sets and
budgets are all device arrays of fixed (num_slots, ...) shape, so a
mixed-sampling workload traces the decode step exactly ONCE and the host
loop only reads back a ``(next_tok, done_mask)`` pair.

Prefill is length-BUCKETED for attention families: prompts right-pad
(edge mode — the pad value is causally masked) to power-of-two buckets,
the true length rides along as a traced scalar, and the jitted prefill
step retraces at most once per bucket instead of once per prompt length.
Families whose prefill is not padding-invariant (recurrent state
integrates pad tokens: xlstm/rglru; MoE capacity-drop routing depends on
the token count: moe) run exact-length prefill instead.

All caches are batched on axis 1 (axis 0 is the scanned layer/group axis),
so slot insertion is a tree-wide dynamic_update_slice at index b.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import time
from collections import deque
from typing import Any, Deque, Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod
from repro.models.api import Model
from repro.models.common import RunConfig
from repro.serve import api
from repro.serve.api import (GenerationRequest, RequestOutput, SamplingParams,
                             StreamEvent)
from repro.serve.kvcache import pad_prefill_cache
from repro.serve.metrics import EngineMetrics
from repro.serve.scheduler import QueueFull, Scheduler, TrackedRequest

log = logging.getLogger(__name__)

# families whose prefill output is invariant to causal right-padding
# (pure-attention stacks); recurrent state (xlstm/rglru) integrates pad
# tokens and MoE capacity-based routing depends on the total token count,
# so those families prefill at exact prompt length
_BUCKETABLE_FAMILIES = ("dense", "whisper", "vision")


def _insert_slot(batched: Any, single: Any, b: int) -> Any:
    """Write a single-request cache (batch size 1 at axis 1) into slot b of
    the batched cache tree."""

    def one(dst, src):
        idx = [0] * dst.ndim
        idx[1] = b
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), tuple(idx))

    return jax.tree_util.tree_map(one, batched, single)


@dataclasses.dataclass
class EngineConfig:
    num_slots: int = 4
    max_len: int = 256
    max_queue: int = 256               # submit() rejects past this bound
    prefill_bucketing: bool = True     # pad prompts to power-of-two buckets
    min_prefill_bucket: int = 8
    # finished RequestOutputs (+ their undrained event buffers) retained
    # for output()/stream(); oldest evicted past this bound so a
    # long-running submit()/step() server stays memory-bounded
    max_retained: int = 1024


class Engine:
    def __init__(self, model: Model, params: Any, rc: RunConfig,
                 ecfg: EngineConfig, extras: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.rc = rc
        self.ecfg = ecfg
        self.extras = extras or {}
        self.sched = Scheduler(ecfg.num_slots, max_queue=ecfg.max_queue)
        cfg = model.cfg
        self.window = cfg.sliding_window or cfg.local_window
        self.caches = model.init_cache(ecfg.num_slots, ecfg.max_len)
        self.metrics_counters = EngineMetrics(num_slots=ecfg.num_slots)

        B = ecfg.num_slots
        # per-slot decode state: every per-request sampling/stopping knob
        # is DATA of fixed shape, so the jitted decode step traces once
        self.positions = np.zeros((B,), np.int32)
        self.last_token = np.zeros((B,), np.int32)
        self.rng_keys = np.zeros((B, 2), np.uint32)
        self.temperature = np.ones((B,), np.float32)
        self.top_k = np.zeros((B,), np.int32)
        self.top_p = np.ones((B,), np.float32)
        self.greedy = np.ones((B,), bool)
        self.stop_ids = np.full((B, api.MAX_STOP_IDS), -1, np.int32)
        self.remaining = np.zeros((B,), np.int32)
        self.active = np.zeros((B,), bool)

        # request-level bookkeeping; _retired drives FIFO eviction of
        # finished outputs/buffers past ecfg.max_retained
        self._outputs: Dict[int, RequestOutput] = {}
        self._buffers: Dict[int, Deque[StreamEvent]] = {}
        self._pending: List[StreamEvent] = []
        self._retired: Deque[int] = deque()

        # trace-counting harness: these tick only when jax (re)traces the
        # python body — tests pin decode==1 and prefill<=len(buckets)
        self.trace_counts = {"decode": 0, "prefill": 0}

        self._bucketed = (ecfg.prefill_bucketing
                          and cfg.family in _BUCKETABLE_FAMILIES)
        self._buckets = (api.prefill_buckets(ecfg.max_len,
                                             ecfg.min_prefill_bucket)
                         if self._bucketed else ())

        # Pre-plan at the exact execution shapes. Decode always runs at
        # M = num_slots tokens in flight; bucketed prefill runs at exactly
        # the bucket lengths — both warm the Planner cache before the
        # first trace (the traced steps then only hit it). Unbucketed
        # families keep the capacity-bound estimate for introspection.
        self.plans: Dict[str, Any] = {
            "decode": plan_mod.preplan_params(
                params, rc.policy, mode="decode", m=ecfg.num_slots,
                act_dtype=cfg.act_dtype),
        }
        if self._bucketed:
            per_bucket = plan_mod.preplan_prefill_buckets(
                params, rc.policy, buckets=self._buckets,
                act_dtype=cfg.act_dtype)
            for m, plans in per_bucket.items():
                self.plans[f"prefill@{m}"] = plans
        else:
            self.plans["prefill@cap"] = plan_mod.preplan_params(
                params, rc.policy, mode="prefill", m=ecfg.max_len,
                act_dtype=cfg.act_dtype)
        for phase, plans in self.plans.items():
            uniq: Dict[str, int] = {}
            rankings: Dict[str, int] = {}
            for _path, pl in plans:
                uniq[pl.describe()] = uniq.get(pl.describe(), 0) + 1
                rk = pl.describe_ranking()
                if rk:  # >1 eligible backend: show the predicted-time order
                    rankings[rk] = rankings.get(rk, 0) + 1
            for desc, count in sorted(uniq.items()):
                log.info("%s plan [%d leaves] %s", phase, count, desc)
            for rk, count in sorted(rankings.items()):
                log.info("%s ranking [%d leaves] %s", phase, count, rk)

        self._decode_fn = jax.jit(
            functools.partial(self._decode_impl, rc=rc.replace(mode="decode")),
        )
        self._prefill_fn = jax.jit(
            functools.partial(self._prefill_impl,
                              rc=self.rc.replace(mode="prefill")),
        )
        # prefill extras (whisper frames / vision embeds), batched once
        self._extra_batch = {
            k: (v[None] if getattr(v, "ndim", 0) == 2 else v[:1])
            for k, v in self.extras.items()
        }

    # ------------------------------------------------------------ admission
    def _admission_error(self, request: GenerationRequest) -> Optional[str]:
        """Why this request can never be served on this engine (None when
        servable). Windowed caches wrap by design, so only the prompt must
        fit; full caches also need room for every decode write (positions
        prompt_len .. prompt_len + max_new_tokens - 2) — past capacity the
        write slot clamps and silently corrupts the newest KV entry."""
        if request.prompt_len > self.ecfg.max_len:
            return (f"prompt length {request.prompt_len} exceeds max_len "
                    f"{self.ecfg.max_len}")
        need = request.prompt_len + request.max_new_tokens - 1
        if self.window == 0 and need > self.ecfg.max_len:
            return (f"prompt_len + max_new_tokens - 1 = {need} exceeds the "
                    f"cache capacity max_len={self.ecfg.max_len}")
        return None

    def submit(self, request: GenerationRequest) -> int:
        """Admission-checked submit. Unservable requests (over-long
        prompt, decode budget past cache capacity) and a full queue
        reject IMMEDIATELY with a clean terminal
        ``RequestOutput(finish_reason="rejected")`` — no prefill compute
        is spent and no deep shape error or silent cache clamp happens
        later."""
        if not isinstance(request, GenerationRequest):
            raise TypeError(
                f"submit() takes a GenerationRequest, got "
                f"{type(request).__name__}; use Engine.generate() for the "
                "prompt-list convenience path")
        if len(request.stop_set) > api.MAX_STOP_IDS:
            raise ValueError(
                f"request has {len(request.stop_set)} stop ids; the engine "
                f"supports at most {api.MAX_STOP_IDS} (api.MAX_STOP_IDS)")
        self.metrics_counters.submitted += 1
        why = self._admission_error(request)
        if why is not None:
            return self._reject(request, why)
        try:
            uid = self.sched.submit(request)
        except QueueFull as e:
            return self._reject(request, str(e))
        self._buffers[uid] = deque()
        return uid

    def _reject(self, request: GenerationRequest, why: str) -> int:
        uid = self.sched.next_uid()
        log.info("request %d rejected: %s", uid, why)
        self.metrics_counters.rejected += 1
        out = RequestOutput(uid=uid, tokens=(), finish_reason="rejected")
        self._outputs[uid] = out
        # the terminal event is delivered (and buffered) by the next step()
        self._buffers[uid] = deque()
        self._pending.append(StreamEvent(uid=uid, index=-1, token=None,
                                         finish_reason="rejected"))
        self._retain(uid)
        return uid

    def _retain(self, uid: int) -> None:
        """FIFO-bound the finished outputs + undrained event buffers: a
        long-running submit()/step() server that never reads them must
        not grow memory linearly in total requests served."""
        self._retired.append(uid)
        while len(self._retired) > self.ecfg.max_retained:
            old = self._retired.popleft()
            self._outputs.pop(old, None)
            self._buffers.pop(old, None)

    # ------------------------------------------------------------- prefill
    def _prefill_impl(self, params, tokens, true_len, key, temperature,
                      top_k, top_p, greedy, extras, *, rc):
        """Jitted per-request prefill: forward at the (bucket-)padded
        length, sample the first token from the logits at the TRUE last
        position, and convert the cache to decode capacity — all on
        device, one trace per bucket."""
        self.trace_counts["prefill"] += 1
        batch = {"tokens": tokens}
        batch.update(extras)
        logits, cache = self.model.prefill(params, batch, rc)
        last = jax.lax.dynamic_slice_in_dim(
            logits[0], true_len - 1, 1, axis=0)[0]
        last = last[: self.model.cfg.vocab_size][None]          # (1, V)
        tok, new_key = api.sample_tokens(
            last, key[None], temperature[None], top_k[None], top_p[None],
            greedy[None])
        cache = pad_prefill_cache(cache, self.ecfg.max_len,
                                  window=self.window, true_len=true_len)
        return tok[0], new_key[0], cache

    def _prefill_one(self, slot: int, tr: TrackedRequest) -> int:
        req = tr.request
        sp = req.sampling
        L = req.prompt_len
        prompt = req.prompt
        if self._bucketed:
            bucket = api.bucket_for(L, self._buckets)
            if bucket > L:
                # edge-pad: the value is causally masked for real rows,
                # and repeating the last token keeps stub models (that
                # read tokens[:, -1]) meaningful in tests
                prompt = np.pad(prompt, (0, bucket - L), mode="edge")
        key = jax.random.PRNGKey(sp.seed)
        tok, new_key, cache = self._prefill_fn(
            self.params, jnp.asarray(prompt[None], jnp.int32),
            jnp.asarray(L, jnp.int32), jnp.asarray(key),
            jnp.asarray(sp.temperature, jnp.float32),
            jnp.asarray(sp.top_k, jnp.int32),
            jnp.asarray(sp.top_p, jnp.float32),
            jnp.asarray(sp.greedy), self._extra_batch,
        )
        self.caches = _insert_slot(self.caches, cache, slot)
        tok = int(tok)
        tr.generated.append(tok)

        # per-slot decode state for this request
        stop = sorted(req.stop_set)
        self.positions[slot] = L
        self.last_token[slot] = tok
        self.rng_keys[slot] = np.asarray(new_key)
        self.temperature[slot] = sp.temperature
        self.top_k[slot] = sp.top_k
        self.top_p[slot] = sp.top_p
        self.greedy[slot] = sp.greedy
        self.stop_ids[slot, :] = -1
        self.stop_ids[slot, : len(stop)] = stop
        self.remaining[slot] = req.max_new_tokens - 1
        self.active[slot] = True
        return tok

    # -------------------------------------------------------------- decode
    def _decode_impl(self, params, caches, tokens, positions, keys,
                     temperature, top_k, top_p, greedy, stop_ids, remaining,
                     active, *, rc):
        """Jitted batched decode step: model decode + in-jit per-slot
        sampling and stopping (serve/api.sample_and_stop). Every
        per-request knob is a fixed-shape device array -> ONE trace."""
        self.trace_counts["decode"] += 1
        logits, new_caches = self.model.decode(
            params, tokens[:, None], positions[:, None], caches, rc)
        logits = logits[:, 0, : self.model.cfg.vocab_size]
        tok, done, new_keys = api.sample_and_stop(
            logits, keys=keys, temperature=temperature, top_k=top_k,
            top_p=top_p, greedy=greedy, stop_ids=stop_ids,
            remaining=remaining, active=active)
        return tok, done, new_keys, new_caches

    # ---------------------------------------------------------------- step
    def step(self) -> List[StreamEvent]:
        """One engine tick: admit+prefill queued requests, one batched
        decode step over active slots, retire finished requests. Returns
        the tick's StreamEvents (prefill tokens, decode tokens, pending
        rejections).

        A request retires in the SAME step its stopping condition is met
        (stop-set token emitted / budget exhausted) — including straight
        out of prefill — so it never occupies a slot for an extra batched
        decode step. Free slots are masked out of the decode inputs
        (token 0 at position 0) instead of replaying stale state."""
        m = self.metrics_counters
        events: List[StreamEvent] = list(self._pending)
        self._pending.clear()

        for slot in self.sched.admit():
            tr = self.sched.slots[slot]
            now = time.perf_counter()
            tr.queue_wait_s = now - tr.submit_t
            m.admitted += 1
            m.queue_wait_s += tr.queue_wait_s
            tok = self._prefill_one(slot, tr)
            tr.prefill_s = time.perf_counter() - now
            tr.decode_t0 = time.perf_counter()
            m.prefills += 1
            m.prefill_prompt_tokens += tr.prompt_len
            m.prefill_s += tr.prefill_s
            m.tokens_generated += 1
            # stop-set token straight out of prefill / budget of one:
            # retire before the request joins a decode batch at all
            reason = None
            if tok in tr.stop_set:
                reason = "stop"
            elif tr.request.max_new_tokens == 1:
                reason = "length"
            events.append(StreamEvent(tr.uid, 0, tok, reason))
            if reason is not None:
                self._finish_slot(slot, reason)

        active_idx = np.nonzero(self.active)[0]
        if active_idx.size:
            t0 = time.perf_counter()
            tok, done, new_keys, self.caches = self._decode_fn(
                self.params, self.caches,
                jnp.asarray(np.where(self.active, self.last_token, 0)),
                jnp.asarray(np.where(self.active, self.positions, 0)),
                jnp.asarray(self.rng_keys),
                jnp.asarray(self.temperature),
                jnp.asarray(self.top_k),
                jnp.asarray(self.top_p),
                jnp.asarray(self.greedy),
                jnp.asarray(self.stop_ids),
                jnp.asarray(self.remaining),
                jnp.asarray(self.active),
            )
            tok = np.asarray(tok)
            done = np.asarray(done)
            # np.array (copy) — np.asarray of a device array is read-only,
            # and the next prefill writes per-slot keys in place
            self.rng_keys = np.array(new_keys)
            m.decode_steps += 1
            m.decode_slot_steps += int(active_idx.size)
            m.decode_s += time.perf_counter() - t0
            m.tokens_generated += int(active_idx.size)

            emitted = self.active.copy()
            self.positions[emitted] += 1
            self.remaining[emitted] -= 1
            self.last_token = np.where(emitted, tok, self.last_token)
            for b in active_idx:
                tr = self.sched.slots[b]
                t = int(tok[b])
                tr.generated.append(t)
                idx = len(tr.generated) - 1
                reason = None
                if done[b]:
                    reason = "stop" if t in tr.stop_set else "length"
                events.append(StreamEvent(tr.uid, idx, t, reason))
                if reason is not None:
                    self._finish_slot(int(b), reason)

        for ev in events:
            buf = self._buffers.get(ev.uid)
            if buf is not None:
                buf.append(ev)
        return events

    def _finish_slot(self, slot: int, reason: str) -> TrackedRequest:
        tr = self.sched.finish(slot)
        self.active[slot] = False
        self.metrics_counters.count_finish(reason)
        decode_s = (time.perf_counter() - tr.decode_t0
                    if len(tr.generated) > 1 else 0.0)
        self._outputs[tr.uid] = RequestOutput(
            uid=tr.uid, tokens=tuple(tr.generated), finish_reason=reason,
            queue_wait_s=tr.queue_wait_s, prefill_s=tr.prefill_s,
            decode_s=decode_s)
        self._retain(tr.uid)
        return tr

    # ------------------------------------------------------------ streaming
    @property
    def idle(self) -> bool:
        return self.sched.idle and not self._pending

    def output(self, uid: int) -> Optional[RequestOutput]:
        """The terminal RequestOutput once ``uid`` finished (else None)."""
        return self._outputs.get(uid)

    def stream(self, uid: int) -> Iterator[StreamEvent]:
        """Iterate ``uid``'s StreamEvents, driving ``step()`` as needed;
        ends after yielding the terminal event. Events for OTHER requests
        produced along the way stay buffered for their own streams."""
        buf = self._buffers.get(uid)
        if buf is None:
            raise KeyError(f"unknown request uid {uid}")
        guard = 0
        while True:
            while buf:
                ev = buf.popleft()
                yield ev
                if ev.done:
                    self._buffers.pop(uid, None)
                    return
            if self.idle:  # pragma: no cover - defensive
                raise RuntimeError(
                    f"engine idle but request {uid} never finished")
            self.step()
            guard += 1
            if guard > 1_000_000:  # pragma: no cover
                raise RuntimeError("stream() did not converge")

    # ------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        """Snapshot of the engine counters (serve/metrics.py)."""
        return self.metrics_counters.snapshot()

    # ---------------------------------------------------------- high level
    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int,
                 sampling: Optional[SamplingParams] = None
                 ) -> Dict[int, List[int]]:
        """Convenience wrapper over submit/step: serve a batch of prompts
        to completion and return {uid: tokens} in submission order. The
        default sampling is greedy — token-for-token identical to the
        pre-redesign blocking engine. Rejected prompts raise (the typed
        submit() surface is the place to handle rejection gracefully)."""
        sampling = sampling or api.GREEDY
        reqs = [GenerationRequest(prompt=p, max_new_tokens=max_new_tokens,
                                  sampling=sampling) for p in prompts]
        # validate the whole batch BEFORE enqueueing anything: a partial
        # raise must not leave accepted prompts queued for a later call
        bad = {i: self._admission_error(r) for i, r in enumerate(reqs)}
        bad = {i: why for i, why in bad.items() if why is not None}
        if bad:
            raise ValueError(
                f"generate(): unservable prompt(s) {bad}; use submit() to "
                "handle rejection as data")
        guard = 0
        uids = []
        for r in reqs:
            # respect the bounded queue: drain instead of rejecting
            while len(self.sched.queue) >= self.sched.max_queue:
                self.step()
                guard += 1
                if guard > 100000:  # pragma: no cover
                    raise RuntimeError("engine did not converge")
            uids.append(self.submit(r))
        while not self.idle:
            self.step()
            guard += 1
            if guard > 100000:  # pragma: no cover
                raise RuntimeError("engine did not converge")
        results: Dict[int, List[int]] = {}
        for uid, req in zip(uids, reqs):
            out = self._outputs[uid]
            # the stopping condition is enforced in-jit; over-generation
            # would be an engine bug — assert the invariant rather than
            # silently truncating it away
            assert len(out.tokens) <= req.max_new_tokens, (
                f"request {uid} generated {len(out.tokens)} tokens, over "
                f"its max_new_tokens={req.max_new_tokens} budget")
            results[uid] = list(out.tokens)
            self._buffers.pop(uid, None)
        return results
