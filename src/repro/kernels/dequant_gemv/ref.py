"""Pure-jnp oracle for the conventional-VQ dequant GEMV baseline."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dequant_gemv_ref(
    x: jax.Array,          # (M, V, d)
    codebooks: jax.Array,  # (C, k, d)
    I: jax.Array,          # (C, V, N)
    scale: jax.Array,      # (N,)
) -> jax.Array:
    M, V, d = x.shape
    N = I.shape[-1]
    cents = jax.vmap(lambda cb, idx: jnp.take(cb, idx, axis=0))(
        codebooks.astype(jnp.float32), I.astype(jnp.int32)
    )  # (C, V, N, d)
    w = cents.sum(axis=0).transpose(0, 2, 1).reshape(V * d, N)
    y = x.astype(jnp.float32).reshape(M, V * d) @ w
    return y * scale[None, :].astype(jnp.float32)
