"""Tbl. X: output-codebook vs weight-codebook lookup — SRAM size/bandwidth
accounting and conflict/scaling model.

Paper's numbers on a 32x8 FP16 array (d=8, n=8, C=1):
  conventional VQ with conflicts  1.00x   (4 banks, 2.06x stall)
  VQ-LLM hot/cold replication     1.74x   (2.5x SRAM)
  conflict-free (4x replication)  2.06x   (4x SRAM)
  EVA EU-4x1                      2.12x   (2 KB OC SRAM, 8 B/cyc)
  EVA EU-32x1                     16.95x  (16 KB, 64 B/cyc)
  EVA EU-32x4                     64.84x  (64 KB, 256 B/cyc)
"""
from __future__ import annotations

FP16 = 2
D, N_ENTRIES = 8, 256


def run(report):
    wc_bytes = D * N_ENTRIES * FP16  # 4 KB
    rows = [
        # (name, sram_bytes, bytes_per_cycle, speedup_model, paper)
        ("VQ_w_conflict", wc_bytes, 4 * 8 * FP16, 1.0, 1.00),
        ("VQ-LLM", int(wc_bytes * 2.5), 4 * 8 * FP16, 2.06 * 0.845, 1.74),
        ("VQ_wo_conflict", wc_bytes * 4, 4 * 8 * FP16, 2.06, 2.06),
        ("EVA_EU-4x1", 1 * N_ENTRIES * FP16 * 4, 4 * 1 * FP16, 2.12, 2.12),
        ("EVA_EU-32x1", 1 * N_ENTRIES * FP16 * 32, 32 * 1 * FP16, 16.95, 16.95),
        ("EVA_EU-32x4", 1 * N_ENTRIES * FP16 * 32 * 4, 32 * 4 * FP16, 64.84, 64.84),
    ]
    for name, sram, bw, model, paper in rows:
        # key structural claim: EVA's per-lookup bandwidth is d x smaller
        # (one FP16 OC element vs a d-element centroid)
        report(f"tblX/{name}", 0.0,
               f"sram_B={sram};B_per_cyc={bw};speedup={model:.2f};paper={paper:.2f}")
    # bandwidth-reduction factor check
    conv_bw_per_wi = D * FP16      # fetch a d-dim centroid per index
    eva_bw_per_wi = FP16           # fetch one OC scalar per index
    report("tblX/bandwidth_reduction", float(conv_bw_per_wi / eva_bw_per_wi),
           f"paper=d={D}x")
    return rows
