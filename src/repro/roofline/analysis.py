"""Three-term roofline analysis from compiled dry-run artifacts.

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

Sources:
  * HLO_FLOPs: our HLO parser (roofline/hlo.py) with while-trip-count
    accounting — per-device FLOPs from the SPMD module, x chips = global.
  * HLO_bytes (HBM traffic proxy): memory_analysis() gives per-device
    argument/output/temp sizes. XLA:CPU does not implement buffer
    donation, so decode caches appear in BOTH arguments and outputs and
    as loop double-buffer temps; on TPU the donated cache is updated in
    place (one token slot written). The traffic model is therefore
    step-kind aware:
      decode : args + (outputs - cache_out_bytes)        (cache read once,
               written one slot; no double-buffer traffic)
      prefill: args + outputs + temp                      (activations
               stream through HBM once)
      train  : args + outputs + 2*temp                    (activations
               written in fwd, read in bwd)
    Arguments dominate decode (weights / VQ indices / KV cache reads),
    which is exactly the term EVA attacks.
  * collective_bytes: per-device wire bytes from the parser (ring model).

Hardware constants (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

from repro.roofline.hlo import HloCosts, analyze

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    # memory_analysis raw
    argument_bytes: int
    output_bytes: int
    temp_bytes: int
    # derived terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0
    useful_ratio: float = 0.0

    def finalize(self) -> "RooflineReport":
        self.t_compute = self.flops_per_device / PEAK_FLOPS
        self.t_memory = self.hbm_bytes_per_device / HBM_BW
        self.t_collective = self.collective_bytes_per_device / LINK_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        if self.model_flops and self.flops_per_device:
            self.useful_ratio = self.model_flops / (self.flops_per_device * self.chips)
        return self

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float = 0.0,
                     step_kind: str = "train",
                     cache_bytes_per_device: float = 0.0) -> RooflineReport:
    mem = compiled.memory_analysis()
    hlo = analyze(compiled.as_text())
    if step_kind == "decode":
        hbm = (mem.argument_size_in_bytes
               + max(mem.output_size_in_bytes - cache_bytes_per_device, 0.0))
    elif step_kind == "prefill":
        hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes
               + mem.temp_size_in_bytes)
    else:
        hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes
               + 2 * mem.temp_size_in_bytes)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=hlo.flops,
        hbm_bytes_per_device=float(hbm),
        collective_bytes_per_device=hlo.collective_bytes,
        collective_breakdown=dict(hlo.collective_bytes_by_op),
        argument_bytes=mem.argument_size_in_bytes,
        output_bytes=mem.output_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        model_flops=model_flops,
    ).finalize()


# --------------------------------------------------------- MODEL_FLOPS ----


def model_flops(cfg, shape_kind: str, seq: int, batch: int, n_params_fc: float,
                n_active_fc: Optional[float] = None) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode processes batch tokens,
    train includes backward (3x forward)."""
    n = n_active_fc if n_active_fc is not None else n_params_fc
    tokens = batch * (seq if shape_kind in ("train", "prefill") else 1)
    mult = 6 if shape_kind == "train" else 2
    return mult * n * tokens


def format_report_row(r: RooflineReport) -> str:
    return (
        f"| {r.arch} | {r.shape} | {r.mesh} | "
        f"{r.t_compute*1e3:.3f} | {r.t_memory*1e3:.3f} | "
        f"{r.t_collective*1e3:.3f} | {r.bottleneck} | "
        f"{r.useful_ratio:.3f} |"
    )
