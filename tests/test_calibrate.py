"""core/calibrate.py: the per-backend time model the ranked Planner
prices candidates with — NNLS fitting from bench rows, interpret-row
exclusion, versioned persistence, and the analytic fallback."""
import json

import numpy as np
import pytest

from repro.core import calibrate


def _row(backend, us, *, macs=1000, adds=2000, bytes_=3000, module="measured",
         extra=None):
    derived = {"plan": f"{backend} M=1 K=8 N=8", "backend": backend,
               "macs": macs, "lookup_adds": adds, "weight_bytes": bytes_}
    derived.update(extra or {})
    return {"module": module, "name": f"measured/{backend}", "derived": derived,
            "us_per_call": us}


def _doc(rows):
    return {"schema": "eva-bench-rows/v1", "rows": rows}


class TestFit:
    def test_recovers_linear_model(self):
        """Rows generated from known constants fit back to a model that
        predicts them (the exact coefficients may differ — the fit only
        has to agree on the observable timings)."""
        true = calibrate.BackendCalibration(
            overhead_us=40.0, us_per_mac=1e-4, us_per_add=5e-4,
            us_per_byte=2e-5)
        rows = []
        rng = np.random.default_rng(0)
        samples = []
        for _ in range(8):
            macs = int(rng.integers(10_000, 5_000_000))
            adds = int(rng.integers(10_000, 5_000_000))
            b = int(rng.integers(10_000, 5_000_000))
            us = calibrate.predict_us(
                type("C", (), dict(macs=macs, lookup_adds=adds,
                                   weight_bytes=b, intermediate_bytes=0,
                                   launches=1))(), true)
            rows.append(_row("eva_direct", us, macs=macs, adds=adds, bytes_=b))
            samples.append((macs, adds, b, us))
        calib = calibrate.fit_calibration(_doc(rows), source="synthetic")
        entry = calib.get("eva_direct")
        assert entry is not None and entry.rows == 8
        assert entry.mean_abs_rel_err < 0.01
        for macs, adds, b, us in samples:
            cost = type("C", (), dict(macs=macs, lookup_adds=adds,
                                      weight_bytes=b, intermediate_bytes=0,
                                      launches=1))()
            assert calibrate.predict_us(cost, entry) == pytest.approx(
                us, rel=0.02)

    def test_interpret_rows_excluded(self):
        rows = [_row("eva_fused_pallas", 999.0, extra={"interpret": 1}),
                _row("eva_direct", 100.0)]
        calib = calibrate.fit_calibration(_doc(rows))
        assert calib.get("eva_fused_pallas") is None
        assert calib.get("eva_direct") is not None

    def test_rows_missing_cost_fields_excluded(self):
        bad = _row("eva_flat", 50.0)
        del bad["derived"]["macs"]
        calib = calibrate.fit_calibration(_doc([bad]))
        assert calib.backends == {}

    def test_failed_rows_excluded(self):
        calib = calibrate.fit_calibration(_doc([_row("eva_direct", -1.0)]))
        assert calib.backends == {}

    def test_nonnegative_coefficients(self):
        """Anticorrelated noise must clamp, not go negative (a negative
        rate would let a backend 'pay itself' on big shapes)."""
        rows = [_row("eva_recon", 100.0, macs=10_000, adds=10, bytes_=10),
                _row("eva_recon", 50.0, macs=20_000, adds=10, bytes_=10)]
        entry = calibrate.fit_calibration(_doc(rows)).get("eva_recon")
        for f in ("overhead_us", "us_per_mac", "us_per_add", "us_per_byte"):
            assert getattr(entry, f) >= 0.0


class TestPersistence:
    def test_roundtrip(self, tmp_path):
        calib = calibrate.fit_calibration(_doc([_row("eva_direct", 120.0)]),
                                          source="BENCH_measured.json")
        path = str(tmp_path / "CALIBRATION.json")
        calibrate.save_calibration(calib, path)
        loaded = calibrate.load_calibration(path)
        assert loaded is not None
        assert loaded.version == calibrate.SCHEMA
        assert loaded.source == "BENCH_measured.json"
        assert loaded.get("eva_direct") == calib.get("eva_direct")

    def test_version_mismatch_returns_none(self, tmp_path):
        path = str(tmp_path / "CALIBRATION.json")
        with open(path, "w") as f:
            json.dump({"schema": "eva-calibration/v0", "backends": {}}, f)
        assert calibrate.load_calibration(path) is None

    def test_missing_or_garbage_returns_none(self, tmp_path):
        assert calibrate.load_calibration(str(tmp_path / "nope.json")) is None
        path = str(tmp_path / "bad.json")
        with open(path, "w") as f:
            f.write("{not json")
        assert calibrate.load_calibration(path) is None

    def test_env_var_overrides_default_path(self, tmp_path, monkeypatch):
        path = str(tmp_path / "alt.json")
        calibrate.save_calibration(
            calibrate.Calibration(calibrate.SCHEMA, "alt", {}), path)
        monkeypatch.setenv(calibrate.ENV_VAR, path)
        assert calibrate.default_calibration_path() == path
        loaded = calibrate.load_default_calibration()
        assert loaded is not None and loaded.source == "alt"


class TestPredict:
    def test_terms_priced_independently(self):
        entry = calibrate.BackendCalibration(
            overhead_us=10.0, us_per_mac=1.0, us_per_add=2.0, us_per_byte=3.0)
        cost = type("C", (), dict(macs=5, lookup_adds=7, weight_bytes=11,
                                  intermediate_bytes=13, launches=2))()
        assert calibrate.predict_us(cost, entry) == pytest.approx(
            10 * 2 + 5 * 1 + 7 * 2 + (11 + 13) * 3)

    def test_analytic_prefers_fused_over_split_shape(self):
        """The analytic fallback must rank the fused kernel ahead of the
        two-kernel split at identical work: the split pays the OC
        round-trip (intermediate_bytes) and a second launch."""
        fused = type("C", (), dict(macs=1000, lookup_adds=1000,
                                   weight_bytes=1000, intermediate_bytes=0,
                                   launches=1))()
        split = type("C", (), dict(macs=1000, lookup_adds=1000,
                                   weight_bytes=1000,
                                   intermediate_bytes=8000, launches=2))()
        assert calibrate.predict_us(fused, calibrate.ANALYTIC) < \
            calibrate.predict_us(split, calibrate.ANALYTIC)
