from repro.data.pipeline import DataConfig, DataPipeline, global_batch_at
