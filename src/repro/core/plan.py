"""Plan-once / execute-many dispatch for every model-layer matmul.

EVA's speedup comes from picking the right *formulation* per shape —
VQ-GEMM + structured lookup at small M, reconstruct-and-GEMM at large M,
the fused Pallas kernel on an accelerator, INT8 GEMM for prefill — and
then executing that frozen choice on every step (the VQ-LLM "select a
code variant per shape, execute the cached selection" structure). This
module is the selection layer:

  LinearSpec   : frozen, hashable description of one matmul site —
                 (M, K, N), weight kind (dense / int8 / vq), the VQ
                 geometry (C, V, 2^n, d, grouped splits), dtypes and the
                 mesh-context flag. Derived from ``(x, params)`` at trace
                 time; equal specs hash equal, so a spec is a cache key.
  PlanPolicy   : frozen, hashable execution policy (vq_mode, impl,
                 epilogue, block_v, int8_prefill, interpret). Statically
                 contradictory policies raise ValueError at construction.
  MatmulPlan   : the concrete executable: chosen backend plus every
                 resolved number (epilogue kind + block_v for jnp;
                 m/v/n tiles for the Pallas kernels — nothing re-derived
                 at execute time), cost-model estimates, the predicted
                 execution time that ranked it and the provenance of
                 that prediction. ``plan.execute(x, leaf)`` runs it;
                 ``plan.describe()`` names it for logs/benchmarks.
  Planner      : LRU cache mapping (LinearSpec, PlanPolicy) -> MatmulPlan.
                 Same spec+policy returns the SAME plan object; inside a
                 jitted decode step the planner is only consulted while
                 tracing, never on the executed path.

Backends register via ``register_backend(name, matcher, planner_fn)``.
Selection is COST-RANKED: the planner collects every backend whose
matcher accepts (spec, policy), prices each candidate's PlanCost through
the per-backend time model in ``core/calibrate.py`` (constants fitted
from committed BENCH_measured.json rows when CALIBRATION.json is
present, shared analytic rates otherwise) and picks the cheapest;
registration order only breaks exact ties. The losing candidates are
recorded on the chosen plan (``plan.ranking``) so logs and benchmarks
can show the decision. Most policies admit a single candidate — the
genuine trade-off today is ``impl="pallas"``, where the fused kernel
and the two-kernel vq_gemm+oc_lookup split backend both match.

The pure-jnp formulations are registered here; the Pallas kernels
register themselves from ``kernels/*/ops.py`` (each owns its tile model)
and are imported lazily on first use, so ``core`` never imports kernel
modules at module scope.

Model layers (``models/common.py linear/grouped_linear``) fetch a plan
per call site instead of threading string knobs; ``eva_matmul`` /
``vq_matmul`` in ``core/ops.py`` remain as thin convenience wrappers
over ``Planner.plan(...).execute(...)``.
"""
from __future__ import annotations

import collections
import dataclasses
import importlib
import logging
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import calibrate as calibrate_mod
from repro.core import ops
from repro.core.ops import EPILOGUES
from repro.core.vq import VQWeight

log = logging.getLogger(__name__)

WEIGHT_KINDS = ("dense", "int8", "vq", "kvq_attn", "vq_logits")
VQ_MODES = ("none", "eva", "dequant")
IMPLS = ("jnp", "pallas")

# backends quarantined after a failure are retried after this cool-off;
# a transient failure (driver hiccup, OOM under pressure) recovers, a
# persistent one re-quarantines on the next attempt
DEFAULT_BACKEND_COOLOFF_S = 30.0


# ---------------------------------------------------------------------------
# Spec / policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    """Shape + weight-kind signature of one matmul site.

    ``kind`` is the *resolved* weight kind: "dense" (fp path), "int8"
    (a dense weight executed through the INT8 prefill GEMM), "vq", or
    "kvq_attn" (a KV-VQ decode-attention site — see
    ``kvq_attention_spec`` for the field mapping). The VQ geometry
    fields are zero for non-VQ kinds. ``in_mesh``
    records whether the spec was derived inside an active mesh context
    (pjit/shard_map) — the SPMD-friendly flat epilogue is preferred
    there, exactly like the pre-plan string-knob behavior."""

    M: int
    K: int
    N: int
    kind: str                      # dense | int8 | vq
    x_dtype: str
    out_dtype: str
    C: int = 0
    V: int = 0
    k: int = 0                     # 2^n centroids per codebook
    d: int = 0
    splits: Tuple[int, ...] = ()   # grouped-family member widths
    in_mesh: bool = False

    def __post_init__(self):
        if self.kind not in WEIGHT_KINDS:
            raise ValueError(
                f"unknown weight kind {self.kind!r}; expected one of {WEIGHT_KINDS}")

    @classmethod
    def for_vq(cls, vq: VQWeight, *, M: int, x_dtype, out_dtype,
               in_mesh: Optional[bool] = None) -> "LinearSpec":
        """Spec for a VQ weight leaf: geometry read off the ``VQWeight``
        (K/N/C/V/centroids/splits), ``M`` supplied by the call site.
        ``in_mesh=None`` auto-detects an active pjit/shard_map context."""
        k = vq.codebooks.shape[-1] if hasattr(vq.codebooks, "shape") else 2 ** vq.n
        return cls(
            M=int(M), K=vq.K, N=vq.N, kind="vq",
            x_dtype=jnp.dtype(x_dtype).name, out_dtype=jnp.dtype(out_dtype).name,
            C=vq.C, V=vq.V, k=int(k), d=vq.d, splits=tuple(vq.splits),
            in_mesh=ops._in_mesh_context() if in_mesh is None else in_mesh,
        )

    @classmethod
    def for_dense(cls, w, *, M: int, x_dtype, out_dtype, kind: str = "dense",
                  in_mesh: Optional[bool] = None) -> "LinearSpec":
        """Spec for a dense weight array ``w`` of shape (.., K, N);
        ``kind`` may be "int8" for the INT8 prefill GEMM path.

        Raises: ValueError (from __post_init__) on an unknown kind."""
        K, N = int(w.shape[-2]), int(w.shape[-1])
        return cls(
            M=int(M), K=K, N=N, kind=kind,
            x_dtype=jnp.dtype(x_dtype).name, out_dtype=jnp.dtype(out_dtype).name,
            in_mesh=ops._in_mesh_context() if in_mesh is None else in_mesh,
        )


@dataclasses.dataclass(frozen=True)
class PlanPolicy:
    """Execution policy for one matmul (the collapsed RunConfig knobs).

    ``vq_mode``  : "eva" | "dequant" | "none" ("none" resolves by run
                   mode: EVA in decode, the dequant baseline elsewhere).
    ``impl``     : "jnp" | "pallas".
    ``epilogue`` : "auto" or one of core/ops.EPILOGUES. Only the EVA jnp
                   backends consume it; impl="pallas" always runs the
                   fused tiled kernel and accepts only "auto".
    ``block_v``  : None (auto-sized) or a pinned v-block height — on jnp
                   only coherent with the v-blocked epilogues
                   ("blocked"/"recon"); on Pallas it pins the kernel's
                   v-tiles.
    ``int8_prefill`` : route dense prefill matmuls through the INT8 GEMM.
    ``interpret``    : Pallas interpret mode (CPU validation).

    Statically contradictory combinations raise ValueError here, so a
    bad policy is loud at construction (not at the first matmul).
    """

    vq_mode: str = "none"
    impl: str = "jnp"
    epilogue: str = "auto"
    block_v: Optional[int] = None
    int8_prefill: bool = False
    interpret: bool = False

    def __post_init__(self):
        if self.vq_mode not in VQ_MODES:
            raise ValueError(
                f"unknown vq_mode {self.vq_mode!r}; expected one of {VQ_MODES}")
        if self.impl not in IMPLS:
            raise ValueError(f"unknown impl {self.impl!r}; expected one of {IMPLS}")
        if self.epilogue not in EPILOGUES + ("auto",):
            raise ValueError(
                f"unknown epilogue {self.epilogue!r}; expected 'auto' or one "
                f"of {EPILOGUES}")
        if self.block_v is not None:
            if isinstance(self.block_v, bool) or not isinstance(self.block_v, int):
                raise ValueError(
                    f"block_v must be None ('auto') or an int, got {self.block_v!r}")
            if self.block_v <= 0:
                raise ValueError(f"block_v must be positive, got {self.block_v}")
            if self.impl == "jnp" and self.vq_mode != "dequant" \
                    and self.epilogue not in ("blocked", "recon"):
                # dequant is exempt: its jnp baseline has no epilogue and
                # documents block_v as ignored; on Pallas (any mode)
                # block_v pins the kernel's v-tiles
                raise ValueError(
                    f"explicit block_v={self.block_v} conflicts with epilogue="
                    f"{self.epilogue!r}; block_v only applies to the v-blocked "
                    "epilogues ('blocked', 'recon') on impl='jnp'")

    def resolve_vq_mode(self, mode: str) -> "PlanPolicy":
        """Resolve vq_mode="none" by run mode (decode -> EVA, else the
        dequant baseline — the historical linear() fallback)."""
        if self.vq_mode != "none":
            return self
        return dataclasses.replace(
            self, vq_mode="eva" if mode == "decode" else "dequant")


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Analytic estimates for ranking, introspection and benchmarking.

    ``macs``         : multiply-accumulates on the GEMM/MXU path.
    ``lookup_adds``  : add-only lookup/reconstruction work (the paper's
                       epilogue adds; 0 for dense/int8).
    ``weight_bytes`` : per-call weight-side HBM traffic (compressed for
                       VQ kinds).
    ``intermediate_bytes`` : extra HBM round-trip traffic of multi-kernel
                       formulations (the split backend's (C, M, V, 2^n)
                       output-codebook buffer; 0 for fused/jnp paths).
    ``launches``     : kernel launches per call (prices dispatch overhead
                       in the calibrated time model)."""

    macs: int
    lookup_adds: int
    weight_bytes: int
    intermediate_bytes: int = 0
    launches: int = 1


@dataclasses.dataclass(frozen=True)
class MatmulPlan:
    """A frozen, executable matmul choice.

    ``config`` holds every resolved number the backend needs (epilogue
    kind, block_v, kernel tiles, ...) — ``execute`` re-derives nothing.
    ``predicted_us``/``provenance``/``ranking`` record how the Planner
    ranked this backend against the other eligible candidates
    ("analytic" constants or a fitted "eva-calibration/v1" entry).
    """

    backend: str
    spec: LinearSpec
    policy: PlanPolicy
    config: Tuple[Tuple[str, Any], ...]
    cost: PlanCost
    run: Callable[[Any, Any], Any]
    predicted_us: Optional[float] = None
    provenance: str = "analytic"
    ranking: Tuple[Tuple[str, float], ...] = ()

    def execute(self, x, leaf):
        """Run the planned matmul. ``leaf`` is the weight leaf the spec
        was derived from (a VQWeight or a dense array)."""
        return self.run(x, leaf)

    @property
    def config_dict(self) -> Dict[str, Any]:
        """The frozen backend config as a plain dict (logging/tests)."""
        return dict(self.config)

    def describe(self) -> str:
        """One-line human summary: backend, shape, resolved config and
        the ranked prediction (``pred=..us(analytic|eva-calibration/v1)``)."""
        s = self.spec
        parts = [self.backend, f"M={s.M}", f"K={s.K}", f"N={s.N}"]
        if s.splits:
            parts.append(f"splits={len(s.splits)}")
        parts += [f"{k}={v}" for k, v in self.config]
        if self.policy.interpret:
            parts.append("interpret")
        if self.predicted_us is not None:
            parts.append(f"pred={self.predicted_us:.0f}us({self.provenance})")
        return " ".join(parts)

    def describe_ranking(self) -> str:
        """The ranked candidate set, cheapest first ('' when only one
        backend was eligible)."""
        if len(self.ranking) < 2:
            return ""
        return " < ".join(f"{b}={us:.0f}us" for b, us in self.ranking)


def kvq_attention_spec(*, B: int, S: int, H: int, Hk: int, hd: int,
                       idx_width: int, entries: int,
                       x_dtype, out_dtype) -> LinearSpec:
    """Spec for a KV-VQ decode-attention site (kind="kvq_attn").

    Decode attention over a vector-quantized cache is a matmul-shaped
    site the planner can rank like any other: the field mapping is
    M=batch, K=cache length S, N=H*hd (the per-token attention output),
    C=Hk (kv heads), V=idx_width (uint8 indices per token per head),
    k=entries (codebook rows), d=hd. Backends registered from
    ``kernels/flash_decode/ops.py`` match on the kind; cost-ranked
    selection chooses between the dequantize-jnp path and the fused
    Pallas kernel.

    Args:
      B/S/H/Hk/hd: decode-attention geometry (static at trace time).
      idx_width: R*G uint8 indices per (token, head) — see
        core.vq.KVQuantConfig.idx_width.
      entries: codebook rows per stage (256).
      x_dtype/out_dtype: query/output dtypes.

    Returns: a hashable LinearSpec usable as a planner cache key.
    """
    return LinearSpec(
        M=int(B), K=int(S), N=int(H * hd), kind="kvq_attn",
        x_dtype=jnp.dtype(x_dtype).name, out_dtype=jnp.dtype(out_dtype).name,
        C=int(Hk), V=int(idx_width), k=int(entries), d=int(hd),
    )


def vq_weight_bytes(spec: LinearSpec) -> int:
    """Compressed per-call weight traffic of a VQ leaf: uint8 (n<=8) or
    int32 indices + codebooks + per-channel scales."""
    idx = spec.C * spec.V * spec.N * (1 if spec.k <= 256 else 4)
    return idx + spec.C * spec.d * spec.k * 4 + spec.N * 4


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Backend:
    name: str
    matcher: Callable[[LinearSpec, PlanPolicy], bool]
    planner_fn: Callable[[LinearSpec, PlanPolicy], MatmulPlan]


_REGISTRY: "collections.OrderedDict[str, _Backend]" = collections.OrderedDict()
_REGISTRY_LOCK = threading.Lock()

# kernel wrapper modules that register Pallas backends on import; loaded
# lazily on the first plan() that can need them (impl="pallas", or a
# no-match retry) so pure-jnp workloads never import pallas
_KERNEL_BACKEND_MODULES = (
    "repro.kernels.fused_vq_matmul.ops",
    "repro.kernels.oc_lookup.ops",
    "repro.kernels.dequant_gemv.ops",
    "repro.kernels.int8_gemm.ops",
    "repro.kernels.flash_decode.ops",  # KV-VQ decode-attention backends
)
_kernels_loaded = False


def register_backend(name: str,
                     matcher: Callable[[LinearSpec, PlanPolicy], bool],
                     planner_fn: Callable[[LinearSpec, PlanPolicy], MatmulPlan],
                     ) -> None:
    """Register (or idempotently re-register) a matmul backend.

    ``matcher(spec, policy)`` says whether this backend can execute the
    site; ``planner_fn(spec, policy)`` freezes every tile size / epilogue
    choice into a MatmulPlan. Every matching backend becomes a ranking
    candidate priced by its cost model; registration order only breaks
    exact predicted-time ties."""
    with _REGISTRY_LOCK:
        _REGISTRY[name] = _Backend(name, matcher, planner_fn)


def registered_backends() -> Tuple[str, ...]:
    """All registered backend names in registration order (kernel
    modules are imported first, so the tuple is complete)."""
    _ensure_kernel_backends()
    return tuple(_REGISTRY)


def _ensure_kernel_backends() -> None:
    global _kernels_loaded
    if _kernels_loaded:
        return
    for mod in _KERNEL_BACKEND_MODULES:
        importlib.import_module(mod)
    # only latch after every import succeeded — a transient failure must
    # stay loud and retryable, not silently de-register the Pallas backends
    _kernels_loaded = True


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


CacheInfo = collections.namedtuple("CacheInfo", "hits misses currsize maxsize")


class Planner:
    """LRU-cached (LinearSpec, PlanPolicy) -> MatmulPlan resolver.

    Planning happens at Python/trace time only: a jitted decode step
    consults the planner while tracing and bakes ``plan.run`` into the
    program, so repeated executed steps never re-enter ``plan``.

    Selection is cost-ranked: every backend whose matcher accepts the
    (spec, policy) pair is built as a candidate and priced through the
    per-backend time model (``calibration`` — fitted constants from
    CALIBRATION.json — when an entry exists, the shared analytic rates
    otherwise); the cheapest predicted time wins and ties fall back to
    registration order. ``calibration="default"`` loads the file named
    by $EVA_CALIBRATION (default ./CALIBRATION.json) at construction;
    ``reload_calibration`` swaps the model for FUTURE planning without
    touching cached plans — plan identity never depends on the cost
    model, only the choice among multiple eligible backends does."""

    def __init__(self, maxsize: int = 1024,
                 calibration: Any = "default",
                 cooloff_s: float = DEFAULT_BACKEND_COOLOFF_S):
        self._cache: "collections.OrderedDict[Tuple[LinearSpec, PlanPolicy], MatmulPlan]" = (
            collections.OrderedDict())
        self._maxsize = maxsize
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._calibration: Optional[calibrate_mod.Calibration] = (
            calibrate_mod.load_default_calibration()
            if calibration == "default" else calibration)
        # graceful degradation: backend name -> monotonic quarantine
        # expiry. A quarantined backend is skipped by ranking until its
        # cool-off passes; both quarantine and release clear the plan
        # cache so re-planning actually changes the choice.
        self.cooloff_s = cooloff_s
        self._quarantine: Dict[str, float] = {}
        self._backend_failures: Dict[str, int] = collections.Counter()
        self._exec_fallbacks = 0

    # ---- backend quarantine (graceful degradation)
    def record_backend_failure(self, backend: str,
                               cooloff_s: Optional[float] = None) -> None:
        """Quarantine ``backend`` for ``cooloff_s`` (planner default when
        None): ranking skips it until the cool-off expires, then it
        becomes a candidate again (transient failures recover). The plan
        cache is cleared so already-planned sites re-rank too."""
        with self._lock:
            self._backend_failures[backend] += 1
            self._quarantine[backend] = time.monotonic() + (
                self.cooloff_s if cooloff_s is None else cooloff_s)
            self._cache.clear()
        log.warning("backend %r quarantined for %.1fs (%d failures so far)",
                    backend, self.cooloff_s if cooloff_s is None else cooloff_s,
                    self._backend_failures[backend])

    def _active_quarantine(self) -> Tuple[str, ...]:
        """Currently-quarantined backend names; expired entries are
        released here (and the cache cleared, so the recovered backend
        is actually re-ranked rather than shadowed by cached fallbacks)."""
        now = time.monotonic()
        with self._lock:
            expired = [b for b, t in self._quarantine.items() if now >= t]
            for b in expired:
                del self._quarantine[b]
            if expired:
                self._cache.clear()
            active = tuple(self._quarantine)
        for b in expired:
            log.info("backend %r released from quarantine (cool-off "
                     "expired); re-ranking on next plan", b)
        return active

    def reset_quarantine(self) -> None:
        """Forget all quarantines + failure counts and clear the plan
        cache (tests around the GLOBAL default planner must call this to
        avoid cross-test contamination)."""
        with self._lock:
            self._quarantine.clear()
            self._backend_failures.clear()
            self._exec_fallbacks = 0
            self._cache.clear()

    def backend_stats(self) -> Dict[str, Any]:
        """Failure/fallback accounting: per-backend failure counts, the
        currently quarantined set and how many execute-time fallback
        switches the planned run chains performed."""
        with self._lock:
            failures = dict(self._backend_failures)
            fallbacks = self._exec_fallbacks
        return {"failures": failures,
                "quarantined": self._active_quarantine(),
                "exec_fallbacks": fallbacks}

    @property
    def calibration(self) -> Optional[calibrate_mod.Calibration]:
        """The loaded cost-model constants (None = analytic only)."""
        return self._calibration

    def reload_calibration(self, calibration: Any = "default") -> None:
        """Swap the cost model used for future planning. Cached plans are
        untouched: the same (spec, policy) keeps returning the SAME plan
        object (re-planning under new constants requires cache_clear)."""
        self._calibration = (calibrate_mod.load_default_calibration()
                             if calibration == "default" else calibration)

    def plan(self, spec: LinearSpec, policy: PlanPolicy) -> MatmulPlan:
        """Resolve (spec, policy) to the cheapest eligible MatmulPlan
        (LRU-cached; quarantined backends are skipped).

        Raises:
          ValueError: no registered backend matches the pair — or, on a
            jnp-policy miss, not even after lazily importing the kernel
            backend modules."""
        quarantined = self._active_quarantine()  # may purge + clear cache
        key = (spec, policy)
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self._hits += 1
                self._cache.move_to_end(key)
                return hit
        # Load the Pallas kernel registrations only when they can be
        # needed: pure-jnp workloads must not pay (or depend on) the
        # pallas imports. A no-match retry covers custom late loads.
        if policy.impl == "pallas":
            _ensure_kernel_backends()
        matched = self._match_all(spec, policy)
        if not matched and not _kernels_loaded:
            _ensure_kernel_backends()
            matched = self._match_all(spec, policy)
        if not matched:
            raise ValueError(
                f"no registered backend matches spec={spec} policy={policy}; "
                f"registered: {tuple(_REGISTRY)}")
        if quarantined:
            healthy = tuple(be for be in matched
                            if be.name not in quarantined)
            if healthy:
                matched = healthy
            else:
                # every eligible backend is quarantined: degrade stepwise
                # — first to the plain jnp formulation of the same mode,
                # then (for EVA) to the dequant jnp baseline, which is
                # token-exact vs EVA and always available — rather than
                # refusing to serve
                degraded = dataclasses.replace(
                    policy, impl="jnp", epilogue="auto",
                    block_v=None, interpret=False)  # lint-ok: PlanPolicy field
                if degraded == policy and policy.vq_mode == "eva":
                    degraded = dataclasses.replace(degraded,
                                                   vq_mode="dequant")
                if degraded != policy:
                    log.warning(
                        "all matched backends %s quarantined for spec=%s; "
                        "degrading policy to %s",
                        tuple(be.name for be in matched), spec, degraded)
                    return self.plan(spec, degraded)
                # last resort: even the degraded jnp candidates are
                # quarantined — refusing to serve is worse than retrying
                # a possibly-recovered backend, so ignore the quarantine
                log.error(
                    "all backends quarantined even under the degraded jnp "
                    "policy for spec=%s; ignoring quarantine", spec)
        built = self._rank(matched, spec, policy)
        with self._lock:  # (re-planning a raced key is harmless)
            self._misses += 1
            self._cache[key] = built
            while len(self._cache) > self._maxsize:
                self._cache.popitem(last=False)
        return built

    def _rank(self, matched: Tuple[_Backend, ...], spec: LinearSpec,
              policy: PlanPolicy) -> MatmulPlan:
        """Build every eligible candidate, price it, pick the cheapest
        (registration order breaks ties), and record the ranking +
        provenance on the chosen plan.

        Candidates are only cross-compared under ONE model: calibrated
        when EVERY candidate has a usable fitted entry, analytic
        otherwise — mixing a backend's fitted microseconds against
        another's order-of-magnitude analytic constants would make the
        comparison meaningless (a partial CALIBRATION.json must not
        flip rankings)."""
        candidates = [be.planner_fn(spec, policy) for be in matched]
        entries = [self._usable_entry(c.backend) for c in candidates]
        if all(e is not None for e in entries):
            prov = self._calibration.version
        else:
            prov = "analytic"
            entries = [None] * len(candidates)
        scored: List[Tuple[float, int, MatmulPlan]] = []
        for order, (candidate, entry) in enumerate(zip(candidates, entries)):
            us = calibrate_mod.predict_us(
                candidate.cost, entry or calibrate_mod.ANALYTIC)
            scored.append((us, order, candidate))
        scored.sort(key=lambda t: (t[0], t[1]))
        us, _, chosen = scored[0]
        ranked_plans = tuple(c for _, _, c in scored)
        run = (self._chain_run(ranked_plans) if len(ranked_plans) > 1
               else chosen.run)
        return dataclasses.replace(
            chosen, run=run, predicted_us=us, provenance=prov,
            ranking=tuple((c.backend, round(u, 3)) for u, _, c in scored),
        )

    def _chain_run(self, ranked: Tuple[MatmulPlan, ...]
                   ) -> Callable[[Any, Any], Any]:
        """Bake the ranked candidates into one run callable: when the
        chosen backend raises while the planned matmul is being BUILT
        (trace/lowering time — where Pallas kernel failures surface),
        the next-cheapest candidate takes over in place, the failed
        backend is quarantined for the cool-off and the fallback is
        counted. Already-compiled executions never re-enter Python, so
        the chain costs nothing on the steady-state path."""

        def run(x, leaf):
            last_err: Optional[Exception] = None
            for cand in ranked:
                try:
                    return cand.run(x, leaf)
                except Exception as e:  # noqa: BLE001 - any backend fault
                    last_err = e
                    self.record_backend_failure(cand.backend)
                    with self._lock:
                        self._exec_fallbacks += 1
                    log.warning("planned backend %r failed at execute "
                                "(%s: %s); trying next-cheapest candidate",
                                cand.backend, type(e).__name__, e)
            raise last_err

        return run

    def _usable_entry(self, backend: str
                      ) -> Optional["calibrate_mod.BackendCalibration"]:
        """The backend's fitted entry when it rests on enough samples to
        trust (calibrate.MIN_FIT_ROWS — an NNLS over fewer rows than
        free parameters fits perfectly but means nothing)."""
        calib = self._calibration
        entry = calib.get(backend) if calib is not None else None
        if entry is not None and entry.rows >= calibrate_mod.MIN_FIT_ROWS:
            return entry
        return None

    @staticmethod
    def _match_all(spec: LinearSpec, policy: PlanPolicy
                   ) -> Tuple[_Backend, ...]:
        with _REGISTRY_LOCK:  # snapshot: register_backend may race
            backends = tuple(_REGISTRY.values())
        return tuple(be for be in backends if be.matcher(spec, policy))

    def cache_info(self) -> CacheInfo:
        """functools-style (hits, misses, currsize, maxsize) counters."""
        return CacheInfo(self._hits, self._misses, len(self._cache),
                         self._maxsize)

    def cache_clear(self) -> None:
        """Drop every cached plan and reset the hit/miss counters."""
        with self._lock:
            self._cache.clear()
            self._hits = 0
            self._misses = 0


_PLANNER = Planner()


def default_planner() -> Planner:
    """The process-global Planner every model-layer entry point uses."""
    return _PLANNER


def reset_quarantine() -> None:
    """Clear the DEFAULT planner's backend quarantine + failure stats
    (test hygiene: the default planner is process-global)."""
    _PLANNER.reset_quarantine()


def plan(spec: LinearSpec, policy: PlanPolicy) -> MatmulPlan:
    """Resolve (spec, policy) through the default planner's cache."""
    return _PLANNER.plan(spec, policy)


def first_match_backend(spec: LinearSpec, policy: PlanPolicy
                        ) -> Optional[str]:
    """The backend the pre-ranking FIRST-MATCH dispatch would have
    chosen (registration order). Benchmarks report it next to the ranked
    choice so ranked-vs-first-match decisions stay visible."""
    _ensure_kernel_backends()
    matched = Planner._match_all(spec, policy)
    return matched[0].name if matched else None


# ---------------------------------------------------------------------------
# Spec derivation + model-layer entry points
# ---------------------------------------------------------------------------


def plan_node(p: Any, x, *, mode: str, policy: PlanPolicy,
              out_dtype=None) -> MatmulPlan:
    """Plan one linear param node ({"w": ...}, {"vq": ...} or
    {"vql": ...}) for input
    ``x`` under run ``mode``. This is the single dispatch point used by
    ``models.common.linear`` — the weight-kind decision lives in the spec
    derivation, the formulation choice in the backend registry."""
    out_dtype = out_dtype or x.dtype
    if "vq" in p:
        vq: VQWeight = p["vq"]
        spec = LinearSpec.for_vq(vq, M=x.size // vq.K, x_dtype=x.dtype,
                                 out_dtype=out_dtype)
        return _PLANNER.plan(spec, policy.resolve_vq_mode(mode))
    if "vql" in p:
        from repro.core import logits_vq as lvq  # local: lvq imports plan
        head = p["vql"]
        spec = lvq.vq_logits_spec(head, M=x.size // head.D, x_dtype=x.dtype,
                                  out_dtype=out_dtype)
        return _PLANNER.plan(spec, policy)
    w = p["w"]
    kind = "int8" if (mode == "prefill" and policy.int8_prefill) else "dense"
    spec = LinearSpec.for_dense(w, M=x.size // int(w.shape[-2]),
                                x_dtype=x.dtype, out_dtype=out_dtype,
                                kind=kind)
    return _PLANNER.plan(spec, policy)


def plan_vq(x, vq: VQWeight, policy: PlanPolicy, out_dtype=None) -> MatmulPlan:
    """Plan a bare VQ matmul (the eva_matmul / vq_matmul wrapper path)."""
    spec = LinearSpec.for_vq(vq, M=x.size // vq.K, x_dtype=x.dtype,
                             out_dtype=out_dtype or x.dtype)
    return _PLANNER.plan(spec, policy.resolve_vq_mode("decode"))


def preplan_params(params: Any, policy: PlanPolicy, *, mode: str, m: int,
                   act_dtype, planner: Optional[Planner] = None,
                   ) -> List[Tuple[Tuple[str, ...], MatmulPlan]]:
    """Walk a param tree and plan every linear leaf at batch size ``m``
    (tokens in flight), warming the planner cache before the first trace
    and returning (path, plan) pairs for logging/introspection.

    Leaves executed at other M (e.g. MoE capacity buffers under vmap)
    simply plan again on first trace — pre-planning is a warm-up plus a
    report, never a constraint."""
    planner = planner or _PLANNER
    out: List[Tuple[Tuple[str, ...], MatmulPlan]] = []

    def walk(node, path):
        if not isinstance(node, dict):
            return
        if "vq" in node:
            vq: VQWeight = node["vq"]
            spec = LinearSpec.for_vq(vq, M=m, x_dtype=act_dtype,
                                     out_dtype=act_dtype, in_mesh=False)
            out.append((path, planner.plan(spec, policy.resolve_vq_mode(mode))))
            return
        if "vql" in node:
            from repro.core import logits_vq as lvq
            spec = lvq.vq_logits_spec(node["vql"], M=m, x_dtype=act_dtype,
                                      out_dtype=jnp.float32)
            out.append((path, planner.plan(spec, policy)))
            return
        if "w" in node and hasattr(node["w"], "ndim") and node["w"].ndim >= 2:
            kind = "int8" if (mode == "prefill" and policy.int8_prefill) \
                else "dense"
            spec = LinearSpec.for_dense(node["w"], M=m, x_dtype=act_dtype,
                                        out_dtype=act_dtype, kind=kind,
                                        in_mesh=False)
            out.append((path, planner.plan(spec, policy)))
            return
        for key, sub in node.items():
            walk(sub, path + (key,))

    walk(params, ())
    return out


def preplan_prefill_buckets(params: Any, policy: PlanPolicy, *,
                            buckets: Tuple[int, ...], act_dtype,
                            planner: Optional[Planner] = None,
                            ) -> Dict[int, List[Tuple[Tuple[str, ...],
                                                      MatmulPlan]]]:
    """Plan every linear leaf at EACH prefill length bucket.

    The serving engine pads prompts to power-of-two buckets, so prefill
    executes at exactly these M values — unlike the old single
    capacity-bound ``prefill@cap`` estimate, every returned plan is the
    one the traced prefill step will fetch (regime choices like
    direct-vs-recon flip with M, so per-bucket planning is not just a
    warm-up: it is the report of what actually runs per bucket)."""
    return {
        m: preplan_params(params, policy, mode="prefill", m=m,
                          act_dtype=act_dtype, planner=planner)
        for m in buckets
    }


# ---------------------------------------------------------------------------
# jnp backend registrations (fp / int8 / dequant / EVA epilogues)
#
# The Pallas counterparts register from kernels/*/ops.py, each owning its
# tile model; these jnp formulations own the epilogue cost models in
# core/ops.py (select_epilogue + the block sizing helpers), which are
# called from HERE only — model layers never re-derive a formulation.
# ---------------------------------------------------------------------------


def _plan_fp(spec: LinearSpec, policy: PlanPolicy) -> MatmulPlan:
    out_dt = jnp.dtype(spec.out_dtype)
    itemsize = jnp.dtype(spec.x_dtype).itemsize

    def run(x, w):
        if w.dtype != x.dtype:
            w = w.astype(x.dtype)
        return ops.fp_matmul(x, w, out_dtype=out_dt)

    cost = PlanCost(macs=spec.M * spec.K * spec.N, lookup_adds=0,
                    weight_bytes=spec.K * spec.N * itemsize)
    return MatmulPlan("fp", spec, policy, (), cost, run)


def _plan_int8_jnp(spec: LinearSpec, policy: PlanPolicy) -> MatmulPlan:
    out_dt = jnp.dtype(spec.out_dtype)

    def run(x, w):
        return ops.int8_matmul(x, w, out_dtype=out_dt)

    cost = PlanCost(macs=spec.M * spec.K * spec.N, lookup_adds=0,
                    weight_bytes=spec.K * spec.N)
    return MatmulPlan("int8_jnp", spec, policy, (), cost, run)


def _plan_dequant_jnp(spec: LinearSpec, policy: PlanPolicy) -> MatmulPlan:
    out_dt = jnp.dtype(spec.out_dtype)

    def run(x, vq):
        return ops.dequant_matmul(x, vq, out_dtype=out_dt)

    cost = PlanCost(macs=spec.M * spec.K * spec.N,
                    lookup_adds=spec.C * spec.V * spec.N * spec.d,
                    weight_bytes=vq_weight_bytes(spec))
    return MatmulPlan("dequant_jnp", spec, policy, (), cost, run)


def _is_eva_jnp(spec: LinearSpec, policy: PlanPolicy) -> bool:
    return (spec.kind == "vq" and policy.impl == "jnp"
            and policy.vq_mode in ("eva", "none"))


def _resolve_eva_epilogue(spec: LinearSpec, policy: PlanPolicy
                          ) -> Tuple[str, Optional[int]]:
    """Freeze (epilogue kind, block_v) for the jnp EVA backends. The only
    call site of core/ops.select_epilogue and the auto block sizers."""
    epi = policy.epilogue
    if epi == "auto":
        return ops.select_epilogue(spec.M, spec.V, spec.N, spec.C, spec.k,
                                   spec.d, distributed=spec.in_mesh)
    if epi == "blocked":
        if policy.block_v is not None:
            return "blocked", min(policy.block_v, spec.V)
        return "blocked", ops.auto_block_v(spec.M, spec.V, spec.N, spec.C,
                                           spec.k)
    if epi == "recon":
        if policy.block_v is not None:
            return "recon", min(policy.block_v, spec.V)
        return "recon", ops.auto_recon_block_v(spec.V, spec.N, spec.d)
    return epi, None


def _eva_jnp_cost(spec: LinearSpec, kind: str) -> PlanCost:
    if kind == "recon":
        # slab-tiled reconstruct-and-GEMM: dequant's algebra, cache-tiled
        return PlanCost(macs=spec.M * spec.K * spec.N,
                        lookup_adds=spec.C * spec.V * spec.N * spec.d,
                        weight_bytes=vq_weight_bytes(spec))
    return PlanCost(
        macs=ops.vq_gemm_macs(spec.M, spec.K, _log2(spec.k), spec.C, spec.d),
        lookup_adds=ops.epilogue_adds(spec.M, spec.K, spec.N, spec.C, spec.d),
        weight_bytes=vq_weight_bytes(spec),
    )


def _log2(k: int) -> int:
    return max(int(k).bit_length() - 1, 0)


def _make_eva_jnp_planner(kind: str):
    def planner_fn(spec: LinearSpec, policy: PlanPolicy) -> MatmulPlan:
        resolved, bv = _resolve_eva_epilogue(spec, policy)
        assert resolved == kind, (resolved, kind)
        out_dt = jnp.dtype(spec.out_dtype)

        def run(x, vq):
            return ops.eva_epilogue_exec(x, vq, kind=kind, block_v=bv,
                                         out_dtype=out_dt)

        config = (("epilogue", kind),) + \
            ((("bv", bv),) if bv is not None else ())
        return MatmulPlan(f"eva_{kind}", spec, policy, config,
                          _eva_jnp_cost(spec, kind), run)

    return planner_fn


def _register_jnp_backends() -> None:
    register_backend(
        "fp",
        lambda s, p: s.kind == "dense",
        _plan_fp,
    )
    register_backend(
        "int8_jnp",
        lambda s, p: s.kind == "int8" and p.impl == "jnp",
        _plan_int8_jnp,
    )
    register_backend(
        "dequant_jnp",
        lambda s, p: s.kind == "vq" and p.vq_mode == "dequant"
        and p.impl == "jnp",
        _plan_dequant_jnp,
    )
    for kind in EPILOGUES:
        register_backend(
            f"eva_{kind}",
            lambda s, p, _kind=kind: _is_eva_jnp(s, p)
            and _resolve_eva_epilogue(s, p)[0] == _kind,
            _make_eva_jnp_planner(kind),
        )


_register_jnp_backends()
