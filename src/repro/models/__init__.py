from repro.models.api import Model, build_model, param_count, SHAPES
from repro.models.common import ModelConfig, RunConfig
