"""Engine observability: aggregate counters for the serving loop.

One ``EngineMetrics`` instance lives on each ``Engine``; the engine
increments it inline (submit / admit / prefill / decode / finish /
fault-recovery) and ``Engine.metrics()`` returns ``snapshot()`` — a
plain dict safe to log, JSON-serialize or emit as bench rows. The
invariants tests pin:

  tokens_generated == prefills + decode_slot_steps - poisoned_slot_steps
                      + extra_decode_tokens
                   == number of token-bearing StreamEvents

(``extra_decode_tokens`` is zero on non-speculative engines, so the
classic one-token-per-slot-step identity still holds there; on
speculative engines it counts the tokens emitted beyond the first in
each accepted draft window.)
  finished         == finished_stop + finished_length + errors + timeouts
  submitted        == admitted + rejected + still queued/running

The resilience counters (errors / timeouts / backend_fallbacks /
snapshots / restores / straggler_steps / poisoned_slot_steps) are pinned
consistent with emitted StreamEvents the same way the finish-reason
totals are: every "error"/"timeout" terminal event increments exactly
one counter here, every poisoned lane suppresses exactly one token
event."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict


@dataclasses.dataclass
class EngineMetrics:
    num_slots: int
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    finished: int = 0
    finished_stop: int = 0
    finished_length: int = 0
    errors: int = 0                  # numerics-quarantined requests
    timeouts: int = 0                # deadline_s / queue-TTL expiries
    prefills: int = 0
    prefill_prompt_tokens: int = 0
    prefill_chunks: int = 0          # chunked-prefill device calls (paged)
    preemptions: int = 0             # out-of-blocks decode evictions (paged)
    # KV memory gauges (paged engines update these on every block
    # alloc/free; contiguous engines set kv_bytes_in_use once at init)
    kv_bytes_in_use: int = 0
    blocks_in_use: int = 0
    blocks_free: int = 0
    peak_blocks_in_use: int = 0
    peak_kv_bytes_in_use: int = 0
    decode_steps: int = 0
    decode_slot_steps: int = 0       # active lanes summed over decode steps
    poisoned_slot_steps: int = 0     # lanes whose logits failed the finite check
    tokens_generated: int = 0
    # ---- speculative decoding (all zero when speculate_k == 0) ----
    drafted_tokens: int = 0          # K per speculating lane per decode step
    accepted_draft_tokens: int = 0   # drafts that matched the verify sample
    rejected_draft_tokens: int = 0   # drafted - accepted
    extra_decode_tokens: int = 0     # emissions beyond 1 per lane per step
    backend_fallbacks: int = 0       # planned-backend failures recovered by re-rank
    snapshots: int = 0
    restores: int = 0
    straggler_steps: int = 0         # watchdog-flagged slow decode steps
    queue_wait_s: float = 0.0        # summed over admitted requests
    prefill_s: float = 0.0           # summed wall time of prefill calls
    decode_s: float = 0.0            # summed wall time of batched decode steps
    started_at: float = dataclasses.field(default_factory=time.perf_counter)

    def count_finish(self, reason: str) -> None:
        self.finished += 1
        # a restore mid-flight annotates the reason but counts as its base
        base = reason.replace("-after-restore", "")
        if base == "stop":
            self.finished_stop += 1
        elif base == "length":
            self.finished_length += 1
        elif base == "error":
            self.errors += 1
        elif base == "timeout":
            self.timeouts += 1
        else:
            raise ValueError(f"not a finish reason for a served request: "
                             f"{reason!r}")

    @property
    def slot_occupancy(self) -> float:
        """Mean fraction of slots doing useful work per batched decode
        step — the paper's weight-tile amortization factor (§V-C)."""
        if self.decode_steps == 0:
            return 0.0
        return self.decode_slot_steps / (self.decode_steps * self.num_slots)

    @property
    def draft_acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verify pass accepted."""
        if self.drafted_tokens == 0:
            return 0.0
        return self.accepted_draft_tokens / self.drafted_tokens

    @property
    def decode_tokens_per_step(self) -> float:
        """Mean tokens emitted per active lane per decode step — 1.0
        without speculation, up to K+1 with it."""
        useful = self.decode_slot_steps - self.poisoned_slot_steps
        if useful <= 0:
            return 0.0
        return (useful + self.extra_decode_tokens) / useful

    @property
    def decode_tokens_per_s(self) -> float:
        if self.decode_s <= 0.0:
            return 0.0
        return self.decode_slot_steps / self.decode_s

    @property
    def tokens_per_s(self) -> float:
        dt = time.perf_counter() - self.started_at
        if dt <= 0.0:
            return 0.0
        return self.tokens_generated / dt

    def state(self) -> Dict[str, float]:
        """The restorable counter fields (everything but the wall
        clock), as used by Engine.snapshot()/restore()."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self) if f.name != "started_at"}

    def restore(self, state: Dict[str, float]) -> None:
        for name, value in state.items():
            setattr(self, name, value)

    def snapshot(self) -> Dict[str, float]:
        out = self.state()
        out["uptime_s"] = time.perf_counter() - self.started_at
        out["slot_occupancy"] = self.slot_occupancy
        out["draft_acceptance_rate"] = self.draft_acceptance_rate
        out["decode_tokens_per_step"] = self.decode_tokens_per_step
        out["decode_tokens_per_s"] = self.decode_tokens_per_s
        out["tokens_per_s"] = self.tokens_per_s
        return out

