"""Per-architecture smoke tests (required): reduced same-family config,
one forward + one train step on CPU, asserting output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import SHAPES, build_model
from repro.models.common import RunConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg):
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "whisper":
        batch["frames"] = jax.random.normal(KEY, (B, 16, cfg.d_model), jnp.float32)
    if cfg.family == "vision":
        batch["image_embeds"] = jax.random.normal(KEY, (B, 8, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    rc = RunConfig(mode="train", remat=False, attn_chunk=8)
    logits, _ = model.forward(params, _batch(cfg), rc)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


# the two slowest train-step archs (~40 s each on the CI host) are tier-2;
# every family keeps test_forward_shapes_and_finite as its fast smoke
_SLOW_TRAIN_ARCHS = ("recurrentgemma_2b", "deepseek_v2_lite_16b")


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_TRAIN_ARCHS else a
    for a in ARCH_IDS
])
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    rc = RunConfig(mode="train", remat=True, attn_chunk=8)
    ocfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, ocfg)
    batch = _batch(cfg)

    loss0, grads = jax.value_and_grad(lambda p: model.loss(p, batch, rc))(params)
    new_params, opt, gnorm = adamw_update(grads, opt, params, ocfg)
    loss1 = model.loss(new_params, batch, rc)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    assert float(gnorm) > 0
    # one step on the same batch should reduce loss
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact published dimensions."""
    cfg = get_config(arch)
    expected = {
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "qwen3_0_6b": (28, 1024, 16, 8, 3072, 151936),
        "llama3_8b": (32, 4096, 32, 8, 14336, 128256),
        "qwen2_72b": (80, 8192, 64, 8, 29568, 152064),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 10944, 102400),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "llama_3_2_vision_11b": (40, 4096, 32, 8, 14336, 128256),
        "llama2_7b": (32, 4096, 32, 32, 11008, 32000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (got, expected)


def test_moe_extras():
    ds = get_config("deepseek_v2_lite_16b")
    assert (ds.num_experts, ds.num_shared_experts, ds.top_k) == (64, 2, 6)
    assert ds.kv_lora_rank == 512 and ds.use_mla
    mx = get_config("mixtral_8x22b")
    assert (mx.num_experts, mx.top_k, mx.sliding_window) == (8, 2, 4096)


def test_input_specs_cover_assigned_shapes():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"] == (4096, 256, "train")
    assert SHAPES["prefill_32k"] == (32768, 32, "prefill")
    assert SHAPES["decode_32k"] == (32768, 128, "decode")
    assert SHAPES["long_500k"] == (524288, 1, "decode")
    m = build_model(get_config("llama3_8b"))
    kind, specs = m.input_specs("decode_32k")
    assert kind == "decode"
    assert specs["tokens"].shape == (128, 1)
    assert "caches" in specs


def test_long_500k_applicability():
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    runs = {a: build_model(get_config(a)).supports_shape("long_500k")
            for a in ARCH_IDS}
    assert runs["xlstm_125m"] and runs["recurrentgemma_2b"] and runs["mixtral_8x22b"]
    for a in ("minitron_4b", "qwen3_0_6b", "llama3_8b", "qwen2_72b",
              "whisper_medium", "deepseek_v2_lite_16b", "llama_3_2_vision_11b"):
        assert not runs[a], a


@pytest.mark.parametrize("arch", ["llama3_8b", "mixtral_8x22b", "xlstm_125m"])
def test_param_specs_no_allocation(arch):
    """Full-size param specs build instantly via eval_shape (no device mem)."""
    model = build_model(get_config(arch))
    specs = model.param_specs()
    total = sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(specs))
    assert total > 1e8  # full-size model described without allocating
    qspecs = model.param_specs(quantized=True)
    assert any(
        hasattr(x, "dtype") and x.dtype == jnp.uint8
        for x in jax.tree_util.tree_leaves(qspecs)
    )
