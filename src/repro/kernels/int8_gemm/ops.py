"""Jit'd wrapper: quantize activations/weights and run the int8 GEMM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.ops import quantize_int8
from repro.kernels.int8_gemm.kernel import int8_gemm_pallas
from repro.kernels.int8_gemm.ref import int8_gemm_ref


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "use_pallas", "out_dtype"),
)
def int8_matmul_kernel(
    x: jax.Array,   # (..., K) float
    w: jax.Array,   # (K, N) float
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
    use_pallas: bool = True,
    out_dtype=None,
) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    M = x.size // K
    xq, xs = quantize_int8(x.reshape(M, K), axis=-1)
    wq, ws = quantize_int8(w, axis=0)

    if not use_pallas:
        y = int8_gemm_ref(xq, wq, xs, ws)
        return y.reshape(*lead, N).astype(out_dtype)

    bm = min(block_m, max(8, M))
    bn = min(block_n, N)
    bk = min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        xq = jnp.pad(xq, ((0, pm), (0, pk)))
        xs = jnp.pad(xs, ((0, pm), (0, 0)))
    if pk or pn:
        wq = jnp.pad(wq, ((0, pk), (0, pn)))
        ws = jnp.pad(ws, ((0, 0), (0, pn)))
    y = int8_gemm_pallas(xq, wq, xs, ws, block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    y = y[:M, :N]
    return y.reshape(*lead, N).astype(out_dtype)
