"""Serving-layer fault tolerance: deterministic fault injection, numerics
quarantine, engine snapshot/restore and the serve restart controller.

The PR-5 engine had no failure story: a NaN-poisoned slot streamed
garbage to its client, one exception inside ``step()`` killed every
in-flight request, and a wedged request held its slot forever. This
module supplies the pieces the engine (serve/engine.py) wires together:

  FaultPlan / FaultSpec : a SEEDABLE, scripted fault schedule injected
        via ``EngineConfig.fault_plan``. Faults fire at named engine
        boundaries (BOUNDARIES) at a scripted tick, optionally targeted
        at one request uid — so every recovery path is exercised by
        deterministic tier-1 tests, not hope. A plan is stateful (each
        spec fires ``times`` polls, then never again); share ONE plan
        instance across engine restarts or the fault re-fires forever.
  InjectedFault         : the exception scripted raise-faults throw.
  CircuitBreaker        : >= k CONSECUTIVE poisoned decode steps trip
        the engine unhealthy — pending requests are rejected cleanly
        and new submits refuse, instead of streaming garbage at line
        rate while every request "finishes" with an error.
  EngineSnapshot        : host-side serialized engine state — scheduler
        queue, tracked requests, per-slot KV caches, PRNG keys and
        sampling state. The array state is PATH-FLATTENED through
        checkpoint/manager.py's format, so a snapshot can be persisted
        with CheckpointManager (save_snapshot / load_snapshot) and a
        restored engine resumes mid-stream token-identically.
  serve_with_restarts   : the serving generalization of
        runtime/fault_tolerance.run_with_restarts — drive an engine to
        idle, snapshotting between ticks; on a step() crash build a
        fresh engine, restore the last snapshot and resume.

Nothing here imports serve/engine.py — the engine imports this module;
``serve_with_restarts`` takes an engine factory and stays duck-typed.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

log = logging.getLogger(__name__)

# the named engine boundaries a FaultSpec can fire at:
#   prefill : raise InjectedFault in place of the admitted request's
#             prefill call (the request is back in the queue after a
#             snapshot restore)
#   decode  : raise InjectedFault before the batched decode step
#   sample  : raise InjectedFault AFTER the decode readback but before
#             host bookkeeping (the classic torn-state crash — only a
#             snapshot restore recovers it consistently)
#   poison  : add NaN/Inf ("mode") into the target slot's logits INSIDE
#             the jitted step — exercises the numerics quarantine
#   backend : simulate a planned backend failing at execute time —
#             exercises quarantine + re-ranked fallback in core/plan.py
BOUNDARIES = ("prefill", "decode", "sample", "poison", "backend")
POISON_MODES = ("nan", "inf")


class InjectedFault(RuntimeError):
    """A scripted fault fired by a FaultPlan at an engine boundary."""

    def __init__(self, boundary: str, tick: int, uid: Optional[int] = None):
        self.boundary = boundary
        self.tick = tick
        self.uid = uid
        at = f" uid={uid}" if uid is not None else ""
        super().__init__(f"injected {boundary} fault at tick {tick}{at}")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault.

    ``tick``  : first engine tick (0-based ``step()`` count) the spec is
                armed at — it fires on the first matching poll with
                ``tick >= spec.tick`` and keeps firing for ``times``
                polls (consecutive poisoned steps drive the breaker).
    ``uid``   : target request (None matches any request at a
                per-request boundary).
    ``mode``  : poison payload, "nan" | "inf" (poison boundary only).
    ``backend``: backend name to fail (backend boundary; None lets the
                engine pick its decode plan's chosen backend)."""

    boundary: str
    tick: int
    uid: Optional[int] = None
    mode: str = "nan"
    times: int = 1
    backend: Optional[str] = None

    def __post_init__(self):
        if self.boundary not in BOUNDARIES:
            raise ValueError(
                f"unknown fault boundary {self.boundary!r}; expected one of "
                f"{BOUNDARIES}")
        if self.mode not in POISON_MODES:
            raise ValueError(
                f"unknown poison mode {self.mode!r}; expected one of "
                f"{POISON_MODES}")
        if self.tick < 0 or self.times < 1:
            raise ValueError(
                f"tick must be >= 0 and times >= 1, got tick={self.tick} "
                f"times={self.times}")


class FaultPlan:
    """A deterministic, stateful schedule of FaultSpecs.

    The engine polls the plan at each boundary; a spec fires when the
    boundary matches, the engine tick has reached ``spec.tick``, its
    ``times`` budget is not exhausted, and the uid matches (a spec with
    ``uid=None`` matches any uid; a poll with ``uid=None`` matches any
    spec). Polls are deterministic in engine order, so a given request
    trace fires the same faults every run."""

    def __init__(self, faults: Iterable[FaultSpec] = ()):
        self.faults: Tuple[FaultSpec, ...] = tuple(faults)
        self._fired = [0] * len(self.faults)

    @classmethod
    def scripted(cls, *faults: FaultSpec) -> "FaultPlan":
        return cls(faults)

    @classmethod
    def seeded(cls, seed: int, *, boundaries: Sequence[str] = BOUNDARIES,
               n_faults: int = 3, max_tick: int = 8,
               uids: Sequence[int] = ()) -> "FaultPlan":
        """A pseudo-random scripted plan derived from ``seed`` — the same
        seed always yields the same spec list, so randomized fault tests
        stay reproducible (pin the seed, pin the failure)."""
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(n_faults):
            boundary = boundaries[int(rng.integers(len(boundaries)))]
            uid = (int(rng.choice(np.asarray(uids)))
                   if len(uids) and boundary in ("poison", "prefill") else None)
            specs.append(FaultSpec(
                boundary=boundary, tick=int(rng.integers(max_tick)),
                uid=uid, mode=POISON_MODES[int(rng.integers(2))]))
        return cls(specs)

    def poll(self, boundary: str, tick: int,
             uid: Optional[int] = None) -> Optional[FaultSpec]:
        """Fire-and-consume the first matching spec (None when nothing
        matches). Each successful poll consumes one of the spec's
        ``times``."""
        for i, spec in enumerate(self.faults):
            if spec.boundary != boundary or tick < spec.tick:
                continue
            if self._fired[i] >= spec.times:
                continue
            if spec.uid is not None and uid is not None and spec.uid != uid:
                continue
            self._fired[i] += 1
            log.warning("fault plan fired: %s (tick=%d uid=%s, %d/%d)",
                        spec.boundary, tick, uid, self._fired[i], spec.times)
            return spec
        return None

    @property
    def exhausted(self) -> bool:
        return all(f >= s.times for f, s in zip(self._fired, self.faults))


# ---------------------------------------------------------------------------
# Numerics circuit breaker
# ---------------------------------------------------------------------------


class CircuitBreaker:
    """Trips after ``k`` CONSECUTIVE poisoned engine steps.

    One poisoned slot is a per-request event (quarantined with
    ``finish_reason="error"``); k poisoned steps in a row mean the model
    or hardware is emitting garbage at line rate — the engine marks
    itself unhealthy, rejects pending requests and refuses new submits
    instead of erroring every request one slot at a time."""

    def __init__(self, k: int = 3):
        if k < 1:
            raise ValueError(f"breaker threshold k must be >= 1, got {k}")
        self.k = k
        self.consecutive = 0
        self.tripped = False

    def record(self, poisoned: bool) -> bool:
        """Record one engine step; returns the (possibly new) tripped
        state. A clean step resets the consecutive count."""
        if not self.tripped:
            self.consecutive = self.consecutive + 1 if poisoned else 0
            if self.consecutive >= self.k:
                self.tripped = True
                log.error("circuit breaker tripped: %d consecutive poisoned "
                          "steps", self.consecutive)
        return self.tripped

    def state(self) -> Tuple[int, int, bool]:
        return (self.k, self.consecutive, self.tripped)

    def restore(self, state: Tuple[int, int, bool]) -> None:
        self.k, self.consecutive, self.tripped = state


# ---------------------------------------------------------------------------
# Engine snapshot
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineSnapshot:
    """Host-side serialized engine state (see Engine.snapshot()).

    ``arrays`` is the PATH-FLATTENED array state (checkpoint/manager.py
    format): every per-slot KV-cache leaf, the PRNG keys and the
    per-slot sampling/stopping state live under ``/caches/...`` and
    ``/slots/...`` paths mapping to host numpy arrays. The request
    bookkeeping (scheduler queue + tracked requests, finished outputs,
    undrained event buffers) is deep-copied Python — a snapshot never
    aliases live engine state, so mutating the engine after
    ``snapshot()`` cannot corrupt it."""

    tick: int
    arrays: Dict[str, np.ndarray]
    uid_counter: int
    queue: List[Any]                  # TrackedRequest clones, FIFO order
    slots: List[Optional[Any]]        # TrackedRequest clones by slot index
    outputs: Dict[int, Any]           # uid -> RequestOutput (frozen)
    buffers: Dict[int, List[Any]]     # uid -> undrained StreamEvents
    pending: List[Any]
    retired: List[int]
    metrics: Dict[str, Any]
    breaker: Tuple[int, int, bool]
    num_slots: int
    max_len: int
    # ---- paged-KV state (serve/paging.py; defaults keep old snapshots
    # loadable by contiguous engines) ----
    paged: bool = False
    block_size: int = 0
    num_blocks: int = 0
    block_tables: Optional[np.ndarray] = None      # (num_slots, W) host copy
    pool_free: Optional[Tuple[int, ...]] = None    # BlockPool free list
    # per-slot owned block ids, allocation order (tuple of tuples)
    owned: Optional[Tuple[Tuple[int, ...], ...]] = None

    def checkpoint_state(self) -> Dict[str, Any]:
        """The array state as a CheckpointManager ``state`` group dict
        (save under one group; the Python bookkeeping is process-local
        and intentionally NOT persisted — cross-process replica failover
        is the ROADMAP item-2 seam this snapshot feeds)."""
        return {"engine_arrays": dict(self.arrays)}


def save_snapshot(snapshot: EngineSnapshot, manager: Any, step: int) -> None:
    """Persist the snapshot's array state through a CheckpointManager
    (checkpoint/manager.py) — same path-flattened npz format training
    checkpoints use."""
    manager.save(step, snapshot.checkpoint_state(), block=True)


def load_snapshot_arrays(manager: Any,
                         step: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Load a persisted snapshot's flat array state back from disk.

    ``manager.restore`` re-nests the saved tree (our "/caches/..." keys
    become path segments), so the group is re-flattened through the same
    path format to recover the EngineSnapshot.arrays keys exactly."""
    from repro.checkpoint import manager as ckpt_manager

    _, state = manager.restore(step)
    flat = ckpt_manager.flatten_with_paths(state["engine_arrays"])
    return {path: np.asarray(leaf) for path, leaf in flat}


# ---------------------------------------------------------------------------
# Serve restart controller
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeRestartStats:
    """What the restart controller did (mirrors runtime RestartStats)."""

    restarts: int = 0
    snapshots: int = 0
    resumed_tick: int = 0
    failures: List[str] = dataclasses.field(default_factory=list)


def serve_with_restarts(
    engine_factory: Callable[[], Any],
    requests: Sequence[Any],
    *,
    max_restarts: int = 3,
    snapshot_every: int = 1,
) -> Tuple[Any, Dict[int, Any], ServeRestartStats]:
    """Serve ``requests`` to completion under checkpoint-restart.

    The serving generalization of ``runtime.fault_tolerance.
    run_with_restarts``: build an engine, submit everything, then step to
    idle taking a host snapshot every ``snapshot_every`` ticks. When
    ``step()`` raises, a FRESH engine from ``engine_factory`` restores
    the last snapshot and resumes — token-identically, because the
    snapshot carries every per-slot PRNG key, KV cache and sampling
    state. Events of the crashed tick were never delivered, and restored
    ticks replay from un-delivered buffered state, so with
    ``snapshot_every=1`` no event is delivered twice.

    The factory must rebuild a compatible engine (same model/params/
    EngineConfig); pass the SAME FaultPlan instance through, or a
    scripted one-shot fault re-arms on every restart and the controller
    crash-loops to ``max_restarts``.

    Returns ``(engine, {uid: RequestOutput}, stats)``."""
    if snapshot_every < 1:
        raise ValueError(f"snapshot_every must be >= 1, got {snapshot_every}")
    stats = ServeRestartStats()
    eng = engine_factory()
    uids = [eng.submit(r) for r in requests]
    snap = eng.snapshot()
    stats.snapshots += 1
    since_snapshot = 0
    while not eng.idle:
        try:
            eng.step()
        except Exception as e:  # noqa: BLE001 - controller catches anything
            stats.restarts += 1
            stats.failures.append(f"{type(e).__name__}: {e}")
            if stats.restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} serve restarts; last: {e}"
                ) from e
            log.warning("engine step crashed (%s); restoring tick-%d "
                        "snapshot (restart %d/%d)", e, snap.tick,
                        stats.restarts, max_restarts)
            eng = engine_factory()
            eng.restore(snap)
            stats.resumed_tick = snap.tick
            since_snapshot = 0
            continue
        since_snapshot += 1
        if since_snapshot >= snapshot_every:
            snap = eng.snapshot()
            stats.snapshots += 1
            since_snapshot = 0
    outputs = {uid: eng.output(uid) for uid in uids}
    return eng, outputs, stats
